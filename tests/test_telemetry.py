"""runtime.telemetry / runtime.obs: deterministic-clock lifecycle tracing,
histogram percentiles, exporter structure, and the metrics/trace
consistency soak.

The soak drives the PR-10 acceptance schedule — a pool sized so three
concurrent mixed-depth requests MUST preempt, with the ngram drafter on —
through a Server carrying a fake monotonic clock, then asserts the trace
invariants the ISSUE pins:

  * every `admit` is closed by a `retire` or continued by a
    `preempt` → `resume` chain (per rid, in order);
  * TTFT (first_token time) >= the request's first prefill_chunk time;
  * Σ accept_hist counts == spec_steps, in ServerMetrics AND in the
    telemetry accept-length histogram;
  * ServerMetrics.to_dict() exposes the shared/private/cached-cold pool
    split + trie entry count, and the split sums to the pool size;
  * the Chrome trace validates against runtime.obs.validate_chrome_trace
    and the Prometheus snapshot carries the expected metric families.

Runs identically under both REPRO_FORCE_JNP legs (attn="exact" is pinned,
so the compiled math is leg-independent).
"""
import itertools
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import registry
from repro.runtime import obs
from repro.runtime.server import Request, Server, ServingConfig
from repro.runtime.telemetry import (ACCEPT_BUCKETS, Histogram, Telemetry)


class FakeClock:
    """Deterministic monotonic clock: each call advances by `tick`."""

    def __init__(self, tick: float = 0.125):
        self.t = 0.0
        self.tick = tick

    def __call__(self) -> float:
        self.t += self.tick
        return self.t


# ---------------------------------------------------------------------------
# histogram unit tests


def test_histogram_percentiles_interpolate():
    h = Histogram((1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 1.5, 3.0, 6.0, 9.0):
        h.record(v)
    s = h.summary()
    assert s["count"] == 6
    assert s["sum"] == pytest.approx(21.5)
    assert s["min"] == 0.5 and s["max"] == 9.0
    # percentiles stay within the observed range and are monotone
    ps = [h.percentile(p) for p in (1, 25, 50, 75, 90, 99)]
    assert all(0.5 <= v <= 9.0 for v in ps)
    assert ps == sorted(ps)


def test_histogram_single_sample_reports_itself():
    h = Histogram((1.0, 10.0))
    h.record(3.0)
    assert h.percentile(50) == pytest.approx(3.0)
    assert h.percentile(99) == pytest.approx(3.0)


def test_histogram_empty_and_bad_bounds():
    assert Histogram((1.0,)).percentile(50) == 0.0
    with pytest.raises(ValueError):
        Histogram((2.0, 1.0))


def test_decode_step_batches_lanes():
    """decode_step: per-lane ITL samples + counters, ONE ring event."""
    clock = FakeClock()
    tel = Telemetry(clock=clock)
    t1 = tel.now()
    tel.first_token(7, 0, t1, 0.0)
    tel.first_token(9, 1, t1, 0.0)
    t2 = tel.now()
    tel.decode_step([(7, 0), (9, 1)], t2)
    assert tel.counters["decode"] == 2
    assert tel.itl.n == 2                       # one ITL sample per lane
    assert tel.itl.vmin == pytest.approx(t2 - t1)
    ev = [e for e in tel.events if e.kind == "decode"]
    assert len(ev) == 1                         # batched into one event
    assert ev[0].data["lanes"] == [(7, 0), (9, 1)]
    assert (ev[0].rid, ev[0].slot) == (7, 0)
    tel.decode_step([], tel.now())              # no-op, no empty event
    assert len([e for e in tel.events if e.kind == "decode"]) == 1
    # the chrome exporter expands the batch back to one instant per lane
    doc = obs.chrome_trace(tel)
    inst = [x for x in doc["traceEvents"]
            if x.get("name") == "decode" and x["ph"] == "i"]
    assert len(inst) == 2
    assert {x["tid"] for x in inst} == {1, 2}   # slot tracks 0+1, 1+1
    assert obs.validate_chrome_trace(doc) == []


def test_telemetry_disabled_records_nothing():
    clock = FakeClock()
    tel = Telemetry(enabled=False, clock=clock)
    tel.submit(0, tel.now(), 4, 1)
    tel.first_token(0, 0, tel.now(), 0.0)
    tel.emission(0, 0, tel.now())
    assert not tel.events and tel.ttft.n == 0 and tel.itl.n == 0
    # the clock still serves (the Server's wall timing shares it)
    assert tel.now() > 0


# ---------------------------------------------------------------------------
# the consistency soak


@pytest.fixture(scope="module")
def soak():
    """Mixed-depth preemption + spec-decode drain with a fake clock.

    Pool math: block_size=4, max_len=32 → 8 blocks/slot worst case; 10
    usable blocks with 3 slots and ~15-block worst-case demand forces
    newest-victim preemption while the ngram drafter runs verify steps."""
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    serving = ServingConfig(n_slots=3, max_len=32, paged=True, block_size=4,
                            num_blocks=10, prefill_chunk=4, attn="exact",
                            drafter="ngram", spec_k=2)
    clock = FakeClock()
    srv = Server(params, cfg, serving, telemetry=Telemetry(clock=clock))
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=6 + i).tolist(),
                    max_new_tokens=8) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    return srv, reqs


def test_soak_preempts_and_speculates(soak):
    srv, _ = soak
    assert srv.metrics.preemptions >= 1, "schedule must exercise preemption"
    assert srv.metrics.spec_steps >= 1, "schedule must exercise spec decode"


def test_soak_admit_chains(soak):
    """Every admit is closed by retire or continued by preempt→resume."""
    srv, reqs = soak
    by_rid: dict[int, list] = {}
    for e in srv.telemetry.events:
        if e.kind in ("admit", "resume", "preempt", "retire"):
            by_rid.setdefault(e.rid, []).append(e.kind)
    assert set(by_rid) == {r.rid for r in reqs}
    for rid, kinds in by_rid.items():
        assert kinds[0] == "admit" and kinds[-1] == "retire", (rid, kinds)
        # interior transitions: admit/resume opens, preempt closes+reopens
        open_ = False
        for k in kinds:
            if k in ("admit", "resume"):
                assert not open_, (rid, kinds)
                open_ = True
            elif k == "preempt":
                assert open_, (rid, kinds)
                open_ = False
            else:   # retire
                assert open_, (rid, kinds)
                open_ = False
        assert not open_, (rid, kinds)


def test_soak_resume_follows_preempt(soak):
    srv, _ = soak
    c = srv.telemetry.counters
    assert c["preempt"] == srv.metrics.preemptions
    # every preempted request came back (the drain completed), and a
    # resume only ever follows a preempt
    assert c["resume"] == c["preempt"]


def test_soak_ttft_after_first_chunk(soak):
    """first_token time >= the rid's first prefill_chunk time."""
    srv, _ = soak
    first_chunk: dict[int, float] = {}
    for e in srv.telemetry.events:
        if e.kind == "prefill_chunk" and e.rid not in first_chunk:
            first_chunk[e.rid] = e.t
        if e.kind == "first_token":
            assert e.rid in first_chunk, "first_token before any chunk"
            assert e.t >= first_chunk[e.rid]
            assert e.data["ttft_s"] > 0


def test_soak_accept_hist_totals(soak):
    """Σ accept_hist == spec_steps — metrics bag and telemetry agree."""
    srv, _ = soak
    m = srv.metrics.summary()
    assert sum(m["accept_hist"].values()) == m["spec_steps"]
    assert srv.telemetry.accept_len.n == m["spec_steps"]
    assert srv.telemetry.counters["spec_verify"] == m["spec_steps"]
    # accepted-draft totals agree too (hist is over accepted counts)
    assert sum(a * n for a, n in m["accept_hist"].items()) \
        == m["draft_accepted"]


def test_soak_pool_split_in_to_dict(soak):
    srv, _ = soak
    d = srv.metrics.to_dict()
    for key in ("blocks_total", "blocks_free", "blocks_shared",
                "blocks_cached_cold", "blocks_private", "trie_entries"):
        assert key in d, key
    assert (d["blocks_free"] + d["blocks_shared"] + d["blocks_cached_cold"]
            + d["blocks_private"]) == d["blocks_total"]
    # drained server: nothing live, so in-use blocks are all cold cache
    assert d["blocks_private"] == 0 and d["blocks_shared"] == 0
    assert d["trie_entries"] == d["blocks_cached_cold"]


def test_soak_step_snapshots(soak):
    srv, _ = soak
    snaps = list(srv.telemetry.snapshots)
    assert len(snaps) == srv.metrics.steps
    assert all(s.wall_s > 0 for s in snaps)
    assert any(s.all_logits and s.c == srv.spec_k + 1 for s in snaps), \
        "spec verify steps must stamp the C=k+1 all-logits shape"
    assert any(s.prefill_lanes for s in snaps)
    for s in snaps:
        assert s.budget_used > 0
        # the token budget gates prefill scheduling; spec-verify steps
        # legitimately exceed it (each spec lane runs k+1 positions)
        if not s.all_logits:
            assert s.budget_used <= s.token_budget
        assert (s.blocks_free + s.blocks_shared + s.blocks_cached_cold
                + s.blocks_private) == 10
    # snapshot times strictly increase with the fake clock
    ts = [s.t for s in snaps]
    assert ts == sorted(ts)


def test_soak_chrome_trace_valid(soak, tmp_path):
    srv, _ = soak
    doc = obs.chrome_trace(srv.telemetry)
    assert obs.validate_chrome_trace(doc) == []
    names = {ev.get("name") for ev in doc["traceEvents"]}
    assert "scheduler" in json.dumps(doc)   # scheduler track named
    assert any(n and n.startswith("req") for n in names)
    assert any(n and n.startswith("step") for n in names)
    # round-trips through the CLI validator
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(doc))
    assert obs.main([str(p)]) == 0
    # and the validator actually rejects structural damage
    doc["traceEvents"].append({"ph": "Q", "ts": 0})
    assert obs.validate_chrome_trace(doc)


def test_soak_prometheus_snapshot(soak):
    srv, _ = soak
    text = obs.prometheus_text(srv.telemetry, srv)
    for needle in ("picoram_ttft_seconds_bucket{le=",
                   "picoram_ttft_seconds_count",
                   "picoram_itl_seconds_sum",
                   "picoram_accept_length_bucket",
                   "picoram_step_wall_seconds_count",
                   'picoram_events_total{kind="admit"}',
                   'picoram_attn_dispatch_total{backend="exact"}',
                   'picoram_kv_blocks{state="cached_cold"}',
                   "picoram_trie_entries"):
        assert needle in text, needle
    # cumulative histogram buckets are monotone
    cum = [int(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
           if ln.startswith("picoram_ttft_seconds_bucket")]
    assert cum == sorted(cum)


def test_soak_events_jsonl(soak, tmp_path):
    srv, _ = soak
    p = tmp_path / "events.jsonl"
    n = obs.write_events_jsonl(srv.telemetry, str(p))
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert len(lines) == n
    kinds = {ln["kind"] for ln in lines}
    assert {"submit", "admit", "retire", "step_snapshot"} <= kinds


def test_telemetry_off_serves_identically():
    """ServingConfig(telemetry=False) changes nothing but observability."""
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    outs = []
    for on in (True, False):
        srv = Server(params, cfg, ServingConfig(
            n_slots=2, max_len=32, paged=True, block_size=4,
            prefill_chunk=4, attn="exact", telemetry=on))
        rng = np.random.RandomState(1)
        reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=5).tolist(),
                        max_new_tokens=6) for _ in range(3)]
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        outs.append([r.output for r in reqs])
        if on:
            assert srv.telemetry.events
        else:
            assert not srv.telemetry.events and srv.telemetry.ttft.n == 0
            # the pool split still lands on the metrics bag
            assert "blocks_free" in srv.metrics.to_dict()
    assert outs[0] == outs[1]


def test_legacy_engine_emits_lifecycle():
    """The slot engine traces admit/first_token/decode/retire too."""
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    srv = Server(params, cfg, ServingConfig(n_slots=2, max_len=32),
                 telemetry=Telemetry(clock=FakeClock()))
    srv.submit(Request(prompt=[3, 1, 4, 1, 5], max_new_tokens=4))
    srv.run_until_drained()
    c = srv.telemetry.counters
    assert c["submit"] == c["admit"] == c["first_token"] == c["retire"] == 1
    assert srv.telemetry.ttft.n == 1
    assert obs.validate_chrome_trace(
        obs.chrome_trace(srv.telemetry)) == []


def test_kernel_counters_site_energy():
    """execute_mvm's trace-time hook accumulates per-site CIM energy
    keyed by the PR-9 site names and counts the backend pick."""
    import jax.numpy as jnp
    from repro.core.cim_matmul import CIMConfig, cim_matmul
    from repro.core.quant import act_site
    from repro.runtime.telemetry import KERNEL_COUNTERS

    KERNEL_COUNTERS.reset()
    cim = CIMConfig(enabled=True)
    x = jnp.linspace(0.0, 1.0, 2 * 16).reshape(2, 16)
    w = jnp.linspace(-1.0, 1.0, 16 * 8).reshape(16, 8)
    with act_site("wq"):
        cim_matmul(x, w, cim)
    snap = KERNEL_COUNTERS.snapshot()
    assert "wq" in snap["site_energy"]
    rec = snap["site_energy"]["wq"]
    assert rec["calls"] >= 1 and rec["dots"] >= 2 * 8
    assert rec["energy_j"] > 0
    assert sum(snap["backend_dispatch"].values()) >= 1
    KERNEL_COUNTERS.reset()
    assert not KERNEL_COUNTERS.snapshot()["site_energy"]


def test_accept_buckets_cover_spec_k():
    # integer accept counts land exactly on bucket edges 0..8
    assert ACCEPT_BUCKETS[0] == 0.0 and ACCEPT_BUCKETS[-1] >= 8.0
    assert list(itertools.islice(iter(ACCEPT_BUCKETS), 3)) == [0.0, 1.0, 2.0]
