"""Speculative decoding + per-request sampling (runtime.speculative).

Three contracts pinned here:

* **greedy parity** — spec decode with any drafter emits BIT-identical
  token streams to plain decode (verification is an argmax prefix match;
  the drafter only affects speed). Soaked on mixed-depth schedules over
  both attention backends.
* **seeded sampling** — temperature>0 draws come from a counter-based
  PRNG keyed by (request seed, emission index): streams are
  bit-reproducible run-to-run and INVARIANT to batch composition, and
  `verify_token`'s rejection rule is distribution-exact (Monte-Carlo
  check against the explicit softmax).
* **the config surface** — SamplingParams validation, the drafter
  registry's parse errors, and the trie high/low-watermark sweep.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig
from repro.runtime import speculative as spec
from repro.runtime.speculative import (NGramDrafter, SamplingParams,
                                       make_drafter, parse_drafter,
                                       sample_token, verify_token)

MAX_LEN = 64

_FORCED = os.environ.get("REPRO_FORCE_JNP", "").strip().lower() in (
    "1", "true", "yes")
needs_pallas = pytest.mark.skipif(
    _FORCED, reason="explicit Pallas attention backend; REPRO_FORCE_JNP "
                    "leg is jnp-only")


@pytest.fixture(scope="module")
def setup():
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg,
                                  max_seq=MAX_LEN)
    return cfg, params


def _mk(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("attn", "exact")
    return Server(params, cfg, ServingConfig(paged=True, **kw))


def _drain(srv, reqs):
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    return [list(r.output) for r in reqs]


def _mixed_requests(cfg, sampling=None):
    """Mixed-depth schedule: prompt lengths 3..19, max_new 1..9 — enough
    length spread that lanes retire and re-admit at different steps, plus
    a max_new=1 request (spec k clamps to 0 → plain lane)."""
    rng = np.random.RandomState(31)
    reqs = []
    for i in range(5):
        p = rng.randint(0, cfg.vocab, size=int(rng.randint(3, 20))).tolist()
        kw = {} if sampling is None else {
            "sampling": SamplingParams(**{**sampling, "seed": 100 + i})}
        reqs.append(Request(prompt=p, max_new_tokens=1 + 2 * i, **kw))
    return reqs


# ---------------------------------------------------------------------------
# SamplingParams + registry validation
# ---------------------------------------------------------------------------
def test_sampling_params_validation():
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy
    for bad in (dict(temperature=-0.1), dict(temperature=float("nan")),
                dict(temperature=float("inf")), dict(top_k=-1),
                dict(top_k=2.5), dict(seed=-1), dict(seed=1.5)):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


def test_submit_rejects_non_sampling_params(setup):
    cfg, params = setup
    srv = _mk(cfg, params)
    with pytest.raises(ValueError):
        srv.submit(Request(prompt=[1, 2], max_new_tokens=2,
                           sampling={"temperature": 1.0}))


def test_drafter_registry_parse():
    assert parse_drafter("off") == ("off", None)
    assert parse_drafter("ngram") == ("ngram", None)
    assert parse_drafter("model:internlm2-1.8b") == \
        ("model", "internlm2-1.8b")
    for bad in ("", "nope", "ngram:arg", "model", "model:",
                "model:not-a-smoke"):
        with pytest.raises(ValueError):
            parse_drafter(bad)
    with pytest.raises(ValueError, match="registered"):
        spec.get_drafter("nope")


def test_make_drafter(setup):
    cfg, _ = setup
    assert make_drafter("off", cfg, MAX_LEN) is None
    assert isinstance(make_drafter("ngram", cfg, MAX_LEN), NGramDrafter)
    # vocab compatibility is checked at construction, not mid-serve
    with pytest.raises(ValueError, match="vocab"):
        make_drafter("model:internlm2-1.8b", cfg.replace(vocab=cfg.vocab + 1),
                     MAX_LEN)


# ---------------------------------------------------------------------------
# sampling primitives
# ---------------------------------------------------------------------------
def test_sample_token_deterministic_per_seed_and_index():
    rng = np.random.RandomState(3)
    logits = rng.randn(32).astype(np.float32)
    sp = SamplingParams(temperature=0.8, seed=5)
    toks = [sample_token(logits, sp, i) for i in range(20)]
    assert toks == [sample_token(logits, sp, i) for i in range(20)]
    # a different seed decorrelates the stream; greedy ignores the seed
    sp2 = SamplingParams(temperature=0.8, seed=6)
    assert toks != [sample_token(logits, sp2, i) for i in range(20)]
    g = SamplingParams()
    assert all(sample_token(logits, g, i) == int(np.argmax(logits))
               for i in range(5))


def test_top_k_restricts_support():
    logits = np.arange(16, dtype=np.float32)
    sp = SamplingParams(temperature=2.0, top_k=3, seed=0)
    allowed = {13, 14, 15}
    assert all(sample_token(logits, sp, i) in allowed for i in range(200))
    p = spec._probs(logits, sp)
    assert p[:13].sum() == 0.0 and p.sum() == pytest.approx(1.0)


def test_verify_token_greedy_is_argmax_match():
    logits = np.array([0.0, 3.0, 1.0], np.float32)
    sp = SamplingParams()
    assert verify_token(logits, 1, sp, 0) == (1, True)
    assert verify_token(logits, 2, sp, 0) == (1, False)


def test_rejection_sampling_is_distribution_exact():
    """Monte-Carlo over emission indices: the (accept | resample) marginal
    of verify_token equals the softmax, for a GOOD draft (the mode) and a
    BAD draft (an unlikely token) — and equals sample_token's marginal."""
    rng = np.random.RandomState(11)
    logits = rng.randn(8).astype(np.float32)
    sp = SamplingParams(temperature=1.0, seed=9)
    p = spec._probs(logits, sp)
    n = 8000
    plain = np.bincount([sample_token(logits, sp, i) for i in range(n)],
                        minlength=8) / n
    for draft in (int(np.argmax(p)), int(np.argmin(p))):
        freq = np.bincount(
            [verify_token(logits, draft, sp, i)[0] for i in range(n)],
            minlength=8) / n
        assert np.abs(freq - p).max() < 0.025, (draft, freq, p)
    assert np.abs(plain - p).max() < 0.025


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------
def test_ngram_drafter_predicts_cycles():
    d = NGramDrafter()
    assert d.propose([1, 2, 3, 1, 2, 3, 1, 2], 4) == [3, 1, 2, 3]
    assert d.propose([5], 3) == [5, 5, 5]          # no history → repeat
    assert len(d.propose([7, 8, 9, 7], 6)) == 6


def test_model_drafter_proposes_in_vocab(setup):
    cfg, _ = setup
    d = make_drafter("model:internlm2-1.8b", cfg, MAX_LEN)
    out = d.propose([3, 1, 4, 1, 5], 4)
    assert len(out) == 4
    assert all(isinstance(t, int) and 0 <= t < cfg.vocab for t in out)
    # deterministic in the lane's history (composition invariance)
    assert out == d.propose([3, 1, 4, 1, 5], 4)


# ---------------------------------------------------------------------------
# greedy parity soaks: spec decode ≡ plain decode, bit-identical
# ---------------------------------------------------------------------------
def test_spec_decode_greedy_bit_identical_exact(setup):
    cfg, params = setup
    plain = _drain(_mk(cfg, params), _mixed_requests(cfg))
    for k in (1, 3):
        srv = _mk(cfg, params, drafter="ngram", spec_k=k)
        assert _drain(srv, _mixed_requests(cfg)) == plain, f"spec_k={k}"
        m = srv.metrics.summary()
        assert m["spec_steps"] > 0
        assert m["mean_accept_len"] >= 1.0
        assert sum(m["accept_hist"].values()) == m["spec_steps"]


def test_spec_decode_model_drafter_bit_identical(setup):
    """A DIFFERENT model drafting (random weights, seed 17) still yields
    the target's exact greedy stream — the drafter can only change speed,
    never tokens."""
    cfg, params = setup
    plain = _drain(_mk(cfg, params), _mixed_requests(cfg))
    srv = _mk(cfg, params, drafter="model:internlm2-1.8b", spec_k=2)
    assert _drain(srv, _mixed_requests(cfg)) == plain


@needs_pallas
def test_spec_decode_greedy_bit_identical_kernel(setup):
    cfg, params = setup
    plain = _drain(_mk(cfg, params, attn="kernel"), _mixed_requests(cfg))
    srv = _mk(cfg, params, attn="kernel", drafter="ngram", spec_k=3)
    assert _drain(srv, _mixed_requests(cfg)) == plain


# ---------------------------------------------------------------------------
# seeded sampling on the engine: reproducible + composition-invariant
# ---------------------------------------------------------------------------
def test_sampled_decode_reproducible_and_composition_invariant(setup):
    cfg, params = setup
    tmp = dict(temperature=0.7, top_k=8)
    a = _drain(_mk(cfg, params), _mixed_requests(cfg, tmp))
    b = _drain(_mk(cfg, params), _mixed_requests(cfg, tmp))
    assert a == b                      # bit-reproducible run to run
    # the probe request decoded ALONE emits the same stream it emitted
    # inside the mixed batch: draws are keyed by (seed, emission index),
    # never by batch composition or scheduling
    probe = _mixed_requests(cfg, tmp)[3]
    alone = _drain(_mk(cfg, params), [probe])
    assert alone == [a[3]]


def test_spec_sampled_decode_reproducible(setup):
    """temperature>0 + drafter: not bit-identical to plain decode (the
    rejection path draws differently) but bit-reproducible and
    composition-invariant — the distribution-exactness itself is pinned
    by the Monte-Carlo primitive test."""
    cfg, params = setup
    tmp = dict(temperature=0.7, top_k=8)
    a = _drain(_mk(cfg, params, drafter="ngram", spec_k=3),
               _mixed_requests(cfg, tmp))
    b = _drain(_mk(cfg, params, drafter="ngram", spec_k=3),
               _mixed_requests(cfg, tmp))
    assert a == b
    probe = _mixed_requests(cfg, tmp)[4]
    alone = _drain(_mk(cfg, params, drafter="ngram", spec_k=3), [probe])
    assert alone == [a[4]]


def test_fork_clones_get_distinct_seeds(setup):
    cfg, params = setup
    srv = _mk(cfg, params, n_slots=3)
    req = Request(prompt=[2, 7, 1, 8, 2, 8], max_new_tokens=4, n_samples=3,
                  sampling=SamplingParams(temperature=1.0, seed=40))
    srv.submit(req)
    seeds = {req.sampling.seed} | {c.sampling.seed for c in req.samples}
    assert seeds == {40, 41, 42}


# ---------------------------------------------------------------------------
# trie capacity sweep
# ---------------------------------------------------------------------------
def test_trie_sweep_unit():
    from repro.runtime.paging import BlockAllocator, PrefixTrie
    alloc = BlockAllocator(num_blocks=8)
    trie = PrefixTrie(block_size=4)
    with pytest.raises(ValueError):
        trie.sweep(alloc, high=1, low=2)
    toks = list(range(16))
    blocks = alloc.acquire(4)
    trie.insert(toks, blocks, alloc)
    alloc.decref(blocks)               # trie is now the sole holder
    assert trie.sweep(alloc, high=4, low=2) == 0   # at/below high: no-op
    assert trie.sweep(alloc, high=3, low=1) == 3   # over high: down to low
    assert trie.cached_blocks == 1 and trie.sweeps == 1


def test_server_trie_watermark_sweeps_cold_prefixes(setup):
    """With trie_watermark set, step() drains cold cached prefixes back to
    the pool even with no admission pressure — a long-lived server's trie
    can't pin the pool as cache."""
    cfg, params = setup
    srv = _mk(cfg, params, num_blocks=16, trie_watermark=0.25)
    hi = srv._trie_hi
    assert hi == 4 and srv._trie_lo == 2
    rng = np.random.RandomState(7)
    for _ in range(3):                 # 3 disjoint 16-token prompts →
        p = rng.randint(0, cfg.vocab, size=16).tolist()   # 6 cached blocks
        srv.submit(Request(prompt=p, max_new_tokens=2))
        srv.run_until_drained()
    assert srv.metrics.trie_sweep_freed > 0
    assert srv.trie.cached_blocks <= hi
