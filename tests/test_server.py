"""Slot-based serving loop: drains, respects slots, matches single-request
greedy decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig


@pytest.fixture(scope="module")
def setup():
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    mod = registry.get_module(cfg)
    logits, cache = mod.prefill(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)}, cfg, max_len=64)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, cache = mod.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache, cfg)
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_single_request_matches_reference(setup):
    cfg, params = setup
    server = Server(params, cfg, ServingConfig(n_slots=1, max_len=64))
    req = Request(prompt=[5, 9, 2, 7], max_new_tokens=6)
    server.submit(req)
    server.run_until_drained()
    assert req.done
    ref = _greedy_reference(cfg, params, req.prompt, 6)
    assert req.output == ref


def test_multi_request_batching_drains(setup):
    cfg, params = setup
    server = Server(params, cfg, ServingConfig(n_slots=2, max_len=64))
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=int(rng.randint(3, 9))).tolist(),
                    max_new_tokens=4) for _ in range(5)]
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 4 for r in reqs)


def test_eos_retires_slot(setup):
    cfg, params = setup
    server = Server(params, cfg, ServingConfig(n_slots=1, max_len=64))
    ref = _greedy_reference(cfg, params, [1, 2, 3], 8)
    eos = ref[2]  # force an early stop at the 3rd generated token
    req = Request(prompt=[1, 2, 3], max_new_tokens=8, eos_id=eos)
    server.submit(req)
    server.run_until_drained()
    assert req.done and len(req.output) == 3


def test_prequant_packed_serving_matches_unpacked():
    """End-to-end packed-int4 serving: the server's nibble-packed stored-code
    params produce EXACTLY the int8-container path's tokens (packing is a
    lossless re-layout), and the decode params really are 4-bit-packed."""
    from repro.core.cim_matmul import CIMConfig
    from repro.models.quantize import quantize_params

    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32",
                                           cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    outs = {}
    for packed in (True, False):
        server = Server(params, cfg, ServingConfig(
            n_slots=1, max_len=64, prequant=True, packed=packed))
        if packed:
            q = [v for k, v in jax.tree_util.tree_flatten_with_path(
                     server.params)[0]
                 if str(k[-1]).find("_q") >= 0]
            assert q and all(a.dtype == jnp.uint8 for a in q)
        req = Request(prompt=[5, 9, 2, 7], max_new_tokens=4)
        server.submit(req)
        server.run_until_drained()
        assert req.done
        outs[packed] = req.output
    assert outs[True] == outs[False]
