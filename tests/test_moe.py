"""MoE dispatch/combine correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe


def _cfg(n_experts=4, top_k=2, cf=8.0):
    return ModelConfig(
        arch="tiny-moe", family="moe", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=16, vocab=64, dtype="float32",
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                      capacity_factor=cf))


def _dense_reference(p, x, cfg):
    """Σ_k w_k · FFN_{e_k}(x) computed without any dispatch machinery."""
    b, t, d = x.shape
    x2 = x.reshape(-1, d)
    probs, ids, weights = moe._route(x2, p["router"], cfg.moe.top_k)
    outs = []
    for e in range(cfg.moe.n_experts):
        h = jax.nn.silu(x2 @ p["e_gate"][e]) * (x2 @ p["e_up"][e])
        outs.append(h @ p["e_down"][e])
    outs = jnp.stack(outs, 1)  # [T, E, D]
    y = jnp.zeros_like(x2)
    for k in range(cfg.moe.top_k):
        y = y + weights[:, k:k + 1] * jnp.take_along_axis(
            outs, ids[:, k][:, None, None], axis=1)[:, 0]
    return y.reshape(b, t, d)


def test_moe_equals_dense_reference_with_ample_capacity():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe.init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y, aux = moe.apply(p, x, cfg, train=False)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drop_is_graceful():
    """With capacity 8 slots/expert and badly skewed routing, overflow tokens
    are dropped (zero contribution), never NaN."""
    cfg = _cfg(n_experts=4, top_k=1, cf=0.05)
    key = jax.random.PRNGKey(2)
    p = moe.init(key, cfg)
    # bias the router hard toward expert 0 → guaranteed overflow
    p["router"] = p["router"].at[:, 0].add(100.0)
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, 64, cfg.d_model))
    y, _ = moe.apply(p, x, cfg, train=False)
    assert bool(jnp.all(jnp.isfinite(y)))
    # most tokens overflowed the 8-slot capacity → their rows are zero
    zero_rows = jnp.mean((jnp.abs(y) < 1e-9).all(-1).astype(jnp.float32))
    assert float(zero_rows) > 0.5


def test_moe_shared_expert_path():
    cfg = _cfg()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, n_shared=2, d_ff_shared=32, shared_gate=True))
    p = moe.init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 4, cfg.d_model))
    y, _ = moe.apply(p, x, cfg, train=False)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))


def test_positions_in_expert_are_dense_slots():
    ids = jnp.asarray([2, 0, 2, 2, 1, 0], jnp.int32)
    pos = moe._positions_in_expert(ids, 4)
    np.testing.assert_array_equal(np.asarray(pos), [0, 0, 1, 2, 0, 1])


def test_expert_padding():
    assert moe.padded_experts(60) == 64
    assert moe.padded_experts(256) == 256
    assert moe.padded_experts(8) == 16
