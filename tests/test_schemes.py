"""BP/WBS/BS computing-flow correctness (Eq. 1, 2, 7)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CIMConfig, PROTOTYPE, Scheme, bp_mvm, bs_mvm,
                        cim_matmul, exact_mvm_codes, wbs_mvm)
from repro.core.schemes import pad_and_group, signed_correction


def _codes(key, shape, hi=16):
    return jax.random.randint(key, shape, 0, hi).astype(jnp.float32)


def _exact_cfg(scheme=Scheme.BP, n_rows=144):
    """ADC with LSB = 1 analog unit ⇒ bit-exact conversion of any level."""
    cfg = dataclasses.replace(PROTOTYPE, scheme=scheme, n_rows=n_rows)
    return dataclasses.replace(cfg, adc_levels=int(cfg.full_scale(
        1 if scheme is Scheme.BS else None,
        1 if scheme in (Scheme.BS, Scheme.WBS) else None)) + 1)


def test_bp_bit_exact_when_lsb_is_one():
    key = jax.random.PRNGKey(0)
    x = _codes(key, (4, 288))
    w = _codes(jax.random.fold_in(key, 1), (288, 8))
    cfg = dataclasses.replace(PROTOTYPE, adc_levels=32401)  # FS+1 levels
    assert jnp.array_equal(bp_mvm(x, w, cfg), exact_mvm_codes(x, w))


@pytest.mark.parametrize("fn,scheme", [(wbs_mvm, Scheme.WBS),
                                       (bs_mvm, Scheme.BS)])
def test_serial_schemes_bit_exact_at_full_resolution(fn, scheme):
    key = jax.random.PRNGKey(2)
    x = _codes(key, (3, 144))
    w = _codes(jax.random.fold_in(key, 3), (144, 5))
    cfg = _exact_cfg(scheme)
    assert jnp.array_equal(fn(x, w, cfg), exact_mvm_codes(x, w))


def test_signed_correction_is_exact_integer_identity():
    """Eq. 7 (generalized): the offset/zero-point correction is exact."""
    key = jax.random.PRNGKey(4)
    x_codes = _codes(key, (6, 200))
    w_signed = jax.random.randint(jax.random.fold_in(key, 5), (200, 7),
                                  -8, 8).astype(jnp.float32)
    zp = jnp.asarray(5.0)
    w_codes = w_signed + 8.0
    y_unsigned = exact_mvm_codes(x_codes, w_codes)
    y = signed_correction(y_unsigned, x_codes, w_codes, w_offset=8,
                          x_zero_point=zp)
    y_ref = exact_mvm_codes(x_codes - zp, w_signed)
    assert jnp.array_equal(y, y_ref)


def test_pad_and_group_zero_pads_are_noops():
    x = jnp.ones((2, 150))
    xg, g = pad_and_group(x, 144)
    assert xg.shape == (2, 2, 144) and g == 2
    assert float(jnp.sum(xg)) == 300.0  # padding contributed zeros


def test_quantization_error_bounded_by_group_lsb():
    key = jax.random.PRNGKey(6)
    x = _codes(key, (8, 430))
    w = _codes(jax.random.fold_in(key, 7), (430, 3))
    cfg = PROTOTYPE  # 362 levels
    groups = -(-430 // 144)
    lsb = cfg.full_scale() / (cfg.gain * cfg.adc_levels)
    err = jnp.abs(bp_mvm(x, w, cfg) - exact_mvm_codes(x, w))
    assert float(err.max()) <= groups * lsb / 2 + 1e-3


def test_gain_reduces_quantization_error_for_small_signals():
    """Fig. 15/18: VTC gain shrinks the LSB when activations are small."""
    key = jax.random.PRNGKey(8)
    x = _codes(key, (16, 144), hi=4)    # small codes: top of range unused
    w = _codes(jax.random.fold_in(key, 9), (144, 4), hi=16)
    y_ref = exact_mvm_codes(x, w)
    errs = {}
    for gain in (1.0, 3.0):
        cfg = dataclasses.replace(PROTOTYPE, gain=gain)
        errs[gain] = float(jnp.mean(jnp.abs(bp_mvm(x, w, cfg) - y_ref)))
    assert errs[3.0] < errs[1.0]


def test_cim_matmul_relative_error_reasonable():
    """ReLU'd Gaussian activations underfill the DAC range at gain 1 — the
    exact situation the paper's VTC gain knob exists for (§V-A). At the
    deployed gain of 3 (Fig. 19) the 8.5-bit pipeline is accurate."""
    key = jax.random.PRNGKey(10)
    x = jax.nn.relu(jax.random.normal(key, (32, 288)))
    w = jax.random.normal(jax.random.fold_in(key, 11), (288, 16)) * 0.1
    yf = x @ w
    rel = {}
    for gain in (1.0, 3.0):
        cim = CIMConfig(enabled=True,
                        macro=dataclasses.replace(PROTOTYPE, gain=gain))
        y = cim_matmul(x, w, cim)
        rel[gain] = float(jnp.linalg.norm(y - yf) / jnp.linalg.norm(yf))
    assert rel[3.0] < rel[1.0]
    assert rel[3.0] < 0.25


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 300), st.integers(1, 6))
def test_bp_exactness_property(seed, k, m):
    """Property: with LSB=1 the whole analog pipeline is lossless (the
    paper's '15-bit ADC covers every level' limit)."""
    key = jax.random.PRNGKey(seed)
    x = _codes(key, (2, k))
    w = _codes(jax.random.fold_in(key, 1), (k, m))
    cfg = dataclasses.replace(PROTOTYPE, adc_levels=int(PROTOTYPE.full_scale()) + 1)
    assert jnp.array_equal(bp_mvm(x, w, cfg), exact_mvm_codes(x, w))
