"""Logical-axis sharding rules: divisibility fallback, optimizer-state
inheritance, cache specs."""
import types

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding


@pytest.fixture
def fake_mesh(monkeypatch):
    mesh = types.SimpleNamespace(
        axis_names=("pod", "data", "model"),
        shape={"pod": 2, "data": 16, "model": 16})
    monkeypatch.setattr(sharding, "_MESH", mesh)
    return mesh


def test_spec_divisible(fake_mesh):
    spec = sharding.spec_for((4096, 14336), ("fsdp", "tp"))
    assert spec == P("data", "model")


def test_spec_drops_nondivisible(fake_mesh):
    # 8 kv heads on a 16-way model axis → replicate (Megatron fallback)
    spec = sharding.spec_for((4096, 8), ("fsdp", "tp"))
    assert spec == P("data", None)


def test_batch_resolves_to_pod_and_data(fake_mesh):
    spec = sharding.spec_for((256, 4096), ("batch", None))
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard 32 ways → dropped
    spec = sharding.spec_for((1, 4096), ("batch", None))
    assert spec == P(None, None)


def test_seq_axis_combines_data_and_model(fake_mesh):
    spec = sharding.spec_for((1, 524288), (None, "seq"))
    assert spec == P(None, ("data", "model"))


def test_param_rules_attention(fake_mesh):
    assert sharding.axes_for(("layers", "attn", "wq"), 3) == (None, "fsdp",
                                                              "tp")
    assert sharding.axes_for(("tok", "embed"), 2) == ("tp", "fsdp")


def test_adafactor_stats_inherit_param_rules(fake_mesh):
    # e_gate is [L,E,D,F] → vr (row means) is [L,E,D], vc is [L,E,F];
    # base rules ("expert","fsdp",None): vr drops last dim, vc drops middle
    assert sharding.axes_for(("stats", "layers", "ffn", "e_gate", "vr"),
                             3) == (None, "expert", "fsdp")
    assert sharding.axes_for(("stats", "layers", "ffn", "e_gate", "vc"),
                             3) == (None, "expert", None)


def test_adamw_state_uses_param_name(fake_mesh):
    # m/v mirror the params tree: last key is the param name itself
    assert sharding.axes_for(("m", "layers", "ffn", "w_up"), 3) == (
        None, "fsdp", "tp")


def test_no_mesh_means_no_constraints():
    sharding.set_mesh(None)
    x = jax.numpy.ones((4, 4))
    assert sharding.constrain(x, "batch", None) is x
    assert sharding.tree_shardings({"a": x}) is None
