"""TD-ADC transfer model + Eq. 4 energy model against paper anchors."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PROTOTYPE, Scheme
from repro.core.adc import adc_quantize, inl_curve
from repro.core.energy import (compute_density_tops_mm2, macro_throughput_gops,
                               mvm_energy)
from repro.core.macro import GEOMETRY, MacroConfig, OperatingPoint


def test_adc_transfer_monotone_and_clipped():
    v = jnp.linspace(-1000.0, 40000.0, 2048)
    q = adc_quantize(v, PROTOTYPE, dequantize=False)
    assert float(q.min()) == 0.0
    assert float(q.max()) == PROTOTYPE.adc_levels - 1
    assert bool(jnp.all(jnp.diff(q) >= 0))


def test_inl_curve_bounded():
    x = jnp.linspace(0, 1, 512)
    for seed in range(5):
        c = inl_curve(x, 1.10, seed)
        assert float(jnp.max(jnp.abs(c))) <= 1.10 + 1e-6


def test_effective_resolution_derates_at_low_vdd():
    lo = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=0.65))
    assert lo.effective_adc_levels() == 256  # 8-bit floor (paper §V-B)
    assert PROTOTYPE.effective_adc_levels() == 362


def test_sigma_e_calibration_point():
    assert abs(PROTOTYPE.sigma_e_lsb() - 0.59) < 1e-6  # Fig. 16(b)


def test_energy_anchors_match_fig21():
    c065 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=0.65))
    c120 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=1.2))
    assert abs(mvm_energy(c065, 144).tops_per_w - 40.2) < 0.5
    assert abs(mvm_energy(c120, 144).tops_per_w - 18.6) < 0.5


def test_throughput_anchors_match_table1():
    c065 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=0.65))
    c120 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=1.2))
    assert abs(macro_throughput_gops(c065) - 3.8) < 0.2
    assert abs(macro_throughput_gops(c120) - 50.3) < 1.0
    assert abs(compute_density_tops_mm2(c120) - 0.68) < 0.02


def test_memory_density_matches_table1():
    assert abs(GEOMETRY.density_kb_mm2 - 547.3) < 1.0  # 40.5Kb / 0.074mm²


def test_scheme_energy_ordering():
    """Eq. 4: at the same macro resolution BS costs the most (B_A·B_W ADC
    conversions), WBS in between, BP the least."""
    e = {}
    for s in (Scheme.BP, Scheme.WBS, Scheme.BS):
        cfg = dataclasses.replace(PROTOTYPE, scheme=s)
        e[s] = mvm_energy(cfg, 144).e_mvm_j
    assert e[Scheme.BP] < e[Scheme.WBS] < e[Scheme.BS]


def test_dual_threshold_saves_adc_energy():
    from repro.core.adc import adc_energy_j
    on = adc_energy_j(PROTOTYPE, dual_threshold=True)
    off = adc_energy_j(PROTOTYPE, dual_threshold=False)
    assert abs(1 - on / off - 0.558) < 1e-6  # measured 55.8 % reduction


def test_operating_point_validation():
    with pytest.raises(ValueError):
        OperatingPoint(vdd=0.4)
    with pytest.raises(ValueError):
        OperatingPoint(temp_c=150.0)
    with pytest.raises(ValueError):
        MacroConfig(gain=8.0)
