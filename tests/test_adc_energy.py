"""TD-ADC transfer model + Eq. 4 energy model against paper anchors."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PROTOTYPE, Scheme
from repro.core.adc import adc_quantize, inl_curve
from repro.core.energy import (compute_density_tops_mm2, macro_throughput_gops,
                               mvm_energy)
from repro.core.macro import GEOMETRY, MacroConfig, OperatingPoint


def test_adc_transfer_monotone_and_clipped():
    v = jnp.linspace(-1000.0, 40000.0, 2048)
    q = adc_quantize(v, PROTOTYPE, dequantize=False)
    assert float(q.min()) == 0.0
    assert float(q.max()) == PROTOTYPE.adc_levels - 1
    assert bool(jnp.all(jnp.diff(q) >= 0))


def test_inl_curve_bounded():
    x = jnp.linspace(0, 1, 512)
    for seed in range(5):
        c = inl_curve(x, 1.10, seed)
        assert float(jnp.max(jnp.abs(c))) <= 1.10 + 1e-6


def test_effective_resolution_derates_at_low_vdd():
    lo = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=0.65))
    assert lo.effective_adc_levels() == 256  # 8-bit floor (paper §V-B)
    assert PROTOTYPE.effective_adc_levels() == 362


def test_sigma_e_calibration_point():
    assert abs(PROTOTYPE.sigma_e_lsb() - 0.59) < 1e-6  # Fig. 16(b)


def test_energy_anchors_match_fig21():
    c065 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=0.65))
    c120 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=1.2))
    assert abs(mvm_energy(c065, 144).tops_per_w - 40.2) < 0.5
    assert abs(mvm_energy(c120, 144).tops_per_w - 18.6) < 0.5


def test_throughput_anchors_match_table1():
    c065 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=0.65))
    c120 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=1.2))
    assert abs(macro_throughput_gops(c065) - 3.8) < 0.2
    assert abs(macro_throughput_gops(c120) - 50.3) < 1.0
    assert abs(compute_density_tops_mm2(c120) - 0.68) < 0.02


def test_memory_density_matches_table1():
    assert abs(GEOMETRY.density_kb_mm2 - 547.3) < 1.0  # 40.5Kb / 0.074mm²


def test_scheme_energy_ordering():
    """Eq. 4: at the same macro resolution BS costs the most (B_A·B_W ADC
    conversions), WBS in between, BP the least."""
    e = {}
    for s in (Scheme.BP, Scheme.WBS, Scheme.BS):
        cfg = dataclasses.replace(PROTOTYPE, scheme=s)
        e[s] = mvm_energy(cfg, 144).e_mvm_j
    assert e[Scheme.BP] < e[Scheme.WBS] < e[Scheme.BS]


def test_dual_threshold_saves_adc_energy():
    from repro.core.adc import adc_energy_j
    on = adc_energy_j(PROTOTYPE, dual_threshold=True)
    off = adc_energy_j(PROTOTYPE, dual_threshold=False)
    assert abs(1 - on / off - 0.558) < 1e-6  # measured 55.8 % reduction


def test_operating_point_validation():
    with pytest.raises(ValueError):
        OperatingPoint(vdd=0.4)
    with pytest.raises(ValueError):
        OperatingPoint(temp_c=150.0)
    with pytest.raises(ValueError):
        MacroConfig(gain=8.0)


# ---------------------------------------------------------------------------
# single source of truth: energy constants derive from the ADC model
# ---------------------------------------------------------------------------
def test_adc_energy_derives_from_ratio_anchor():
    """E_ADC/(N·E_MAC) = 3.0 at the 7-bit/128-level CAP-RAM anchor must
    hold by construction — adc_energy_j and _solve_e_mac_ref both read the
    same core.adc constants, so the identity is exact (rtol 1e-6)."""
    from repro.core.adc import (ADC_RATIO_E_ADC_OVER_N_E_MAC,
                                ADC_RATIO_N_ROWS, adc_energy_j)
    from repro.core.energy import (E_MAC_REF_J, VOLT_REF,
                                   energy_voltage_scale)
    cfg = dataclasses.replace(PROTOTYPE, adc_levels=128)
    # both sides ride the same voltage curve; compare at iso-voltage
    vs = energy_voltage_scale(cfg.op.vdd) / energy_voltage_scale(VOLT_REF)
    ratio = adc_energy_j(cfg, dual_threshold=False) \
        / (ADC_RATIO_N_ROWS * E_MAC_REF_J * vs)
    np.testing.assert_allclose(ratio, ADC_RATIO_E_ADC_OVER_N_E_MAC,
                               rtol=1e-6)


def test_dual_threshold_gating_single_source():
    """The gated/ungated conversion-energy ratio IS the shared constant —
    no hardcoded 0.558 elsewhere can drift from it."""
    from repro.core.adc import DUAL_THRESHOLD_GATING, adc_energy_j
    gated = adc_energy_j(PROTOTYPE, dual_threshold=True)
    ungated = adc_energy_j(PROTOTYPE, dual_threshold=False)
    np.testing.assert_allclose(gated / ungated, 1.0 - DUAL_THRESHOLD_GATING,
                               rtol=1e-6)


def test_e_mac_ref_derivation_matches_macro_derating():
    """_solve_e_mac_ref's 256-level de-rating at the 0.65 V anchor comes
    from MacroConfig.effective_adc_levels, not a literal: re-derive the
    anchor from the macro model and check the solved constant (rtol 1e-6)."""
    from repro.core.adc import (ADC_RATIO_E_ADC_OVER_N_E_MAC,
                                ADC_RATIO_LEVELS, DUAL_THRESHOLD_GATING)
    from repro.core.energy import E_MAC_REF_J, VOLT_REF
    m = MacroConfig(op=OperatingPoint(vdd=VOLT_REF))
    n = m.n_rows
    adc_factor = ADC_RATIO_E_ADC_OVER_N_E_MAC * n \
        * (m.effective_adc_levels() / ADC_RATIO_LEVELS) \
        * (1.0 - DUAL_THRESHOLD_GATING)
    expect = (2.0 * n / 40.2e12) / (adc_factor + 4.0 * n)
    np.testing.assert_allclose(E_MAC_REF_J, expect, rtol=1e-6)
    assert m.effective_adc_levels() == 256        # the low-vdd de-rating
