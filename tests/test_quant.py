"""Quantizer + STE unit and property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quant import (ActQuantConfig, WeightQuantConfig, act_scale,
                              bit_planes, clip_ste, fake_quant_unsigned,
                              quantize_act, quantize_weight, round_ste,
                              weight_scale)


def test_round_ste_forward_and_grad():
    x = jnp.array([0.2, 0.5, 1.7, -1.2])
    assert jnp.allclose(round_ste(x), jnp.round(x))
    g = jax.grad(lambda v: jnp.sum(round_ste(v)))(x)
    assert jnp.allclose(g, 1.0)  # Eq. 5: derivative taken as identity


def test_clip_ste_grad_is_identity():
    x = jnp.array([-5.0, 0.3, 9.0])
    g = jax.grad(lambda v: jnp.sum(clip_ste(v, 0.0, 1.0)))(x)
    assert jnp.allclose(g, 1.0)


def test_weight_codes_cover_unsigned_range():
    cfg = WeightQuantConfig()
    w = jnp.linspace(-1.0, 1.0, 64)
    s = weight_scale(w, cfg)
    codes = quantize_weight(w, s, cfg)
    assert float(codes.min()) >= 0.0 and float(codes.max()) <= 15.0
    # Eq. 7 mapping: -8..7 → 0..15, zero maps to 8
    z = quantize_weight(jnp.zeros(3), s, cfg)
    assert jnp.allclose(z, 8.0)


def test_act_codes_nonneg_relu_case():
    cfg = ActQuantConfig()
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0), (128,)))
    s = act_scale(x, cfg)
    q, zp = quantize_act(x, s, cfg)
    assert float(zp) == 0.0  # paper's unsigned DAC case
    assert float(q.min()) >= 0 and float(q.max()) <= 15


def test_bit_planes_reconstruct():
    q = jnp.arange(16.0)
    planes = bit_planes(q, 4)
    recon = sum((2 ** p) * planes[p] for p in range(4))
    assert jnp.allclose(recon, q)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=2, max_size=64),
       st.integers(2, 8))
def test_fake_quant_error_bound(vals, bits):
    x = jnp.asarray(vals, jnp.float32)
    x = x - jnp.min(jnp.minimum(x, 0))  # unsigned quantizer: x ≥ 0
    scale = jnp.maximum(jnp.max(x), 1e-6) / ((1 << bits) - 1)
    y = fake_quant_unsigned(x, bits, scale)
    assert float(jnp.max(jnp.abs(y - x))) <= float(scale) / 2 + 1e-5


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_affine_quant_roundtrip_random(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,)) * jax.random.uniform(key, (), minval=0.1,
                                                            maxval=10.0)
    cfg = ActQuantConfig()
    s = act_scale(x, cfg)
    q, zp = quantize_act(x, s, cfg)
    x_hat = (q - zp) * s
    assert float(jnp.max(jnp.abs(x_hat - x))) <= float(s) * 0.51 + 1e-6
