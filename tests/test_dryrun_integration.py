"""Multi-pod dry-run integration: real 512-placeholder-device lowering in a
subprocess (jax locks device count at first init, so these cannot run
in-process with the rest of the suite)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, mesh, tmp, cim="off"):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(tmp),
           "--cim", cim]
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=1500)
    assert proc.returncode == 0, proc.stderr[-2000:]
    mesh_name = {"single": "pod16x16", "multi": "pod2x16x16"}[mesh]
    cell = f"{arch}__{shape}__{mesh_name}" + \
        (f"__cim-{cim}" if cim != "off" else "")
    with open(os.path.join(tmp, cell + ".json")) as f:
        return json.load(f)


@pytest.mark.slow
def test_multi_pod_decode_cell(tmp_path):
    """The 2×16×16 = 512-chip mesh must lower+compile (pod axis shards)."""
    r = _run_cell("internlm2-1.8b", "decode_32k", "multi", tmp_path)
    assert r["status"] == "ok", r.get("error")
    assert r["roofline"]["chips"] == 512
    assert r["roofline"]["collective_bytes"] > 0
    assert r["memory_analysis"]["temp_size_in_bytes"] > 0


@pytest.mark.slow
def test_single_pod_train_cell(tmp_path):
    r = _run_cell("internlm2-1.8b", "train_4k", "single", tmp_path)
    assert r["status"] == "ok", r.get("error")
    assert r["roofline"]["chips"] == 256
    rl = r["roofline"]
    assert rl["dominant"] in ("compute", "memory", "collective")
    assert rl["model_flops"] > 0


@pytest.mark.slow
def test_long_context_skip_policy(tmp_path):
    """Pure full-attention archs skip long_500k with a recorded reason."""
    r = _run_cell("llama3-8b", "long_500k", "single", tmp_path)
    assert r["status"] == "skipped"
    assert "full-softmax-attention" in r["reason"]


@pytest.mark.slow
def test_prequant_packed_serving_cell(tmp_path):
    """The nibble-packed-u4 serving flow (ISSUE 1) must lower+compile on the
    production mesh — decode against offline-quantized stored codes."""
    r = _run_cell("internlm2-1.8b", "decode_32k", "single", tmp_path,
                  cim="bp-prequant")
    assert r["status"] == "ok", r.get("error")
    assert r["roofline"]["chips"] == 256
