"""analysis.bench_trend: BENCH_ci.json ingestion, history accumulation and
the rendered markdown perf-trajectory table (the bench-smoke CI artifact)."""
import json

import pytest

from repro.analysis import bench_trend


def _doc(us_decode=400.0, ratio=1.02):
    return {
        "schema": "pico-ram/kernel_bench/v1",
        "jax": "0.4.37",
        "backend": "cpu",
        "rows": [
            {"name": "kernel_ref_jnp_576x64", "us": 120.0, "derived": "oracle"},
            {"name": "kernel_pallas_noisy_m32_k288_n64", "us": 700.0,
             "derived": f"einsum_noisy_us=900.0|err_sigma fused=0.100 "
                        f"einsum=0.098 ratio={ratio:.3f}"},
            {"name": "decode_packed_m8_k576_n128", "us": us_decode,
             "derived": "unpacked_us=500.0|w_bytes 73728->36864 "
                        "(2.00x less HBM)"},
            {"name": "serve_decode_paged_s4_r4", "us": 90000.0,
             "derived": "decode_tok_s=11.0|prefill_tok_s=30.4|steps=6"},
            {"name": "serve_kv_bytes_occ25_s4", "us": 1000.0,
             "derived": "kv_bytes slot=262144 paged=16384 "
                        "(16.00x less HBM)"},
            # schema-v3 paged-attention sweep rows: the extractor must keep
            # the LARGEST window's score-byte probe and must not let the
            # attnkernel serving row clobber the exact-path serve tok/s
            {"name": "paged_attn_decode_w64", "us": 800.0,
             "derived": "exact_us=300.0|score_bytes exact=2048 kernel=64 "
                        "(32x less)"},
            {"name": "paged_attn_decode_w256", "us": 900.0,
             "derived": "exact_us=600.0|score_bytes exact=8192 kernel=64 "
                        "(128x less)"},
            {"name": "serve_decode_paged_attnkernel_s4_r4", "us": 95000.0,
             "derived": "decode_tok_s=9.5|exact_tok_s=11.0|ratio=0.864"},
            # schema-v4 autotune rows: the tuned/default pair must feed the
            # speedup column only — neither row carries a score-byte probe,
            # and the w4096 name must NOT clobber the score-window metric
            {"name": "paged_attn_decode_w4096_default", "us": 34000.0,
             "derived": "block_size=16|kblocks=1|row_tile=None"},
            {"name": "paged_attn_decode_w4096_tuned", "us": 5600.0,
             "derived": "default_us=34000.0|speedup=6.07x|block_size=128|"
                        "kblocks=1|row_tile=None"},
            {"name": "cim_mvm_m64_g2_n64_tuned", "us": 206.0,
             "derived": "default_us=285.0|speedup=1.38x|bm=128|bn=64"},
            # schema-v5 shared-prefix serving row: decode-lane concurrency
            # of the prefix-sharing pool vs the sharing-disabled pool
            {"name": "serve_shared_prefix_s8_r7", "us": 6000.0,
             "derived": "peak_lanes shared=7 nosharing=1 (7.0x)|"
                        "prefill_tok_saved=336|"
                        "preempt shared=0 nosharing=21"},
            # schema-v6 spec-decode serving row: speculative-vs-plain
            # greedy tok/s plus the accept-length statistics
            {"name": "serve_spec_decode_k4_s2", "us": 400.0,
             "derived": "spec_tok_s=2511.6|plain_tok_s=1128.8|"
                        "speedup=2.23x|accept_rate=0.47|"
                        "mean_accept_len=2.87|hist=0:50;1:6;2:7;3:2;4:45"},
            # schema-v7 energy-pareto row: uniform-vs-mixed serving
            # energy/token with the precision search's KL-proxy numbers
            {"name": "energy_pareto_mixed_precision", "us": 2.2e7,
             "derived": "uniform_pj_tok=26692.7|mixed_pj_tok=18448.8|"
                        "energy_win=1.447x|kl_uniform=2.2014|"
                        "kl_mixed=2.2163|kl_budget=0.080|levels=wq:128"},
            # schema-v8 serve-SLO row: telemetry-histogram TTFT
            # percentiles + the telemetry-on/off overhead percentage
            {"name": "serve_slo_paged_s4_r6", "us": 5200.0,
             "derived": "ttft_p50_ms=104.20|ttft_p99_ms=310.55|"
                        "itl_p50_ms=4.10|itl_p99_ms=9.80|"
                        "tok_s_on=182.0|tok_s_off=184.5|"
                        "overhead_pct=+1.36"},
        ],
    }


def test_extract_metrics():
    m = bench_trend.extract_metrics(_doc())
    assert m["decode_tok_s"] == pytest.approx(8 / 400.0 * 1e6)
    assert m["w_bytes_packed"] == 36864
    assert m["w_bytes_int8"] == 73728
    assert m["hbm_win"] == pytest.approx(2.0)
    assert m["sigma_ratio"] == pytest.approx(1.02)
    assert m["noisy_us"] == 700.0
    assert m["ref_us"] == 120.0
    # schema-v2 serving sweep rows
    assert m["serve_decode_tok_s"] == pytest.approx(11.0)
    assert m["kv_bytes_slot"] == 262144
    assert m["kv_bytes_paged"] == 16384
    assert m["kv_win"] == pytest.approx(16.0)
    # schema-v3 paged-attention sweep: largest window wins; the attnkernel
    # serving row fills its own metric without clobbering serve_decode_tok_s
    assert m["attn_kernel_tok_s"] == pytest.approx(9.5)
    assert m["score_window"] == 256
    assert m["score_bytes_exact"] == 8192
    assert m["score_bytes_kernel"] == 64
    assert m["score_win"] == pytest.approx(128.0)
    # schema-v4 autotune pair: speedup extracted from the tuned row; the
    # w4096 tuned/default names don't disturb the score-window probe above
    assert m["tune_window"] == 4096
    assert m["tune_speedup"] == pytest.approx(6.07)
    # schema-v5 shared-prefix serving row
    assert m["prefix_lanes"] == 7
    assert m["prefix_lanes_base"] == 1
    assert m["prefix_win"] == pytest.approx(7.0)
    assert m["prefix_tok_saved"] == 336
    # schema-v6 spec-decode serving row
    assert m["spec_speedup"] == pytest.approx(2.23)
    assert m["spec_accept_len"] == pytest.approx(2.87)
    # schema-v7 energy-pareto row
    assert m["uniform_pj_tok"] == pytest.approx(26692.7)
    assert m["mixed_pj_tok"] == pytest.approx(18448.8)
    assert m["energy_win"] == pytest.approx(1.447)
    assert m["energy_kl_delta"] == pytest.approx(2.2163 - 2.2014)
    # schema-v8 serve-SLO row
    assert m["ttft_p50_ms"] == pytest.approx(104.20)
    assert m["ttft_p99_ms"] == pytest.approx(310.55)
    assert m["telemetry_overhead_pct"] == pytest.approx(1.36)


def test_extract_metrics_tolerates_missing_rows():
    doc = _doc()
    doc["rows"] = doc["rows"][:1]
    m = bench_trend.extract_metrics(doc)
    assert "decode_tok_s" not in m and "sigma_ratio" not in m
    md = bench_trend.render_markdown([{"label": "x", "metrics": m}])
    assert "—" in md


def test_load_bench_rejects_bad_schema(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"schema": "other/v1", "rows": [{}]}))
    with pytest.raises(ValueError):
        bench_trend.load_bench(str(p))


def test_history_append_and_render(tmp_path):
    hist = tmp_path / "hist.jsonl"
    for i, label in enumerate(("run-a", "run-b")):
        b = tmp_path / f"b{i}.json"
        b.write_text(json.dumps(_doc(us_decode=400.0 + 100 * i)))
        rc = bench_trend.main(["--history", str(hist), "--append", str(b),
                               "--label", label,
                               "--out", str(tmp_path / "TREND.md")])
        assert rc == 0
    entries = bench_trend.load_history(str(hist))
    assert [e["label"] for e in entries] == ["run-a", "run-b"]
    md = (tmp_path / "TREND.md").read_text()
    assert "run-a" in md and "run-b" in md
    assert "20000" in md    # 8 tok / 400 µs
    assert "2.00×" in md and "36864" in md
    assert "9.5" in md and "128×" in md    # v3 attn-kernel + score probe
    assert "6.07×" in md                   # v4 tuned-vs-default speedup
    assert "7 vs 1 (7.0×)" in md and "336" in md  # v5 shared-prefix row
    assert "2.23×" in md and "2.87" in md         # v6 spec-decode row
    assert "1.45×" in md and "+0.0149" in md      # v7 energy-pareto row
    assert "104.2" in md and "310.6" in md        # v8 serve-SLO TTFT
    assert "+1.36%" in md                         # v8 telemetry overhead
    # table stays well-formed: every data row has the 23 columns
    rows = [ln for ln in md.splitlines() if ln.startswith("| run-")]
    assert all(ln.count("|") == 24 for ln in rows)


def test_one_shot_mode(tmp_path):
    b1 = tmp_path / "one" / "BENCH_ci.json"
    b1.parent.mkdir()
    b1.write_text(json.dumps(_doc()))
    out = tmp_path / "T.md"
    assert bench_trend.main([str(b1), "--out", str(out)]) == 0
    assert "kernel_bench perf trajectory" in out.read_text()


def test_pareto_section_from_manifest(tmp_path):
    """--precision-manifest appends the Pareto section rendered from the
    deployment manifest; a malformed manifest degrades to no section (the
    same warn-and-serve-defaults contract as the Server)."""
    from repro.analysis import precision_search as ps
    manifest = {
        "schema": ps.MANIFEST_SCHEMA, "arch": "internlm2-1.8b", "seed": 0,
        "act_qmax": 15, "base_adc_levels": 362,
        "default": {"scale": 1.0, "zero_point": 0.0},
        "sites": {"wq": {"act_scale": 0.5, "act_zero_point": 0.0,
                         "adc_levels": 128, "scheme": "bp",
                         "per_channel": None, "k": 64, "m": 8, "calls": 2}},
        "metrics": {"uniform_pj_per_token": 100.0,
                    "mixed_pj_per_token": 69.0, "energy_win": 100.0 / 69.0,
                    "kl_uniform": 1.0, "kl_proxy": 1.05, "kl_budget": 0.08,
                    "trace": []},
    }
    mp = tmp_path / "manifest.json"
    ps.save_manifest(str(mp), manifest)
    b1 = tmp_path / "BENCH_ci.json"
    b1.write_text(json.dumps(_doc()))
    out = tmp_path / "T.md"
    assert bench_trend.main([str(b1), "--out", str(out),
                             "--precision-manifest", str(mp)]) == 0
    md = out.read_text()
    assert "Energy/accuracy Pareto" in md
    assert "uniform 4b×4b BP (362-level ADC)" in md
    assert "1.449×" in md and "wq=128" in md
    # malformed manifest: section silently absent, render still succeeds
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.warns(UserWarning, match="precision manifest"):
        assert bench_trend.main([str(b1), "--out", str(out),
                                 "--precision-manifest", str(bad)]) == 0
    assert "Energy/accuracy Pareto" not in out.read_text()
