"""Checkpoint atomicity, bf16 roundtrip, keep-N, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(key, (8, 16)),
        "nested": {"b": jax.random.normal(key, (4,)).astype(jnp.bfloat16),
                   "c": jnp.arange(5, dtype=jnp.int32)},
        "scalar": jnp.asarray(3, jnp.int32),
    }


def test_roundtrip_including_bf16(tmp_path):
    t = _tree()
    save_pytree(str(tmp_path / "ck"), t, metadata={"step": 7})
    like = jax.eval_shape(lambda: t)
    out, md = load_pytree(str(tmp_path / "ck"), like)
    assert md["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [30, 40]
    assert mgr.latest_step() == 40


def test_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    like = jax.eval_shape(lambda: _tree(0))
    out, md = mgr.restore(like)
    assert md["step"] == 2
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(_tree(2)["a"]))


def test_shape_mismatch_raises(tmp_path):
    save_pytree(str(tmp_path / "ck"), {"a": jnp.ones((4,))})
    like = {"a": jax.ShapeDtypeStruct((5,), jnp.float32)}
    with pytest.raises(ValueError):
        load_pytree(str(tmp_path / "ck"), like)


def test_atomic_overwrite(tmp_path):
    """Re-saving the same step replaces the directory without tmp residue."""
    p = str(tmp_path / "ck")
    save_pytree(p, {"a": jnp.ones((2,))})
    save_pytree(p, {"a": jnp.zeros((2,))})
    out, _ = load_pytree(p, {"a": jax.ShapeDtypeStruct((2,), jnp.float32)})
    assert float(out["a"].sum()) == 0.0
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]
