"""Pipeline-parallel stage scan: fill-drain schedule correctness on a
1-stage mesh (semantics) — multi-stage behaviour is exercised in the
dry-run subprocess environment where >1 host device exists."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import pipeline_apply


def test_single_stage_pipeline_is_identity_schedule():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("stage",))
    w = jnp.asarray([[2.0]])  # one stage: h → 2h

    def stage(params, h):
        return h * params[0, 0]

    x = jnp.arange(6.0).reshape(3, 2)[:, None, :]  # 3 microbatches of [1,2]
    out = pipeline_apply(stage, w[None], x, mesh=mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x * 2.0))
