"""Mixed-precision autotuner: manifest round-trip + fallback discipline,
per-call-site tree identity (scanned vs unrolled), search determinism, and
the live ServingConfig → site_overrides dispatch path."""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.analysis import precision_search as ps
from repro.analysis.calibrate import calibrate_act_tree
from repro.configs.registry import SMOKES
from repro.core.cim_matmul import CIMConfig, SitePrecision
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig

MAX_LEN = 64


@pytest.fixture(scope="module")
def cim_setup():
    cfg = SMOKES["internlm2-1.8b"].replace(
        dtype="float32", cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg,
                                  max_seq=MAX_LEN)
    return cfg, params


@pytest.fixture(scope="module")
def cal_tokens():
    return np.random.RandomState(7).randint(
        0, SMOKES["internlm2-1.8b"].vocab, size=(2, 16))


@pytest.fixture(scope="module")
def manifest(cim_setup, cal_tokens):
    cfg, params = cim_setup
    # one candidate rung + no per-channel retry keeps the module-scoped
    # search cheap; the full ladder is exercised by benchmarks/kernel_bench
    return ps.search(params, cal_tokens, cfg, seed=0,
                     bit_candidates=(7.0,), try_per_channel=False)


# ---------------------------------------------------------------------------
# per-call-site calibration tree
# ---------------------------------------------------------------------------
def test_tree_identical_between_scanned_and_unrolled(cim_setup, cal_tokens):
    """Site keys are weight names (no layer index), and calibration always
    unrolls — the tree must not depend on the serving cfg's scan setting."""
    cfg, params = cim_setup
    t_scan = calibrate_act_tree(params, cal_tokens,
                                cfg.replace(scan_layers=True))
    t_unroll = calibrate_act_tree(params, cal_tokens,
                                  cfg.replace(scan_layers=False))
    assert t_scan == t_unroll
    assert set(t_scan["sites"]) == {"wq", "wk", "wv", "wo",
                                    "w_gate", "w_up", "w_down"}


def test_tree_entries_carry_grid_and_traffic(cim_setup, cal_tokens):
    cfg, params = cim_setup
    tree = calibrate_act_tree(params, cal_tokens, cfg)
    n_tok = cal_tokens.size
    for name, e in tree["sites"].items():
        assert e["scale"] > 0.0
        assert 0.0 <= e["zero_point"] <= tree["qmax"]
        assert e["calls"] == cfg.n_layers       # one call per layer
        assert e["rows"] == cfg.n_layers * n_tok
        assert e["k"] > 0 and e["m"] > 0
    # per-site grids are genuinely tighter than the whole-model default
    assert min(e["scale"] for e in tree["sites"].values()) \
        < tree["default"]["scale"]


# ---------------------------------------------------------------------------
# search: determinism + budget honesty
# ---------------------------------------------------------------------------
def test_search_deterministic_under_fixed_seed(cim_setup, cal_tokens,
                                               manifest):
    cfg, params = cim_setup
    again = ps.search(params, cal_tokens, cfg, seed=0,
                      bit_candidates=(7.0,), try_per_channel=False)
    assert manifest == again


def test_search_monotone_energy_and_bounded_proxy(manifest):
    m = manifest["metrics"]
    assert m["mixed_pj_per_token"] <= m["uniform_pj_per_token"]
    assert m["energy_win"] >= 1.0
    assert m["kl_proxy"] <= m["kl_uniform"] + m["kl_budget"] + 1e-9
    # every accepted override is coarser than native resolution
    for step in m["trace"]:
        assert step["adc_levels"] < manifest["base_adc_levels"]


# ---------------------------------------------------------------------------
# manifest I/O: round-trip + degradation to uniform defaults
# ---------------------------------------------------------------------------
def test_manifest_round_trip(tmp_path, manifest):
    path = str(tmp_path / "man.json")
    ps.save_manifest(path, manifest)
    loaded = ps.load_manifest(path, arch=manifest["arch"])
    assert loaded == manifest
    ovs = ps.manifest_overrides(loaded)
    assert dict(ovs).keys() == manifest["sites"].keys()
    for name, ov in ovs:
        assert isinstance(ov, SitePrecision)
        assert ov.act_scale == manifest["sites"][name]["act_scale"]


@pytest.mark.parametrize("corrupt", ["missing", "garbage", "schema", "arch"])
def test_manifest_degrades_to_uniform_defaults(tmp_path, manifest, corrupt):
    """Mirrors the PR-6 tune-cache fallback: any load problem warns and
    serves uniform defaults, never raises."""
    path = str(tmp_path / "man.json")
    if corrupt == "garbage":
        with open(path, "w") as f:
            f.write("{this is not json")
    elif corrupt == "schema":
        doc = dict(manifest, schema="pico-ram/precision_manifest/v999")
        with open(path, "w") as f:
            json.dump(doc, f)
    elif corrupt == "arch":
        ps.save_manifest(path, manifest)
    with warnings.catch_warnings(record=True) as ws:
        warnings.simplefilter("always")
        loaded = ps.load_manifest(
            path, arch="some-other-arch" if corrupt == "arch"
            else manifest["arch"])
    assert loaded is None
    assert any("precision manifest" in str(w.message) for w in ws)
    # and the serving-side application is the identity on None
    cim = CIMConfig(enabled=True)
    assert ps.apply_manifest(cim, None) == cim


# ---------------------------------------------------------------------------
# the live dispatch path: ServingConfig(precision_manifest=...) end-to-end
# ---------------------------------------------------------------------------
def test_server_consumes_manifest_through_site_overrides(
        tmp_path, cim_setup, manifest):
    cfg, params = cim_setup
    path = str(tmp_path / "man.json")
    ps.save_manifest(path, manifest)
    server = Server(params, cfg, ServingConfig(
        n_slots=2, max_len=MAX_LEN, precision_manifest=path))
    assert dict(server.cfg.cim.site_overrides).keys() \
        == manifest["sites"].keys()
    assert server.cfg.cim.act.static_scale \
        == pytest.approx(manifest["default"]["act_scale"])
    r = Request(prompt=[1, 2, 3, 4], max_new_tokens=4)
    server.submit(r)
    server.run_until_drained()
    assert len(r.output) == 4

    # a stale manifest (wrong arch) must still serve — uniform defaults
    stale = dict(manifest, arch="some-other-arch")
    ps.save_manifest(path, stale)
    with warnings.catch_warnings(record=True) as ws:
        warnings.simplefilter("always")
        fallback = Server(params, cfg, ServingConfig(
            n_slots=2, max_len=MAX_LEN, precision_manifest=path))
    assert any("precision manifest" in str(w.message) for w in ws)
    assert fallback.cfg.cim.site_overrides == ()
    r2 = Request(prompt=[1, 2, 3, 4], max_new_tokens=4)
    fallback.submit(r2)
    fallback.run_until_drained()
    assert len(r2.output) == 4


def test_site_overrides_change_the_matmul(cim_setup, cal_tokens):
    """An override with different ADC levels must actually change the site's
    numerics (proves resolve_site_cfg is on the live path, not dead
    config)."""
    cfg, params = cim_setup
    tree = calibrate_act_tree(params, cal_tokens, cfg)
    probe = np.random.RandomState(3).randint(0, cfg.vocab, size=(1, 8))
    mod = registry.get_module(cfg)
    base = ps._logits(params, probe, ps._probe_cfg(cfg, {}, tree), mod)
    coarse = ps._logits(params, probe, ps._probe_cfg(
        cfg, {"w_up": SitePrecision(adc_levels=32, scheme="bp")}, tree), mod)
    assert not np.allclose(np.asarray(base), np.asarray(coarse))


def test_serving_config_validates_zero_point():
    with pytest.raises(ValueError, match="act_zero_point"):
        ServingConfig(act_zero_point=3.0)


def test_energy_accounting_matches_uniform_closed_form(cim_setup,
                                                       cal_tokens):
    """Uniform energy/token from the tree must equal the closed-form sum
    over sites of e_mvm_j(k)·m·rows / tokens."""
    from repro.core.energy import mvm_energy
    cfg, params = cim_setup
    tree = calibrate_act_tree(params, cal_tokens, cfg)
    n_tok = cal_tokens.size
    expect = sum(mvm_energy(cfg.cim.macro, e["k"]).e_mvm_j
                 * e["m"] * e["rows"] / n_tok
                 for e in tree["sites"].values())
    got = ps.energy_per_token_j(tree, cfg, {}, n_tok)
    assert got == pytest.approx(expect, rel=1e-12)
