"""kernels.autotune: tuning-cache round-trip into both dispatchers
(paged attention kblocks/row_tile, CIM MVM bm/bn), shape-family bucketing,
and the malformed/stale-cache fallbacks. Pure cache-plumbing tests run in
both REPRO_FORCE_JNP legs; only the end-to-end kernel-execution check
needs Pallas."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels import ops
from repro.kernels import paged_attention as pa
from repro.core.macro import MacroConfig

_FORCED = os.environ.get("REPRO_FORCE_JNP", "").strip().lower() in (
    "1", "true", "yes")
needs_pallas = pytest.mark.skipif(
    _FORCED, reason="direct Pallas kernel tests; REPRO_FORCE_JNP leg is "
                    "jnp-only")


def _write_cache(path, entries):
    doc = autotune.save_cache(str(path), entries)
    assert doc["schema"] == autotune.CACHE_SCHEMA
    return str(path)


# ---------------------------------------------------------------------------
# shape families
# ---------------------------------------------------------------------------
def test_shape_families_bucket():
    assert autotune.attn_family(4096, 1) == "decode_w4096"
    assert autotune.attn_family(40, 1) == "decode_w64"    # rounds up
    assert autotune.attn_family(256, 8) == "prefill_w256"
    assert autotune.mvm_family(32, 4, 128) == "m32_g4_n128"
    assert autotune.mvm_family(33, 4, 128) == "m64_g4_n128"


def test_cache_key_includes_platform():
    k = autotune.cache_key("paged_attn", "decode_w64", "kernel")
    assert k.endswith("|" + jax.default_backend())
    assert autotune.cache_key("a", "b", "c", "tpu") == "a|b|c|tpu"


# ---------------------------------------------------------------------------
# round-trip: write → reload → dispatch picks the tuned config
# ---------------------------------------------------------------------------
def test_attn_dispatch_picks_tuned_config(tmp_path, monkeypatch):
    path = _write_cache(tmp_path / "tc.json", {
        autotune.cache_key("paged_attn", "decode_w64", "kernel"):
            {"kblocks": 4, "row_tile": None},
    })
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    # decode window 40 buckets to w64 → tuned hit, clamped to the geometry
    kb, rt = pa._resolve_attn_config(window=40, c=1, mb=5, cg=2)
    assert (kb, rt) == (4, None)
    # prefill family has no entry → defaults
    assert pa._resolve_attn_config(window=40, c=8, mb=5, cg=16) == (1, None)


def test_attn_tuned_config_clamped_to_geometry(tmp_path, monkeypatch):
    path = _write_cache(tmp_path / "tc.json", {
        autotune.cache_key("paged_attn", "decode_w64", "kernel"):
            {"kblocks": 64, "row_tile": 999},
    })
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    kb, rt = pa._resolve_attn_config(window=64, c=1, mb=3, cg=4)
    assert kb == 3 and rt == 4


def test_mvm_dispatch_picks_tuned_tiles(tmp_path, monkeypatch):
    x = jnp.zeros((8, 288))
    fam = autotune.mvm_family(8, 2, 64)
    path = _write_cache(tmp_path / "tc.json", {
        autotune.cache_key("cim_mvm", fam, "pallas"): {"bm": 32, "bn": 64},
    })
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    assert ops._resolve_tiles(x, 64, 144, None, None) == (32, 64)
    # explicit kwargs always win over the cache
    assert ops._resolve_tiles(x, 64, 144, 16, 16) == (16, 16)
    # a different shape family misses → (128, 128) defaults
    assert ops._resolve_tiles(jnp.zeros((8, 144)), 64, 144,
                              None, None) == (128, 128)


def test_platform_mismatch_is_a_miss(tmp_path, monkeypatch):
    path = _write_cache(tmp_path / "tc.json", {
        autotune.cache_key("paged_attn", "decode_w64", "kernel",
                           platform="tpu-v9"): {"kblocks": 8},
    })
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    assert pa._resolve_attn_config(window=64, c=1, mb=8, cg=2) == (1, None)


def test_cache_reloads_on_rewrite(tmp_path, monkeypatch):
    """A freshly rewritten cache file is picked up without restarting."""
    key = autotune.cache_key("paged_attn", "decode_w64", "kernel")
    path = _write_cache(tmp_path / "tc.json", {key: {"kblocks": 2}})
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    assert pa._resolve_attn_config(window=64, c=1, mb=8, cg=2)[0] == 2
    os.utime(_write_cache(tmp_path / "tc.json", {key: {"kblocks": 8}}),
             (1e9, 1e9))  # force a distinct mtime even on coarse clocks
    assert pa._resolve_attn_config(window=64, c=1, mb=8, cg=2)[0] == 8


@needs_pallas
def test_tuned_attn_end_to_end_matches_exact(tmp_path, monkeypatch):
    """The full dispatch chain under a tuned cache: paged_attention with
    backend="kernel" runs the kblocks>1 pipeline and still matches exact."""
    from tests.test_paged_attention import _make_case
    case = _make_case(61, b=2, mb=8, c=1)
    q, kp, vp, tables, positions, kvl = case
    o_ref = pa.paged_attention(q, kp, vp, tables, positions=positions,
                               kv_len=kvl, backend="exact")
    path = _write_cache(tmp_path / "tc.json", {
        autotune.cache_key("paged_attn", "decode_w64", "kernel"):
            {"kblocks": 4, "row_tile": None},
    })
    monkeypatch.setenv(autotune.CACHE_ENV, path)
    o = pa.paged_attention(q, kp, vp, tables, positions=positions,
                           kv_len=kvl, backend="kernel")
    assert jnp.allclose(o, o_ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# malformed / stale caches degrade to defaults, never error
# ---------------------------------------------------------------------------
def test_missing_cache_file_is_empty(monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, "/nonexistent/tune.json")
    assert autotune.load_cache() == {}
    assert pa._resolve_attn_config(window=64, c=1, mb=8, cg=2) == (1, None)


def test_no_env_is_empty(monkeypatch):
    monkeypatch.delenv(autotune.CACHE_ENV, raising=False)
    assert autotune.load_cache() == {}


def test_malformed_json_falls_back(tmp_path, monkeypatch):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    monkeypatch.setenv(autotune.CACHE_ENV, str(p))
    with pytest.warns(UserWarning, match="ignoring tune cache"):
        assert autotune.load_cache() == {}
    assert pa._resolve_attn_config(window=64, c=1, mb=8, cg=2) == (1, None)


def test_stale_schema_falls_back(tmp_path, monkeypatch):
    p = tmp_path / "stale.json"
    p.write_text(json.dumps({"schema": "pico-ram/tune_cache/v0",
                             "entries": {"x": {"kblocks": 8}}}))
    monkeypatch.setenv(autotune.CACHE_ENV, str(p))
    with pytest.warns(UserWarning, match="schema"):
        assert autotune.load_cache() == {}


def test_non_dict_entries_dropped(tmp_path, monkeypatch):
    p = tmp_path / "odd.json"
    p.write_text(json.dumps({"schema": autotune.CACHE_SCHEMA,
                             "entries": {"a": [1, 2], "b": {"bm": 64}}}))
    monkeypatch.setenv(autotune.CACHE_ENV, str(p))
    assert autotune.load_cache() == {"b": {"bm": 64}}


# ---------------------------------------------------------------------------
# candidate enumeration (what kernel_bench --autotune times)
# ---------------------------------------------------------------------------
def test_attn_candidates_default_first():
    cands = autotune.attn_candidates(512, 2)
    assert cands[0] == {"block_size": None, "kblocks": 1, "row_tile": None}
    assert {"block_size": None, "kblocks": 8, "row_tile": None} in cands
    assert all(c["kblocks"] <= 16 for c in cands)


def test_attn_candidates_block_size_axis():
    """Stating the pool's pagination adds coarser-block layout candidates
    (consumed by serve.py, not the dispatcher); the default stays first."""
    cands = autotune.attn_candidates(256, 4, block_size=16)
    assert cands[0] == {"block_size": 16, "kblocks": 1, "row_tile": None}
    sizes = {c["block_size"] for c in cands}
    assert {16, 64, 128} <= sizes
    # mb=6 is not divisible by 4 or 8 → no coarser layouts proposed
    assert all(c["block_size"] == 8
               for c in autotune.attn_candidates(6, 4, block_size=8))


def test_mvm_candidates_default_first():
    cands = autotune.mvm_candidates(128, 128)
    assert cands[0] == {"bm": 128, "bn": 128}
    assert len(cands) > 1
