"""Unified execution engine: backend registry, auto-selection, custom-VJP
STE, and the nibble-packed serving path (ISSUE 1 acceptance tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CIMConfig, PROTOTYPE, PackedCodes, Scheme, SimLevel,
                        available_backends, choose_backend, cim_matmul,
                        cim_matmul_prequant, cim_matmul_ste, execute_mvm,
                        get_backend)
from repro.core.cim_matmul import quantize_weight_offline
from repro.core.quant import act_scale, quantize_act
from repro.kernels.ops import pack_codes, packed_col_sums, unpack_codes


def _xw(key, m=8, k=300, n=10):
    x = jax.nn.relu(jax.random.normal(key, (m, k)))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    return x, w


# ---------------------------------------------------------------------------
# registry + selection
# ---------------------------------------------------------------------------
def test_registry_has_all_backends():
    assert available_backends() == ("einsum", "pallas", "pallas_packed",
                                    "scan")
    with pytest.raises(ValueError, match="unknown CIM backend"):
        get_backend("does-not-exist")


def test_auto_selects_pallas_at_ideal_bp():
    """Acceptance: backend='auto' picks the fused kernel at IDEAL/BP."""
    x, w = _xw(jax.random.PRNGKey(0))
    assert choose_backend(CIMConfig(enabled=True), x, w) == "pallas"
    packed = PackedCodes(pack_codes(jnp.zeros((300, 10))), 300)
    assert choose_backend(CIMConfig(enabled=True), x, packed) == "pallas_packed"


@pytest.mark.parametrize("level,scheme,expect", [
    (SimLevel.NOISY, Scheme.BP, "einsum"),
    (SimLevel.FULL, Scheme.BP, "einsum"),
    (SimLevel.IDEAL, Scheme.WBS, "einsum"),
    (SimLevel.IDEAL, Scheme.BS, "einsum"),
])
def test_auto_falls_back_to_jnp_backends(level, scheme, expect):
    x, w = _xw(jax.random.PRNGKey(1))
    macro = dataclasses.replace(PROTOTYPE, sim_level=level, scheme=scheme)
    cfg = CIMConfig(enabled=True, macro=macro)
    assert choose_backend(cfg, x, w) == expect


def test_auto_scans_large_noisy_bp_layers():
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    cfg = CIMConfig(enabled=True, macro=macro)
    x = jnp.zeros((4096, 4320))   # 30 groups × 4096 rows × 4096 cols ≫ 64 MB
    w = jnp.zeros((4320, 4096))
    assert choose_backend(cfg, x, w) == "scan"


def test_explicit_backend_validation():
    """The deterministic kernel must refuse stochastic sim levels loudly."""
    x, w = _xw(jax.random.PRNGKey(2))
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    cfg = CIMConfig(enabled=True, macro=macro, backend="pallas")
    with pytest.raises(ValueError, match="deterministic"):
        cim_matmul(x, w, cfg, key=jax.random.PRNGKey(3))
    wbs = CIMConfig(enabled=True, backend="pallas").with_scheme(Scheme.WBS)
    with pytest.raises(ValueError, match="scheme"):
        cim_matmul(x, w, wbs)


# ---------------------------------------------------------------------------
# backend agreement (acceptance: einsum / scan / pallas-interpret allclose)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["einsum", "scan", "pallas",
                                     "pallas_packed"])
@pytest.mark.parametrize("k", [144, 300])
def test_backends_agree_at_ideal(backend, k):
    x, w = _xw(jax.random.PRNGKey(4), k=k)
    ref = cim_matmul(x, w, CIMConfig(enabled=True, backend="einsum"))
    got = cim_matmul(x, w, CIMConfig(enabled=True, backend=backend))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_scan_noise_is_reproducible_and_comparable_to_einsum():
    """Stochastic backends draw per-group keys in a different order, so
    outputs differ draw-by-draw — but a given key must be reproducible and
    the noise magnitude must match the einsum path's."""
    x, w = _xw(jax.random.PRNGKey(5), k=430)
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    key = jax.random.PRNGKey(6)
    ideal = cim_matmul(x, w, CIMConfig(enabled=True, backend="einsum"))
    errs = {}
    for backend in ("einsum", "scan"):
        cfg = CIMConfig(enabled=True, macro=macro, backend=backend)
        y1 = cim_matmul(x, w, cfg, key=key)
        y2 = cim_matmul(x, w, cfg, key=key)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        assert bool(jnp.all(jnp.isfinite(y1)))
        errs[backend] = float(jnp.linalg.norm(y1 - ideal))
    ratio = errs["scan"] / errs["einsum"]
    assert 0.5 < ratio < 2.0, errs


# ---------------------------------------------------------------------------
# packed path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [10, 11, 144, 433])
def test_pack_unpack_roundtrip(k):
    codes = jax.random.randint(jax.random.PRNGKey(7), (k, 5), 0, 16)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(pack_codes(codes), k)),
        np.asarray(codes.astype(jnp.float32)))


def test_pack_codes_leading_dims():
    codes = jax.random.randint(jax.random.PRNGKey(8), (3, 7, 4), 0, 16)
    packed = pack_codes(codes)
    assert packed.shape == (3, 4, 4) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(packed, 7)),
        np.asarray(codes.astype(jnp.float32)))


def test_packed_col_sums_matches_dense():
    codes = jax.random.randint(jax.random.PRNGKey(9), (11, 6), 0, 16)
    np.testing.assert_array_equal(
        np.asarray(packed_col_sums(pack_codes(codes))),
        np.asarray(jnp.sum(codes, axis=0).astype(jnp.float32)))


@pytest.mark.parametrize("k", [288, 300, 433])
def test_packed_kernel_bit_exact_vs_unpacked(k):
    """cim_mvm_pallas_packed ≡ cim_mvm_pallas on random codes, incl. odd K
    and K not a multiple of the macro depth."""
    from repro.kernels.ops import cim_mvm_pallas, cim_mvm_pallas_packed
    key = jax.random.PRNGKey(10)
    x = jax.random.randint(key, (16, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, 24), 0,
                           16).astype(jnp.float32)
    y_plain = cim_mvm_pallas(x, w, PROTOTYPE)
    y_packed = cim_mvm_pallas_packed(x, pack_codes(w), PROTOTYPE)
    np.testing.assert_array_equal(np.asarray(y_packed), np.asarray(y_plain))


@pytest.mark.parametrize("k", [300, 299])
@pytest.mark.parametrize("backend", [None, "einsum", "scan"])
def test_prequant_packed_matches_unpacked(k, backend):
    """Acceptance: the nibble-packed serving path is bit-exact vs the int8
    container path on every backend (jnp backends unpack on the fly)."""
    x, w = _xw(jax.random.PRNGKey(11), k=k)
    cfg = CIMConfig(enabled=True)
    if backend:
        cfg = dataclasses.replace(cfg, backend=backend)
    codes, scale = quantize_weight_offline(w, cfg)
    y_u = cim_matmul_prequant(x, codes, scale, cfg)
    y_p = cim_matmul_prequant(x, pack_codes(codes), scale, cfg)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


def test_execute_mvm_packed_correction_is_exact():
    """Eq. 7 correction from packed_col_sums == correction from dense codes
    even when pack-padding adds a zero row (odd K)."""
    key = jax.random.PRNGKey(12)
    x = jax.nn.relu(jax.random.normal(key, (4, 145)))  # odd K
    cfg = CIMConfig(enabled=True)
    s_x = act_scale(x, cfg.act)
    x_codes, zp = quantize_act(x, s_x, cfg.act)
    codes = jax.random.randint(jax.random.fold_in(key, 1), (145, 3), 0, 16)
    s_w = jnp.asarray(0.01)
    y_dense = execute_mvm(x_codes, codes.astype(jnp.float32), cfg,
                          s_x=s_x, s_w=s_w, x_zero_point=zp)
    y_packed = execute_mvm(x_codes, PackedCodes(pack_codes(codes), 145), cfg,
                           s_x=s_x, s_w=s_w, x_zero_point=zp)
    np.testing.assert_array_equal(np.asarray(y_packed), np.asarray(y_dense))


# ---------------------------------------------------------------------------
# custom-VJP STE
# ---------------------------------------------------------------------------
def test_ste_grad_is_float_matmul_grad():
    """Acceptance: cim_matmul_ste's custom VJP == d(x@w) exactly."""
    x, w = _xw(jax.random.PRNGKey(13))
    cfg = CIMConfig(enabled=True)
    gx, gw = jax.grad(lambda a, b: jnp.sum(cim_matmul_ste(a, b, cfg) ** 2)
                      / 1e3, argnums=(0, 1))(x, w)
    y = cim_matmul(x, w, cfg)          # forward value the cotangent sees
    g = 2.0 * y / 1e3
    np.testing.assert_allclose(np.asarray(gx), np.asarray(g @ w.T),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ g),
                               rtol=1e-5, atol=1e-7)


def test_ste_forward_equals_cim_matmul():
    x, w = _xw(jax.random.PRNGKey(14))
    cfg = CIMConfig(enabled=True)
    np.testing.assert_array_equal(np.asarray(cim_matmul_ste(x, w, cfg)),
                                  np.asarray(cim_matmul(x, w, cfg)))


def test_ste_vmaps_and_jits():
    """The MoE expert path vmaps the STE over experts under jit."""
    x, w = _xw(jax.random.PRNGKey(15), k=144)
    cfg = CIMConfig(enabled=True)
    xs, ws = jnp.stack([x, x * 0.5]), jnp.stack([w, w * 2.0])
    f = jax.jit(jax.vmap(lambda a, b: cim_matmul_ste(a, b, cfg)))
    out = f(xs, ws)
    assert out.shape == (2,) + x.shape[:-1] + (w.shape[-1],)
    g = jax.grad(lambda a: jnp.sum(f(a, ws)))(xs)
    # unit cotangent → dL/dx = 1 @ wᵀ, i.e. each row is Σ_m w[k, m]
    expect0 = jnp.broadcast_to(jnp.sum(ws[0], axis=-1), x.shape)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(expect0),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# wrappers contain no dispatch (acceptance: route through execute_mvm)
# ---------------------------------------------------------------------------
def test_wrappers_route_through_engine(monkeypatch):
    """cim_matmul and cim_matmul_prequant call engine.execute_mvm — no
    direct backend dispatch left in the wrappers."""
    import importlib
    cm = importlib.import_module("repro.core.cim_matmul")
    calls = []
    real = cm.execute_mvm

    def spy(*args, **kwargs):
        calls.append(kwargs.get("backend"))
        return real(*args, **kwargs)

    monkeypatch.setattr(cm, "execute_mvm", spy)
    x, w = _xw(jax.random.PRNGKey(16), k=144)
    cfg = CIMConfig(enabled=True)
    cim_matmul(x, w, cfg)
    codes, scale = quantize_weight_offline(w, cfg)
    cim_matmul_prequant(x, codes, scale, cfg)
    assert len(calls) == 2


def test_cim_matmul_grad_under_auto_matches_einsum_backend():
    """Regression (review): auto→pallas must keep cim_matmul differentiable
    — the kernel's custom VJP delegates to the einsum pipeline's VJP."""
    x, w = _xw(jax.random.PRNGKey(17))
    auto = CIMConfig(enabled=True)
    ein = dataclasses.replace(auto, backend="einsum")
    for argnum in (0, 1):
        g_a = jax.grad(lambda a, b: jnp.sum(cim_matmul(a, b, auto)),
                       argnums=argnum)(x, w)
        g_e = jax.grad(lambda a, b: jnp.sum(cim_matmul(a, b, ein)),
                       argnums=argnum)(x, w)
        np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_e),
                                   rtol=1e-5, atol=1e-6)


def test_prequant_packed_grad_wrt_activations():
    """Input-saliency-style grads flow through the packed kernel (stored
    codes carry no cotangent)."""
    x, w = _xw(jax.random.PRNGKey(18))
    cfg = CIMConfig(enabled=True)
    codes, scale = quantize_weight_offline(w, cfg)
    gp = jax.grad(lambda a: jnp.sum(
        cim_matmul_prequant(a, pack_codes(codes), scale, cfg)))(x)
    gu = jax.grad(lambda a: jnp.sum(cim_matmul_prequant(
        a, codes, scale, dataclasses.replace(cfg, backend="einsum"))))(x)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gu),
                               rtol=1e-5, atol=1e-6)


def test_moe_expert_weights_respect_cim_switch():
    """Regression (review): stored codes are picked up only under
    cfg.cim.enabled, matching common.dense / gru._mm."""
    from repro.configs.registry import SMOKES
    from repro.models.moe import _expert_weights
    cfg_on = SMOKES["qwen2-moe-a2.7b"].replace(cim=CIMConfig(enabled=True))
    cfg_off = cfg_on.replace(cim=CIMConfig(enabled=False))
    p = {"e_gate": jnp.zeros((4, 8, 8)),
         "e_gate_q": jnp.zeros((4, 4, 8), jnp.uint8),
         "e_gate_scale": jnp.ones((4, 1, 1))}
    assert set(_expert_weights(p, "e_gate", cfg_on)) == {"q", "s"}
    assert set(_expert_weights(p, "e_gate", cfg_off)) == {"w"}
