"""Unified execution engine: backend registry, auto-selection, custom-VJP
STE, the nibble-packed serving path (ISSUE 1 acceptance tests), the
stochastic fused backend and per-channel prequant scales (ISSUE 2)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _require_pallas():
    """Skip tests that EXPLICITLY name a Pallas backend when the suite runs
    as the REPRO_FORCE_JNP=1 CI leg: that leg models an environment without
    interpret-mode Pallas, where explicit pallas* requests cannot run (the
    env var only redirects backend="auto"). Auto-based tests keep running —
    proving the escape hatch keeps jnp-only environments green."""
    if os.environ.get("REPRO_FORCE_JNP", "").strip().lower() \
            in ("1", "true", "yes"):
        pytest.skip("explicit Pallas backend; REPRO_FORCE_JNP leg is jnp-only")

from repro.core import (CIMConfig, PROTOTYPE, PackedCodes, Scheme, SimLevel,
                        available_backends, choose_backend, cim_matmul,
                        cim_matmul_prequant, cim_matmul_ste, execute_mvm,
                        get_backend)
from repro.core.cim_matmul import quantize_weight_offline
from repro.core.quant import act_scale, quantize_act
from repro.kernels.ops import pack_codes, packed_col_sums, unpack_codes


def _xw(key, m=8, k=300, n=10):
    x = jax.nn.relu(jax.random.normal(key, (m, k)))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    return x, w


# ---------------------------------------------------------------------------
# registry + selection
# ---------------------------------------------------------------------------
def test_registry_has_all_backends():
    assert available_backends() == ("einsum", "pallas", "pallas_noisy",
                                    "pallas_noisy_packed", "pallas_packed",
                                    "scan")
    with pytest.raises(ValueError, match="unknown CIM backend"):
        get_backend("does-not-exist")


def test_auto_selects_pallas_at_ideal_bp(monkeypatch):
    """Acceptance: backend='auto' picks the fused kernel at IDEAL/BP."""
    monkeypatch.delenv("REPRO_FORCE_JNP", raising=False)
    x, w = _xw(jax.random.PRNGKey(0))
    assert choose_backend(CIMConfig(enabled=True), x, w) == "pallas"
    packed = PackedCodes(pack_codes(jnp.zeros((300, 10))), 300)
    assert choose_backend(CIMConfig(enabled=True), x, packed) == "pallas_packed"


def _noisy_cfg(seed=0, level=SimLevel.NOISY, **kw):
    macro = dataclasses.replace(PROTOTYPE, sim_level=level)
    return CIMConfig(enabled=True, macro=macro, noise_seed=seed, **kw)


@pytest.mark.parametrize("level", [SimLevel.NOISY, SimLevel.FULL])
def test_auto_selects_pallas_noisy_with_seed(monkeypatch, level):
    """Acceptance: auto + BP + NOISY/FULL + noise_seed → the fused
    stochastic kernel (packed sibling for PackedCodes weights); without a
    seed the jnp fallback of test_auto_falls_back_to_jnp_backends holds."""
    monkeypatch.delenv("REPRO_FORCE_JNP", raising=False)
    x, w = _xw(jax.random.PRNGKey(20))
    cfg = _noisy_cfg(level=level)
    assert choose_backend(cfg, x, w) == "pallas_noisy"
    packed = PackedCodes(pack_codes(jnp.zeros((300, 10))), 300)
    assert choose_backend(cfg, x, packed) == "pallas_noisy_packed"
    noseed = dataclasses.replace(cfg, noise_seed=None)
    assert choose_backend(noseed, x, w) == "einsum"


def test_force_jnp_env_override(monkeypatch):
    """REPRO_FORCE_JNP=1 pins auto-selection to the jnp backends (the
    escape hatch for environments without interpret-mode Pallas); explicit
    backend names are honored unchanged."""
    x, w = _xw(jax.random.PRNGKey(21))
    monkeypatch.setenv("REPRO_FORCE_JNP", "1")
    assert choose_backend(CIMConfig(enabled=True), x, w) == "einsum"
    assert choose_backend(_noisy_cfg(), x, w) == "einsum"
    packed = PackedCodes(pack_codes(jnp.zeros((300, 10))), 300)
    assert choose_backend(CIMConfig(enabled=True), x, packed) == "einsum"
    explicit = CIMConfig(enabled=True, backend="pallas")
    assert choose_backend(explicit, x, w) == "pallas"
    monkeypatch.setenv("REPRO_FORCE_JNP", "0")
    assert choose_backend(CIMConfig(enabled=True), x, w) == "pallas"


@pytest.mark.parametrize("level,scheme,expect", [
    (SimLevel.NOISY, Scheme.BP, "einsum"),
    (SimLevel.FULL, Scheme.BP, "einsum"),
    (SimLevel.IDEAL, Scheme.WBS, "einsum"),
    (SimLevel.IDEAL, Scheme.BS, "einsum"),
])
def test_auto_falls_back_to_jnp_backends(level, scheme, expect):
    x, w = _xw(jax.random.PRNGKey(1))
    macro = dataclasses.replace(PROTOTYPE, sim_level=level, scheme=scheme)
    cfg = CIMConfig(enabled=True, macro=macro)
    assert choose_backend(cfg, x, w) == expect


def test_auto_scans_large_noisy_bp_layers():
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    cfg = CIMConfig(enabled=True, macro=macro)
    x = jnp.zeros((4096, 4320))   # 30 groups × 4096 rows × 4096 cols ≫ 64 MB
    w = jnp.zeros((4320, 4096))
    assert choose_backend(cfg, x, w) == "scan"


def test_explicit_backend_validation():
    """The deterministic kernel must refuse stochastic sim levels loudly."""
    x, w = _xw(jax.random.PRNGKey(2))
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    cfg = CIMConfig(enabled=True, macro=macro, backend="pallas")
    with pytest.raises(ValueError, match="deterministic"):
        cim_matmul(x, w, cfg, key=jax.random.PRNGKey(3))
    wbs = CIMConfig(enabled=True, backend="pallas").with_scheme(Scheme.WBS)
    with pytest.raises(ValueError, match="scheme"):
        cim_matmul(x, w, wbs)


# ---------------------------------------------------------------------------
# backend agreement (acceptance: einsum / scan / pallas-interpret allclose)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["einsum", "scan", "pallas",
                                     "pallas_packed"])
@pytest.mark.parametrize("k", [144, 300])
def test_backends_agree_at_ideal(backend, k):
    if backend.startswith("pallas"):
        _require_pallas()
    x, w = _xw(jax.random.PRNGKey(4), k=k)
    ref = cim_matmul(x, w, CIMConfig(enabled=True, backend="einsum"))
    got = cim_matmul(x, w, CIMConfig(enabled=True, backend=backend))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_scan_noise_is_reproducible_and_comparable_to_einsum():
    """Stochastic backends draw per-group keys in a different order, so
    outputs differ draw-by-draw — but a given key must be reproducible and
    the noise magnitude must match the einsum path's."""
    x, w = _xw(jax.random.PRNGKey(5), k=430)
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    key = jax.random.PRNGKey(6)
    ideal = cim_matmul(x, w, CIMConfig(enabled=True, backend="einsum"))
    errs = {}
    for backend in ("einsum", "scan"):
        cfg = CIMConfig(enabled=True, macro=macro, backend=backend)
        y1 = cim_matmul(x, w, cfg, key=key)
        y2 = cim_matmul(x, w, cfg, key=key)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        assert bool(jnp.all(jnp.isfinite(y1)))
        errs[backend] = float(jnp.linalg.norm(y1 - ideal))
    ratio = errs["scan"] / errs["einsum"]
    assert 0.5 < ratio < 2.0, errs


# ---------------------------------------------------------------------------
# packed path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [10, 11, 144, 433])
def test_pack_unpack_roundtrip(k):
    codes = jax.random.randint(jax.random.PRNGKey(7), (k, 5), 0, 16)
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(pack_codes(codes), k)),
        np.asarray(codes.astype(jnp.float32)))


def test_pack_codes_leading_dims():
    codes = jax.random.randint(jax.random.PRNGKey(8), (3, 7, 4), 0, 16)
    packed = pack_codes(codes)
    assert packed.shape == (3, 4, 4) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(unpack_codes(packed, 7)),
        np.asarray(codes.astype(jnp.float32)))


def test_packed_col_sums_matches_dense():
    codes = jax.random.randint(jax.random.PRNGKey(9), (11, 6), 0, 16)
    np.testing.assert_array_equal(
        np.asarray(packed_col_sums(pack_codes(codes))),
        np.asarray(jnp.sum(codes, axis=0).astype(jnp.float32)))


@pytest.mark.parametrize("k", [288, 300, 433])
def test_packed_kernel_bit_exact_vs_unpacked(k):
    """cim_mvm_pallas_packed ≡ cim_mvm_pallas on random codes, incl. odd K
    and K not a multiple of the macro depth."""
    _require_pallas()
    from repro.kernels.ops import cim_mvm_pallas, cim_mvm_pallas_packed
    key = jax.random.PRNGKey(10)
    x = jax.random.randint(key, (16, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, 24), 0,
                           16).astype(jnp.float32)
    y_plain = cim_mvm_pallas(x, w, PROTOTYPE)
    y_packed = cim_mvm_pallas_packed(x, pack_codes(w), PROTOTYPE)
    np.testing.assert_array_equal(np.asarray(y_packed), np.asarray(y_plain))


@pytest.mark.parametrize("k", [300, 299])
@pytest.mark.parametrize("backend", [None, "einsum", "scan"])
def test_prequant_packed_matches_unpacked(k, backend):
    """Acceptance: the nibble-packed serving path is bit-exact vs the int8
    container path on every backend (jnp backends unpack on the fly)."""
    x, w = _xw(jax.random.PRNGKey(11), k=k)
    cfg = CIMConfig(enabled=True)
    if backend:
        cfg = dataclasses.replace(cfg, backend=backend)
    codes, scale = quantize_weight_offline(w, cfg)
    y_u = cim_matmul_prequant(x, codes, scale, cfg)
    y_p = cim_matmul_prequant(x, pack_codes(codes), scale, cfg)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


def test_execute_mvm_packed_correction_is_exact():
    """Eq. 7 correction from packed_col_sums == correction from dense codes
    even when pack-padding adds a zero row (odd K)."""
    key = jax.random.PRNGKey(12)
    x = jax.nn.relu(jax.random.normal(key, (4, 145)))  # odd K
    cfg = CIMConfig(enabled=True)
    s_x = act_scale(x, cfg.act)
    x_codes, zp = quantize_act(x, s_x, cfg.act)
    codes = jax.random.randint(jax.random.fold_in(key, 1), (145, 3), 0, 16)
    s_w = jnp.asarray(0.01)
    y_dense = execute_mvm(x_codes, codes.astype(jnp.float32), cfg,
                          s_x=s_x, s_w=s_w, x_zero_point=zp)
    y_packed = execute_mvm(x_codes, PackedCodes(pack_codes(codes), 145), cfg,
                           s_x=s_x, s_w=s_w, x_zero_point=zp)
    np.testing.assert_array_equal(np.asarray(y_packed), np.asarray(y_dense))


# ---------------------------------------------------------------------------
# custom-VJP STE
# ---------------------------------------------------------------------------
def test_ste_grad_is_float_matmul_grad():
    """Acceptance: cim_matmul_ste's custom VJP == d(x@w) exactly."""
    x, w = _xw(jax.random.PRNGKey(13))
    cfg = CIMConfig(enabled=True)
    gx, gw = jax.grad(lambda a, b: jnp.sum(cim_matmul_ste(a, b, cfg) ** 2)
                      / 1e3, argnums=(0, 1))(x, w)
    y = cim_matmul(x, w, cfg)          # forward value the cotangent sees
    g = 2.0 * y / 1e3
    np.testing.assert_allclose(np.asarray(gx), np.asarray(g @ w.T),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ g),
                               rtol=1e-5, atol=1e-7)


def test_ste_forward_equals_cim_matmul():
    x, w = _xw(jax.random.PRNGKey(14))
    cfg = CIMConfig(enabled=True)
    np.testing.assert_array_equal(np.asarray(cim_matmul_ste(x, w, cfg)),
                                  np.asarray(cim_matmul(x, w, cfg)))


def test_ste_vmaps_and_jits():
    """The MoE expert path vmaps the STE over experts under jit."""
    x, w = _xw(jax.random.PRNGKey(15), k=144)
    cfg = CIMConfig(enabled=True)
    xs, ws = jnp.stack([x, x * 0.5]), jnp.stack([w, w * 2.0])
    f = jax.jit(jax.vmap(lambda a, b: cim_matmul_ste(a, b, cfg)))
    out = f(xs, ws)
    assert out.shape == (2,) + x.shape[:-1] + (w.shape[-1],)
    g = jax.grad(lambda a: jnp.sum(f(a, ws)))(xs)
    # unit cotangent → dL/dx = 1 @ wᵀ, i.e. each row is Σ_m w[k, m]
    expect0 = jnp.broadcast_to(jnp.sum(ws[0], axis=-1), x.shape)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(expect0),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# wrappers contain no dispatch (acceptance: route through execute_mvm)
# ---------------------------------------------------------------------------
def test_wrappers_route_through_engine(monkeypatch):
    """cim_matmul and cim_matmul_prequant call engine.execute_mvm — no
    direct backend dispatch left in the wrappers."""
    import importlib
    cm = importlib.import_module("repro.core.cim_matmul")
    calls = []
    real = cm.execute_mvm

    def spy(*args, **kwargs):
        calls.append(kwargs.get("backend"))
        return real(*args, **kwargs)

    monkeypatch.setattr(cm, "execute_mvm", spy)
    x, w = _xw(jax.random.PRNGKey(16), k=144)
    cfg = CIMConfig(enabled=True)
    cim_matmul(x, w, cfg)
    codes, scale = quantize_weight_offline(w, cfg)
    cim_matmul_prequant(x, codes, scale, cfg)
    assert len(calls) == 2


def test_cim_matmul_grad_under_auto_matches_einsum_backend():
    """Regression (review): auto→pallas must keep cim_matmul differentiable
    — the kernel's custom VJP delegates to the einsum pipeline's VJP."""
    x, w = _xw(jax.random.PRNGKey(17))
    auto = CIMConfig(enabled=True)
    ein = dataclasses.replace(auto, backend="einsum")
    for argnum in (0, 1):
        g_a = jax.grad(lambda a, b: jnp.sum(cim_matmul(a, b, auto)),
                       argnums=argnum)(x, w)
        g_e = jax.grad(lambda a, b: jnp.sum(cim_matmul(a, b, ein)),
                       argnums=argnum)(x, w)
        np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_e),
                                   rtol=1e-5, atol=1e-6)


def test_prequant_packed_grad_wrt_activations():
    """Input-saliency-style grads flow through the packed kernel (stored
    codes carry no cotangent)."""
    x, w = _xw(jax.random.PRNGKey(18))
    cfg = CIMConfig(enabled=True)
    codes, scale = quantize_weight_offline(w, cfg)
    gp = jax.grad(lambda a: jnp.sum(
        cim_matmul_prequant(a, pack_codes(codes), scale, cfg)))(x)
    gu = jax.grad(lambda a: jnp.sum(cim_matmul_prequant(
        a, codes, scale, dataclasses.replace(cfg, backend="einsum"))))(x)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gu),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# stochastic fused backend (acceptance: seeded repro + distribution match)
# ---------------------------------------------------------------------------
def test_noisy_kernel_bit_reproducible_per_seed():
    """Acceptance: same noise_seed → bit-identical outputs; different seeds
    → differing outputs (the counter-based in-kernel PRNG contract)."""
    _require_pallas()
    x, w = _xw(jax.random.PRNGKey(22), m=16, k=430, n=24)
    cfg = _noisy_cfg(seed=7, backend="pallas_noisy")
    y1 = cim_matmul(x, w, cfg)
    y2 = cim_matmul(x, w, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    y3 = cim_matmul(x, w, dataclasses.replace(cfg, noise_seed=8))
    assert bool(jnp.any(y1 != y3))
    assert bool(jnp.all(jnp.isfinite(y1)))


def test_inl_seed_salts_noise_draws():
    """inl_seed decorrelates same-shaped MVMs under one noise_seed (the
    per-layer/per-step salt) on the fused kernel AND the jnp path — without
    it, two identical layers would share one frozen noise realization."""
    x, w = _xw(jax.random.PRNGKey(36), m=16, k=288, n=24)
    for backend in ("einsum", "pallas_noisy"):
        if backend == "pallas_noisy":
            _require_pallas()
        cfg = _noisy_cfg(seed=5, backend=backend)
        y_a = cim_matmul(x, w, cfg, inl_seed=0)
        y_b = cim_matmul(x, w, cfg, inl_seed=1)
        y_a2 = cim_matmul(x, w, cfg, inl_seed=0)
        np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_a2))
        assert bool(jnp.any(y_a != y_b)), backend


@pytest.mark.parametrize("level", [SimLevel.NOISY, SimLevel.FULL])
def test_noisy_kernel_distribution_matches_einsum(level):
    """Acceptance: the fused stochastic kernel's output distribution matches
    the einsum reference — same mean (vs the ideal output) and the same
    ADC-chain error σ within tolerance. Draw-for-draw equality is impossible
    (different PRNGs); distributional agreement is the contract."""
    _require_pallas()
    x, w = _xw(jax.random.PRNGKey(23), m=48, k=432, n=32)
    ideal = cim_matmul(x, w, CIMConfig(enabled=True, backend="einsum"))
    fused = cim_matmul(x, w, _noisy_cfg(seed=3, level=level,
                                        backend="pallas_noisy"))
    ein = cim_matmul(x, w, _noisy_cfg(seed=3, level=level, backend="einsum"))
    e_fused = np.asarray(fused - ideal).ravel()
    e_ein = np.asarray(ein - ideal).ravel()
    # same noise magnitude (σ_E of the simulated converter chain)...
    ratio = float(np.std(e_fused)) / max(float(np.std(e_ein)), 1e-12)
    assert 0.85 < ratio < 1.18, (np.std(e_fused), np.std(e_ein))
    # ...and no systematic bias between the two pipelines
    scale = float(np.std(e_ein)) / np.sqrt(e_ein.size)
    assert abs(float(np.mean(e_fused) - np.mean(e_ein))) < 6 * scale


def test_noisy_packed_bit_identical_to_unpacked():
    """The noise draw depends on (seed, output coordinate, group) only —
    never the weight container — so packed and unpacked stochastic kernels
    agree bit-for-bit under one seed (mirrors the IDEAL packed test)."""
    _require_pallas()
    from repro.kernels.ops import cim_mvm_pallas_noisy, \
        cim_mvm_pallas_noisy_packed
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    key = jax.random.PRNGKey(24)
    for k in (288, 433):
        x = jax.random.randint(key, (16, k), 0, 16).astype(jnp.float32)
        w = jax.random.randint(jax.random.fold_in(key, k), (k, 24), 0,
                               16).astype(jnp.float32)
        y_u = cim_mvm_pallas_noisy(x, w, macro, noise_seed=5)
        y_p = cim_mvm_pallas_noisy_packed(x, pack_codes(w), macro,
                                          noise_seed=5)
        np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


def test_jnp_backends_seeded_reproducible_from_noise_seed():
    """noise_seed without an explicit key also makes einsum/scan runs
    reproducible (the engine derives key = PRNGKey(noise_seed))."""
    x, w = _xw(jax.random.PRNGKey(25), k=430)
    for backend in ("einsum", "scan"):
        cfg = _noisy_cfg(seed=11, backend=backend)
        y1 = cim_matmul(x, w, cfg)
        y2 = cim_matmul(x, w, cfg)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        y3 = cim_matmul(x, w, dataclasses.replace(cfg, noise_seed=12))
        assert bool(jnp.any(y1 != y3))


def test_noisy_grad_under_auto_matches_einsum(monkeypatch):
    """auto→pallas_noisy keeps cim_matmul differentiable: the custom VJP
    delegates to the einsum pipeline's deterministic STE backward."""
    _require_pallas()
    monkeypatch.delenv("REPRO_FORCE_JNP", raising=False)
    x, w = _xw(jax.random.PRNGKey(26))
    auto = _noisy_cfg(seed=2)
    assert choose_backend(auto, x, w) == "pallas_noisy"
    ein = CIMConfig(enabled=True,
                    macro=dataclasses.replace(PROTOTYPE,
                                              sim_level=SimLevel.NOISY),
                    backend="einsum")
    for argnum in (0, 1):
        g_a = jax.grad(lambda a, b: jnp.sum(cim_matmul(a, b, auto)),
                       argnums=argnum)(x, w)
        g_e = jax.grad(lambda a, b: jnp.sum(cim_matmul(a, b, ein)),
                       argnums=argnum)(x, w)
        np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_e),
                                   rtol=1e-5, atol=1e-6)


def test_noisy_prequant_packed_end_to_end():
    """Serving path at NOISY: nibble-packed prequant weights through the
    stochastic packed kernel — reproducible per seed, and in distribution
    with the einsum NOISY prequant reference."""
    _require_pallas()
    x, w = _xw(jax.random.PRNGKey(27), m=32, k=432, n=16)
    cfg = _noisy_cfg(seed=4, backend="pallas_noisy_packed")
    codes, scale = quantize_weight_offline(w, cfg)
    y1 = cim_matmul_prequant(x, pack_codes(codes), scale, cfg)
    y2 = cim_matmul_prequant(x, pack_codes(codes), scale, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    ein = dataclasses.replace(cfg, backend="einsum")
    y_e = cim_matmul_prequant(x, codes, scale, ein)
    ideal = cim_matmul_prequant(
        x, codes, scale, CIMConfig(enabled=True, backend="einsum"))
    ratio = float(jnp.std(y1 - ideal)) / max(float(jnp.std(y_e - ideal)),
                                             1e-12)
    assert 0.7 < ratio < 1.4, ratio


def test_pallas_noisy_rejects_ideal_and_needs_seed():
    x, w = _xw(jax.random.PRNGKey(28))
    cfg = CIMConfig(enabled=True, backend="pallas_noisy")  # IDEAL level
    with pytest.raises(ValueError, match="stochastic"):
        cim_matmul(x, w, cfg)
    noseed = _noisy_cfg(seed=None, backend="pallas_noisy")
    with pytest.raises(ValueError, match="noise_seed"):
        cim_matmul(x, w, noseed)


# ---------------------------------------------------------------------------
# per-channel weight scales through the prequant path
# ---------------------------------------------------------------------------
def _pc_cfg(**kw):
    from repro.core.quant import WeightQuantConfig
    return CIMConfig(enabled=True,
                     weight=WeightQuantConfig(per_channel=True), **kw)


def test_quantize_weight_offline_per_channel_shapes():
    key = jax.random.PRNGKey(29)
    w = jax.random.normal(key, (300, 10))
    codes, scale = quantize_weight_offline(w, _pc_cfg())
    assert scale.shape == (1, 10) and codes.shape == (300, 10)
    stacked = jax.random.normal(key, (4, 300, 10))
    codes_l, scale_l = quantize_weight_offline(stacked, _pc_cfg())
    assert scale_l.shape == (4, 1, 10)
    # each stacked layer quantizes exactly like its unstacked self
    c0, s0 = quantize_weight_offline(stacked[0], _pc_cfg())
    np.testing.assert_array_equal(np.asarray(codes_l[0]), np.asarray(c0))
    np.testing.assert_array_equal(np.asarray(scale_l[0]), np.asarray(s0))


def test_per_channel_bit_exact_vs_per_matrix_when_uniform():
    """Acceptance: when every output channel shares one range, per-channel
    and per-matrix scaling produce bit-identical codes, scales and outputs
    (packed and unpacked)."""
    key = jax.random.PRNGKey(30)
    x, w = _xw(key, k=300)
    amax = float(jnp.max(jnp.abs(w)))
    w = w.at[0, :].set(amax)  # every column attains the same |max|
    pm = CIMConfig(enabled=True)
    pc = _pc_cfg()
    c_pm, s_pm = quantize_weight_offline(w, pm)
    c_pc, s_pc = quantize_weight_offline(w, pc)
    np.testing.assert_array_equal(np.asarray(c_pm), np.asarray(c_pc))
    np.testing.assert_array_equal(
        np.asarray(jnp.broadcast_to(s_pm, s_pc.shape)), np.asarray(s_pc))
    for packer in (lambda c: c, pack_codes):
        y_pm = cim_matmul_prequant(x, packer(c_pm), s_pm, pm)
        y_pc = cim_matmul_prequant(x, packer(c_pc), s_pc, pc)
        np.testing.assert_array_equal(np.asarray(y_pc), np.asarray(y_pm))


@pytest.mark.parametrize("k", [300, 299])
@pytest.mark.parametrize("backend", [None, "einsum", "scan"])
def test_per_channel_prequant_packed_matches_unpacked(k, backend):
    """Acceptance: per-channel s_w flows end-to-end through prequant, packed
    and unpacked bit-exactly equal on every backend (incl. odd K)."""
    x, w = _xw(jax.random.PRNGKey(31), k=k)
    cfg = _pc_cfg() if backend is None \
        else dataclasses.replace(_pc_cfg(), backend=backend)
    codes, scale = quantize_weight_offline(w, cfg)
    y_u = cim_matmul_prequant(x, codes, scale, cfg)
    y_p = cim_matmul_prequant(x, pack_codes(codes), scale, cfg)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))


def test_per_channel_tightens_quantization_error():
    """Per-channel scaling must not lose accuracy — and on a matrix whose
    column ranges differ wildly it must win (the reason the knob exists)."""
    key = jax.random.PRNGKey(32)
    x = jax.nn.relu(jax.random.normal(key, (32, 300)))
    w = jax.random.normal(jax.random.fold_in(key, 1), (300, 10))
    w = w * (10.0 ** jnp.linspace(-2, 0, 10))[None, :]  # 100× range spread
    y_ref = x @ w
    err = {}
    for name, cfg in (("pm", CIMConfig(enabled=True)), ("pc", _pc_cfg())):
        codes, scale = quantize_weight_offline(w, cfg)
        y = cim_matmul_prequant(x, codes, scale, cfg)
        err[name] = float(jnp.linalg.norm(y - y_ref))
    # per-channel halves-plus the end-to-end error here; it cannot reach the
    # full 100× because the shared 8.5-bit ADC quantization error is
    # scale-independent and dominates once weight error shrinks
    assert err["pc"] < 0.6 * err["pm"], err


def test_packedcodes_carries_scale():
    """PackedCodes is self-describing: execute_mvm with s_w=None uses the
    container's scales; cim_matmul_prequant accepts the container form."""
    from repro.core.quant import act_scale as asc, quantize_act as qact
    key = jax.random.PRNGKey(33)
    x, w = _xw(key, k=145)  # odd K exercises pack-padding too
    cfg = _pc_cfg()
    codes, scale = quantize_weight_offline(w, cfg)
    pc = PackedCodes(pack_codes(codes), 145, scale)
    s_x = asc(x, cfg.act)
    x_codes, zp = qact(x, s_x, cfg.act)
    y_carried = execute_mvm(x_codes, pc, cfg, s_x=s_x, s_w=None,
                            x_zero_point=zp)
    y_explicit = execute_mvm(x_codes, pc, cfg, s_x=s_x, s_w=scale,
                             x_zero_point=zp)
    np.testing.assert_array_equal(np.asarray(y_carried),
                                  np.asarray(y_explicit))
    y_wrapper = cim_matmul_prequant(x, pc, None, cfg)
    assert y_wrapper.shape == y_carried.shape
    # a scale-less container without explicit s_w must fail loudly
    bare = PackedCodes(pack_codes(codes), 145)
    with pytest.raises(ValueError, match="s_w"):
        execute_mvm(x_codes, bare, cfg, s_x=s_x, s_w=None, x_zero_point=zp)


def test_per_channel_through_quantize_params_consumer():
    """models.quantize.quantize_params + the GRU consumer run end-to-end
    with per-channel scales (packed serving format)."""
    from repro.models import gru
    from repro.models.quantize import quantize_params
    from repro.core.quant import WeightQuantConfig
    cim = CIMConfig(enabled=True, weight=WeightQuantConfig(per_channel=True))
    cfg = gru.gru_config(cim=cim)
    p = gru.init(jax.random.PRNGKey(34), cfg)
    q = quantize_params(p, cfg)
    assert q["w_z_q"].dtype == jnp.uint8
    assert q["w_z_scale"].shape == (1, cfg.d_model)
    frames = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(35),
                                           (2, 3, cfg.d_model)))
    logits = gru.forward(q, frames, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_moe_expert_weights_respect_cim_switch():
    """Regression (review): stored codes are picked up only under
    cfg.cim.enabled, matching common.dense / gru._mm. Nibble-packed uint8
    codes ride as a PackedCodes container (codes + carried scales, logical
    K from the config); int8 containers keep the {"q", "s"} pair."""
    from repro.configs.registry import SMOKES
    from repro.models.moe import _expert_weights
    cfg_on = SMOKES["qwen2-moe-a2.7b"].replace(cim=CIMConfig(enabled=True))
    cfg_off = cfg_on.replace(cim=CIMConfig(enabled=False))
    p = {"e_gate": jnp.zeros((4, 8, 8)),
         "e_gate_q": jnp.zeros((4, 4, 8), jnp.uint8),
         "e_gate_scale": jnp.ones((4, 1, 1))}
    wp = _expert_weights(p, "e_gate", cfg_on)
    assert set(wp) == {"pk"}
    assert isinstance(wp["pk"], PackedCodes)
    assert wp["pk"].k == cfg_on.d_model
    assert wp["pk"].scale is p["e_gate_scale"]
    assert set(_expert_weights(p, "e_gate", cfg_off)) == {"w"}
    p_int8 = {"e_gate_q": jnp.zeros((4, 8, 8), jnp.int8),
              "e_gate_scale": jnp.ones((4, 1, 1))}
    assert set(_expert_weights(p_int8, "e_gate", cfg_on)) == {"q", "s"}
