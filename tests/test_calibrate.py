"""Static calibrated activation scales: the recorder hook, the calibrate
helper, and the serving-level batch-composition invariance it exists for
(closing the dynamic-act_scale coupling documented in runtime/server.py
since PR 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.calibrate import calibrate_act_scale, collect_act_spans
from repro.configs.registry import SMOKES
from repro.core.cim_matmul import CIMConfig
from repro.core.quant import ActQuantConfig, act_scale, quantize_act, \
    record_act_spans
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig

MAX_LEN = 64


@pytest.fixture(scope="module")
def cim_setup():
    cfg = SMOKES["internlm2-1.8b"].replace(
        dtype="float32", cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg,
                                  max_seq=MAX_LEN)
    return cfg, params


# ---------------------------------------------------------------------------
# quantizer-level static behaviour
# ---------------------------------------------------------------------------
def test_record_act_spans_captures_eager_spans():
    cfg = ActQuantConfig()
    x = jnp.asarray([[-1.0, 0.0, 2.0], [0.5, 3.0, 1.0]])
    with record_act_spans() as spans:
        s = act_scale(x, cfg)
    # span = max - min(·, 0) = 3 - (-1) = 4; scale = span / qmax
    assert spans == [pytest.approx(4.0)]
    assert float(s) == pytest.approx(4.0 / cfg.qmax)
    # recorder closed: no further captures
    act_scale(x, cfg)
    assert len(spans) == 1


def test_static_scale_overrides_dynamic_and_pins_zero_point():
    cfg = ActQuantConfig(static_scale=0.25)
    x = jnp.asarray([-0.4, 0.0, 1.0, 3.0])
    assert float(act_scale(x, cfg)) == pytest.approx(0.25)
    q, zp = quantize_act(x, act_scale(x, cfg), cfg)
    assert float(zp) == 0.0
    # grid is lane-local: q = clip(round(x / 0.25), 0, 15); negatives clip
    assert np.allclose(np.asarray(q), [0.0, 0.0, 4.0, 12.0])
    # and the static grid ignores the tensor's content entirely
    q2, _ = quantize_act(x.at[0].set(-50.0), act_scale(x, cfg), cfg)
    assert np.allclose(np.asarray(q2)[1:], np.asarray(q)[1:])


# ---------------------------------------------------------------------------
# calibrate helper
# ---------------------------------------------------------------------------
def test_collect_spans_one_per_cim_matmul(cim_setup):
    cfg, params = cim_setup
    tokens = np.arange(8, dtype=np.int32).reshape(1, 8) % cfg.vocab
    spans = collect_act_spans(params, tokens, cfg)
    # per-layer profile: qkv+o (4) + swiglu gate/up/down (3) per layer
    # (forward() stops at the final norm — unembed runs at serving time
    # with the same static grid)
    assert len(spans) == cfg.n_layers * 7
    assert all(s > 0 for s in spans)


def test_calibrate_act_scale_values_and_percentile(cim_setup):
    cfg, params = cim_setup
    tokens = np.arange(8, dtype=np.int32).reshape(1, 8) % cfg.vocab
    cal = calibrate_act_scale(params, tokens, cfg)
    assert cal["scale"] == pytest.approx(max(cal["spans"]) / cal["qmax"])
    tight = calibrate_act_scale(params, tokens, cfg, percentile=0.5)
    assert tight["scale"] <= cal["scale"]
    with pytest.raises(ValueError):
        calibrate_act_scale(params, tokens, cfg, percentile=0.0)
    cfg_off = cfg.replace(cim=CIMConfig(enabled=False))
    with pytest.raises(ValueError):
        calibrate_act_scale(params, tokens, cfg_off)


# ---------------------------------------------------------------------------
# the point of it all: batch-composition invariance under static scales
# ---------------------------------------------------------------------------
def test_static_scale_decouples_lane_from_batch(cim_setup):
    """Under a static calibrated scale a request's greedy tokens are
    IDENTICAL whether it serves alone or batched with other requests —
    the dynamic per-tensor act_scale cannot provide this (its grid is a
    global max over the batched tensor)."""
    cfg, params = cim_setup
    tokens = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab
    scale = calibrate_act_scale(params, tokens, cfg)["scale"]
    probe = [5, 9, 2, 7, 4]
    companions = [[11, 3, 8], [1, 2, 3, 4, 5, 6]]

    def probe_tokens(with_companions: bool):
        server = Server(params, cfg, ServingConfig(
            n_slots=3, max_len=MAX_LEN, paged=True, block_size=8,
            prefill_chunk=4, attn="exact", act_scale=scale))
        req = Request(prompt=list(probe), max_new_tokens=4)
        server.submit(req)
        if with_companions:
            for p in companions:
                server.submit(Request(prompt=list(p), max_new_tokens=4))
        server.run_until_drained()
        return req.output

    assert probe_tokens(False) == probe_tokens(True)


def test_server_act_scale_requires_cim(cim_setup):
    cfg, params = cim_setup
    float_cfg = cfg.replace(cim=CIMConfig(enabled=False))
    with pytest.raises(AssertionError):
        Server(params, float_cfg,
               ServingConfig(n_slots=1, max_len=MAX_LEN, act_scale=0.1))


# ---------------------------------------------------------------------------
# regression: the static-grid mismatch (span counts negatives, zp did not)
# ---------------------------------------------------------------------------
def test_static_grid_parity_with_dynamic_on_post_relu():
    """On non-negative (post-ReLU-like) activations the calibrated static
    grid must reproduce the dynamic path's codes exactly: lo = 0 → zp = 0
    and the scales coincide, so static-vs-dynamic is bit-identical."""
    from repro.analysis.calibrate import _grid
    x = jnp.asarray([0.0, 0.3, 1.1, 2.9, 3.0])
    dyn_cfg = ActQuantConfig()
    s_dyn = act_scale(x, dyn_cfg)
    q_dyn, zp_dyn = quantize_act(x, s_dyn, dyn_cfg)
    scale, zp = _grid(0.0, float(jnp.max(x)), dyn_cfg.qmax)
    st_cfg = ActQuantConfig(static_scale=scale, static_zero_point=zp)
    q_st, zp_st = quantize_act(x, act_scale(x, st_cfg), st_cfg)
    assert zp == 0.0 and float(zp_st) == float(zp_dyn) == 0.0
    assert np.array_equal(np.asarray(q_st), np.asarray(q_dyn))


def test_static_grid_bounded_error_on_signed_activations():
    """Signed activations: the span is measured as max − min(·,0), so a
    zp=0 static grid (the old behaviour) clips the whole negative tail the
    calibrated scale reserved range for. With the calibrated zero point the
    dequantized error is bounded by scale/2 everywhere."""
    from repro.analysis.calibrate import _grid
    x = jnp.asarray([-2.0, -0.7, 0.0, 0.9, 2.0])
    qmax = ActQuantConfig().qmax
    span = float(jnp.max(x) - jnp.minimum(jnp.min(x), 0.0))   # recorder's
    scale, zp = _grid(float(jnp.min(x)), span, qmax)
    assert zp > 0.0

    def dequant_err(cfg):
        q, z = quantize_act(x, act_scale(x, cfg), cfg)
        xhat = (q - z) * cfg.static_scale
        return float(jnp.max(jnp.abs(xhat - x)))

    fixed = dequant_err(ActQuantConfig(static_scale=scale,
                                       static_zero_point=zp))
    broken = dequant_err(ActQuantConfig(static_scale=scale))   # old zp=0
    assert fixed <= scale / 2 + 1e-6          # grid covers the signed range
    assert broken >= abs(float(jnp.min(x))) - scale  # negatives clipped
    assert fixed < broken / 3


def test_calibrated_zero_point_flows_through_cim_matmul(cim_setup):
    """End-to-end: calibrating the static grid on the SAME tensor the
    dynamic path sees must give BIT-PARITY with the dynamic matmul —
    identical scale, and the calibrated zero point recovers exactly the
    negative range the dynamic grid covers (the Eq. 7 digital fold). The
    zp=0 static grid of old clips every negative activation instead and is
    strictly worse."""
    cfg, _ = cim_setup
    from repro.core.cim_matmul import cim_matmul
    import dataclasses as dc
    rng = np.random.RandomState(0)
    # negative-shifted activations: the regime the zp=0 grid clips hardest
    x = jnp.asarray((rng.randn(4, 24) - 1.0).astype(np.float32))
    w = jnp.asarray(rng.randn(24, 8).astype(np.float32))
    ref = np.asarray(x @ w)
    span = float(jnp.max(x) - jnp.minimum(jnp.min(x), 0.0))
    from repro.analysis.calibrate import _grid
    scale, zp = _grid(float(jnp.min(x)), span, cfg.cim.act.qmax)

    def run(static_zp):
        cim = dc.replace(cfg.cim, act=dc.replace(
            cfg.cim.act, static_scale=scale, static_zero_point=static_zp))
        return np.asarray(cim_matmul(x, w, cim))

    y_dyn = np.asarray(cim_matmul(x, w, cfg.cim))       # dynamic grid
    np.testing.assert_array_equal(run(zp), y_dyn)       # static parity
    err_fixed = np.abs(run(zp) - ref).max()
    err_broken = np.abs(run(0.0) - ref).max()           # old zp=0 static
    assert err_fixed < err_broken


# ---------------------------------------------------------------------------
# regression: vmapped MoE expert matmuls were silently skipped
# ---------------------------------------------------------------------------
def test_moe_calibration_records_expert_sites():
    """The span recorder must see the routed-expert FFN matmuls (they were
    traced through vmap before — concrete-only recording dropped them
    silently, so expert weights served on an uncalibrated grid)."""
    from repro.analysis.calibrate import calibrate_act_tree
    cfg = SMOKES["qwen2-moe-a2.7b"].replace(
        dtype="float32", cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_LEN)
    tokens = np.arange(8, dtype=np.int32).reshape(1, 8) % cfg.vocab
    tree = calibrate_act_tree(params, tokens, cfg)
    assert {"e_gate", "e_up", "e_down"} <= set(tree["sites"])
    for name in ("e_gate", "e_up", "e_down"):
        e = tree["sites"][name]
        assert e["scale"] > 0.0 and e["k"] > 0 and e["rows"] > 0


def test_recorder_fails_loudly_on_traced_spans():
    """A span the recorder cannot capture concretely (a tracer leaking into
    act_scale under an open recorder) must raise, not silently record
    nothing — that silence was exactly the MoE bug."""
    x = jnp.ones((2, 4))
    with record_act_spans():
        with pytest.raises(RuntimeError, match="traced activation"):
            jax.jit(lambda v: act_scale(v, ActQuantConfig()))(x)
