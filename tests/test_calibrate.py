"""Static calibrated activation scales: the recorder hook, the calibrate
helper, and the serving-level batch-composition invariance it exists for
(closing the dynamic-act_scale coupling documented in runtime/server.py
since PR 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.calibrate import calibrate_act_scale, collect_act_spans
from repro.configs.registry import SMOKES
from repro.core.cim_matmul import CIMConfig
from repro.core.quant import ActQuantConfig, act_scale, quantize_act, \
    record_act_spans
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig

MAX_LEN = 64


@pytest.fixture(scope="module")
def cim_setup():
    cfg = SMOKES["internlm2-1.8b"].replace(
        dtype="float32", cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg,
                                  max_seq=MAX_LEN)
    return cfg, params


# ---------------------------------------------------------------------------
# quantizer-level static behaviour
# ---------------------------------------------------------------------------
def test_record_act_spans_captures_eager_spans():
    cfg = ActQuantConfig()
    x = jnp.asarray([[-1.0, 0.0, 2.0], [0.5, 3.0, 1.0]])
    with record_act_spans() as spans:
        s = act_scale(x, cfg)
    # span = max - min(·, 0) = 3 - (-1) = 4; scale = span / qmax
    assert spans == [pytest.approx(4.0)]
    assert float(s) == pytest.approx(4.0 / cfg.qmax)
    # recorder closed: no further captures
    act_scale(x, cfg)
    assert len(spans) == 1


def test_static_scale_overrides_dynamic_and_pins_zero_point():
    cfg = ActQuantConfig(static_scale=0.25)
    x = jnp.asarray([-0.4, 0.0, 1.0, 3.0])
    assert float(act_scale(x, cfg)) == pytest.approx(0.25)
    q, zp = quantize_act(x, act_scale(x, cfg), cfg)
    assert float(zp) == 0.0
    # grid is lane-local: q = clip(round(x / 0.25), 0, 15); negatives clip
    assert np.allclose(np.asarray(q), [0.0, 0.0, 4.0, 12.0])
    # and the static grid ignores the tensor's content entirely
    q2, _ = quantize_act(x.at[0].set(-50.0), act_scale(x, cfg), cfg)
    assert np.allclose(np.asarray(q2)[1:], np.asarray(q)[1:])


# ---------------------------------------------------------------------------
# calibrate helper
# ---------------------------------------------------------------------------
def test_collect_spans_one_per_cim_matmul(cim_setup):
    cfg, params = cim_setup
    tokens = np.arange(8, dtype=np.int32).reshape(1, 8) % cfg.vocab
    spans = collect_act_spans(params, tokens, cfg)
    # per-layer profile: qkv+o (4) + swiglu gate/up/down (3) per layer
    # (forward() stops at the final norm — unembed runs at serving time
    # with the same static grid)
    assert len(spans) == cfg.n_layers * 7
    assert all(s > 0 for s in spans)


def test_calibrate_act_scale_values_and_percentile(cim_setup):
    cfg, params = cim_setup
    tokens = np.arange(8, dtype=np.int32).reshape(1, 8) % cfg.vocab
    cal = calibrate_act_scale(params, tokens, cfg)
    assert cal["scale"] == pytest.approx(max(cal["spans"]) / cal["qmax"])
    tight = calibrate_act_scale(params, tokens, cfg, percentile=0.5)
    assert tight["scale"] <= cal["scale"]
    with pytest.raises(ValueError):
        calibrate_act_scale(params, tokens, cfg, percentile=0.0)
    cfg_off = cfg.replace(cim=CIMConfig(enabled=False))
    with pytest.raises(ValueError):
        calibrate_act_scale(params, tokens, cfg_off)


# ---------------------------------------------------------------------------
# the point of it all: batch-composition invariance under static scales
# ---------------------------------------------------------------------------
def test_static_scale_decouples_lane_from_batch(cim_setup):
    """Under a static calibrated scale a request's greedy tokens are
    IDENTICAL whether it serves alone or batched with other requests —
    the dynamic per-tensor act_scale cannot provide this (its grid is a
    global max over the batched tensor)."""
    cfg, params = cim_setup
    tokens = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab
    scale = calibrate_act_scale(params, tokens, cfg)["scale"]
    probe = [5, 9, 2, 7, 4]
    companions = [[11, 3, 8], [1, 2, 3, 4, 5, 6]]

    def probe_tokens(with_companions: bool):
        server = Server(params, cfg, ServingConfig(
            n_slots=3, max_len=MAX_LEN, paged=True, block_size=8,
            prefill_chunk=4, attn="exact", act_scale=scale))
        req = Request(prompt=list(probe), max_new_tokens=4)
        server.submit(req)
        if with_companions:
            for p in companions:
                server.submit(Request(prompt=list(p), max_new_tokens=4))
        server.run_until_drained()
        return req.output

    assert probe_tokens(False) == probe_tokens(True)


def test_server_act_scale_requires_cim(cim_setup):
    cfg, params = cim_setup
    float_cfg = cfg.replace(cim=CIMConfig(enabled=False))
    with pytest.raises(AssertionError):
        Server(params, float_cfg,
               ServingConfig(n_slots=1, max_len=MAX_LEN, act_scale=0.1))
