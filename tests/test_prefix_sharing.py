"""Prefix-sharing serving engine: trie reuse, watermark preemption and
parallel sampling are all BIT-identical to one-request-at-a-time decode,
plus the ServingConfig construction surface (validation, from_flags, and
the retirement of the PR-7 legacy-kwarg shim: bare keyword construction
is now a TypeError).

Why bit-identity is even available: K/V content is a pure function of the
absolute-position token prefix, so blocks cached by one request serve any
other request with the same prefix exactly; greedy decode then makes
preemption-resume (re-prefilling prompt + already-emitted tokens)
deterministic. All pinned on attn="exact"; the kernel backend has its own
preemption soak below (token equality, within-float-tolerance argmax).
"""
import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig

MAX_LEN = 64

_FORCED = os.environ.get("REPRO_FORCE_JNP", "").strip().lower() in (
    "1", "true", "yes")
needs_pallas = pytest.mark.skipif(
    _FORCED, reason="explicit Pallas attention backend; REPRO_FORCE_JNP "
                    "leg is jnp-only")


@pytest.fixture(scope="module")
def setup():
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_LEN)
    mod = registry.get_module(cfg)
    prefill = jax.jit(lambda p, b: mod.prefill(p, b, cfg, max_len=MAX_LEN))
    decode = jax.jit(lambda p, t, c: mod.decode_step(p, t, c, cfg))

    def one_at_a_time(prompt, n_new):
        logits, cache = prefill(
            params, {"tokens": jnp.asarray([prompt], jnp.int32)})
        out = [int(jnp.argmax(logits[0]))]
        while len(out) < n_new:
            logits, cache = decode(
                params, jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(logits[0])))
        return out

    return cfg, params, one_at_a_time


def _mk(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("attn", "exact")
    return Server(params, cfg, ServingConfig(paged=True, **kw))


# ---------------------------------------------------------------------------
# trie reuse
# ---------------------------------------------------------------------------
def test_shared_prefix_skips_prefill_bit_identical(setup):
    """A follower sharing a 16-token prompt prefix with a drained request
    prefills only its tail — and still emits exactly its single-request
    tokens (the cached blocks ARE its prefix K/V)."""
    cfg, params, one_at_a_time = setup
    rng = np.random.RandomState(21)
    prefix = rng.randint(0, cfg.vocab, size=16).tolist()
    server = _mk(cfg, params)
    warm = Request(prompt=prefix + [7, 7], max_new_tokens=3)
    server.submit(warm)
    server.run_until_drained()
    assert server.trie.cached_blocks == 2          # 16 tokens / bs 8
    before = server.metrics.prefill_tokens
    follower = Request(prompt=prefix + [3, 1, 4], max_new_tokens=4)
    server.submit(follower)
    server.run_until_drained()
    assert follower.output == one_at_a_time(follower.prompt, 4)
    assert server.metrics.prefix_hit_tokens == 16
    # only the 3-token tail went through the prefill path
    assert server.metrics.prefill_tokens - before == 3
    assert server.trie.hits == 1


def test_sharing_on_off_same_tokens(setup):
    """Sharing is a pure capacity optimization: identical token lists with
    the trie on and off, on a mixed batch of overlapping prompts."""
    cfg, params, _ = setup
    rng = np.random.RandomState(23)
    prefix = rng.randint(0, cfg.vocab, size=8).tolist()
    prompts = [prefix + [t] for t in (5, 9)] + [prefix, [1, 2, 3]]

    def drain(sharing):
        srv = _mk(cfg, params, n_slots=4, prefix_sharing=sharing)
        reqs = [Request(prompt=list(p), max_new_tokens=4) for p in prompts]
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        return [r.output for r in reqs], srv

    on, srv_on = drain(True)
    off, srv_off = drain(False)
    assert on == off
    assert srv_off.metrics.prefix_hit_tokens == 0
    # sequential submits of one batch can't hit (all admitted before any
    # prefill completes); the flush still proves the trie cached blocks
    assert srv_on.flush_prefix_cache() > 0
    assert srv_on.alloc.stats.in_use == 0


def test_flush_prefix_cache_empty_and_disabled(setup):
    cfg, params, _ = setup
    assert _mk(cfg, params).flush_prefix_cache() == 0
    assert _mk(cfg, params, prefix_sharing=False).flush_prefix_cache() == 0


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------
def test_preempted_lane_resumes_via_trie(setup):
    """Under pool pressure with sharing ON, a preempted lane re-admits
    through the trie (its own full blocks were registered at preemption),
    so the resume re-prefills only the partial tail — tokens stay exactly
    the single-request decode's."""
    cfg, params, one_at_a_time = setup
    # ample token budget: all three lanes prefill in lockstep, so the
    # preempted lane has completed ≥ 1 full block (registered at
    # preemption) and its resume provably goes through the trie
    server = _mk(cfg, params, n_slots=3, num_blocks=5, watermark=0.0,
                 token_budget=32)
    rng = np.random.RandomState(29)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=9).tolist(),
                    max_new_tokens=6) for _ in range(3)]
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    for r in reqs:
        assert r.output == one_at_a_time(r.prompt, 6)
    assert server.metrics.preemptions > 0
    assert server.metrics.prefix_hit_tokens > 0   # resumed through the trie
    server.flush_prefix_cache()
    assert server.alloc.stats.in_use == 0


@needs_pallas
def test_preemption_soak_kernel_backend(setup):
    """The same pressure schedule on the Pallas attention backend: token
    equality with one-at-a-time decode survives preemption + trie resume
    on the kernel path too."""
    cfg, params, one_at_a_time = setup
    server = _mk(cfg, params, n_slots=3, num_blocks=5, watermark=0.0,
                 token_budget=32, attn="kernel")
    rng = np.random.RandomState(29)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=9).tolist(),
                    max_new_tokens=6) for _ in range(3)]
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    for r in reqs:
        assert r.output == one_at_a_time(r.prompt, 6)
    assert server.metrics.preemptions > 0


# ---------------------------------------------------------------------------
# parallel sampling
# ---------------------------------------------------------------------------
def test_parallel_samples_match_single_request(setup):
    """n_samples=N: one prefill, N lanes forked copy-on-write off the
    shared block chain. Greedy decode ⇒ every sample must equal the
    single-request tokens — any cross-lane contamination through a shared
    tail block breaks this immediately."""
    cfg, params, one_at_a_time = setup
    server = _mk(cfg, params, n_slots=4)
    prompt = [11, 3, 8, 5, 2, 9, 14, 6, 1, 12, 4]   # 11 tokens: partial tail
    req = Request(prompt=list(prompt), max_new_tokens=5, n_samples=3)
    server.submit(req)
    server.run_until_drained()
    ref = one_at_a_time(prompt, 5)
    assert req.output == ref
    assert len(req.samples) == 2
    for clone in req.samples:
        assert clone.done and clone.output == ref
    # parent + clones each privatized the shared partial tail block
    assert server.metrics.cow_forks == 3
    assert server.metrics.prefix_hit_tokens == 2 * len(prompt)


def test_parallel_sampling_needs_paged_engine(setup):
    cfg, params, _ = setup
    srv = Server(params, cfg, ServingConfig(n_slots=2, max_len=MAX_LEN))
    with pytest.raises(ValueError):
        srv.submit(Request(prompt=[1, 2], max_new_tokens=2, n_samples=2))
    with pytest.raises(ValueError):
        _mk(cfg, params).submit(
            Request(prompt=[1, 2], max_new_tokens=2, n_samples=0))


# ---------------------------------------------------------------------------
# ServingConfig surface
# ---------------------------------------------------------------------------
def test_serving_config_validation():
    for bad in (dict(n_slots=0), dict(max_len=1), dict(prefill_chunk=0),
                dict(token_budget=0), dict(watermark=1.0),
                dict(watermark=-0.1), dict(paged=True, block_size=0),
                dict(paged=True, max_len=100, block_size=16),
                dict(paged=True, num_blocks=0), dict(attn="nope"),
                # PR-8 fields: drafter registry + spec_k + trie watermark
                dict(paged=True, max_len=128, block_size=16,
                     drafter="nope"),
                dict(paged=True, max_len=128, block_size=16,
                     drafter="model:not-a-smoke"),
                dict(drafter="ngram"),              # needs the paged engine
                dict(paged=True, max_len=128, block_size=16, spec_k=0),
                dict(paged=True, max_len=128, block_size=16,
                     trie_watermark=1.5),
                dict(paged=True, max_len=128, block_size=16,
                     prefix_sharing=False, trie_watermark=0.5),
                dict(trie_watermark=0.5)):          # needs the paged engine
        with pytest.raises(ValueError):
            ServingConfig(**bad)
    assert ServingConfig(paged=True, max_len=128, block_size=16)
    assert ServingConfig(paged=True, max_len=128, block_size=16,
                         drafter="ngram", spec_k=2, trie_watermark=0.75)


def test_serving_config_from_flags():
    args = argparse.Namespace(
        slots=3, max_len=32, paged=True, block_size=8, num_blocks=None,
        prefill_chunk=4, token_budget=7, attn="exact", watermark=0.25,
        no_prefix_sharing=False, cim="bp-prequant",
        drafter="ngram", spec_k=2, trie_watermark=0.75)
    sc = ServingConfig.from_flags(args, act_scale=0.5)
    assert sc == ServingConfig(
        n_slots=3, max_len=32, paged=True, block_size=8, prefill_chunk=4,
        token_budget=7, attn="exact", watermark=0.25,
        prequant=True, act_scale=0.5,
        drafter="ngram", spec_k=2, trie_watermark=0.75)
    # --no-prefix-sharing still maps through
    assert not ServingConfig.from_flags(argparse.Namespace(
        paged=True, max_len=32, block_size=8,
        no_prefix_sharing=True)).prefix_sharing
    # missing attributes keep dataclass defaults
    assert ServingConfig.from_flags(argparse.Namespace()) == ServingConfig()


def test_legacy_kwarg_shim_retired(setup):
    """The PR-7 one-release DeprecationWarning shim is gone: bare keyword
    construction raises a TypeError that names ServingConfig, whether the
    kwargs were once-supported names or never existed."""
    cfg, params, _ = setup
    with pytest.raises(TypeError, match="ServingConfig"):
        Server(params, cfg, n_slots=1, max_len=MAX_LEN, paged=True,
               block_size=8, prefill_chunk=4, attn="exact")
    with pytest.raises(TypeError, match="ServingConfig"):   # config + kwargs
        Server(params, cfg, ServingConfig(), n_slots=2)
    with pytest.raises(TypeError, match="ServingConfig"):   # unknown kwarg
        Server(params, cfg, slots=2)
    # the blessed path still works end to end
    srv = Server(params, cfg, ServingConfig(
        n_slots=1, max_len=MAX_LEN, paged=True, block_size=8,
        prefill_chunk=4, attn="exact"))
    req = Request(prompt=[4, 2, 9], max_new_tokens=2)
    srv.submit(req)
    srv.run_until_drained()
    assert req.done and len(req.output) == 2
