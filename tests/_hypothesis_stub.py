"""Minimal stand-in for `hypothesis` so the tier-1 suite collects and runs
in containers without the dependency.

conftest.py installs this into sys.modules as "hypothesis" (and
"hypothesis.strategies") ONLY when the real package is missing — with
hypothesis installed the tests get genuine property-based testing,
shrinking and all. The stub covers exactly the strategy surface the suite
uses (integers / floats / lists) and runs each property deterministically:
`max_examples` draws from a fixed per-test seed, so failures reproduce.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, *, allow_nan: bool = True,
           allow_infinity: bool = True, width: int = 64) -> _Strategy:
    del allow_nan, allow_infinity, width  # stub draws plain finite floats
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.example_from(rng) for _ in range(n)]
    return _Strategy(draw)


_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        # NOT functools.wraps: the wrapper must expose a zero-arg signature
        # (pytest would otherwise read the property's parameters as missing
        # fixtures — real hypothesis consumes them the same way).
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.example_from(rng) for s in strategies]
                fn(*drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper._stub_max_examples = getattr(fn, "_stub_max_examples",
                                             _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return deco
