"""Precision extension (§V nibble-serial 8-bit) and macro mapping (§III-A
9-cell banking / §V-C on-chip residence)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PROTOTYPE
from repro.core.mapping import (MacroBudget, gru_144_shapes, map_layer,
                                map_model)
from repro.core.precision import (extended_matmul, extended_mvm_codes,
                                  split_nibbles)


def test_nibble_split_reconstructs():
    codes = jnp.arange(256.0)
    hi, lo = split_nibbles(codes)
    assert jnp.array_equal(16 * hi + lo, codes)
    assert float(hi.max()) == 15 and float(lo.max()) == 15


def test_extended_mvm_exact_at_full_resolution():
    """With LSB=1 per nibble pass, the 8b×8b decomposition is lossless."""
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (4, 288), 0, 256).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (288, 5), 0,
                           256).astype(jnp.float32)
    cfg = dataclasses.replace(PROTOTYPE, adc_levels=32401)
    y = extended_mvm_codes(x, w, cfg)
    ref = jnp.einsum("bk,km->bm", x, w)
    assert jnp.array_equal(y, ref)


def test_extended_matmul_accuracy_beats_4bit():
    """8b×8b nibble-serial should be far more accurate than single-pass
    4b×4b at the same ADC (it spends 4× the energy — the §II trade)."""
    from repro.core import CIMConfig, cim_matmul
    key = jax.random.PRNGKey(2)
    x = jax.nn.relu(jax.random.normal(key, (16, 288)))
    w = jax.random.normal(jax.random.fold_in(key, 3), (288, 8)) * 0.1
    ref = x @ w
    y8 = extended_matmul(x, w, dataclasses.replace(PROTOTYPE, gain=3.0))
    y4 = cim_matmul(x, w, CIMConfig(
        enabled=True, macro=dataclasses.replace(PROTOTYPE, gain=3.0)))
    err8 = float(jnp.linalg.norm(y8 - ref) / jnp.linalg.norm(ref))
    err4 = float(jnp.linalg.norm(y4 - ref) / jnp.linalg.norm(ref))
    assert err8 < err4


def test_layer_tiling():
    lm = map_layer("ffn", k=300, m=20)
    assert lm.tiles == 3 * 3  # ceil(300/144) × ceil(20/8)


def test_gru_fits_on_chip():
    m = map_model(gru_144_shapes(), MacroBudget(n_macros=64))
    assert m.fits
    assert m.total_weights == 3 * 288 * 144 + 144 * 16
    assert 0.0 < m.bank_utilization() < 1.0
    assert m.reload_bits_per_pass() == 0


def test_overflow_requires_reload():
    m = map_model([("big", 4096, 4096)], MacroBudget(n_macros=4))
    assert not m.fits
    assert m.reload_bits_per_pass() > 0
    assert m.resident_fraction < 1.0
