"""Property-based engine invariants over random shapes (hypothesis).

Runs with real `hypothesis` when installed (the CI tests-full / coverage
jobs), falling back to the deterministic conftest mini-stub otherwise —
strategies here deliberately stay inside the stub's surface (integers /
floats / lists). Properties pinned:

  * pack_codes / unpack_codes round-trip over random (K, N) incl. odd K and
    stacked leading dims (layers / experts);
  * packed_col_sums == the Eq. 7 ΣW̃ of the unpacked codes, exactly;
  * per-channel weight scales: shape contract [..., 1, M], bit-exact
    equivalence with per-matrix scales when every column shares one range,
    and prequant-path agreement between the two scale layouts;
  * salt_seed: salt 0 is the identity, distinct salts produce distinct
    effective seeds (decorrelated converter instances), int32 closure.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cim_matmul import CIMConfig, cim_matmul_prequant, \
    quantize_weight_offline
from repro.kernels.ops import (pack_codes, packed_col_sums, salt_seed,
                               unpack_codes)

_SET = dict(max_examples=25, deadline=None)


def _codes(seed: int, *shape: int) -> np.ndarray:
    return np.random.RandomState(seed).randint(
        0, 16, size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# pack / unpack round-trips
# ---------------------------------------------------------------------------
@settings(**_SET)
@given(st.integers(1, 65), st.integers(1, 24), st.integers(0, 2**16))
def test_pack_unpack_roundtrip(k, n, seed):
    w = _codes(seed, k, n)
    packed = pack_codes(jnp.asarray(w))
    assert packed.dtype == jnp.uint8
    assert packed.shape == ((k + 1) // 2, n)
    back = np.asarray(unpack_codes(packed, k))
    np.testing.assert_array_equal(back, w)
    # without the trim arg, odd K exposes the zero pack-padding row
    full = np.asarray(unpack_codes(packed))
    assert full.shape[0] == 2 * ((k + 1) // 2)
    if k % 2:
        np.testing.assert_array_equal(full[-1], np.zeros(n))


@settings(**_SET)
@given(st.integers(1, 4), st.integers(1, 33), st.integers(1, 8),
       st.integers(0, 2**16))
def test_pack_roundtrip_stacked_leading_dims(lead, k, n, seed):
    """Stacked layers / experts [L, K, N] pass through pack untouched."""
    w = _codes(seed, lead, k, n)
    packed = pack_codes(jnp.asarray(w))
    assert packed.shape == (lead, (k + 1) // 2, n)
    np.testing.assert_array_equal(np.asarray(unpack_codes(packed, k)), w)


@settings(**_SET)
@given(st.integers(1, 65), st.integers(1, 24), st.integers(0, 2**16))
def test_packed_col_sums_matches_unpacked(k, n, seed):
    """Eq. 7 ΣW̃ straight from the packed bytes — exact, incl. odd-K
    pack-padding rows (zero codes are no-ops in the sum)."""
    w = _codes(seed, k, n)
    packed = pack_codes(jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(packed_col_sums(packed)),
                                  w.sum(axis=0))


# ---------------------------------------------------------------------------
# per-channel weight scales
# ---------------------------------------------------------------------------
@settings(**_SET)
@given(st.integers(2, 40), st.integers(1, 12), st.integers(0, 2**16))
def test_per_channel_scale_shape_and_broadcast(k, m, seed):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(k, m).astype(np.float32))
    cfg = CIMConfig(enabled=True)
    cfg_pc = dataclasses.replace(
        cfg, weight=dataclasses.replace(cfg.weight, per_channel=True))
    codes, s_w = quantize_weight_offline(w, cfg_pc)
    assert s_w.shape == (1, m)          # [..., 1, M] broadcast contract
    assert codes.shape == (k, m) and codes.dtype == jnp.int8
    # offset-encoded codes (Eq. 7: W̃ = q + 8) dequantize back to within one
    # scale step of the float weight, per channel
    deq = (np.asarray(codes, np.float32)
           - cfg_pc.weight.offset) * np.asarray(s_w)
    assert np.all(np.abs(deq - np.asarray(w)) <= np.asarray(s_w) + 1e-7)


@settings(**_SET)
@given(st.integers(2, 24), st.integers(1, 8), st.integers(0, 2**16),
       st.floats(0.1, 4.0))
def test_per_channel_equals_per_matrix_on_shared_range(k, m, seed, amp):
    """When every output channel spans the same range the per-channel grid
    degenerates to the per-matrix one — outputs must agree bit-for-bit
    through the full prequant pipeline."""
    rng = np.random.RandomState(seed)
    w = rng.randn(k, m).astype(np.float32)
    # rescale every column to the same |max| so both layouts pick one scale
    # (x / amax(x) puts each column's extreme element at exactly ±1.0)
    w = w / np.max(np.abs(w), axis=0, keepdims=True) * np.float32(amp)
    w = jnp.asarray(w)
    x = jnp.asarray(rng.randn(3, k).astype(np.float32))
    cfg = CIMConfig(enabled=True, backend="einsum")
    cfg_pc = dataclasses.replace(
        cfg, weight=dataclasses.replace(cfg.weight, per_channel=True))
    codes_m, s_m = quantize_weight_offline(w, cfg)
    codes_c, s_c = quantize_weight_offline(w, cfg_pc)
    np.testing.assert_array_equal(np.asarray(codes_m), np.asarray(codes_c))
    y_m = cim_matmul_prequant(x, codes_m, s_m, cfg)
    y_c = cim_matmul_prequant(x, codes_c, s_c, cfg_pc)
    np.testing.assert_array_equal(np.asarray(y_m), np.asarray(y_c))


# ---------------------------------------------------------------------------
# salt_seed contract
# ---------------------------------------------------------------------------
@settings(**_SET)
@given(st.integers(-2**31, 2**31 - 1))
def test_salt_zero_is_identity(seed):
    assert int(salt_seed(seed, 0)) == seed


@settings(**_SET)
@given(st.integers(-2**31, 2**31 - 1), st.integers(0, 1023),
       st.integers(0, 1023))
def test_distinct_salts_decorrelate(seed, a, b):
    """Distinct salts must name distinct converter instances: the XOR with
    the golden-ratio-scrambled salt is injective over the shard/layer salt
    range, so effective seeds never collide (and stay int32)."""
    sa, sb = salt_seed(seed, a), salt_seed(seed, b)
    assert sa.dtype == jnp.int32 and sb.dtype == jnp.int32
    if a != b:
        assert int(sa) != int(sb)
    else:
        assert int(sa) == int(sb)


@settings(**_SET)
@given(st.integers(-2**31, 2**31 - 1), st.integers(1, 2**31 - 1))
def test_salt_matches_traced_python_parity(seed, salt):
    """Python-int salts and traced int32 salts fold identically (the static
    inl_seed salt vs the engine's traced axis_index salt)."""
    static = salt_seed(seed, salt)
    traced = jax.jit(salt_seed)(jnp.int32(seed),
                                jnp.asarray(salt & 0x7FFFFFFF, jnp.int32))
    assert int(static) == int(traced)
