"""Paged-attention kernel subsystem: registry contracts, Pallas-kernel vs
exact-reference parity (decode + chunked prefill, GQA shapes, windows
spanning ≥ 4 blocks), trash-block NaN/garbage hardening, and the
1-device-mesh shard_map bit-identity.

The Pallas tests run the kernel in interpret mode (CPU CI); under
REPRO_FORCE_JNP=1 the explicit-kernel tests skip — that leg models an
environment without interpret-mode Pallas, where auto-selection must pin
the exact backend (which IS tested in that leg).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import paged_attention as pa
from repro.parallel import sharding

_FORCED = os.environ.get("REPRO_FORCE_JNP", "").strip().lower() in (
    "1", "true", "yes")
needs_pallas = pytest.mark.skipif(
    _FORCED, reason="direct Pallas kernel tests; REPRO_FORCE_JNP leg is "
                    "jnp-only")


def _make_case(seed, *, b=3, kh=2, g=2, dh=32, bs=8, mb=5, c=1,
               full_depth=False):
    """Random pool + block tables + per-slot depths for a C-wide step.

    Returns everything both backends consume. Depths are mixed across
    slots (or pinned to the deepest window with full_depth); allocated
    blocks are distinct ids >= 1, unallocated table entries point at the
    trash block 0 — exactly the runtime.paging layout.
    """
    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    w = mb * bs
    nb = b * mb + 1
    q = jax.random.normal(key, (b, c, kh * g, dh), jnp.float32)
    kp = jax.random.normal(jax.random.fold_in(key, 1), (nb, bs, kh, dh),
                           jnp.float32)
    vp = jax.random.normal(jax.random.fold_in(key, 2), (nb, bs, kh, dh),
                           jnp.float32)
    if full_depth:
        lens = np.full(b, w - c, np.int64)
    else:
        lens = np.array([rng.randint(0, w - c + 1) for _ in range(b)])
    kvl = lens + c
    # distinct physical blocks per slot, trash block elsewhere
    free = list(range(1, nb))
    rng.shuffle(free)
    tables = np.zeros((b, mb), np.int32)
    for s in range(b):
        need = -(-int(kvl[s]) // bs)
        for j in range(need):
            tables[s, j] = free.pop()
    positions = jnp.asarray(lens[:, None] + np.arange(c), jnp.int32)
    return (q, kp, vp, jnp.asarray(tables), positions,
            jnp.asarray(kvl, jnp.int32))


def _run(backend, case):
    q, kp, vp, tables, positions, kvl = case
    return pa.paged_attention(q, kp, vp, tables, positions=positions,
                              kv_len=kvl, backend=backend)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contents():
    assert {"exact", "kernel"} <= set(pa.available_attn_backends())
    assert pa.get_attn_backend("exact").name == "exact"
    assert pa.get_attn_backend("kernel").pallas
    with pytest.raises(ValueError, match="unknown attention backend"):
        pa.get_attn_backend("nope")
    with pytest.raises(ValueError):
        pa.choose_attn_backend("nope")


def test_auto_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_JNP", raising=False)
    assert pa.choose_attn_backend("auto") == "kernel"
    assert pa.choose_attn_backend("exact") == "exact"
    monkeypatch.setenv("REPRO_FORCE_JNP", "1")
    assert pa.choose_attn_backend("auto") == "exact"
    # explicit names bypass the env pin, like the CIM engine's backends
    assert pa.choose_attn_backend("kernel") == "kernel"


# ---------------------------------------------------------------------------
# kernel vs exact parity
# ---------------------------------------------------------------------------
@needs_pallas
@pytest.mark.parametrize("kh,g", [(1, 4), (2, 2), (4, 1)])
def test_decode_parity_gqa_shapes(kh, g):
    """C=1 decode at mixed depths over a 5-block window, for MHA/GQA/MQA
    group shapes."""
    case = _make_case(11 + kh, kh=kh, g=g, c=1)
    o_exact = _run("exact", case)
    o_kernel = _run("kernel", case)
    assert o_kernel.shape == o_exact.shape
    assert jnp.allclose(o_kernel, o_exact, atol=2e-5, rtol=2e-5), \
        float(jnp.max(jnp.abs(o_kernel - o_exact)))


@needs_pallas
@pytest.mark.parametrize("c", [2, 5, 8])
def test_prefill_chunk_parity(c):
    """C-wide prefill chunks (causal within the chunk, windows ≥ 4 blocks)
    agree with the exact one-pass softmax."""
    case = _make_case(23 + c, b=2, mb=6, c=c)
    o_exact = _run("exact", case)
    o_kernel = _run("kernel", case)
    assert jnp.allclose(o_kernel, o_exact, atol=2e-5, rtol=2e-5), \
        float(jnp.max(jnp.abs(o_kernel - o_exact)))


@needs_pallas
def test_full_window_decode_parity():
    """Deepest possible decode: every table entry allocated, the query at
    the last position of the window."""
    case = _make_case(5, b=2, mb=4, c=1, full_depth=True)
    assert jnp.allclose(_run("kernel", case), _run("exact", case),
                        atol=2e-5, rtol=2e-5)


@needs_pallas
def test_idle_lane_outputs_finite():
    """kv_len = 0 lanes (idle slots in a mixed batch) must emit finite
    values from both backends — their outputs are discarded, but NaN would
    poison the whole jit output buffer check."""
    q, kp, vp, tables, positions, kvl = _make_case(7, b=2, c=1)
    kvl = kvl.at[0].set(0)
    positions = positions.at[0].set(0)
    tables = tables.at[0].set(0)
    for backend in ("exact", "kernel"):
        o = pa.paged_attention(q, kp, vp, tables, positions=positions,
                               kv_len=kvl, backend=backend)
        assert bool(jnp.all(jnp.isfinite(o))), backend


# ---------------------------------------------------------------------------
# trash-block hardening: NaN/garbage in never-attended storage
# ---------------------------------------------------------------------------
@needs_pallas
@pytest.mark.parametrize("poison", [float("nan"), 1e6, -1e6])
def test_trash_block_poison_invariance(poison):
    """Physical block 0 (masked-lane writes, unallocated table entries) is
    never read at non-zero softmax weight — poisoning it with NaN or huge
    garbage must not change either backend's output by a single bit.
    NaN is the adversarial case: a masked weight of exactly 0 still turns
    into NaN through 0·NaN unless the V rows are sanitized."""
    case = _make_case(31, b=3, mb=5, c=1)
    q, kp, vp, tables, positions, kvl = case
    kp_p = kp.at[0].set(poison)
    vp_p = vp.at[0].set(poison)
    for backend in ("exact", "kernel"):
        clean = pa.paged_attention(q, kp, vp, tables, positions=positions,
                                   kv_len=kvl, backend=backend)
        dirty = pa.paged_attention(q, kp_p, vp_p, tables,
                                   positions=positions, kv_len=kvl,
                                   backend=backend)
        assert jnp.array_equal(clean, dirty), backend


@needs_pallas
def test_stale_block_tail_poison_invariance():
    """Positions past kv_len INSIDE an allocated block (the stale tail a
    LIFO-reused block carries) are masked too: poison every pool position
    at or past each slot's kv_len and require bit-identical outputs."""
    case = _make_case(37, b=2, mb=4, c=3)
    q, kp, vp, tables, positions, kvl = case
    bs = kp.shape[1]
    kp_p, vp_p = np.asarray(kp).copy(), np.asarray(vp).copy()
    for s in range(tables.shape[0]):
        for j, blk in enumerate(np.asarray(tables[s])):
            if blk == 0:
                continue
            off = int(kvl[s]) - j * bs
            if off < bs:
                kp_p[blk, max(off, 0):] = np.nan
                vp_p[blk, max(off, 0):] = np.nan
    for backend in ("exact", "kernel"):
        clean = pa.paged_attention(q, kp, vp, tables, positions=positions,
                                   kv_len=kvl, backend=backend)
        dirty = pa.paged_attention(q, jnp.asarray(kp_p), jnp.asarray(vp_p),
                                   tables, positions=positions, kv_len=kvl,
                                   backend=backend)
        assert jnp.array_equal(clean, dirty), backend


# ---------------------------------------------------------------------------
# mesh dispatch
# ---------------------------------------------------------------------------
@needs_pallas
def test_one_device_mesh_bit_identity():
    """The shard_map wrapping on a 1-device mesh must be bit-identical to
    the plain kernel call (the same contract the CIM engine pins)."""
    from repro.launch.mesh import make_host_mesh
    case = _make_case(41, b=2, c=1)
    ref = _run("kernel", case)
    sharding.set_mesh(make_host_mesh(1, 1))
    try:
        meshed = _run("kernel", case)
    finally:
        sharding.set_mesh(None)
    assert jnp.array_equal(ref, meshed)


def test_exact_backend_matches_pre_registry_math():
    """The exact backend IS the PR-4 path: gather + decode_attention /
    paged_prefill_attention, with the V sanitization a bit-exact no-op on
    clean pools."""
    from repro.models import common
    for c in (1, 4):
        case = _make_case(47 + c, b=2, c=c)
        q, kp, vp, tables, positions, kvl = case
        k_win = common.paged_gather(kp, tables)
        v_win = common.paged_gather(vp, tables)
        if c == 1:
            ref = common.decode_attention(q, k_win, v_win,
                                          kvl[:, None, None, None])
        else:
            ref = common.paged_prefill_attention(q, k_win, v_win,
                                                 positions, kvl)
        got = _run("exact", case)
        assert jnp.array_equal(ref, got)
