"""Paged-attention kernel subsystem: registry contracts, Pallas-kernel vs
exact-reference parity (decode + chunked prefill, GQA shapes, windows
spanning ≥ 4 blocks), trash-block NaN/garbage hardening, and the
1-device-mesh shard_map bit-identity.

The Pallas tests run the kernel in interpret mode (CPU CI); under
REPRO_FORCE_JNP=1 the explicit-kernel tests skip — that leg models an
environment without interpret-mode Pallas, where auto-selection must pin
the exact backend (which IS tested in that leg).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import paged_attention as pa
from repro.parallel import sharding

_FORCED = os.environ.get("REPRO_FORCE_JNP", "").strip().lower() in (
    "1", "true", "yes")
needs_pallas = pytest.mark.skipif(
    _FORCED, reason="direct Pallas kernel tests; REPRO_FORCE_JNP leg is "
                    "jnp-only")


def _make_case(seed, *, b=3, kh=2, g=2, dh=32, bs=8, mb=5, c=1,
               full_depth=False):
    """Random pool + block tables + per-slot depths for a C-wide step.

    Returns everything both backends consume. Depths are mixed across
    slots (or pinned to the deepest window with full_depth); allocated
    blocks are distinct ids >= 1, unallocated table entries point at the
    trash block 0 — exactly the runtime.paging layout.
    """
    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    w = mb * bs
    nb = b * mb + 1
    q = jax.random.normal(key, (b, c, kh * g, dh), jnp.float32)
    kp = jax.random.normal(jax.random.fold_in(key, 1), (nb, bs, kh, dh),
                           jnp.float32)
    vp = jax.random.normal(jax.random.fold_in(key, 2), (nb, bs, kh, dh),
                           jnp.float32)
    if full_depth:
        lens = np.full(b, w - c, np.int64)
    else:
        lens = np.array([rng.randint(0, w - c + 1) for _ in range(b)])
    kvl = lens + c
    # distinct physical blocks per slot, trash block elsewhere
    free = list(range(1, nb))
    rng.shuffle(free)
    tables = np.zeros((b, mb), np.int32)
    for s in range(b):
        need = -(-int(kvl[s]) // bs)
        for j in range(need):
            tables[s, j] = free.pop()
    positions = jnp.asarray(lens[:, None] + np.arange(c), jnp.int32)
    return (q, kp, vp, jnp.asarray(tables), positions,
            jnp.asarray(kvl, jnp.int32))


def _run(backend, case):
    q, kp, vp, tables, positions, kvl = case
    return pa.paged_attention(q, kp, vp, tables, positions=positions,
                              kv_len=kvl, backend=backend)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contents():
    assert {"exact", "kernel"} <= set(pa.available_attn_backends())
    assert pa.get_attn_backend("exact").name == "exact"
    assert pa.get_attn_backend("kernel").pallas
    with pytest.raises(ValueError, match="unknown attention backend"):
        pa.get_attn_backend("nope")
    with pytest.raises(ValueError):
        pa.choose_attn_backend("nope")


def test_auto_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_JNP", raising=False)
    assert pa.choose_attn_backend("auto") == "kernel"
    assert pa.choose_attn_backend("exact") == "exact"
    monkeypatch.setenv("REPRO_FORCE_JNP", "1")
    assert pa.choose_attn_backend("auto") == "exact"
    # explicit names bypass the env pin, like the CIM engine's backends
    assert pa.choose_attn_backend("kernel") == "kernel"


# ---------------------------------------------------------------------------
# kernel vs exact parity
# ---------------------------------------------------------------------------
@needs_pallas
@pytest.mark.parametrize("kh,g", [(1, 4), (2, 2), (4, 1)])
def test_decode_parity_gqa_shapes(kh, g):
    """C=1 decode at mixed depths over a 5-block window, for MHA/GQA/MQA
    group shapes."""
    case = _make_case(11 + kh, kh=kh, g=g, c=1)
    o_exact = _run("exact", case)
    o_kernel = _run("kernel", case)
    assert o_kernel.shape == o_exact.shape
    assert jnp.allclose(o_kernel, o_exact, atol=2e-5, rtol=2e-5), \
        float(jnp.max(jnp.abs(o_kernel - o_exact)))


@needs_pallas
@pytest.mark.parametrize("c", [2, 5, 8])
def test_prefill_chunk_parity(c):
    """C-wide prefill chunks (causal within the chunk, windows ≥ 4 blocks)
    agree with the exact one-pass softmax."""
    case = _make_case(23 + c, b=2, mb=6, c=c)
    o_exact = _run("exact", case)
    o_kernel = _run("kernel", case)
    assert jnp.allclose(o_kernel, o_exact, atol=2e-5, rtol=2e-5), \
        float(jnp.max(jnp.abs(o_kernel - o_exact)))


@needs_pallas
def test_full_window_decode_parity():
    """Deepest possible decode: every table entry allocated, the query at
    the last position of the window."""
    case = _make_case(5, b=2, mb=4, c=1, full_depth=True)
    assert jnp.allclose(_run("kernel", case), _run("exact", case),
                        atol=2e-5, rtol=2e-5)


@needs_pallas
def test_idle_lane_outputs_finite():
    """kv_len = 0 lanes (idle slots in a mixed batch) must emit finite
    values from both backends — their outputs are discarded, but NaN would
    poison the whole jit output buffer check."""
    q, kp, vp, tables, positions, kvl = _make_case(7, b=2, c=1)
    kvl = kvl.at[0].set(0)
    positions = positions.at[0].set(0)
    tables = tables.at[0].set(0)
    for backend in ("exact", "kernel"):
        o = pa.paged_attention(q, kp, vp, tables, positions=positions,
                               kv_len=kvl, backend=backend)
        assert bool(jnp.all(jnp.isfinite(o))), backend


# ---------------------------------------------------------------------------
# trash-block hardening: NaN/garbage in never-attended storage
# ---------------------------------------------------------------------------
@needs_pallas
@pytest.mark.parametrize("poison", [float("nan"), 1e6, -1e6])
def test_trash_block_poison_invariance(poison):
    """Physical block 0 (masked-lane writes, unallocated table entries) is
    never read at non-zero softmax weight — poisoning it with NaN or huge
    garbage must not change either backend's output by a single bit.
    NaN is the adversarial case: a masked weight of exactly 0 still turns
    into NaN through 0·NaN unless the V rows are sanitized."""
    case = _make_case(31, b=3, mb=5, c=1)
    q, kp, vp, tables, positions, kvl = case
    kp_p = kp.at[0].set(poison)
    vp_p = vp.at[0].set(poison)
    for backend in ("exact", "kernel"):
        clean = pa.paged_attention(q, kp, vp, tables, positions=positions,
                                   kv_len=kvl, backend=backend)
        dirty = pa.paged_attention(q, kp_p, vp_p, tables,
                                   positions=positions, kv_len=kvl,
                                   backend=backend)
        assert jnp.array_equal(clean, dirty), backend


@needs_pallas
def test_stale_block_tail_poison_invariance():
    """Positions past kv_len INSIDE an allocated block (the stale tail a
    LIFO-reused block carries) are masked too: poison every pool position
    at or past each slot's kv_len and require bit-identical outputs."""
    case = _make_case(37, b=2, mb=4, c=3)
    q, kp, vp, tables, positions, kvl = case
    bs = kp.shape[1]
    kp_p, vp_p = np.asarray(kp).copy(), np.asarray(vp).copy()
    for s in range(tables.shape[0]):
        for j, blk in enumerate(np.asarray(tables[s])):
            if blk == 0:
                continue
            off = int(kvl[s]) - j * bs
            if off < bs:
                kp_p[blk, max(off, 0):] = np.nan
                vp_p[blk, max(off, 0):] = np.nan
    for backend in ("exact", "kernel"):
        clean = pa.paged_attention(q, kp, vp, tables, positions=positions,
                                   kv_len=kvl, backend=backend)
        dirty = pa.paged_attention(q, jnp.asarray(kp_p), jnp.asarray(vp_p),
                                   tables, positions=positions, kv_len=kvl,
                                   backend=backend)
        assert jnp.array_equal(clean, dirty), backend


# ---------------------------------------------------------------------------
# multi-block pipeline (kblocks > 1) and wide row tiles
# ---------------------------------------------------------------------------
@needs_pallas
@pytest.mark.parametrize("kblocks", [2, 4])
@pytest.mark.parametrize("c", [1, 4])
def test_kblocks_parity(kblocks, c):
    """Fetching kblocks KV blocks per sequential grid step must match the
    single-block pipeline AND the exact backend at mixed depths, for both
    decode and chunked prefill. mb=5 is not divisible by 2 or 4, so the
    block-table padding (trash block 0 on the tail) is exercised too."""
    case = _make_case(53 + kblocks + c, b=3, mb=5, c=c)
    q, kp, vp, tables, positions, kvl = case
    lens = kvl - c
    o_one = pa.paged_flash_attention(q, kp, vp, tables, lens, kvl, kblocks=1)
    o_multi = pa.paged_flash_attention(q, kp, vp, tables, lens, kvl,
                                       kblocks=kblocks)
    o_exact = _run("exact", case)
    assert jnp.allclose(o_multi, o_one, atol=2e-6, rtol=2e-6), \
        float(jnp.max(jnp.abs(o_multi - o_one)))
    assert jnp.allclose(o_multi, o_exact, atol=2e-5, rtol=2e-5)


@needs_pallas
@pytest.mark.parametrize("row_tile", [3, 4])
def test_row_tile_parity(row_tile):
    """Wider C·G row tiles (dividing and non-dividing — the latter pads the
    folded q rows) agree with the single-tile kernel and exact."""
    case = _make_case(59 + row_tile, b=2, kh=2, g=2, mb=6, c=4)  # cg = 8
    q, kp, vp, tables, positions, kvl = case
    lens = kvl - 4
    o_one = pa.paged_flash_attention(q, kp, vp, tables, lens, kvl)
    o_tiled = pa.paged_flash_attention(q, kp, vp, tables, lens, kvl,
                                       kblocks=2, row_tile=row_tile)
    assert jnp.allclose(o_tiled, o_one, atol=2e-6, rtol=2e-6), \
        float(jnp.max(jnp.abs(o_tiled - o_one)))
    assert jnp.allclose(o_tiled, _run("exact", case), atol=2e-5, rtol=2e-5)


@needs_pallas
@pytest.mark.parametrize("poison", [float("nan"), 1e6])
def test_kblocks_trash_poison_invariance(poison):
    """The padded table tail and masked sub-blocks of the multi-block fetch
    all point at trash block 0 — poisoning it must not move a bit even when
    several sub-blocks of one fetch straddle the valid/trash boundary."""
    case = _make_case(67, b=3, mb=5, c=1)
    q, kp, vp, tables, positions, kvl = case
    lens = kvl - 1
    kp_p = kp.at[0].set(poison)
    vp_p = vp.at[0].set(poison)
    for kwargs in ({"kblocks": 4}, {"kblocks": 2, "row_tile": 2}):
        clean = pa.paged_flash_attention(q, kp, vp, tables, lens, kvl,
                                         **kwargs)
        dirty = pa.paged_flash_attention(q, kp_p, vp_p, tables, lens, kvl,
                                         **kwargs)
        assert jnp.array_equal(clean, dirty), kwargs


# ---------------------------------------------------------------------------
# fused decode write-scatter
# ---------------------------------------------------------------------------
@needs_pallas
def test_fused_write_bit_identity():
    """fused_paged_write must land each slot's new K/V row bit-identically
    to the host-side paged_write on every real block; the trash block (the
    one deliberate divergence: invalid lanes become no-ops instead of trash
    writes) is untouched."""
    from repro.models import common
    case = _make_case(71, b=3, mb=5, c=1)
    q, kp, vp, tables, positions, kvl = case
    b, kh, dh = q.shape[0], kp.shape[2], kp.shape[3]
    bs = kp.shape[1]
    key = jax.random.PRNGKey(91)
    new_k = jax.random.normal(key, (b, 1, kh, dh), jnp.float32)
    new_v = jax.random.normal(jax.random.fold_in(key, 1), (b, 1, kh, dh),
                              jnp.float32)
    # valid write targets: slot s appends at kv_len[s]-1 inside its last
    # allocated block; slot 0 is forced invalid (flat_idx 0)
    flat = []
    for s in range(b):
        pos = int(kvl[s]) - 1
        blk = int(tables[s, pos // bs])
        flat.append(blk * bs + pos % bs)
    flat[0] = 0
    flat_idx = jnp.asarray(flat, jnp.int32)[:, None]
    ref_k = common.paged_write(kp, new_k, flat_idx)
    ref_v = common.paged_write(vp, new_v, flat_idx)
    got_k, got_v = pa.fused_paged_write(kp, vp, new_k, new_v, flat_idx)
    assert jnp.array_equal(ref_k[1:], got_k[1:])
    assert jnp.array_equal(ref_v[1:], got_v[1:])
    # trash block: fused keeps the original storage (no-op write)
    assert jnp.array_equal(got_k[0], kp[0])
    assert jnp.array_equal(got_v[0], vp[0])
    # and attention over the written pools agrees bit-for-bit, since the
    # divergent bits live in storage that is never read unmasked
    o_ref = pa.paged_attention(q, ref_k, ref_v, tables, positions=positions,
                               kv_len=kvl, backend="kernel")
    o_got = pa.paged_attention(q, got_k, got_v, tables, positions=positions,
                               kv_len=kvl, backend="kernel")
    assert jnp.array_equal(o_ref, o_got)


# ---------------------------------------------------------------------------
# mesh dispatch
# ---------------------------------------------------------------------------
@needs_pallas
def test_one_device_mesh_bit_identity():
    """The shard_map wrapping on a 1-device mesh must be bit-identical to
    the plain kernel call (the same contract the CIM engine pins)."""
    from repro.launch.mesh import make_host_mesh
    case = _make_case(41, b=2, c=1)
    ref = _run("kernel", case)
    sharding.set_mesh(make_host_mesh(1, 1))
    try:
        meshed = _run("kernel", case)
    finally:
        sharding.set_mesh(None)
    assert jnp.array_equal(ref, meshed)


def test_exact_backend_matches_pre_registry_math():
    """The exact backend IS the PR-4 path: gather + decode_attention /
    paged_prefill_attention, with the V sanitization a bit-exact no-op on
    clean pools."""
    from repro.models import common
    for c in (1, 4):
        case = _make_case(47 + c, b=2, c=c)
        q, kp, vp, tables, positions, kvl = case
        k_win = common.paged_gather(kp, tables)
        v_win = common.paged_gather(vp, tables)
        if c == 1:
            ref = common.decode_attention(q, k_win, v_win,
                                          kvl[:, None, None, None])
        else:
            ref = common.paged_prefill_attention(q, k_win, v_win,
                                                 positions, kvl)
        got = _run("exact", case)
        assert jnp.array_equal(ref, got)
