"""HLO collective parser + roofline arithmetic."""
import pytest

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import Roofline

HLO = """
HloModule test
%add { ... }
%param.3 = bf16[256,14336]{1,0} parameter(0)
%wrapped_convert.1 = f32[4096,512]{1,0} fusion(%param.3)
%all-gather = f32[4096,512]{1,0} all-gather(%wrapped_convert.1), dimensions={0}
%all-reduce = f32[] all-reduce(%wrapped_reduce), to_apply=%add
%wrapped_reduce = f32[128,64]{1,0} fusion(%all-gather)
%rs = bf16[8,16]{1,0} reduce-scatter(%wrapped_reduce), dimensions={0}
%a2a = (f32[2,4]{1,0}, f32[2,4]{1,0}) all-to-all(%x, %y)
%x = f32[2,4]{1,0} parameter(1)
%y = f32[2,4]{1,0} parameter(2)
%cp = f32[16]{0} collective-permute(%x), source_target_pairs={{0,1}}
"""


def test_collective_byte_accounting():
    st = collective_bytes(HLO)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                "reduce-scatter": 1, "all-to-all": 1,
                                "collective-permute": 1}
    assert st.bytes_by_kind["all-gather"] == 4096 * 512 * 4
    assert st.bytes_by_kind["all-reduce"] == 128 * 64 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 128 * 64 * 4
    assert st.bytes_by_kind["all-to-all"] == 2 * (2 * 4 * 4)
    assert st.bytes_by_kind["collective-permute"] == 2 * 4 * 4


def test_roofline_terms_and_dominance():
    rl = Roofline(arch="x", shape="train_4k", mesh="m", chips=256,
                  hlo_flops=197e12, hlo_bytes=819e9 * 2,
                  collective_bytes=50e9 * 0.5,
                  model_flops=197e12 * 256 * 0.8,
                  peak_bytes_per_chip=0, collective_detail={})
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(2.0)
    assert rl.t_collective == pytest.approx(0.5)
    assert rl.dominant == "memory"
    assert rl.useful_ratio == pytest.approx(0.8)
    # bound = 2 s → achieved useful flops/s per chip = 0.8·197e12/2
    assert rl.roofline_fraction == pytest.approx(0.4)
