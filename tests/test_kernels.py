"""Pallas cim_mvm kernel vs pure-jnp oracle: shape/dtype sweeps + properties.

interpret=True executes the kernel body on CPU (the brief's validation mode);
tolerance is a couple of float32 ULPs of the LSB-scaled accumulation (the
kernel and oracle may sum groups in different orders).

The whole module calls the Pallas kernels directly, so it is skipped under
REPRO_FORCE_JNP=1 — that CI leg models an environment WITHOUT interpret-mode
Pallas support, where only the jnp engine backends (and the auto-selection
escape hatch routing to them) must stay green.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.skipif(
    os.environ.get("REPRO_FORCE_JNP", "").strip().lower()
    in ("1", "true", "yes"),
    reason="direct Pallas kernel tests; REPRO_FORCE_JNP leg is jnp-only")

from repro.core.macro import MacroConfig
from repro.core.schemes import bp_mvm
from repro.kernels.ops import cim_mvm_pallas
from repro.kernels.ref import cim_mvm_ref


def _codes(key, shape, dtype=jnp.float32):
    return jax.random.randint(key, shape, 0, 16).astype(dtype)


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (4, 144, 8), (16, 288, 32), (128, 144, 128),
    (130, 1000, 257), (7, 2048, 9), (256, 4320, 64),
])
def test_kernel_matches_ref_shapes(m, k, n):
    key = jax.random.PRNGKey(m * 1000 + k + n)
    x = _codes(key, (m, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    cfg = MacroConfig()
    y_k = cim_mvm_pallas(x, w, cfg)
    kp = -(-k // cfg.n_rows) * cfg.n_rows
    xp = jnp.pad(x, ((0, 0), (0, kp - k)))
    wp = jnp.pad(w, ((0, 0), (0, 0))) if kp == k else \
        jnp.pad(w, ((0, kp - k), (0, 0)))
    y_r = cim_mvm_ref(xp, wp, n_rows=cfg.n_rows, levels=cfg.adc_levels,
                      gain=cfg.gain, full_scale=cfg.full_scale())
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-6, atol=1e-1)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_kernel_input_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    x = _codes(key, (8, 288), dtype)
    w = _codes(jax.random.fold_in(key, 8), (288, 16), dtype)
    cfg = MacroConfig()
    y = cim_mvm_pallas(x, w, cfg)
    y_core = bp_mvm(x.astype(jnp.float32), w.astype(jnp.float32), cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_core),
                               rtol=1e-6, atol=1e-1)


@pytest.mark.parametrize("gain,levels", [(1.0, 362), (2.0, 362), (4.0, 256),
                                         (1.0, 1024)])
def test_kernel_gain_and_levels(gain, levels):
    key = jax.random.PRNGKey(9)
    x = _codes(key, (16, 144))
    w = _codes(jax.random.fold_in(key, 10), (144, 8))
    cfg = MacroConfig(gain=gain, adc_levels=levels)
    y_k = cim_mvm_pallas(x, w, cfg)
    y_c = bp_mvm(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=1e-6, atol=1e-1)


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 128), (128, 32)])
def test_kernel_block_shape_invariance(bm, bn):
    """Output must not depend on the VMEM tile choice."""
    key = jax.random.PRNGKey(11)
    x = _codes(key, (64, 432))
    w = _codes(jax.random.fold_in(key, 12), (432, 64))
    cfg = MacroConfig()
    base = cim_mvm_pallas(x, w, cfg)
    tiled = cim_mvm_pallas(x, w, cfg, bm=bm, bn=bn)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled),
                               rtol=1e-6, atol=1e-2)


def test_kernel_batched_leading_dims():
    key = jax.random.PRNGKey(13)
    x = _codes(key, (2, 3, 5, 288))
    w = _codes(jax.random.fold_in(key, 14), (288, 16))
    cfg = MacroConfig()
    y = cim_mvm_pallas(x, w, cfg)
    assert y.shape == (2, 3, 5, 16)
    y2 = bp_mvm(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-6, atol=1e-1)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 96), st.integers(1, 500),
       st.integers(1, 40))
def test_kernel_property_random_shapes(seed, m, k, n):
    key = jax.random.PRNGKey(seed)
    x = _codes(key, (m, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    cfg = MacroConfig()
    y_k = cim_mvm_pallas(x, w, cfg)
    y_c = bp_mvm(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=1e-6, atol=1e-1)


def test_kernel_exact_when_lsb_one():
    """Same losslessness property as the core pipeline."""
    key = jax.random.PRNGKey(15)
    x = _codes(key, (32, 288))
    w = _codes(jax.random.fold_in(key, 16), (288, 24))
    cfg = MacroConfig(adc_levels=32401)
    y = cim_mvm_pallas(x, w, cfg)
    assert jnp.array_equal(y, jnp.einsum("mk,kn->mn", x, w))


def test_packed_kernel_matches_unpacked():
    """4-bit-packed weights (2 codes/byte) must agree with the plain kernel
    — same math, quarter the weight HBM bytes."""
    from repro.kernels.ops import cim_mvm_pallas_packed, pack_codes
    key = jax.random.PRNGKey(21)
    cfg = MacroConfig()
    x = _codes(key, (32, 432))          # 3 macro groups, even K
    w = _codes(jax.random.fold_in(key, 22), (432, 24))
    y_plain = cim_mvm_pallas(x, w, cfg)
    y_packed = cim_mvm_pallas_packed(x, pack_codes(w), cfg)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_plain),
                               rtol=1e-6, atol=1e-2)


def test_pack_codes_roundtrip():
    from repro.kernels.ops import pack_codes
    w = _codes(jax.random.PRNGKey(23), (10, 7))
    p = np.asarray(pack_codes(w))
    lo, hi = p & 15, (p >> 4) & 15
    recon = np.stack([lo, hi], 1).reshape(10, 7)
    np.testing.assert_array_equal(recon, np.asarray(w))
