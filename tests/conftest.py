import os
import sys

# tests run single-device (smoke configs); the dry-run subprocess tests set
# their own XLA_FLAGS — never set device-count flags here (per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
