import os
import sys

# tests run single-device (smoke configs); the dry-run subprocess tests set
# their own XLA_FLAGS — never set device-count flags here (per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property-based tests prefer real hypothesis; containers without it fall
# back to the deterministic mini-shim so the tier-1 suite still collects
# and runs every module (see _hypothesis_stub.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub as _stub

    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub
    _stub.strategies = _stub
