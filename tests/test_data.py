"""Data pipeline: determinism, elasticity, learnability structure."""
import numpy as np

from repro.data.tokens import SyntheticLMDataset


def test_batches_are_deterministic():
    a = SyntheticLMDataset(512, 32, 8, seed=3).batch(5)
    b = SyntheticLMDataset(512, 32, 8, seed=3).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    d = SyntheticLMDataset(512, 32, 4).batch(0)
    np.testing.assert_array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])


def test_elastic_host_sharding_reconstructs_global_batch():
    """2 hosts × half-batch vs 1 host × full batch — host shards differ by
    host_id but each host's stream is reproducible independently."""
    h0 = SyntheticLMDataset(512, 16, 8, n_hosts=2, host_id=0)
    h1 = SyntheticLMDataset(512, 16, 8, n_hosts=2, host_id=1)
    assert h0.host_batch == h1.host_batch == 4
    b0, b1 = h0.batch(3), h1.batch(3)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # restart reproducibility at any step
    np.testing.assert_array_equal(h0.batch(3)["tokens"], b0["tokens"])


def test_vocab_bounds():
    d = SyntheticLMDataset(100, 64, 4).batch(0)
    assert d["tokens"].min() >= 0 and d["tokens"].max() < 100
