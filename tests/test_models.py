"""Per-architecture smoke tests (reduced same-family configs, CPU) and
cache-consistency checks (decode recurrence vs full-sequence forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, SMOKES
from repro.data.tokens import synthetic_batch
from repro.models import registry

ALL_ARCHS = sorted(SMOKES)


def _train_batch(cfg, b=2, s=32, key=0):
    shape = ShapeConfig("t", s, b, "train")
    return synthetic_batch(cfg, shape, step=key)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_shapes_and_finiteness(arch):
    cfg = SMOKES[arch]
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=40)
    batch = _train_batch(cfg)
    mod = registry.get_module(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: mod.train_loss(p, batch, cfg, None))(params)
    assert jnp.isfinite(loss), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in leaves), arch
    # every parameter should receive some gradient signal overall
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in leaves)
    assert total > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill(T)+decode(T+1th) must equal prefill(T+1)'s last logits.

    Validates KV/latent/SSM caches against the chunked full-sequence path —
    for RWKV6/Mamba2 this is the chunk-algebra vs exact-recurrence identity.
    f32 so tolerances are meaningful.
    """
    cfg = SMOKES[arch].replace(dtype="float32")
    if cfg.moe is not None:
        # exactness needs no capacity drops (prefill routes T tokens at
        # once, decode routes 1 — different capacities ⇒ different drops)
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, capacity_factor=16.0))
    t = 17  # deliberately not a multiple of the chunk sizes
    max_len = t + 4 + cfg.n_image_tokens  # image prefix occupies cache slots
    params = registry.init_params(jax.random.PRNGKey(1), cfg, max_seq=max_len)
    mod = registry.get_module(cfg)
    key = jax.random.PRNGKey(7)
    full = {"tokens": jax.random.randint(key, (2, t + 1), 0, cfg.vocab)}
    if cfg.n_image_tokens:
        full["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (2, cfg.n_image_tokens, cfg.d_model)) * 0.02
    if cfg.encoder_layers:
        full["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (2, cfg.encoder_len, cfg.d_model)) * 0.02
    part = {k: (v[:, :t] if k == "tokens" else v) for k, v in full.items()}

    logits_full, _ = mod.prefill(params, full, cfg, max_len=max_len)
    _, cache = mod.prefill(params, part, cfg, max_len=max_len)
    logits_dec, _ = mod.decode_step(params, full["tokens"][:, t:t + 1],
                                    cache, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen2-moe-a2.7b", "rwkv6-7b",
                                  "zamba2-2.7b"])
def test_cim_mode_trains(arch):
    """The paper's technique as a config switch: QAT forward runs the analog
    pipeline, gradients flow via STE."""
    from repro.core.cim_matmul import CIMConfig
    cfg = SMOKES[arch].replace(cim=CIMConfig(enabled=True), dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(2), cfg, max_seq=40)
    batch = _train_batch(cfg, b=2, s=16)
    mod = registry.get_module(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: mod.train_loss(p, batch, cfg, None))(params)
    assert jnp.isfinite(loss)
    gsum = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
               for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


def test_full_configs_match_assignment():
    """The exact assigned hyper-parameters (brief's table)."""
    c = ARCHS["qwen2-moe-a2.7b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (24, 2048, 16, 16, 1408, 151936)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (60, 4, 4)
    c = ARCHS["deepseek-v3-671b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128,
                                                           129280)
    assert (c.moe.n_experts, c.moe.top_k) == (256, 8) and c.mla and c.mtp
    c = ARCHS["rwkv6-7b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 4096, 14336,
                                                        65536)
    c = ARCHS["internvl2-26b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 6144, 48, 8, 16384, 92553)
    c = ARCHS["llama3-8b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 8, 14336, 128256)
    c = ARCHS["granite-3-8b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (40, 4096, 12800,
                                                        49155)
    c = ARCHS["internlm2-1.8b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (24, 2048, 8192, 92544)
    c = ARCHS["stablelm-3b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        32, 2560, 32, 6912, 50304)
    c = ARCHS["zamba2-2.7b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (54, 2560, 10240,
                                                        32000)
    assert c.ssm.d_state == 64
    c = ARCHS["whisper-large-v3"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        32, 1280, 20, 5120, 51866)


def test_chunked_attention_matches_dense():
    """Flash-style online softmax vs naive attention."""
    from repro.models.common import chunked_attention
    key = jax.random.PRNGKey(3)
    b, t, h, kh, dh = 2, 37, 4, 2, 16
    q = jax.random.normal(key, (b, t, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kh, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kh, dh))
    out = chunked_attention(q, k, v, causal=True, chunk=8)
    # naive reference
    g = h // kh
    qg = q.reshape(b, t, kh, g, dh)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k) / np.sqrt(dh)
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bqkgc,bckd->bqkgd", p, v).reshape(b, t, h, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
