"""Mesh-sharded fused CIM dispatch: sharded-vs-single-device semantics.

Fast lanes run in-process on the default single CPU device (a 1-device mesh
must be bit-identical to the unsharded kernel — the salt is 0 and shard_map
is an identity wrapper). Multi-device semantics (psum over the contraction
shards, axis_index-salted seed decorrelation, packed/unpacked bit-identity
under a mesh) run in a subprocess with 4 forced host devices, since jax
locks the device count at first init.
"""
import dataclasses
import os
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cim_matmul import CIMConfig, cim_matmul
from repro.core.engine import choose_backend
from repro.core.macro import SimLevel
from repro.kernels.ops import salt_seed
from repro.launch.mesh import make_host_mesh
from repro.parallel import sharding

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FORCED = os.environ.get("REPRO_FORCE_JNP", "").strip().lower() in (
    "1", "true", "yes")
needs_pallas = pytest.mark.skipif(
    _FORCED, reason="REPRO_FORCE_JNP pins auto to jnp backends")


def _noisy_cfg(seed=0, backend="auto"):
    return CIMConfig(
        enabled=True, backend=backend, noise_seed=seed,
        macro=dataclasses.replace(CIMConfig().macro,
                                  sim_level=SimLevel.NOISY))


def _xw(key, m=8, k=576, n=64):
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    return x, w


@pytest.fixture
def mesh1():
    mesh = make_host_mesh(1, 1)
    sharding.set_mesh(mesh)
    yield mesh
    sharding.set_mesh(None)


# ---------------------------------------------------------------------------
# fast in-process lanes
# ---------------------------------------------------------------------------
@needs_pallas
def test_one_device_mesh_bit_identical_noisy(mesh1):
    """Acceptance: the shard_map-wrapped fused stochastic kernel under a
    1-device mesh is bit-identical to the unsharded call (axis_index salt
    is 0 → same PRNG stream, same group boundaries)."""
    x, w = _xw(jax.random.PRNGKey(0))
    cfg = _noisy_cfg(seed=3)
    y_mesh = cim_matmul(x, w, cfg)
    sharding.set_mesh(None)
    y_plain = cim_matmul(x, w, cfg)
    np.testing.assert_array_equal(np.asarray(y_mesh), np.asarray(y_plain))


@needs_pallas
def test_one_device_mesh_bit_identical_ideal(mesh1):
    x, w = _xw(jax.random.PRNGKey(1))
    cfg = CIMConfig(enabled=True)
    y_mesh = cim_matmul(x, w, cfg)
    sharding.set_mesh(None)
    np.testing.assert_array_equal(np.asarray(y_mesh),
                                  np.asarray(cim_matmul(x, w, cfg)))


def test_auto_still_resolves_fused_under_mesh(monkeypatch):
    """A mesh no longer demotes NOISY+seed auto-selection to scan — the
    engine wraps the fused kernel in shard_map instead (the selection
    itself is mesh-independent; REPRO_FORCE_JNP still pins jnp)."""
    mesh = types.SimpleNamespace(axis_names=("data", "model"),
                                 shape={"data": 16, "model": 16})
    monkeypatch.setattr(sharding, "_MESH", mesh)
    x = jnp.zeros((4, 576))
    w = jnp.zeros((576, 64))
    monkeypatch.delenv("REPRO_FORCE_JNP", raising=False)
    assert choose_backend(_noisy_cfg(seed=0), x, w) == "pallas_noisy"
    monkeypatch.setenv("REPRO_FORCE_JNP", "1")
    assert choose_backend(_noisy_cfg(seed=0), x, w) in ("einsum", "scan")


def test_mvm_plan_axis_assignment(monkeypatch):
    mesh = types.SimpleNamespace(axis_names=("pod", "data", "model"),
                                 shape={"pod": 2, "data": 16, "model": 16})
    monkeypatch.setattr(sharding, "_MESH", mesh)
    # K=2304 divides 16 → contraction over data; M=2048 over model; the
    # leading activation dim over pod
    plan = sharding.mvm_plan((128, 1, 2304), 2304, 2048)
    assert plan.ctr_axes == ("data",)
    assert plan.col_axes == ("model",)
    assert plan.row_axes == ("pod",)
    # K not divisible → contraction replicated, rows take data too
    plan = sharding.mvm_plan((128, 1, 2300), 2300, 2048)
    assert plan.ctr_axes == ()
    assert plan.row_axes == ("pod", "data")
    # packed weights shard K in byte units: K=2304 divides 16 but not 32
    # half-rows → k_unit=2 drops the contraction sharding at data=16 when
    # K/16 would be odd
    plan = sharding.mvm_plan((8, 2288), 2288, 64, k_unit=2)
    assert plan.ctr_axes == ()   # 2288 % (16*2) = 16 → replicate
    plan = sharding.mvm_plan((8, 2304), 2304, 64, k_unit=2)
    assert plan.ctr_axes == ("data",)
    # no mesh → identity plan
    monkeypatch.setattr(sharding, "_MESH", None)
    plan = sharding.mvm_plan((8, 2304), 2304, 64)
    assert plan.ctr_axes == plan.row_axes == plan.col_axes == ()


def test_in_shard_context_flag():
    """sharding.shard_map marks its body trace: the engine's nesting guard
    (a matmul inside the MoE EP region must not open a second shard_map)."""
    mesh = make_host_mesh(1, 1)
    seen = []

    def body(x):
        seen.append(sharding.in_shard_context())
        return x * 2

    assert not sharding.in_shard_context()
    out = sharding.shard_map(
        body, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),),
        out_specs=jax.sharding.PartitionSpec(),
        check_vma=False)(jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4,)))
    assert seen == [True]
    assert not sharding.in_shard_context()


def test_salt_seed_contract():
    """salt 0 = identity; distinct salts give distinct streams; python-int
    and traced salts agree (the inl_seed/axis_index salting contract)."""
    s = jnp.int32(1234)
    assert int(salt_seed(s, 0)) == 1234
    a, b = int(salt_seed(s, 1)), int(salt_seed(s, 2))
    assert len({1234, a, b}) == 3
    assert int(salt_seed(s, jnp.int32(7))) == int(salt_seed(s, 7))
    # golden-ratio scramble, bit-for-bit: seed ^ (salt * 0x9E3779B9 mod 2^32)
    expect = np.uint32(1234) ^ np.uint32((7 * 0x9E3779B9) & 0xFFFFFFFF)
    assert np.uint32(int(salt_seed(s, 7)) & 0xFFFFFFFF) == expect


# ---------------------------------------------------------------------------
# multi-device semantics (subprocess: 4 forced host devices, 2×2 mesh)
# ---------------------------------------------------------------------------
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.pop("REPRO_FORCE_JNP", None)
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.core.cim_matmul import (CIMConfig, cim_matmul,
                                   cim_matmul_prequant,
                                   quantize_weight_offline)
from repro.core.macro import SimLevel
from repro.kernels.ops import pack_codes
from repro.launch.mesh import make_host_mesh
from repro.parallel import sharding

noisy = CIMConfig(enabled=True, noise_seed=3,
                  macro=dataclasses.replace(CIMConfig().macro,
                                            sim_level=SimLevel.NOISY))
ideal = CIMConfig(enabled=True)
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 576))
w = jax.random.normal(jax.random.fold_in(key, 1), (576, 64)) * 0.1

# unsharded references
y_ideal = cim_matmul(x, w, ideal)
y_noisy_1dev = cim_matmul(x, w, noisy)
y_ein = cim_matmul(x, w, dataclasses.replace(noisy, backend="einsum"))

mesh = make_host_mesh(2, 2)
sharding.set_mesh(mesh)

# 1) deterministic kernel: K=576 over data=2 → 288 per shard, group
# boundaries stay aligned to the 144-row macro depth, so the sharded MVM is
# the same set of ADC conversions — equal up to f32 reassociation of the
# correction arithmetic.
y_ideal_m = cim_matmul(x, w, ideal)
np.testing.assert_allclose(np.asarray(y_ideal_m), np.asarray(y_ideal),
                           rtol=5e-3, atol=1e-3)

# 2) stochastic kernel through the psum path: same ADC-chain error
# distribution as the einsum reference (PR 2 tolerances)
y_noisy_m = cim_matmul(x, w, noisy)
e_sh = np.asarray(y_noisy_m - y_ideal).ravel()
e_ein = np.asarray(y_ein - y_ideal).ravel()
ratio = float(np.std(e_sh)) / max(float(np.std(e_ein)), 1e-12)
assert 0.85 < ratio < 1.18, (np.std(e_sh), np.std(e_ein))
scale = float(np.std(e_ein)) / np.sqrt(e_ein.size)
assert abs(float(np.mean(e_sh) - np.mean(e_ein))) < 6 * scale

# ...and the sharded stochastic call is reproducible per seed
np.testing.assert_array_equal(np.asarray(cim_matmul(x, w, noisy)),
                              np.asarray(y_noisy_m))

# 3) axis_index-salted seeds decorrelate shards: duplicate the weight
# columns so the two model shards solve IDENTICAL local problems at
# identical local coordinates — without the salt their draws would be
# bit-equal. (The unsharded kernel keeps distinct global coordinates, so
# it never had this failure mode.)
w2 = jnp.concatenate([w, w], axis=1)            # [576, 128] → 64 cols/shard
y2 = cim_matmul(x, w2, noisy)
assert bool(jnp.any(y2[:, :64] != y2[:, 64:])), "shards drew the same noise"

# 4) packed/unpacked bit-identity holds under the mesh too (noise draws are
# container-independent; the packed plan shards K in byte units)
codes, s_w = quantize_weight_offline(w, noisy)
y_u = cim_matmul_prequant(x, codes, s_w, noisy)
y_p = cim_matmul_prequant(x, pack_codes(codes), s_w, noisy)
np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_u))

# 5) grads flow through the sharded custom-VJP path
g = jax.grad(lambda a: jnp.sum(cim_matmul(a, w, noisy)))(x)
assert g.shape == x.shape and bool(jnp.all(jnp.isfinite(g)))
print("ENGINE_SHARDED_OK")
"""


@pytest.mark.slow
def test_multi_device_sharded_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ENGINE_SHARDED_OK" in proc.stdout
