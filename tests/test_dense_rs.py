"""dense_rs (explicit psum_scatter TP epilogue, §Perf B1) must be
numerically identical to the GSPMD all-reduce path. Subprocess with 4 host
devices (mesh 2×2)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import SMOKES
from repro.models import registry
from repro.parallel import sharding

cfg0 = SMOKES["llama3-8b"].replace(dtype="float32")
cfg1 = cfg0.replace(tp_reduce_scatter=True)
params = registry.init_params(jax.random.PRNGKey(0), cfg0, max_seq=40)
mod = registry.get_module(cfg0)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                      cfg0.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                      cfg0.vocab)}
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 2)
sharding.set_mesh(mesh)
with mesh:
    l0 = jax.jit(lambda p, b: mod.train_loss(p, b, cfg0, None))(params, batch)
    l1 = jax.jit(lambda p, b: mod.train_loss(p, b, cfg1, None))(params, batch)
    lg0, _ = jax.jit(lambda p, b: mod.prefill(p, b, cfg0))(
        params, {"tokens": batch["tokens"]})
    lg1, _ = jax.jit(lambda p, b: mod.prefill(p, b, cfg1))(
        params, {"tokens": batch["tokens"]})
np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                           rtol=1e-4, atol=1e-4)
# gradient path through psum_scatter (its transpose is all_gather)
g1 = jax.jit(jax.grad(lambda p: mod.train_loss(p, batch, cfg1, None)))(params)
assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g1))
print("DENSE_RS_OK")
"""


@pytest.mark.slow
def test_dense_rs_matches_gspmd_path():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DENSE_RS_OK" in proc.stdout
