"""Gradient compression with error feedback: the wire is int8 but the bias
does not accumulate."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.collectives import (compress_decompress, compressed_psum,
                                        dequantize_int8, quantize_int8)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 5
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Accumulated compressed sum ≈ accumulated true sum (EF property)."""
    key = jax.random.PRNGKey(1)
    err = jnp.zeros((256,))
    true_acc = comp_acc = jnp.zeros((256,))
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (256,)) * 0.01
        y, err = compress_decompress(g, err)
        true_acc = true_acc + g
        comp_acc = comp_acc + y
    # residual error is bounded by ONE quantization step, not 50
    resid = float(jnp.max(jnp.abs(true_acc - comp_acc)))
    single_step = float(jnp.max(jnp.abs(err)))
    assert resid <= single_step + 1e-6


def test_compressed_psum_single_device_mesh():
    """Semantics check on a trivial mesh: mean-psum of one participant."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    x = jnp.linspace(-1, 1, 64)
    err0 = jnp.zeros_like(x)

    def f(x, e):
        return compressed_psum(x, "data", e)

    from repro.parallel.sharding import shard_map
    y, err = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=(jax.sharding.PartitionSpec(),) * 2))(x, err0)
    np.testing.assert_allclose(np.asarray(y + err), np.asarray(x), atol=1e-6)
