"""Distributed MoE correctness: psum-EP and a2a-EP must equal the local
(no-mesh) reference bit-for-bit up to f32 tolerance. Runs in a subprocess
with 4 forced host devices (mesh 2×2: data×model)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe
from repro.parallel import sharding

cfg = ModelConfig(arch="t", family="moe", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=16, vocab=64, dtype="float32",
                  moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=16,
                                capacity_factor=8.0))
key = jax.random.PRNGKey(0)
p = moe.init(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 32))

sharding.set_mesh(None)
y_local, aux_local = moe.apply(p, x, cfg, train=False)

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 2)
sharding.set_mesh(mesh)
with mesh:
    y_psum, aux_psum = jax.jit(
        lambda pp, xx: moe.apply(pp, xx, cfg, train=False))(p, x)
    cfg_a2a = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ep_mode="a2a"))
    y_a2a, aux_a2a = jax.jit(
        lambda pp, xx: moe.apply(pp, xx, cfg_a2a, train=False))(p, x)

np.testing.assert_allclose(np.asarray(y_psum), np.asarray(y_local),
                           rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_local),
                           rtol=2e-5, atol=2e-5)
# the aux load-balance loss is a shard-local estimator averaged across
# shards (GShard-style): Σ_e mean_shard(f_e·p_e) ≠ global Σ_e f_e·p_e
# exactly — outputs above are exact, aux agrees to a few percent
np.testing.assert_allclose(float(aux_psum), float(aux_local), rtol=5e-2)
np.testing.assert_allclose(float(aux_a2a), float(aux_local), rtol=5e-2)
print("MOE_DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_moe_psum_and_a2a_match_local_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MOE_DISTRIBUTED_OK" in proc.stdout


SCRIPT_DECODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.cim_matmul import CIMConfig
from repro.models import moe
from repro.models.quantize import quantize_params
from repro.parallel import sharding
from repro.launch.mesh import make_host_mesh

cfg = ModelConfig(arch="t", family="moe", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=16, vocab=64, dtype="float32",
                  moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=16,
                                capacity_factor=8.0))
key = jax.random.PRNGKey(0)
p = moe.init(key, cfg)
xd = jax.random.normal(jax.random.fold_in(key, 2), (8, 1, 32))  # decode t=1

sharding.set_mesh(None)
yd_local, auxd_local = moe.apply(p, xd, cfg, train=False)

cfg_a2a = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, ep_mode="a2a"))
mesh = make_host_mesh(2, 2)
sharding.set_mesh(mesh)
with mesh:
    # t=1 is not divisible by the model axis → the chunked a2a decode path
    yd_a2a, auxd_a2a = jax.jit(
        lambda pp, xx: moe.apply(pp, xx, cfg_a2a, train=False))(p, xd)
np.testing.assert_allclose(np.asarray(yd_a2a), np.asarray(yd_local),
                           rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(float(auxd_a2a), float(auxd_local), rtol=5e-2)

# ---- nibble-packed expert weights (engine.PackedCodes through the EP
# shard specs): the dispatch layout re-calibrates the dynamic activation
# scale per expert buffer, so agreement with the local packed reference is
# at the 4-bit-requantization scale, not bitwise — pin it to the same order
# as the local quantization error vs float.
cfg_cim = dataclasses.replace(cfg, cim=CIMConfig(enabled=True,
                                                 backend="scan"))
cfg_cim_a2a = dataclasses.replace(
    cfg_cim, moe=dataclasses.replace(cfg.moe, ep_mode="a2a"))
pq = quantize_params(p, cfg_cim, packed=True)
assert pq["e_gate_q"].dtype == jnp.uint8        # packed container in place
sharding.set_mesh(None)
y_float = yd_local
yq_local, _ = moe.apply(pq, xd, cfg_cim, train=False)
err_ref = float(np.max(np.abs(np.asarray(yq_local - y_float))))
sharding.set_mesh(mesh)
with mesh:
    yq_a2a, _ = jax.jit(
        lambda pp, xx: moe.apply(pp, xx, cfg_cim_a2a, train=False))(pq, xd)
    # auto backend: the fused packed Pallas kernel runs per-shard inside
    # the EP shard_map (in_shard_context guard) — must agree with scan to
    # float tolerance on the identical buffers
    cfg_auto = dataclasses.replace(cfg_cim_a2a,
                                   cim=CIMConfig(enabled=True))
    yq_auto, _ = jax.jit(
        lambda pp, xx: moe.apply(pp, xx, cfg_auto, train=False))(pq, xd)
err_a2a = float(np.max(np.abs(np.asarray(yq_a2a - y_float))))
assert err_a2a < 3 * max(err_ref, 1e-6), (err_a2a, err_ref)
np.testing.assert_allclose(np.asarray(yq_auto), np.asarray(yq_a2a),
                           rtol=2e-4, atol=2e-4)
print("MOE_A2A_DECODE_OK")
"""


@pytest.mark.slow
def test_moe_a2a_decode_and_packed_experts():
    """The chunked a2a decode path (t=1) matches the local reference; the
    nibble-packed PackedCodes expert containers ride the EP shard specs in
    both scan and auto (fused-Pallas-per-shard) backends."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FORCE_JNP", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT_DECODE], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MOE_A2A_DECODE_OK" in proc.stdout


SCRIPT_NON_DIVISIBLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.cim_matmul import CIMConfig
from repro.models import moe
from repro.models.quantize import quantize_params
from repro.parallel import sharding
from repro.launch.mesh import make_host_mesh

cfg = ModelConfig(arch="t", family="moe", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=16, vocab=64, dtype="float32",
                  moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=16,
                                capacity_factor=8.0))
key = jax.random.PRNGKey(0)
p = moe.init(key, cfg)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 32))

sharding.set_mesh(None)
y_local, aux_local = moe.apply(p, x, cfg, train=False)

# model axis of 3 does NOT divide the 16 padded experts -> apply() takes the
# batch-sharded fallback: every shard computes the FULL expert set on its
# own batch slice inside shard_map (expert compute is not expert-parallel,
# but the tokens are data-parallel and the vmapped kernels trace in-shard).
mesh = make_host_mesh(2, 3)
assert moe.padded_experts(cfg.moe.n_experts) % mesh.shape["model"] != 0
sharding.set_mesh(mesh)
with mesh:
    y_mesh, aux_mesh = jax.jit(
        lambda pp, xx: moe.apply(pp, xx, cfg, train=False))(p, x)
np.testing.assert_allclose(np.asarray(y_mesh), np.asarray(y_local),
                           rtol=2e-5, atol=2e-5)
# aux is EXACT now: the fallback psums the raw (me_sum, pe_sum) router
# stats over the batch axes instead of averaging shard-local estimators
np.testing.assert_allclose(float(aux_mesh), float(aux_local), rtol=1e-5)

# The fallback routes through sharding.shard_map over the batch axes: the
# output is batch-sharded over "data", NOT fully replicated (the PR-5 pin
# this test used to carry — the ROADMAP item that landed here).
from jax.sharding import NamedSharding, PartitionSpec as P
sh = y_mesh.sharding
assert not sh.is_fully_replicated, f"fallback output not sharded: {sh}"
assert sh.spec[0] == ("data",) or sh.spec[0] == "data", sh.spec

# CIM prequant packed experts under the same fallback: _expert_ffn vmaps
# the engine entry point over the expert axis, so the in-shard-context +
# _under_vmap guards must keep auto backend selection OFF nested mesh
# dispatch (a shard_map cannot nest under vmap). Each shard re-calibrates
# the dynamic activation scale over its OWN batch slice (same as the a2a
# dispatch layout), so agreement with the local packed reference is at the
# 4-bit-requantization scale, not bitwise — pin it to the same order as
# the local quantization error vs float.
cfg_cim = dataclasses.replace(cfg, cim=CIMConfig(enabled=True))
pq = quantize_params(p, cfg_cim, packed=True)
sharding.set_mesh(None)
yq_local, _ = moe.apply(pq, x, cfg_cim, train=False)
err_ref = float(np.max(np.abs(np.asarray(yq_local - y_local))))
sharding.set_mesh(mesh)
with mesh:
    yq_mesh, _ = jax.jit(
        lambda pp, xx: moe.apply(pp, xx, cfg_cim, train=False))(pq, x)
err_mesh = float(np.max(np.abs(np.asarray(yq_mesh - y_local))))
assert err_mesh < 3 * max(err_ref, 1e-6), (err_mesh, err_ref)
print("MOE_NON_DIVISIBLE_OK")
"""


@pytest.mark.slow
def test_moe_non_divisible_experts_local_fallback():
    """A mesh whose model axis (3) cannot divide the padded experts (16)
    falls back to a BATCH-sharded local MoE: each shard runs the full
    expert set on its own batch slice inside shard_map, the raw router
    stats psum to an exact global aux loss, and the in-shard guard keeps
    the vmapped CIM expert kernels off nested mesh dispatch. Outputs match
    the no-mesh reference and are sharded over "data" (the former
    fully-replicated pin this test carried as a ROADMAP open item)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FORCE_JNP", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT_NON_DIVISIBLE],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MOE_NON_DIVISIBLE_OK" in proc.stdout
