"""Monte-Carlo SQNR harness vs the paper's §II-A quantitative claims."""
import dataclasses

import pytest

from repro.core import PROTOTYPE, Scheme
from repro.core.sqnr import simulate_sqnr

N_FAST = 1 << 13  # enough Monte-Carlo for ±0.5 dB on these comparisons


def _sqnr(scheme, **kw):
    cfg = dataclasses.replace(PROTOTYPE, scheme=scheme,
                              **{k: v for k, v in kw.items()
                                 if k in ("adc_levels", "n_rows")})
    return simulate_sqnr(cfg, k=144, n_samples=N_FAST)


def test_fig2b_bp_beats_wbs_and_bs_at_iso_energy():
    """Fig. 2(b): levels 1024/256/32 are iso-energy; BP +7.8 dB over WBS,
    +21.6 dB over BS."""
    bp = _sqnr(Scheme.BP, adc_levels=1024)
    wbs = _sqnr(Scheme.WBS, adc_levels=256)
    bs = _sqnr(Scheme.BS, adc_levels=32)
    assert abs(bp.energy_per_mvm_j - wbs.energy_per_mvm_j) / bp.energy_per_mvm_j < 0.01
    assert abs(bp.energy_per_mvm_j - bs.energy_per_mvm_j) / bp.energy_per_mvm_j < 0.01
    assert abs((bp.sqnr_db - wbs.sqnr_db) - 7.8) < 1.5
    assert abs((bp.sqnr_db - bs.sqnr_db) - 21.6) < 2.0


def test_fig2a_ordering_at_fixed_levels():
    """Fig. 2(a): levels=64; BP(N=9) ≈ +1.8 dB over WBS(N=36), +3.5 over
    BS(N=144)."""
    bp = _sqnr(Scheme.BP, adc_levels=64, n_rows=9)
    wbs = _sqnr(Scheme.WBS, adc_levels=64, n_rows=36)
    bs = _sqnr(Scheme.BS, adc_levels=64, n_rows=144)
    assert bp.sqnr_db > wbs.sqnr_db > bs.sqnr_db
    assert abs((bp.sqnr_db - wbs.sqnr_db) - 1.8) < 1.0
    assert abs((bp.sqnr_db - bs.sqnr_db) - 3.5) < 1.5


def test_one_extra_adc_bit_gives_6db():
    lo = _sqnr(Scheme.BP, adc_levels=181)
    hi = _sqnr(Scheme.BP, adc_levels=362)
    assert abs((hi.sqnr_db - lo.sqnr_db) - 6.0) < 1.0


def test_halving_n_gives_3db():
    """§II-A: halving N only buys ~3 dB (digital accumulation of errors)."""
    n144 = _sqnr(Scheme.BP, adc_levels=362, n_rows=144)
    n72 = _sqnr(Scheme.BP, adc_levels=362, n_rows=72)
    assert abs((n72.sqnr_db - n144.sqnr_db) - 3.0) < 1.2
