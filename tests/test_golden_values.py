"""Golden-value regression: the Fig. 18 PVT sweep and Fig. 21 energy model
pinned against committed CSVs (benchmarks/golden/).

The analog-fidelity core — σ_E across voltage/temperature corners, the ADC
level de-rating, the dual-threshold TD-ADC energy model, the Eq. 4 TOPS/W
curve — was previously pinned only by hand-picked example values; transfer-
curve and PVT-corner behaviour is exactly where CIM reproductions silently
drift (Yin et al. arXiv:2212.04320, Yoshioka et al. arXiv:2411.06079). Any
intentional recalibration must regenerate the CSVs (the generator is the
inline snippet in each CSV's git history / CHANGES.md) and justify the
delta; an unintentional drift fails here loudly.

Tolerances: the macro/energy model is deterministic closed-form python, so
the pins are tight (rtol 1e-6); the paper-anchor checks (40.2 / 18.6
TOPS/W, σ_E = 0.59 LSB) allow the few-percent slack of the fitted model.
"""
import csv
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import PROTOTYPE
from repro.core.adc import adc_energy_j, inl_curve
from repro.core.dac import dac_energy_j
from repro.core.energy import macro_throughput_gops, mvm_energy
from repro.core.macro import OperatingPoint

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "golden")
RTOL = 1e-6


def _load(name: str) -> dict:
    out = {}
    with open(os.path.join(GOLDEN_DIR, name), newline="") as f:
        for r in csv.DictReader(f):
            out[(r["point"], r["metric"])] = float(r["value"])
    return out


@pytest.fixture(scope="module")
def fig18():
    return _load("fig18_pvt_golden.csv")


@pytest.fixture(scope="module")
def fig21():
    return _load("fig21_energy_golden.csv")


# ---------------------------------------------------------------------------
# Fig. 18: σ_E over PVT corners, gain, and process instances
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("vdd", (0.65, 0.8, 0.9, 1.0, 1.2))
def test_fig18_voltage_corners(fig18, vdd):
    m = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=vdd))
    assert m.sigma_e_lsb() == pytest.approx(
        fig18[(f"vdd_{vdd:g}", "sigma_e_lsb")], rel=RTOL)
    assert m.effective_adc_levels() == int(
        fig18[(f"vdd_{vdd:g}", "effective_adc_levels")])


@pytest.mark.parametrize("temp", (-40.0, 25.0, 105.0))
def test_fig18_temperature_corners(fig18, temp):
    m = dataclasses.replace(PROTOTYPE, op=OperatingPoint(temp_c=temp))
    assert m.sigma_e_lsb() == pytest.approx(
        fig18[(f"temp_{temp:g}", "sigma_e_lsb")], rel=RTOL)


@pytest.mark.parametrize("gain", (1.0, 2.0, 3.0, 4.0))
def test_fig18_gain_study(fig18, gain):
    m = dataclasses.replace(PROTOTYPE, gain=gain)
    assert m.sigma_e_lsb() == pytest.approx(
        fig18[(f"gain_{gain:g}", "sigma_e_lsb")], rel=RTOL)
    # σ_E × LSB must SHRINK with gain (the paper's net-win conclusion)
    assert m.sigma_e_lsb() * m.adc_lsb() == pytest.approx(
        fig18[(f"gain_{gain:g}", "sigma_analog")], rel=RTOL)


def test_fig18_gain_sigma_analog_monotone(fig18):
    vals = [fig18[(f"gain_{g:g}", "sigma_analog")] for g in (1, 2, 3, 4)]
    assert vals == sorted(vals, reverse=True)


def test_fig18_process_inl_spread(fig18):
    """8 groups × 5 chips of seeded INL instances (jnp evaluation — runs
    identically in both REPRO_FORCE_JNP legs; the env var only steers
    engine backend selection)."""
    spans = []
    for inst in range(40):
        c = inl_curve(jnp.linspace(0, 1, 256), PROTOTYPE.inl_amp_lsb,
                      seed=inst)
        spans.append(float(jnp.max(jnp.abs(c))))
    assert min(spans) == pytest.approx(
        fig18[("process", "inl_span_best")], rel=1e-5)
    assert max(spans) == pytest.approx(
        fig18[("process", "inl_span_worst")], rel=1e-5)
    # every instance stays within the measured ±1.10 LSB bound
    assert max(spans) <= PROTOTYPE.inl_amp_lsb + 1e-6


def test_fig18_paper_anchor():
    """The calibration anchor itself: σ_E = 0.59 LSB at (0.9 V, 25 °C)."""
    assert PROTOTYPE.sigma_e_lsb() == pytest.approx(0.59, rel=1e-3)


# ---------------------------------------------------------------------------
# Fig. 21: energy efficiency / clock / throughput over voltage
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("vdd", (0.65, 0.75, 0.9, 1.05, 1.2))
def test_fig21_voltage_sweep(fig21, vdd):
    m = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=vdd))
    rep = mvm_energy(m, 144)
    key = f"vdd_{vdd:g}"
    assert rep.tops_per_w == pytest.approx(fig21[(key, "tops_per_w")],
                                           rel=RTOL)
    assert m.clock_hz() / 1e6 == pytest.approx(fig21[(key, "fclk_mhz")],
                                               rel=RTOL)
    assert macro_throughput_gops(m) == pytest.approx(fig21[(key, "gops")],
                                                     rel=RTOL)
    assert rep.e_mvm_j == pytest.approx(fig21[(key, "e_mvm_j")], rel=RTOL)
    assert rep.e_adc_j == pytest.approx(fig21[(key, "e_adc_j")], rel=RTOL)


def test_fig21_adc_dual_threshold_gating(fig21):
    gated = adc_energy_j(PROTOTYPE, dual_threshold=True)
    ungated = adc_energy_j(PROTOTYPE, dual_threshold=False)
    assert gated == pytest.approx(
        fig21[("nominal", "adc_energy_gated_j")], rel=RTOL)
    assert ungated == pytest.approx(
        fig21[("nominal", "adc_energy_ungated_j")], rel=RTOL)
    # the measured 55.8 % main-path power gating (§IV)
    assert gated / ungated == pytest.approx(1.0 - 0.558, rel=1e-6)


@pytest.mark.parametrize("sparsity", (0.0, 0.5, 0.9))
def test_fig21_dac_sparsity_share(fig21, sparsity):
    """Sparsity-dependent DAC energy share (paper: 2.4–14.6 %); seeded jnp
    draw — deterministic across backends and FORCE_JNP legs."""
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (4096,), 0, 16).astype(jnp.float32)
    mask = jax.random.uniform(jax.random.fold_in(key, 1),
                              (4096,)) >= sparsity
    e_dac = float(dac_energy_j(codes * mask, PROTOTYPE))
    e_tot = mvm_energy(PROTOTYPE, 144).e_mvm_j
    share = e_dac / (e_tot + e_dac)
    assert share == pytest.approx(
        fig21[(f"dac_sparsity_{sparsity:g}", "dac_share")], rel=1e-5)


def test_fig21_paper_anchors(fig21):
    """Both measured Fig. 21 endpoints: 40.2 TOPS/W @ 0.65 V and
    18.6 TOPS/W @ 1.2 V (the two-point calibration of the V^α fit)."""
    assert fig21[("vdd_0.65", "tops_per_w")] == pytest.approx(40.2, rel=0.01)
    assert fig21[("vdd_1.2", "tops_per_w")] == pytest.approx(18.6, rel=0.01)
