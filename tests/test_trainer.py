"""Training loop: convergence, preemption/restart continuity, grad
compression, microbatching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import SMOKES
from repro.runtime.trainer import PreemptionError, Trainer

SHAPE = ShapeConfig("tiny", 32, 4, "train")


def _tc(**kw):
    base = dict(steps=8, lr=1e-3, warmup_steps=2, checkpoint_every=4,
                log_every=1, keep_checkpoints=2)
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases(tmp_path):
    cfg = SMOKES["internlm2-1.8b"]
    tr = Trainer(cfg, SHAPE, _tc(steps=20), str(tmp_path))
    out = tr.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] - 0.1


def test_preemption_restart_is_bitwise_identical(tmp_path):
    """Kill at step 5, auto-resume from the step-4 checkpoint: final loss
    must equal an uninterrupted run (deterministic data + seeded rng)."""
    cfg = SMOKES["internlm2-1.8b"]
    tr1 = Trainer(cfg, SHAPE, _tc(), str(tmp_path / "a"))
    clean = tr1.run()

    tr2 = Trainer(cfg, SHAPE, _tc(), str(tmp_path / "b"), preempt_at=5)
    resumed = tr2.run()
    l1 = [m["loss"] for m in clean["metrics"]][-1]
    l2 = [m["loss"] for m in resumed["metrics"]][-1]
    assert l1 == pytest.approx(l2, abs=0.0), (l1, l2)


def test_preemption_without_restart_budget_raises(tmp_path):
    cfg = SMOKES["internlm2-1.8b"]
    tr = Trainer(cfg, SHAPE, _tc(), str(tmp_path), preempt_at=2)
    with pytest.raises(PreemptionError):
        tr.run(max_restarts=0)


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    """grad accumulation over 2 microbatches ≈ full-batch step (f32)."""
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32", remat=False)
    from repro.runtime.trainer import make_train_step
    step_full, opt = make_train_step(cfg, _tc(microbatch=0))
    step_micro, _ = make_train_step(cfg, _tc(microbatch=2))
    from repro.models import registry
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=40)
    state = {"params": params, "opt": opt.init(params)}
    from repro.data.tokens import SyntheticLMDataset
    ds = SyntheticLMDataset(cfg.vocab, 32, 4)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    rng = jax.random.PRNGKey(1)
    _, m1 = jax.jit(step_full)(state, batch, rng)
    _, m2 = jax.jit(step_micro)(state, batch, rng)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_grad_compression_trains(tmp_path):
    cfg = SMOKES["internlm2-1.8b"]
    tr = Trainer(cfg, SHAPE, _tc(steps=12, grad_compression=True),
                 str(tmp_path))
    out = tr.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert np.isfinite(losses[-1]) and losses[-1] < losses[0]
