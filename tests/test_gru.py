"""The paper's §V-C dim-144 KWS GRU: training, CIM evaluation, mapping."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import PROTOTYPE
from repro.core.cim_matmul import CIMConfig
from repro.models import gru


def _data(key, n=64, t=6, n_classes=4):
    proto = jax.random.normal(key, (n_classes, t, 144))
    y = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, n_classes)
    x = proto[y] + 0.3 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (n, t, 144))
    return jax.nn.relu(x), y


def test_gru_trains_and_cim_eval_close():
    key = jax.random.PRNGKey(0)
    cfg = gru.gru_config(n_classes=4)
    x, y = _data(key)
    p = gru.init(jax.random.fold_in(key, 3), cfg)

    @jax.jit
    def step(p):
        g = jax.grad(lambda q: gru.train_loss(q, {"frames": x, "labels": y},
                                              cfg))(p)
        return jax.tree.map(lambda a, b: a - 0.1 * b, p, g)

    l0 = float(gru.train_loss(p, {"frames": x, "labels": y}, cfg))
    for _ in range(60):
        p = step(p)
    l1 = float(gru.train_loss(p, {"frames": x, "labels": y}, cfg))
    assert l1 < l0 - 0.2

    acc_float = float(jnp.mean(
        jnp.argmax(gru.forward(p, x, cfg), -1) == y))
    macro = dataclasses.replace(PROTOTYPE, gain=3.0)
    cim_cfg = cfg.replace(cim=CIMConfig(enabled=True, macro=macro))
    acc_cim = float(jnp.mean(
        jnp.argmax(gru.forward(p, x, cim_cfg), -1) == y))
    assert acc_float > 0.9
    assert acc_cim >= acc_float - 0.15  # 4b×4b + 8.5b ADC holds accuracy


def test_gru_gate_matmuls_are_two_macro_groups():
    """Input+hidden concat is 288 = exactly two N=144 macro groups —
    the paper's 'perfectly fit into the SRAM' sizing."""
    cfg = gru.gru_config()
    p = gru.init(jax.random.PRNGKey(1), cfg)
    assert p["w_z"].shape == (288, 144)
    assert 288 % PROTOTYPE.n_rows == 0
