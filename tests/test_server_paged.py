"""Paged-KV serving engine: equivalence soaks, chunk invariance, allocator
accounting, prequant composition.

Equivalence contracts (greedy token IDs, exact list equality):

  * paged == one-request-at-a-time decode on ARBITRARY (mixed-depth,
    randomized admission/retirement) schedules — the paged step keeps true
    per-slot positions and per-slot masks, so its math is the single-
    request math regardless of what else shares the batch;
  * paged == the legacy slot engine on DEPTH-ALIGNED schedules (request
    waves admitted and retired together). The legacy engine's shared `pos`
    makes mixed-depth slots attend over zero-K/V gap positions (softmax
    dilution — see the runtime.server module docstring), so it is only an
    exact baseline when all active slots sit at equal depth; the paged
    engine is pinned against it exactly there, and against the one-at-a-
    time reference everywhere.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig

MAX_LEN = 64

_FORCED = os.environ.get("REPRO_FORCE_JNP", "").strip().lower() in (
    "1", "true", "yes")
needs_pallas = pytest.mark.skipif(
    _FORCED, reason="explicit Pallas attention backend; REPRO_FORCE_JNP "
                    "leg is jnp-only")


@pytest.fixture(scope="module")
def setup():
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_LEN)
    mod = registry.get_module(cfg)
    prefill = jax.jit(lambda p, b: mod.prefill(p, b, cfg, max_len=MAX_LEN))
    decode = jax.jit(lambda p, t, c: mod.decode_step(p, t, c, cfg))

    def one_at_a_time(prompt, n_new, eos_id=None):
        logits, cache = prefill(
            params, {"tokens": jnp.asarray([prompt], jnp.int32)})
        out = [int(jnp.argmax(logits[0]))]
        while len(out) < n_new:
            logits, cache = decode(
                params, jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(logits[0])))
            if eos_id is not None and out[-1] == eos_id:
                break
        return out

    return cfg, params, one_at_a_time


def _mk_server(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 4)
    # the BIT-identity soaks in this file pin the exact attention backend
    # (the dense-cache-equivalent math); the Pallas kernel backend agrees
    # within float tolerance and has its own soak below
    kw.setdefault("attn", "exact")
    return Server(params, cfg, ServingConfig(paged=True, **kw))


# ---------------------------------------------------------------------------
# equivalence: paged vs one-at-a-time on a mixed-depth random schedule
# ---------------------------------------------------------------------------
def test_soak_mixed_depth_vs_single_request(setup):
    """Randomized admission: requests land mid-flight at arbitrary depths
    (the schedule the legacy engine cannot serve exactly); every request's
    tokens must equal its single-request decode."""
    cfg, params, one_at_a_time = setup
    rng = np.random.RandomState(42)
    server = _mk_server(cfg, params)
    schedule = {0: 2, 2: 1, 3: 1, 7: 1}   # step → submissions
    reqs, step = [], 0
    while reqs == [] or any(not r.done for r in reqs) or server.queue:
        for _ in range(schedule.get(step, 0)):
            plen = int(rng.randint(3, 9))
            r = Request(prompt=rng.randint(0, cfg.vocab, size=plen).tolist(),
                        max_new_tokens=int(rng.randint(2, 6)))
            server.submit(r)
            reqs.append(r)
        server.step()
        step += 1
        assert step < 200, "schedule did not drain"
    for r in reqs:
        assert r.output == one_at_a_time(r.prompt, r.max_new_tokens), r.rid
    # pool fully recycled after the drain: only trie-cached prefix blocks
    # remain, and flushing the prefix cache releases those too
    server.flush_prefix_cache()
    assert server.alloc.stats.in_use == 0
    assert server.kv_cache_bytes()["in_use"] == 0


@pytest.mark.slow
def test_soak_waves_vs_legacy_and_single(setup):
    """Seeded admission/retirement soak in depth-aligned waves: all three
    engines — paged, legacy slots, one-at-a-time — produce bit-identical
    token lists. Waves re-admit into freshly freed blocks (LIFO free list),
    so stale block contents from retired requests are constantly reused."""
    cfg, params, one_at_a_time = setup
    rng = np.random.RandomState(3)
    waves = []
    for _ in range(4):
        n = int(rng.randint(1, 3))
        plen = int(rng.randint(3, 10))
        mnew = int(rng.randint(2, 7))
        waves.append([
            Request(prompt=rng.randint(0, cfg.vocab, size=plen).tolist(),
                    max_new_tokens=mnew) for _ in range(n)])

    def run(paged):
        # sharing disabled: this soak pins the RAW allocator lifecycle
        # (every block freed at retirement; reuse = allocs > peak) — the
        # trie's deliberate block retention has its own tests
        srv = _mk_server(cfg, params, prefix_sharing=False) if paged else \
            Server(params, cfg, ServingConfig(n_slots=2, max_len=MAX_LEN))
        outs = []
        for wave in waves:
            ws = [Request(prompt=list(r.prompt),
                          max_new_tokens=r.max_new_tokens) for r in wave]
            for r in ws:
                srv.submit(r)
            srv.run_until_drained()
            outs.extend(r.output for r in ws)
        return outs, srv

    legacy, _ = run(False)
    paged, srv = run(True)
    assert legacy == paged
    singles = [one_at_a_time(r.prompt, r.max_new_tokens)
               for wave in waves for r in wave]
    assert paged == singles
    # the soak actually exercised block reuse, not just first allocation
    st = srv.alloc.stats
    assert st.total_allocs > st.peak_in_use
    assert st.total_frees == st.total_allocs and st.in_use == 0


def test_eos_retirement_paged(setup):
    cfg, params, one_at_a_time = setup
    ref = one_at_a_time([1, 2, 3], 8)
    eos = ref[2]
    server = _mk_server(cfg, params, n_slots=1)
    req = Request(prompt=[1, 2, 3], max_new_tokens=8, eos_id=eos)
    server.submit(req)
    server.run_until_drained()
    assert req.done and len(req.output) == 3
    assert req.output == ref[:3]


# ---------------------------------------------------------------------------
# chunked prefill: chunk-size invariance through the unified step
# ---------------------------------------------------------------------------
def test_prefill_chunk_size_invariance(setup):
    """The exact-softmax paged prefill makes outputs independent of the
    chunk schedule: 2-token chunks, 5-token chunks and one whole-prompt
    chunk give identical tokens (and match single-request decode)."""
    cfg, params, one_at_a_time = setup
    prompt = [7, 3, 11, 19, 2, 5, 13]
    ref = one_at_a_time(prompt, 5)
    for chunk in (2, 5, 16):
        server = _mk_server(cfg, params, n_slots=1, prefill_chunk=chunk)
        req = Request(prompt=list(prompt), max_new_tokens=5)
        server.submit(req)
        server.run_until_drained()
        assert req.output == ref, f"chunk={chunk}"


def test_token_budget_throttles_prefill(setup):
    """A token budget below the chunk width stalls prefill lanes without
    corrupting results; decode lanes keep priority."""
    cfg, params, one_at_a_time = setup
    server = _mk_server(cfg, params, prefill_chunk=4, token_budget=2)
    rng = np.random.RandomState(1)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=6).tolist(),
                    max_new_tokens=3) for _ in range(3)]
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    for r in reqs:
        assert r.output == one_at_a_time(r.prompt, 3)
    assert server.metrics.prefill_tokens == sum(len(r.prompt) for r in reqs)


# ---------------------------------------------------------------------------
# capacity accounting + composition + guardrails
# ---------------------------------------------------------------------------
def test_preemption_under_pool_pressure(setup):
    """A pool sized for ~one request: optimistic watermark admission lets
    several lanes in, pool pressure preempts the newest back to the queue,
    and every request still drains bit-identical to the reference — the
    preempted lane resumes its own (prompt + emitted tokens) prefix, and
    greedy decode makes the resume deterministic."""
    cfg, params, one_at_a_time = setup
    # worst case per request below: ceil((8 + 4) / 8) = 2 blocks
    server = _mk_server(cfg, params, num_blocks=3, prefix_sharing=False,
                        n_slots=3)
    rng = np.random.RandomState(5)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=8).tolist(),
                    max_new_tokens=4) for _ in range(3)]
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    for r in reqs:
        assert r.output == one_at_a_time(r.prompt, 4)
    assert server.metrics.preemptions > 0   # pressure actually hit
    assert server.alloc.stats.peak_in_use <= 3
    assert server.alloc.stats.in_use == 0


def test_kv_bytes_scale_with_occupancy(setup):
    """The paged pool's in-use bytes track allocated blocks, not slots —
    the memory win over the monolithic [n_slots, max_len] cache."""
    cfg, params, _ = setup
    server = _mk_server(cfg, params, n_slots=4)
    legacy = Server(params, cfg, ServingConfig(n_slots=4, max_len=MAX_LEN))
    req = Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=4)
    server.submit(req)
    server.step()
    kv = server.kv_cache_bytes()
    assert 0 < kv["in_use"] < kv["total"]
    lv = legacy.kv_cache_bytes()
    assert lv["in_use"] == lv["total"]     # slot cache is always resident
    # one 5-token prompt occupies 1 block = 1/(4 slots × 8 blocks) of parity
    assert kv["in_use"] * 8 < lv["total"]


def test_prequant_packed_paged_matches_legacy():
    """PackedCodes (nibble-packed int4) serving weights compose with the
    paged cache: identical tokens to the legacy prequant engine."""
    from repro.core.cim_matmul import CIMConfig
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32",
                                           cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=MAX_LEN)
    outs = {}
    for paged in (False, True):
        server = Server(params, cfg, ServingConfig(
            n_slots=1, max_len=MAX_LEN, prequant=True, packed=True,
            paged=paged, block_size=8, prefill_chunk=4))
        q = [v for k, v in
             jax.tree_util.tree_flatten_with_path(server.params)[0]
             if str(k[-1]).find("_q") >= 0]
        assert q and all(a.dtype == jnp.uint8 for a in q)
        req = Request(prompt=[5, 9, 2, 7], max_new_tokens=4)
        server.submit(req)
        server.run_until_drained()
        outs[paged] = req.output
    assert outs[True] == outs[False]


def test_request_metrics_recorded(setup):
    cfg, params, _ = setup
    server = _mk_server(cfg, params)
    req = Request(prompt=[1, 2, 3, 4], max_new_tokens=3)
    server.submit(req)
    server.run_until_drained()
    assert req.done
    assert req.t_submit <= req.t_first <= req.t_done
    assert req.latency_s >= req.ttft_s >= 0.0
    m = server.metrics.summary()
    assert m["prefill_tokens"] == 4
    assert m["decode_tokens"] == len(req.output) - 1
    assert m["decode_tok_s"] > 0


def test_eos_on_first_token_retires_at_prefill(setup):
    """An EOS emitted as the very first (prefill-time) token retires the
    request immediately — no post-EOS decoding on a held slot."""
    cfg, params, one_at_a_time = setup
    first = one_at_a_time([1, 2, 3], 1)[0]
    server = _mk_server(cfg, params, n_slots=1)
    req = Request(prompt=[1, 2, 3], max_new_tokens=8, eos_id=first)
    server.submit(req)
    server.run_until_drained()
    assert req.done and req.output == [first]
    server.flush_prefix_cache()
    assert server.alloc.stats.in_use == 0


def test_invalid_scheduler_params_rejected(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError):
        _mk_server(cfg, params, token_budget=0)
    with pytest.raises(ValueError):
        _mk_server(cfg, params, prefill_chunk=0)


def test_empty_prompt_rejected_both_engines(setup):
    cfg, params, _ = setup
    for srv in (_mk_server(cfg, params),
                Server(params, cfg, ServingConfig(n_slots=1,
                                                  max_len=MAX_LEN))):
        with pytest.raises(ValueError):
            srv.submit(Request(prompt=[], max_new_tokens=2))
        assert srv.queue == [] and not any(srv.slot_req)


def test_decode_lanes_never_exceed_budget(setup):
    """Scheduler invariant: a lane only becomes decode by completing
    prefill, which itself consumes budget, so decode lanes can never
    outnumber token_budget — no decode lane is ever dropped
    (stalled_decodes stays 0; prefill lanes absorb all the stalling), and
    a budget of 1 still drains correctly with single-request-identical
    outputs."""
    cfg, params, one_at_a_time = setup
    server = _mk_server(cfg, params, token_budget=1, prefill_chunk=1)
    reqs = [Request(prompt=[3 + s, 7, 2], max_new_tokens=4)
            for s in range(2)]
    for r in reqs:
        server.submit(r)
    server.run_until_drained(max_steps=500)
    for r in reqs:
        assert r.output == one_at_a_time(r.prompt, 4)
    assert server.metrics.stalled_decodes == 0
    assert server.metrics.stalled_prefills > 0


def test_legacy_metrics_share_one_clock(setup):
    """The slot engine's submit-time prefill counts toward prefill_tokens
    and wall_s, so its tok/s rates are comparable with the paged engine's
    (whose prefill runs inside step())."""
    cfg, params, _ = setup
    server = Server(params, cfg, ServingConfig(n_slots=1, max_len=MAX_LEN))
    req = Request(prompt=[1, 2, 3, 4, 5], max_new_tokens=3)
    server.submit(req)
    server.run_until_drained()
    m = server.metrics.summary()
    assert m["prefill_tokens"] == 5
    assert m["wall_s"] > 0 and m["prefill_tok_s"] > 0


def test_max_new_one_matches_single_request(setup):
    """A max_new_tokens=1 request completes at prefill time with exactly
    one token (one-at-a-time semantics; the legacy engine overshoots to 2
    — documented divergence)."""
    cfg, params, one_at_a_time = setup
    server = _mk_server(cfg, params, n_slots=1)
    req = Request(prompt=[4, 8, 15], max_new_tokens=1)
    server.submit(req)
    server.run_until_drained()
    assert req.done and req.output == one_at_a_time([4, 8, 15], 1)
    server.flush_prefix_cache()
    assert server.alloc.stats.in_use == 0


def test_unservable_requests_rejected_at_submit(setup):
    """Poison requests must be rejected BEFORE queueing: an oversized
    prompt or a worst-case reservation larger than the whole pool would
    otherwise stall admission forever (or raise mid-serve) and strand
    in-flight requests."""
    cfg, params, _ = setup
    server = _mk_server(cfg, params, num_blocks=2)
    good = Request(prompt=[1, 2, 3], max_new_tokens=3)
    server.submit(good)
    with pytest.raises(ValueError):   # needs ceil(36/8)=5 > 2 blocks
        server.submit(Request(prompt=list(range(20)), max_new_tokens=16))
    with pytest.raises(ValueError):   # prompt longer than max_len
        server.submit(Request(prompt=list(range(MAX_LEN)), max_new_tokens=2))
    assert server.queue == []         # nothing poisoned the queue
    server.run_until_drained()        # in-flight request still completes
    assert good.done and len(good.output) == 3


# ---------------------------------------------------------------------------
# Pallas attention-kernel backend: soak parity + trash-block hardening
# ---------------------------------------------------------------------------
@needs_pallas
def test_soak_mixed_depth_kernel_backend(setup):
    """The Pallas flash backend through the full serving loop: randomized
    mixed-depth admission, greedy tokens equal to one-request-at-a-time
    decode (the kernel agrees with exact within float tolerance — far
    below the logit gaps of this seeded schedule)."""
    cfg, params, one_at_a_time = setup
    rng = np.random.RandomState(9)
    server = _mk_server(cfg, params, attn="kernel")
    schedule = {0: 2, 3: 1}
    reqs, step = [], 0
    while reqs == [] or any(not r.done for r in reqs) or server.queue:
        for _ in range(schedule.get(step, 0)):
            plen = int(rng.randint(3, 9))
            r = Request(prompt=rng.randint(0, cfg.vocab, size=plen).tolist(),
                        max_new_tokens=int(rng.randint(2, 5)))
            server.submit(r)
            reqs.append(r)
        server.step()
        step += 1
        assert step < 200, "schedule did not drain"
    for r in reqs:
        assert r.output == one_at_a_time(r.prompt, r.max_new_tokens), r.rid
    server.flush_prefix_cache()
    assert server.alloc.stats.in_use == 0


@needs_pallas
def test_prefill_chunk_invariance_kernel_backend(setup):
    """Chunk-size invariance holds on the kernel backend too: the online
    softmax accumulates over KV blocks, not prompt chunks, so the chunk
    schedule cannot reassociate the reduction."""
    cfg, params, one_at_a_time = setup
    prompt = [7, 3, 11, 19, 2, 5, 13]
    ref = one_at_a_time(prompt, 4)
    for chunk in (2, 5, 16):
        server = _mk_server(cfg, params, n_slots=1, prefill_chunk=chunk,
                            attn="kernel")
        req = Request(prompt=list(prompt), max_new_tokens=4)
        server.submit(req)
        server.run_until_drained()
        assert req.output == ref, f"chunk={chunk}"


def _poison_trash_block(server, value):
    """Fill physical block 0 of every layer pool with `value`."""
    server.cache = jax.tree.map(lambda a: a.at[:, 0].set(value),
                                server.cache)


@pytest.mark.parametrize("attn", ["exact",
                                  pytest.param("kernel",
                                               marks=needs_pallas)])
@pytest.mark.parametrize("poison", [float("nan"), 1e6])
def test_trash_block_poison_server(setup, attn, poison):
    """Poison physical block 0 (the masked-lane write sink / unallocated-
    table target) with NaN / huge garbage before serving: a mixed-depth
    schedule must produce exactly the tokens of a clean run on BOTH
    attention backends — any future softmax-weight leak onto the trash
    block shows up here immediately."""
    cfg, params, _ = setup
    rng = np.random.RandomState(17)
    prompts = [rng.randint(0, cfg.vocab, size=int(rng.randint(3, 9))).tolist()
               for _ in range(3)]

    def drain(poison_value):
        server = _mk_server(cfg, params, attn=attn)
        if poison_value is not None:
            _poison_trash_block(server, poison_value)
        reqs = [Request(prompt=list(p), max_new_tokens=3) for p in prompts]
        for r in reqs:
            server.submit(r)
        server.run_until_drained()
        return [r.output for r in reqs]

    assert drain(poison) == drain(None)


def test_unsupported_arch_raises():
    """MLA latent caches (deepseek) keep the dense slot engine for now —
    requesting paged serving must fail loudly, not silently fall back."""
    cfg = SMOKES["deepseek-v3-671b"].replace(dtype="float32")
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    with pytest.raises(NotImplementedError):
        Server(params, cfg, ServingConfig(n_slots=1, max_len=32, paged=True,
                                          block_size=8))
