"""runtime.paging invariants: the refcounted block allocator, the prefix
trie, and the slot tables' copy-on-write remapping.

Property-based (hypothesis; the stub in containers without it): a random
op stream drives the allocator against a pure-python refcount model, trie
insert/match must round-trip arbitrary token chains, and eviction must
never free a block a live holder still maps. These are the invariants the
serving engine's prefix sharing leans on — a leak or a premature free here
is silent KV corruption there.
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.paging import (TRASH_BLOCK, BlockAllocator, PrefixTrie,
                                  SlotTables)

POOL = 8


# ---------------------------------------------------------------------------
# allocator: refcounts vs a pure-python model
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=10_000),
                min_size=1, max_size=60))
def test_allocator_matches_refcount_model(ops):
    """Random acquire/incref/decref stream: stats and per-block refcounts
    track a dict model exactly, blocks free iff their count hits 0, and a
    full drain restores the empty pool with allocs == frees."""
    alloc = BlockAllocator(POOL)
    model: dict[int, int] = {}
    for op in ops:
        kind = op % 3
        if kind == 0:
            n = 1 + (op // 3) % 2
            if alloc.can_acquire(n):
                ids = alloc.acquire(n)
                assert len(set(ids)) == n and TRASH_BLOCK not in ids
                for b in ids:
                    assert b not in model, "re-issued a live block"
                    model[b] = 1
        elif model:
            b = sorted(model)[(op // 3) % len(model)]
            if kind == 1:
                alloc.incref([b])
                model[b] += 1
            else:
                freed = alloc.decref([b])
                model[b] -= 1
                if model[b] == 0:
                    assert freed == [b]
                    del model[b]
                else:
                    assert freed == []
        stt = alloc.stats
        assert stt.in_use == len(model)
        assert stt.free == POOL - len(model)
        assert stt.shared == sum(1 for v in model.values() if v >= 2)
        assert stt.private == stt.in_use - stt.shared
        assert all(alloc.refcount(b) == v for b, v in model.items())
    for b, v in list(model.items()):
        alloc.decref([b] * v)
    assert alloc.stats.in_use == 0 and alloc.stats.free == POOL
    assert alloc.stats.total_frees == alloc.stats.total_allocs


def test_allocator_exhaustion_and_bad_sizes():
    alloc = BlockAllocator(2)
    alloc.acquire(2)
    assert not alloc.can_acquire(1)
    with pytest.raises(RuntimeError):
        alloc.acquire(1)
    with pytest.raises(ValueError):
        BlockAllocator(0)


def test_freed_blocks_are_reissued_lifo():
    """LIFO free list: the most recently freed block comes back first —
    the adversarial order for stale-contents bugs, pinned so soaks keep
    exercising it."""
    alloc = BlockAllocator(4)
    a, b = alloc.acquire(2)
    alloc.decref([a])
    alloc.decref([b])
    assert alloc.acquire(2) == [b, a]


# ---------------------------------------------------------------------------
# prefix trie: insert/match round-trip, refcount ownership, eviction
# ---------------------------------------------------------------------------
BS = 4


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=5),
                min_size=0, max_size=40),
       st.integers(min_value=0, max_value=40))
def test_trie_insert_match_roundtrip(tokens, cut):
    """insert() then match() returns exactly the inserted chain; a partial
    tail (< block_size tokens) never matches; shorter prefixes match their
    block-aligned prefix; the trie holds one ref per cached block so the
    chain survives the inserting request, and flush() releases it all."""
    alloc = BlockAllocator(16)
    trie = PrefixTrie(BS)
    nfull = len(tokens) // BS
    full = tokens[:nfull * BS]
    blocks = alloc.acquire(nfull)
    assert trie.insert(full, blocks, alloc) == nfull
    assert trie.match(list(tokens)) == blocks     # tail tokens ignored
    k = cut % (nfull + 1) if nfull else 0
    assert trie.match(full[:k * BS]) == blocks[:k]
    # a diverging token truncates the match at that chunk boundary
    if nfull:
        div = list(full)
        div[(nfull - 1) * BS] += 1
        assert trie.match(div) == blocks[:nfull - 1]
    assert all(alloc.refcount(b) == 2 for b in blocks)
    alloc.decref(blocks)                          # requester retires
    assert alloc.stats.in_use == nfull            # cache keeps them alive
    assert trie.evictable(alloc) == nfull
    assert trie.flush(alloc) == nfull
    assert alloc.stats.in_use == 0 and trie.cached_blocks == 0


def test_trie_duplicate_insert_keeps_canonical_blocks():
    """Re-inserting an already-cached chain registers nothing: the caller's
    duplicate blocks stay caller-owned (refcount 1) and are freed by the
    caller alone; the canonical chain keeps serving matches."""
    alloc = BlockAllocator(16)
    trie = PrefixTrie(BS)
    toks = list(range(2 * BS))
    first = alloc.acquire(2)
    trie.insert(toks, first, alloc)
    dup = alloc.acquire(2)
    assert trie.insert(toks, dup, alloc) == 0
    assert all(alloc.refcount(b) == 1 for b in dup)
    assert trie.match(toks) == first
    assert alloc.decref(dup) == dup


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=5))
def test_trie_evict_never_frees_live_blocks(n, j):
    """With an external holder on chain position j, evict() frees exactly
    the unshared suffix behind it (leaf-first cannot reach past a live
    block), and the freed blocks all had only the trie's ref."""
    j = min(j, n - 1)
    alloc = BlockAllocator(16)
    trie = PrefixTrie(BS)
    blocks = alloc.acquire(n)
    trie.insert(list(range(n * BS)), blocks, alloc)
    alloc.decref(blocks)          # requester gone; trie is sole holder
    alloc.incref([blocks[j]])     # ... except a live slot maps block j
    assert trie.evictable(alloc) == n - 1 - j
    freed = trie.evict(n, alloc)
    assert freed == n - 1 - j
    assert alloc.refcount(blocks[j]) == 2       # untouched
    assert all(trie.owns(b) for b in blocks[:j + 1])
    assert all(not trie.owns(b) for b in blocks[j + 1:])
    assert alloc.stats.in_use == j + 1


def test_trie_evicts_lru_chain_first():
    alloc = BlockAllocator(16)
    trie = PrefixTrie(BS)
    a = alloc.acquire(1)
    b = alloc.acquire(1)
    trie.insert([1] * BS, a, alloc)
    trie.insert([2] * BS, b, alloc)
    alloc.decref(a + b)
    trie.match([1] * BS)          # refresh a: b becomes the LRU entry
    assert trie.evict(1, alloc) == 1
    assert trie.owns(a[0]) and not trie.owns(b[0])


def test_trie_forget_block_drops_subtree_keeps_shared_alive():
    alloc = BlockAllocator(16)
    trie = PrefixTrie(BS)
    blocks = alloc.acquire(3)
    trie.insert(list(range(3 * BS)), blocks, alloc)
    trie.forget_block(blocks[1], alloc)   # drops blocks[1] and [2]
    assert trie.owns(blocks[0])
    assert not trie.owns(blocks[1]) and not trie.owns(blocks[2])
    # the requester's refs kept the forgotten blocks alive
    assert all(alloc.refcount(b) == 1 for b in blocks[1:])
    assert alloc.refcount(blocks[0]) == 2


# ---------------------------------------------------------------------------
# slot tables: growth accounting + copy-on-write remap
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=32),
                min_size=1, max_size=8))
def test_slot_tables_growth_accounting(lengths):
    """grow() backs exactly ceil(len / bs) blocks at the high-water length;
    release() frees everything the slot owned."""
    alloc = BlockAllocator(16)
    tab = SlotTables(1, 16, BS)
    hi = 0
    for ln in lengths:
        hi = max(hi, ln)
        tab.grow(0, hi, alloc)
        assert int(tab.n_alloc[0]) == tab.blocks_for(hi)
        assert alloc.stats.in_use == tab.blocks_for(hi)
        held = tab.held(0)
        assert len(set(held)) == len(held) and TRASH_BLOCK not in held
    freed = tab.release(0, alloc)
    assert len(freed) == tab.blocks_for(hi)
    assert alloc.stats.in_use == 0


def test_slot_tables_cow_replace():
    """replace() remaps one logical block to a private copy: the slot
    drops its ref on the shared original (the other holder keeps it) and
    release() frees the private copy with the rest."""
    alloc = BlockAllocator(8)
    tab = SlotTables(1, 4, BS)
    tab.grow(0, 3 * BS, alloc)
    held = tab.held(0)
    alloc.incref([held[1]])               # trie / other slot shares it
    [nb] = alloc.acquire(1)
    tab.replace(0, 1, nb, alloc)
    assert tab.held(0) == [held[0], nb, held[2]]
    assert alloc.refcount(held[1]) == 1   # only the other holder remains
    freed = tab.release(0, alloc)
    assert set(freed) == {held[0], held[2], nb}
    assert alloc.stats.in_use == 1        # the shared original


def test_assign_installs_preincrefd_chain():
    """assign() trusts the caller's increfs (trie match / fork stash): the
    installed chain reads back via held(), and release() returns only the
    blocks whose last ref the slot held."""
    alloc = BlockAllocator(8)
    tab = SlotTables(2, 4, BS)
    chain = alloc.acquire(2)              # e.g. matched trie blocks ...
    alloc.incref(chain)                   # ... incref'd for the new slot
    tab.assign(0, chain, 2 * BS)
    assert tab.held(0) == chain and int(tab.lens[0]) == 2 * BS
    assert tab.release(0, alloc) == []    # original holder still refs them
    assert alloc.decref(chain) == chain
