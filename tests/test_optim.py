"""Optimizers: convergence on a quadratic, factored-state shapes, clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adafactor, adamw, apply_updates, cosine_warmup, \
    global_norm_clip


def _quadratic_target():
    key = jax.random.PRNGKey(0)
    target = {"w": jax.random.normal(key, (8, 4)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (4,))}
    params = jax.tree.map(jnp.zeros_like, target)

    def loss(p):
        return sum(jnp.sum((a - t) ** 2) for a, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    return params, loss


def _run(opt, params, loss, steps=200):
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


def test_adamw_converges():
    params, loss = _quadratic_target()
    opt = adamw(lambda s: 0.05, weight_decay=0.0)
    assert _run(opt, params, loss) < 1e-2


def test_adafactor_converges():
    # adafactor's rms clipping makes |update| ≈ lr, so (as in the paper) the
    # schedule must decay: relative step ∝ 1/√t
    import jax.numpy as jnp
    params, loss = _quadratic_target()
    opt = adafactor(lambda s: 0.5 / jnp.sqrt(s.astype(jnp.float32)),
                    weight_decay=0.0)
    assert _run(opt, params, loss, steps=500) < 5e-2


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((128, 64)), "s": jnp.zeros((7,))}
    opt = adafactor(lambda s: 1e-3)
    st = opt.init(params)
    assert st["stats"]["w"]["vr"].shape == (128,)
    assert st["stats"]["w"]["vc"].shape == (64,)
    assert st["stats"]["s"]["v"].shape == (7,)
    total_stats = sum(x.size for x in jax.tree.leaves(st["stats"]))
    assert total_stats == 128 + 64 + 7  # ≪ 2·(128·64)


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = global_norm_clip(g, 1.0)
    assert float(gn) == 20.0
    np.testing.assert_allclose(np.asarray(clipped["a"]), 0.5, rtol=1e-6)


def test_cosine_warmup_shape():
    lr = cosine_warmup(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == 1.0
    assert 0.09 < float(lr(jnp.asarray(100))) < 0.11
    assert float(lr(jnp.asarray(55))) < 1.0
