"""Reproduce the paper's §V-C keyword-spotting deployment: the dim-144 GRU
trained in float, then evaluated on the simulated PICO-RAM macro at the
paper's operating points (gain 3, PVT corners).

    PYTHONPATH=src python examples/kws_gru.py [--steps 300]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PROTOTYPE
from repro.core.cim_matmul import CIMConfig
from repro.core.macro import OperatingPoint, SimLevel
from repro.core.mapping import MacroBudget, gru_144_shapes, map_model
from repro.models import gru


def make_kws_data(key, proto, n=1024):
    """Synthetic keyword task: each class is a distinct temporal trajectory
    in the 144-dim (stub-MFCC) feature space, plus noise. `proto` fixes the
    class definitions across the train/test splits."""
    n_classes, t, _ = proto.shape
    y = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, n_classes)
    x = proto[y] + 0.4 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (n, t, 144))
    return jax.nn.relu(x), y


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    key = jax.random.PRNGKey(0)

    # --- the mapping story: the GRU fits the macro budget on chip ----------
    mapping = map_model(gru_144_shapes(), MacroBudget(n_macros=64))
    print(f"GRU-144 weights: {mapping.total_weights / 1e3:.1f} K "
          f"(paper: 0.16 M params incl. embeddings) — fits on chip: "
          f"{mapping.fits}, bank utilization "
          f"{mapping.bank_utilization() * 100:.1f}%")

    cfg = gru.gru_config(n_classes=12)
    proto = jax.random.normal(key, (12, 12, 144)) * 1.2
    xtr, ytr = make_kws_data(jax.random.fold_in(key, 8), proto)
    xte, yte = make_kws_data(jax.random.fold_in(key, 9), proto, n=512)
    p = gru.init(jax.random.fold_in(key, 3), cfg)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: gru.train_loss(
            q, {"frames": xtr, "labels": ytr}, cfg))(p)
        return jax.tree.map(lambda pp, gg: pp - 0.1 * gg, p, g), loss

    for i in range(args.steps):
        p, loss = step(p)
        if i % 50 == 0:
            print(f"  step {i}: loss {float(loss):.3f}")

    def acc(cfg_eval):
        logits = gru.forward(p, xte, cfg_eval)
        return float(jnp.mean(jnp.argmax(logits, -1) == yte))

    print(f"float accuracy:            {acc(cfg):.4f}")
    for vdd, temp in ((0.9, 25.0), (0.65, 25.0), (1.2, 25.0), (0.9, -40.0),
                      (0.9, 105.0)):
        macro = dataclasses.replace(PROTOTYPE, gain=3.0,
                                    sim_level=SimLevel.FULL,
                                    op=OperatingPoint(vdd=vdd, temp_c=temp))
        cim_cfg = cfg.replace(cim=CIMConfig(enabled=True, macro=macro))
        print(f"CIM 4b×4b @ {vdd:.2f} V, {temp:+.0f} °C, gain 3: "
              f"accuracy {acc(cim_cfg):.4f}")


if __name__ == "__main__":
    main()
