"""Serve a small model with batched requests on the CIM execution mode.

    PYTHONPATH=src python examples/serve_decode.py [--cim] [--paged]

--paged runs the paged-KV engine (block-pool cache, chunked prefill through
the unified step); default is the legacy slot cache.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import SMOKES
from repro.core.cim_matmul import CIMConfig
from repro.models import registry
from repro.runtime.server import Request, Server, ServingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cim", action="store_true",
                    help="run every matmul on the simulated macro")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV engine + chunked prefill")
    args = ap.parse_args()

    cfg = SMOKES["internlm2-1.8b"]
    if args.cim:
        cfg = cfg.replace(cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg, max_seq=96)
    server = Server(params, cfg, ServingConfig(
        n_slots=args.slots, max_len=96, paged=args.paged, block_size=8,
        prefill_chunk=8))

    rng = np.random.RandomState(0)
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.randint(4, 20))
        r = Request(prompt=rng.randint(0, cfg.vocab, size=plen).tolist(),
                    max_new_tokens=8)
        server.submit(r)
        reqs.append(r)

    t0 = time.monotonic()
    server.run_until_drained()
    dt = time.monotonic() - t0
    for r in reqs:
        print(f"req{r.rid} ({len(r.prompt)} prompt tokens) -> {r.output}")
    tokens = sum(len(r.output) for r in reqs)
    print(f"\nmode={'CIM-BP' if args.cim else 'float'}: {tokens} tokens in "
          f"{server.steps_run} batched decode steps, {tokens / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
