"""Quickstart: the PICO-RAM macro as a JAX matmul.

Runs on CPU in seconds:
    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (CIMConfig, PROTOTYPE, Scheme, cim_matmul)
from repro.core.energy import mvm_energy
from repro.core.sqnr import simulate_sqnr
from repro.kernels.ops import cim_mvm_pallas

key = jax.random.PRNGKey(0)

# --- 1. a float matmul on the simulated analog macro ------------------------
x = jax.nn.relu(jax.random.normal(key, (8, 288)))          # activations ≥ 0
w = jax.random.normal(jax.random.fold_in(key, 1), (288, 16)) * 0.1

y_float = x @ w
for gain in (1.0, 3.0):
    cim = CIMConfig(enabled=True,
                    macro=dataclasses.replace(PROTOTYPE, gain=gain))
    y_cim = cim_matmul(x, w, cim)
    rel = float(jnp.linalg.norm(y_cim - y_float) / jnp.linalg.norm(y_float))
    print(f"BP 4b×4b @8.5-bit ADC, gain={gain:g}: rel err {rel * 100:.2f}%")

# --- 2. the schemes the paper compares against ------------------------------
print("\nscheme comparison (Eq. 4 energy / Monte-Carlo SQNR, K=144):")
for scheme in (Scheme.BP, Scheme.WBS, Scheme.BS):
    macro = dataclasses.replace(PROTOTYPE, scheme=scheme)
    r = simulate_sqnr(macro, k=144, n_samples=1 << 12)
    e = mvm_energy(macro, 144)
    print(f"  {scheme.value:3s}: SQNR {r.sqnr_db:5.1f} dB | "
          f"E_MVM {e.e_mvm_j * 1e12:6.2f} pJ | {e.tops_per_w:5.1f} TOPS/W")

# --- 3. the fused TPU kernel (interpret mode on CPU) -------------------------
codes_x = jnp.floor(x / (x.max() / 15.0))
codes_w = jnp.floor((w - w.min()) / ((w.max() - w.min()) / 15.0))
y_kernel = cim_mvm_pallas(codes_x, codes_w, PROTOTYPE)
print(f"\nPallas kernel output: {y_kernel.shape}, "
      f"finite={bool(jnp.all(jnp.isfinite(y_kernel)))}")
print("done.")
