"""Reproduce the paper's §II-A SQNR study (Fig. 2) from the command line.

    PYTHONPATH=src python examples/sqnr_study.py
"""
import dataclasses

from repro.core import PROTOTYPE, Scheme
from repro.core.sqnr import simulate_sqnr

print("Fig. 2(b): N=144, iso-energy configs (levels 1024/256/32)")
vals = {}
for scheme, levels in ((Scheme.BP, 1024), (Scheme.WBS, 256), (Scheme.BS, 32)):
    cfg = dataclasses.replace(PROTOTYPE, scheme=scheme, adc_levels=levels)
    r = simulate_sqnr(cfg, k=144, n_samples=1 << 14)
    vals[scheme] = r
    print(f"  {scheme.value:3s} levels={levels:5d}: {r.sqnr_db:6.2f} dB  "
          f"E={r.energy_per_mvm_j * 1e12:6.2f} pJ")
print(f"  BP−WBS = {vals[Scheme.BP].sqnr_db - vals[Scheme.WBS].sqnr_db:.1f} dB"
      f" (paper: 7.8) | BP−BS = "
      f"{vals[Scheme.BP].sqnr_db - vals[Scheme.BS].sqnr_db:.1f} dB (paper: 21.6)")

print("\nFig. 2(a): levels=64, iso-energy N (9/36/144)")
for scheme, n in ((Scheme.BP, 9), (Scheme.WBS, 36), (Scheme.BS, 144)):
    cfg = dataclasses.replace(PROTOTYPE, scheme=scheme, n_rows=n,
                              adc_levels=64)
    r = simulate_sqnr(cfg, k=144, n_samples=1 << 14)
    print(f"  {scheme.value:3s} N={n:3d}: {r.sqnr_db:6.2f} dB")
