"""End-to-end driver: CIM-aware QAT of a language model (paper §II-B/V-C).

Trains a reduced llama3-family model twice — float baseline and with every
matmul on the simulated PICO-RAM macro (BP, STE) — for a few hundred steps,
then compares losses and evaluates the float model under post-training CIM
(the BP scheme's training simplicity claim: QAT ≈ standard flow).

    PYTHONPATH=src python examples/train_cim_qat.py [--steps 200]

CPU runtime scales with --steps; the default (200) matches the brief's
"few hundred steps" at ~10M params.
"""
import argparse
import time

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import SMOKES
from repro.core.cim_matmul import CIMConfig
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()

    base = SMOKES[args.arch].replace(
        d_model=256, d_ff=512, vocab=1024)         # ~10M params
    shape = ShapeConfig("qat", args.seq, args.batch, "train")
    tc = TrainConfig(steps=args.steps, lr=1e-3, warmup_steps=10,
                     checkpoint_every=args.steps, log_every=20)

    results = {}
    for mode, cfg in (("float", base),
                      ("cim_bp", base.replace(cim=CIMConfig(enabled=True)))):
        t0 = time.monotonic()
        tr = Trainer(cfg, shape, tc, f"/tmp/qat_{mode}")
        out = tr.run()
        losses = [m["loss"] for m in out["metrics"]]
        results[mode] = losses
        print(f"[{mode}] first={losses[0]:.3f} last={losses[-1]:.3f} "
              f"({time.monotonic() - t0:.0f}s, "
              f"{len(tr.straggler_steps)} straggler steps)")

    gap = results["cim_bp"][-1] - results["float"][-1]
    print(f"\nfinal-loss gap (CIM-QAT − float): {gap:+.4f} nats "
          f"(paper: BP QAT tracks the standard flow; BS needs GSTE and "
          f"often diverges)")


if __name__ == "__main__":
    main()
