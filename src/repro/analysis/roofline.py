"""Three-term roofline analysis from the compiled dry-run artifact.

    compute   = HLO_FLOPs      / (chips × 197 TFLOP/s bf16)
    memory    = HLO_bytes      / (chips × 819 GB/s HBM)
    collective= collective_B   / (chips × 50 GB/s/link ICI)

plus MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE) / 2·N·D
(inference) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs that
catches remat/dispatch/quantization waste.

HLO_FLOPs/bytes come from compiled.cost_analysis(); cost_analysis totals are
whole-program (all chips), so both are divided by the chip count. Collective
bytes come from analysis.hlo parsing of the partitioned module (per-chip
already, since the module is the per-device program).
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12      # TPU v5e-class bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link per chip


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    peak_bytes_per_chip: float
    collective_detail: dict

    # NOTE: compiled.cost_analysis() on the partitioned module reports the
    # PER-DEVICE program (verified against a hand-computed matmul), so
    # hlo_flops / hlo_bytes / collective_bytes are all per-chip already;
    # model_flops is global and is divided by chips where compared.
    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def wire_bytes(self) -> float:
        """Physical per-chip link traffic: ring all-reduce moves ≈2× its
        operand bytes ((n−1)/n reduce-scatter + (n−1)/n all-gather); AG / RS /
        A2A / permute move ≈1× the operand."""
        detail = (self.collective_detail or {}).get("bytes", {})
        if not detail:
            return self.collective_bytes
        total = 0.0
        for kind, b in detail.items():
            total += (2.0 if kind == "all-reduce" else 1.0) * b
        return total

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        if not self.hlo_flops:
            return 0.0
        return self.model_flops / self.chips / self.hlo_flops

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step ran at the
        bound implied by the dominant term: useful_FLOPs/chip/step_time over
        peak. This is the score-bearing number in EXPERIMENTS.md §Perf."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        if t_bound <= 0:
            return 0.0
        return (self.model_flops / self.chips / t_bound) / PEAK_FLOPS

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "wire_bytes": self.wire_bytes,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_bytes_per_chip": self.peak_bytes_per_chip,
            "collective_detail": self.collective_detail,
        }


def count_params(abstract_params) -> int:
    import jax
    return sum(int(x.size) for x in jax.tree.leaves(abstract_params))


def count_active_params(abstract_params, cfg) -> float:
    """MoE-aware active parameter count for MODEL_FLOPS."""
    import jax
    total = routed = 0
    for kp, leaf in jax.tree_util.tree_flatten_with_path(abstract_params)[0]:
        path = [getattr(k, "key", str(k)) for k in kp]
        total += int(leaf.size)
        if path[-1] in ("e_gate", "e_up", "e_down"):
            routed += int(leaf.size)
    if cfg.moe is None or routed == 0:
        return float(total)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return float(total - routed + routed * frac)


def model_flops(cfg, shape, abstract_params) -> float:
    n_active = count_active_params(abstract_params, cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch
