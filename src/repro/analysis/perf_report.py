"""Render the §Perf hillclimb log from experiments/perf/*.json.

    PYTHONPATH=src python -m repro.analysis.perf_report
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .report import _rl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/perf")
    args = ap.parse_args()
    rows = ["| var | cell | hypothesis | dom | t_comp | t_mem | t_coll |"
            " temp GiB | roofline frac | verdict |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    by_cell = {}
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        c = json.load(open(f))
        vid = c.get("variant", os.path.basename(f)[:-5])
        by_cell.setdefault(vid[0], []).append((vid, c))
    for group in sorted(by_cell):
        base = None
        for vid, c in sorted(by_cell[group]):
            if c["status"] != "ok":
                rows.append(f"| {vid} | {c['arch']}×{c['shape']} |"
                            f" {c.get('hypothesis', '')[:60]} | ERROR |"
                            f" | | | | | {c.get('error', '')[:60]} |")
                continue
            rl = _rl(c)
            temp = c["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
            terms = {"compute": rl.t_compute, "memory": rl.t_memory,
                     "collective": rl.t_collective}
            if base is None:
                base = (terms, temp, rl.roofline_fraction)
                verdict = "baseline"
            else:
                dom0 = max(base[0], key=base[0].get)
                delta = terms[dom0] / base[0][dom0] - 1
                verdict = (f"{dom0} {delta * 100:+.0f}% vs base; "
                           f"frac {base[2]:.3f}→{rl.roofline_fraction:.3f}")
            rows.append(
                f"| {vid} | {c['arch']}×{c['shape']} |"
                f" {c.get('hypothesis', '')[:70]} | {rl.dominant} |"
                f" {rl.t_compute:.3f} | {rl.t_memory:.3f} |"
                f" {rl.t_collective:.3f} | {temp:.1f} |"
                f" {rl.roofline_fraction:.4f} | {verdict} |")
    print("\n".join(rows))


if __name__ == "__main__":
    main()
