"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
artifacts emitted by launch/dryrun.py.

    PYTHONPATH=src python -m repro.analysis.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .roofline import Roofline


def _rl(c: dict) -> Roofline:
    """Rebuild the Roofline from raw stored fields (so metric refinements —
    e.g. the 2× all-reduce wire weighting — apply to old artifacts too)."""
    r = c["roofline"]
    return Roofline(
        arch=c["arch"], shape=c["shape"], mesh=c["mesh"], chips=r["chips"],
        hlo_flops=r["hlo_flops"], hlo_bytes=r["hlo_bytes"],
        collective_bytes=r["collective_bytes"],
        model_flops=r["model_flops"],
        peak_bytes_per_chip=r.get("peak_bytes_per_chip", 0.0),
        collective_detail=r.get("collective_detail", {}))


def _fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def _fmt_flops(f: float) -> str:
    return f"{f / 1e12:.2f}"


def load_cells(d: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def roofline_table(cells: list[dict], *, mesh: str = "pod16x16",
                   xp_only: bool = True) -> str:
    rows = ["| arch | shape | dom. | t_comp (s) | t_mem (s) | t_coll (s) | "
            "useful | roofline frac | HLO TFLOP/chip | mem GiB/chip | note |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["mesh"] != mesh or c.get("cim", "off") != "off":
            continue
        is_xp = c["cell"].endswith("__xp")
        if xp_only != is_xp:
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — |"
                        f" — | — | — | {c['reason'][:60]} |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | | | |"
                        f" | {c['error'][:60]} |")
            continue
        rl = _rl(c)
        mem = c["memory_analysis"].get("temp_size_in_bytes", 0)
        note = ""
        if mem > 16 * 2**30:
            note = "over 16 GiB HBM — see §Perf"
        rows.append(
            f"| {c['arch']} | {c['shape']} | {rl.dominant} |"
            f" {rl.t_compute:.3f} | {rl.t_memory:.3f} |"
            f" {rl.t_collective:.3f} | {rl.useful_ratio:.2f} |"
            f" {rl.roofline_fraction:.3f} | {_fmt_flops(rl.hlo_flops)} |"
            f" {_fmt_bytes(mem)} | {note} |")
    return "\n".join(rows)


def dryrun_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile (s) | temp GiB/chip | "
            "args GiB/chip | collective bytes/chip | AR/AG/RS/A2A/CP counts |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["cell"].endswith("__xp") or c.get("cim", "off") != "off":
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"skipped | | | | | {c['reason'][:50]} |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | ERROR"
                        f" | | | | | {c['error'][:50]} |")
            continue
        m = c["memory_analysis"]
        det = c["roofline"]["collective_detail"]
        counts = det.get("counts", {})
        cstr = "/".join(str(counts.get(k, 0)) for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | ok |"
            f" {c['compile_s']} | {_fmt_bytes(m.get('temp_size_in_bytes', 0))} |"
            f" {_fmt_bytes(m.get('argument_size_in_bytes', 0))} |"
            f" {c['roofline']['collective_bytes']:.3g} | {cstr} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print("## §Roofline (single-pod, unrolled-extrapolated exact costs)\n")
    print(roofline_table(cells, mesh=args.mesh))
    print("\n## §Dry-run (scanned builds — compile proof + memory)\n")
    print(dryrun_table(cells))


if __name__ == "__main__":
    main()
