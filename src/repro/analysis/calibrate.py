"""Static activation-scale calibration for CIM serving.

The dynamic per-tensor act_scale (core.quant) takes a global max over the
batched activation tensor, so every lane's 4-bit DAC grid depends on what
else shares the batch — CIM-mode serving outputs change with batch
COMPOSITION (the coupling documented in runtime/server.py since PR 4). The
hardware has no such coupling: the paper's input interface is a fixed
charge-domain C-DAC reference (cf. the P-8T macro's low-cost DAC,
arXiv:2211.16008), i.e. a CALIBRATED STATIC grid.

This module is the calibration half of that fix:

    tokens = jnp.asarray([[...prompt...]], jnp.int32)
    cal = calibrate_act_scale(params, tokens, cfg)
    server = Server(params, cfg,
                    ServingConfig(..., act_scale=cal["scale"]))

`collect_act_spans` runs one EAGER forward (layer scan unrolled so values
are concrete) with a recorder hooked into core.quant.act_scale and returns
the per-matmul activation spans in call order — `quant.SpanRecord` entries
(floats carrying the call-site name, the signed range [lo, hi] and the
(k, m, rows) shape metadata) — one entry per CIM-routed matmul.

Two reductions of that profile:

* `calibrate_act_scale` — ONE static (scale, zero_point) grid for the whole
  model (max span / qmax, optionally a percentile over call sites; the
  zero point covers the profile's most negative tail). The grids are PAIRS
  now: the recorder measures span = max − min(·, 0), so a zero-pinned
  static grid both wasted range above the data and clipped the negative
  tail the span accounted for — the calibrated zp closes that grid
  mismatch (exact digital fold via schemes.signed_correction).
* `calibrate_act_tree` — the PER-CALL-SITE calibration tree: one
  (scale, zero_point) + range/shape entry per site name ("wq", "w_up",
  "e_gate", "head", ...). Site names deliberately exclude the layer index
  (layers share weight names), so the tree is identical between scanned
  and unrolled layer configs and each site resolves one constant grid even
  when all layers share a single lax.scan trace. This is the profile the
  mixed-precision autotuner (analysis.precision_search) searches over.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core import quant


def _calibration_cfg(cfg):
    """The config the calibration forward runs under: CIM enabled with the
    DYNAMIC scale (that is what is being measured), deterministic einsum
    backend (cheap in eager mode), layer scan unrolled so every span is a
    concrete value the recorder can capture."""
    cim = cfg.cim
    if not cim.enabled:
        raise ValueError("activation calibration needs cfg.cim.enabled")
    cim = dataclasses.replace(
        cim, backend="einsum", noise_seed=None,
        act=dataclasses.replace(cim.act, static_scale=None))
    return cfg.replace(cim=cim, scan_layers=False)


def collect_act_spans(params, tokens, cfg, *, mod=None) -> list:
    """Per-matmul activation spans (max − min(·, 0)), in call order, over
    one eager forward of `tokens` [B, T] int32. Entries are
    `quant.SpanRecord` (float subclass): plain span arithmetic keeps
    working, and each record carries (site, lo, hi, k, m, rows)."""
    if mod is None:
        from repro.models import registry
        mod = registry.get_module(cfg)
    cal_cfg = _calibration_cfg(cfg)
    with quant.record_act_spans() as spans:
        mod.forward(params, {"tokens": jnp.asarray(tokens, jnp.int32)},
                    cal_cfg, train=False)
    if not spans:
        raise RuntimeError("calibration forward recorded no activation "
                           "spans — did every matmul bypass the CIM path?")
    return spans


def _grid(lo: float, span: float, qmax: int) -> tuple[float, float]:
    """(scale, zero_point) covering [min(lo, 0), min(lo, 0) + span]."""
    scale = span / qmax
    zp = float(round(min(max(-min(lo, 0.0) / scale, 0.0), float(qmax))))
    return scale, zp


def calibrate_act_scale(params, tokens, cfg, *, percentile: float = 1.0,
                        mod=None) -> dict:
    """One static DAC grid from a calibration batch.

    percentile < 1.0 drops the hottest call sites from the max (the VTC
    gain trade of Fig. 15: a tighter grid at the cost of clipping their
    tails). Returns {"scale", "zero_point", "spans", "span", "qmax"}; feed
    (scale, zero_point) to ServingConfig(act_scale=..., act_zero_point=...)
    / ActQuantConfig(static_scale=..., static_zero_point=...). The zero
    point covers the profile's most negative activation tail — span is
    measured as max − min(·, 0), so a grid without it clips exactly the
    range the calibrated scale reserved.
    """
    if not 0.0 < percentile <= 1.0:
        raise ValueError(f"percentile must be in (0, 1], got {percentile}")
    spans = collect_act_spans(params, tokens, cfg, mod=mod)
    ordered = sorted(spans)
    idx = max(0, math.ceil(percentile * len(ordered)) - 1)
    span = ordered[idx]
    qmax = cfg.cim.act.qmax
    lo = min((r.lo for r in spans), default=0.0)
    scale, zp = _grid(lo, float(span), qmax)
    return {"scale": scale, "zero_point": zp, "span": span, "spans": spans,
            "qmax": qmax}


def calibrate_act_tree(params, tokens, cfg, *, percentile: float = 1.0,
                       mod=None) -> dict:
    """Per-call-site calibration tree from one eager calibration forward.

    Aggregates the span profile BY SITE NAME (layer-index-free, so scanned
    and unrolled configs yield the identical tree): per site, the range is
    the min/percentile-max envelope over every call that hit the site
    (layers × chunks × experts), reduced to a static (scale, zero_point)
    grid plus the shape/traffic metadata (k, m, rows, calls) the precision
    autotuner's energy accounting consumes.

    Returns {"sites": {name: {"scale", "zero_point", "lo", "hi", "span",
    "k", "m", "rows", "calls"}}, "default": the whole-model grid,
    "qmax": ...} with sites ordered by first appearance (call order).
    """
    if not 0.0 < percentile <= 1.0:
        raise ValueError(f"percentile must be in (0, 1], got {percentile}")
    spans = collect_act_spans(params, tokens, cfg, mod=mod)
    qmax = cfg.cim.act.qmax
    by_site: dict[str, list] = {}
    for r in spans:
        by_site.setdefault(r.site or "<unnamed>", []).append(r)
    sites = {}
    for name, recs in by_site.items():
        ordered = sorted(float(r) for r in recs)
        idx = max(0, math.ceil(percentile * len(ordered)) - 1)
        span = ordered[idx]
        lo = min(r.lo for r in recs)
        scale, zp = _grid(lo, span, qmax)
        sites[name] = {
            "scale": scale, "zero_point": zp, "lo": lo,
            "hi": max(r.hi for r in recs), "span": span,
            "k": max(r.k for r in recs),
            "m": max((r.m for r in recs if r.m is not None), default=None),
            "rows": sum(r.rows for r in recs), "calls": len(recs)}
    lo_all = min(r.lo for r in spans)
    ordered = sorted(spans)
    idx = max(0, math.ceil(percentile * len(ordered)) - 1)
    scale, zp = _grid(lo_all, float(ordered[idx]), qmax)
    return {"sites": sites,
            "default": {"scale": scale, "zero_point": zp,
                        "span": float(ordered[idx]), "lo": lo_all},
            "qmax": qmax}
