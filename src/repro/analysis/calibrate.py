"""Static activation-scale calibration for CIM serving.

The dynamic per-tensor act_scale (core.quant) takes a global max over the
batched activation tensor, so every lane's 4-bit DAC grid depends on what
else shares the batch — CIM-mode serving outputs change with batch
COMPOSITION (the coupling documented in runtime/server.py since PR 4). The
hardware has no such coupling: the paper's input interface is a fixed
charge-domain C-DAC reference (cf. the P-8T macro's low-cost DAC,
arXiv:2211.16008), i.e. a CALIBRATED STATIC grid.

This module is the calibration half of that fix:

    tokens = jnp.asarray([[...prompt...]], jnp.int32)
    cal = calibrate_act_scale(params, tokens, cfg)
    server = Server(params, cfg,
                    ServingConfig(..., act_scale=cal["scale"]))

`collect_act_spans` runs one EAGER forward (layer scan unrolled so values
are concrete) with a recorder hooked into core.quant.act_scale and returns
the per-matmul activation spans in call order — one entry per CIM-routed
matmul, i.e. the per-layer amax profile. `calibrate_act_scale` reduces the
profile to a single static scale (max span / qmax, optionally a percentile
over call sites) — one fixed DAC grid for the whole model, matching the
macro's single analog reference. Per-call-site static scales are a
follow-up (they need per-layer plumbing through the params tree).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core import quant


def _calibration_cfg(cfg):
    """The config the calibration forward runs under: CIM enabled with the
    DYNAMIC scale (that is what is being measured), deterministic einsum
    backend (cheap in eager mode), layer scan unrolled so every span is a
    concrete value the recorder can capture."""
    cim = cfg.cim
    if not cim.enabled:
        raise ValueError("activation calibration needs cfg.cim.enabled")
    cim = dataclasses.replace(
        cim, backend="einsum", noise_seed=None,
        act=dataclasses.replace(cim.act, static_scale=None))
    return cfg.replace(cim=cim, scan_layers=False)


def collect_act_spans(params, tokens, cfg, *, mod=None) -> list[float]:
    """Per-matmul activation spans (max − min(·, 0)), in call order, over
    one eager forward of `tokens` [B, T] int32."""
    if mod is None:
        from repro.models import registry
        mod = registry.get_module(cfg)
    cal_cfg = _calibration_cfg(cfg)
    with quant.record_act_spans() as spans:
        mod.forward(params, {"tokens": jnp.asarray(tokens, jnp.int32)},
                    cal_cfg, train=False)
    if not spans:
        raise RuntimeError("calibration forward recorded no activation "
                           "spans — did every matmul bypass the CIM path?")
    return spans


def calibrate_act_scale(params, tokens, cfg, *, percentile: float = 1.0,
                        mod=None) -> dict:
    """One static DAC scale from a calibration batch.

    percentile < 1.0 drops the hottest call sites from the max (the VTC
    gain trade of Fig. 15: a tighter grid at the cost of clipping their
    tails). Returns {"scale", "spans", "span", "qmax"}; feed "scale" to
    ServingConfig(act_scale=...) / ActQuantConfig.static_scale.
    """
    if not 0.0 < percentile <= 1.0:
        raise ValueError(f"percentile must be in (0, 1], got {percentile}")
    spans = collect_act_spans(params, tokens, cfg, mod=mod)
    ordered = sorted(spans)
    idx = max(0, math.ceil(percentile * len(ordered)) - 1)
    span = ordered[idx]
    qmax = cfg.cim.act.qmax
    return {"scale": span / qmax, "span": span, "spans": spans,
            "qmax": qmax}
