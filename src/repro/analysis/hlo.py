"""HLO text parsing: collective-op byte accounting.

cost_analysis() has FLOPs and touched bytes but NOT collective traffic;
per the brief we parse the (post-partitioning, per-device SPMD) HLO text and
sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Optimized HLO spells operands as bare %names, so this is a two-pass parse:
(1) symbol table of every instruction's result shape(s); (2) per collective
line, resolve operand names against the table.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COLL_RE = re.compile(
    r"=\s*(?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(([^)]*)\)")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes_of(text: str) -> int:
    """Total bytes of all dtype[shape] tokens in `text` (tuples sum)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op (per-device program)."""
    # pass 1: result shapes — the shape expression right after "name ="
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result shape = everything before the opcode word; just take the
        # first shape-ish prefix (tuple or single shape)
        if rhs.startswith("("):
            end = rhs.find(")")
            sizes[name] = _shape_bytes_of(rhs[:end + 1])
        else:
            sm = _SHAPE_RE.match(rhs)
            sizes[name] = _shape_bytes_of(sm.group(0)) if sm else 0

    bytes_by: dict[str, int] = defaultdict(int)
    count_by: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind, phase, args = m.group(1), m.group(2), m.group(3)
        if phase == "-done":  # the -start already carried the operands
            continue
        inline = _shape_bytes_of(args)
        if inline:
            total = inline
        else:
            total = sum(sizes.get(nm, 0) for nm in _NAME_RE.findall(args))
        bytes_by[kind] += total
        count_by[kind] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))
