"""Per-PR perf trajectory from BENCH_ci.json artifacts → markdown report.

The CI bench-smoke job has been uploading one BENCH_ci.json per run since
PR 2 (schema pico-ram/kernel_bench/v1); this module turns those point
measurements into the trajectory the ROADMAP asks for. Two modes:

  one-shot over explicit files (oldest → newest):
    PYTHONPATH=src python -m repro.analysis.bench_trend \
        run1/BENCH_ci.json run2/BENCH_ci.json --out TREND.md

  accumulating history (what CI runs — the previous run's history artifact
  is downloaded when present, the current bench is appended, and both the
  updated history and the rendered report are re-uploaded):
    PYTHONPATH=src python -m repro.analysis.bench_trend \
        --history bench_history.jsonl --append BENCH_ci.json \
        --label "$GITHUB_SHA" --out TREND.md

Tracked columns (parsed from the bench rows; missing rows render as "—"):
  * decode tokens/s — the --small packed decode sweep's wall time converted
    to tokens/second (interpret-mode on CPU CI: a structural trend, not TPU
    absolute perf — a 10× regression still shows as a 10× regression);
  * weight-HBM bytes of the packed decode shape and its ×-less-HBM factor
    vs int8 (the nibble-packing win — exact byte counts, platform-free);
  * fused-vs-einsum σ ratio of the stochastic kernel's ADC-chain error (the
    in-kernel PRNG distributional-agreement number the engine tests pin —
    drift here means a PRNG/transfer regression);
  * fused stochastic kernel wall µs;
  * (schema v2) the serving sweep: paged-engine decode tok/s from the
    end-to-end runtime.server drain, and the resident KV-cache bytes at
    25 % slot occupancy — paged pool vs the monolithic slot cache, with the
    ×-less-HBM factor (exact byte counts, platform-free);
  * (schema v3) the paged-attention sweep: the paged engine drained on the
    Pallas flash attention backend (kernel decode tok/s next to the exact
    backend's), and the peak score-tensor bytes of the LARGEST swept
    window — exact materializes [B, C, KH, G, W] (O(W)), the kernel keeps
    one [C·G, block] tile (O(block)); the ×-less factor is the memory
    probe the acceptance criteria pin;
  * (schema v4) the autotune sweep: tuned-vs-default speedup of the
    W=4096 decode paged-attention family (`paged_attn_decode_w4096_tuned`
    vs its `_default` twin, from `kernel_bench --autotune`) — the number
    the bench-smoke job gates at ≥ 1.25×;
  * (schema v5) the shared-prefix serving row: peak decode lanes of the
    prefix-sharing paged pool vs the same pool with sharing disabled (the
    ×-concurrency factor the bench-smoke job gates at > 5×), plus the
    prefill tokens the trie absorbed — deterministic lane/token counts,
    platform-free;
  * (schema v6) the spec-decode serving row: speculative-vs-plain greedy
    decode tok/s on the paged engine with the ngram drafter (warm-timed
    legs, bit-identical outputs asserted in the bench) — the speedup the
    bench-smoke job gates at ≥ 1.5×, plus the mean accepted length per
    verify step (1 + accepted drafts, the number the speedup is made of);
  * (schema v7) the energy-pareto row: serving energy/token (Eq. 4 over
    the calibration traffic profile) of uniform 4b×4b BP at native ADC
    resolution vs the searched per-site mixed-precision manifest, the
    ×-energy win the bench-smoke job gates at ≥ 1.3×, and the
    accuracy-proxy delta (held-out logit KL vs float: mixed − uniform,
    bounded by the search's kl_budget) — deterministic model numbers,
    platform-free;
  * (schema v8) the serve-SLO row: p50/p99 time-to-first-token from the
    runtime.telemetry histograms of a paged-engine drain, plus the
    telemetry overhead percentage (decode tok/s with the event
    trace / snapshots / histograms enabled vs disabled — the bench-smoke
    job gates it < 3 %).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys


def load_bench(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    schema = str(doc.get("schema", ""))
    if not schema.startswith("pico-ram/kernel_bench/"):
        raise ValueError(f"{path}: unexpected schema {schema!r}")
    if not doc.get("rows"):
        raise ValueError(f"{path}: no bench rows")
    return doc


def extract_metrics(doc: dict) -> dict:
    """One BENCH_ci.json document → the tracked scalar metrics."""
    out: dict = {}
    for r in doc["rows"]:
        name, us, derived = r["name"], float(r["us"]), str(r.get("derived", ""))
        m = re.match(r"decode_packed_m(\d+)_k(\d+)_n(\d+)", name)
        if m and "decode_tok_s" not in out:
            toks = int(m.group(1))
            out["decode_shape"] = f"m{m.group(1)}_k{m.group(2)}_n{m.group(3)}"
            out["decode_tok_s"] = toks / us * 1e6
            wb = re.search(r"w_bytes\s+(\d+)->(\d+)\s+\(([\d.]+)x", derived)
            if wb:
                out["w_bytes_packed"] = int(wb.group(2))
                out["w_bytes_int8"] = int(wb.group(1))
                out["hbm_win"] = float(wb.group(3))
        if name.startswith("kernel_pallas_noisy"):
            out["noisy_us"] = us
            sr = re.search(r"ratio=([\d.]+)", derived)
            if sr:
                out["sigma_ratio"] = float(sr.group(1))
        if name.startswith("kernel_ref_jnp"):
            out["ref_us"] = us
        if name.startswith("serve_decode_paged_attnkernel"):
            sd = re.search(r"decode_tok_s=([\d.]+)", derived)
            if sd:
                out["attn_kernel_tok_s"] = float(sd.group(1))
        elif name.startswith("serve_decode_paged"):
            sd = re.search(r"decode_tok_s=([\d.]+)", derived)
            if sd:
                out["serve_decode_tok_s"] = float(sd.group(1))
        m4 = re.match(r"paged_attn_decode_w(\d+)_tuned", name)
        if m4:
            sp = re.search(r"speedup=([\d.]+)x", derived)
            if sp and int(m4.group(1)) >= out.get("tune_window", 0):
                out["tune_window"] = int(m4.group(1))
                out["tune_speedup"] = float(sp.group(1))
            continue  # the tuned/default pair carries no score-bytes probe
        if name.endswith("_default"):
            continue
        m3 = re.match(r"paged_attn_decode_w(\d+)", name)
        if m3:
            w = int(m3.group(1))
            sb = re.search(
                r"score_bytes\s+exact=(\d+)\s+kernel=(\d+)\s+\((\d+)x",
                derived)
            if sb and w >= out.get("score_window", 0):
                out["score_window"] = w
                out["score_bytes_exact"] = int(sb.group(1))
                out["score_bytes_kernel"] = int(sb.group(2))
                out["score_win"] = float(sb.group(3))
        if name.startswith("serve_shared_prefix"):
            pl = re.search(r"shared=(\d+) nosharing=(\d+) \(([\d.]+)x",
                           derived)
            if pl:
                out["prefix_lanes"] = int(pl.group(1))
                out["prefix_lanes_base"] = int(pl.group(2))
                out["prefix_win"] = float(pl.group(3))
            ts = re.search(r"prefill_tok_saved=(\d+)", derived)
            if ts:
                out["prefix_tok_saved"] = int(ts.group(1))
        if name.startswith("serve_spec_decode"):
            sp = re.search(r"speedup=([\d.]+)x", derived)
            if sp:
                out["spec_speedup"] = float(sp.group(1))
            ml = re.search(r"mean_accept_len=([\d.]+)", derived)
            if ml:
                out["spec_accept_len"] = float(ml.group(1))
        if name.startswith("energy_pareto"):
            ep = re.search(
                r"uniform_pj_tok=([\d.]+)\|mixed_pj_tok=([\d.]+)\|"
                r"energy_win=([\d.]+)x", derived)
            if ep:
                out["uniform_pj_tok"] = float(ep.group(1))
                out["mixed_pj_tok"] = float(ep.group(2))
                out["energy_win"] = float(ep.group(3))
            kd = re.search(r"kl_uniform=([\d.]+)\|kl_mixed=([\d.]+)",
                           derived)
            if kd:
                out["energy_kl_delta"] = float(kd.group(2)) \
                    - float(kd.group(1))
        if name.startswith("serve_slo"):
            tt = re.search(r"ttft_p50_ms=([\d.]+)\|ttft_p99_ms=([\d.]+)",
                           derived)
            if tt:
                out["ttft_p50_ms"] = float(tt.group(1))
                out["ttft_p99_ms"] = float(tt.group(2))
            ov = re.search(r"overhead_pct=([+-]?[\d.]+)", derived)
            if ov:
                out["telemetry_overhead_pct"] = float(ov.group(1))
        if name.startswith("serve_kv_bytes_occ25"):
            kb = re.search(
                r"kv_bytes\s+slot=(\d+)\s+paged=(\d+)\s+\(([\d.]+)x", derived)
            if kb:
                out["kv_bytes_slot"] = int(kb.group(1))
                out["kv_bytes_paged"] = int(kb.group(2))
                out["kv_win"] = float(kb.group(3))
    return out


def entry_from_bench(path: str, label: str | None = None) -> dict:
    doc = load_bench(path)
    return {
        "label": label or os.path.basename(os.path.dirname(path) or path),
        "jax": doc.get("jax"),
        "backend": doc.get("backend"),
        "metrics": extract_metrics(doc),
    }


def load_history(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    entries = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                entries.append(json.loads(line))
    return entries


def save_history(path: str, entries: list[dict]) -> None:
    with open(path, "w") as f:
        for e in entries:
            f.write(json.dumps(e) + "\n")


def _fmt(v, spec: str = "{:.3f}") -> str:
    return "—" if v is None else spec.format(v)


def render_markdown(entries: list[dict]) -> str:
    lines = [
        "# kernel_bench perf trajectory",
        "",
        "Interpret-mode CPU CI numbers — structural trend, not TPU absolute "
        "perf. Byte counts and the σ ratio are platform-free.",
        "",
        "| run | decode tok/s | packed weight HBM B | vs int8 | "
        "fused σ ratio | fused noisy µs | serve tok/s | attn-kernel tok/s | "
        "paged KV B @25% | vs slot | score B (kernel) | vs exact | "
        "tuned speedup | prefix lanes | prefill tok saved | spec speedup | "
        "accept len | mixed pJ/tok | energy win | ΔKL proxy | "
        "ttft p50 ms | ttft p99 ms | telemetry ovh |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---"
        "|---|---|---|---|---|---|---|",
    ]
    for e in entries:
        m = e.get("metrics", {})
        prefix_lanes = None
        if m.get("prefix_lanes") is not None:
            prefix_lanes = (f"{m['prefix_lanes']} vs "
                            f"{m.get('prefix_lanes_base', '?')} "
                            f"({m.get('prefix_win', 0):.1f}×)")
        lines.append(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} "
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |"
            .format(
                str(e.get("label", "?"))[:24],
                _fmt(m.get("decode_tok_s"), "{:.0f}"),
                _fmt(m.get("w_bytes_packed"), "{:d}"),
                _fmt(m.get("hbm_win"), "{:.2f}×"),
                _fmt(m.get("sigma_ratio")),
                _fmt(m.get("noisy_us"), "{:.1f}"),
                _fmt(m.get("serve_decode_tok_s"), "{:.1f}"),
                _fmt(m.get("attn_kernel_tok_s"), "{:.1f}"),
                _fmt(m.get("kv_bytes_paged"), "{:d}"),
                _fmt(m.get("kv_win"), "{:.2f}×"),
                _fmt(m.get("score_bytes_kernel"), "{:d}"),
                _fmt(m.get("score_win"), "{:.0f}×"),
                _fmt(m.get("tune_speedup"), "{:.2f}×"),
                prefix_lanes or "—",
                _fmt(m.get("prefix_tok_saved"), "{:d}"),
                _fmt(m.get("spec_speedup"), "{:.2f}×"),
                _fmt(m.get("spec_accept_len"), "{:.2f}"),
                _fmt(m.get("mixed_pj_tok"), "{:.0f}"),
                _fmt(m.get("energy_win"), "{:.2f}×"),
                _fmt(m.get("energy_kl_delta"), "{:+.4f}"),
                _fmt(m.get("ttft_p50_ms"), "{:.1f}"),
                _fmt(m.get("ttft_p99_ms"), "{:.1f}"),
                _fmt(m.get("telemetry_overhead_pct"), "{:+.2f}%"),
            ))
    shapes = {e.get("metrics", {}).get("decode_shape") for e in entries}
    shapes.discard(None)
    if shapes:
        lines += ["", f"decode shape(s): {', '.join(sorted(shapes))}"]
    windows = {e.get("metrics", {}).get("score_window") for e in entries}
    windows.discard(None)
    if windows:
        lines += ["", "score-tensor probe window(s): "
                  + ", ".join(str(w) for w in sorted(windows))]
    lines.append("")
    return "\n".join(lines)


def render_pareto_markdown(manifest: dict) -> str:
    """Energy/accuracy Pareto section from a precision-search manifest —
    the deployment artifact `serve.py --precision-manifest` consumes, so
    the table describes exactly what `ServingConfig` dispatches."""
    from repro.analysis.precision_search import pareto_points
    m = manifest["metrics"]
    pts = pareto_points(manifest)
    win = m["energy_win"]
    lines = [
        "## Energy/accuracy Pareto (mixed analog precision)",
        "",
        f"Per-site precision manifest (schema `{manifest['schema']}`, "
        f"arch `{manifest['arch']}`, seed {manifest['seed']}) served "
        "through `ServingConfig(precision_manifest=…)` → "
        "`CIMConfig.site_overrides`. Energy is Eq. 4 over the calibration "
        "traffic profile; the accuracy proxy is held-out logit KL to the "
        f"float model (budget {m['kl_budget']} over the uniform config).",
        "",
        "| config | pJ/token | vs uniform | KL vs float |",
        "|---|---|---|---|",
    ]
    base = pts[0]["pj_per_token"]
    for p in pts:
        lines.append("| {} | {:.1f} | {:.3f}× | {:.4f} |".format(
            p["config"], p["pj_per_token"],
            base / max(p["pj_per_token"], 1e-30), p["kl"]))
    sites = ", ".join(
        f"{name}={e['adc_levels']}" for name, e in
        sorted(manifest["sites"].items()))
    lines += [
        "",
        f"mixed config: {win:.3f}× lower energy/token "
        f"({(1 - 1 / win) * 100:.1f} % saved) at iso-accuracy-proxy.",
        "",
        f"per-site ADC levels: {sites}",
        "",
    ]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="*",
                    help="BENCH_ci.json files, oldest first (one-shot mode)")
    ap.add_argument("--history", default=None, metavar="JSONL",
                    help="accumulating history file (read if present, "
                         "re-written with --append applied)")
    ap.add_argument("--append", default=None, metavar="BENCH_JSON",
                    help="append this bench document to --history")
    ap.add_argument("--label", default=None,
                    help="label for the appended entry (e.g. the git sha)")
    ap.add_argument("--out", default="TREND.md",
                    help="markdown report path")
    ap.add_argument("--max-entries", type=int, default=200,
                    help="keep only the newest N history entries")
    ap.add_argument("--precision-manifest", default=None, metavar="JSON",
                    dest="precision_manifest",
                    help="append the energy/accuracy Pareto section "
                         "rendered from this precision-search manifest")
    args = ap.parse_args(argv)

    if bool(args.history) != bool(args.append) and not args.bench:
        ap.error("--history and --append go together")
    entries: list[dict] = []
    if args.history:
        entries = load_history(args.history)
        if args.append:
            entries.append(entry_from_bench(args.append, args.label))
            entries = entries[-args.max_entries:]
            save_history(args.history, entries)
    for path in args.bench:
        entries.append(entry_from_bench(path))
    if not entries:
        ap.error("nothing to render: pass bench files or --history/--append")
    md = render_markdown(entries)
    if args.precision_manifest:
        from repro.analysis.precision_search import load_manifest
        manifest = load_manifest(args.precision_manifest)
        if manifest is not None:
            md += "\n" + render_pareto_markdown(manifest)
    with open(args.out, "w") as f:
        f.write(md)
    print(f"wrote {args.out} ({len(entries)} run(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
