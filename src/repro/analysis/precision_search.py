"""Per-layer mixed analog precision autotuner (ROADMAP open item 1).

The paper's energy headline is converter-bound: at the native 362 levels the
TD-ADC is ~48 % of a BP group MVM's energy (Eq. 4 with the §IV gating), and
TD-ADC energy scales ~linearly with LEVELS — so per-call-site ADC resolution
is the dominant serving energy knob, and different call sites can afford
very different resolutions (a K=2048 FFN reduction hides more ADC noise per
output than the logit head the argmax reads). This module searches that
space:

    profile  = calibrate_act_tree(...)          # per-site grids + shapes
    manifest = search(params, cal_tokens, cfg)  # greedy per-site descent
    save_manifest(path, manifest)
    # serving:  Server(..., ServingConfig(precision_manifest=path))
    # launch:   python -m repro.launch.serve ... --precision-manifest path

Per site the search enumerates (ADC bits → levels via
core.precision.adc_levels_for_bits, scheme bp vs wbs/bs via
core.schemes/macro.Scheme, per-channel vs per-matrix weight scales) and
scores each candidate against:

* `core.energy.mvm_energy` — Eq. 4 energy/token from the profile's
  (k, m, rows) traffic counts (every ADC constant derives from core.adc's
  single source of truth, so this sweep cannot diverge from the Fig. 21
  golden);
* an SQNR screen (`core.sqnr.simulate_sqnr` at the site's K) that discards
  candidates below a quantization-noise floor before touching the model;
* a held-out logit-KL probe: the candidate config runs through the LIVE
  per-site dispatch path (CIMConfig.site_overrides resolved by
  cim_matmul.resolve_site_cfg) and the mean KL(base ‖ candidate) of the
  next-token distributions on held-out tokens must stay inside the
  iso-accuracy budget.

The result is a versioned JSON deployment manifest (schema
"pico-ram/precision_manifest/v1", mirroring the PR-6 tune cache's
fallback discipline: unknown schema / malformed file / wrong arch degrade
to uniform defaults with a warning, never an error) that
`ServingConfig(precision_manifest=...)` consumes.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import warnings

import jax
import jax.numpy as jnp

from repro.analysis.calibrate import _calibration_cfg, calibrate_act_tree
from repro.core import energy as energy_mod
from repro.core.cim_matmul import SitePrecision
from repro.core.macro import Scheme
from repro.core.precision import ADC_BIT_CANDIDATES, adc_levels_for_bits

MANIFEST_SCHEMA = "pico-ram/precision_manifest/v1"


# ---------------------------------------------------------------------------
# energy accounting (Eq. 4 over the calibration traffic profile)
# ---------------------------------------------------------------------------
def site_energy_per_token_j(entry: dict, cfg, *, adc_levels: int | None = None,
                            scheme: str | None = None,
                            n_tokens: int = 1) -> float:
    """Energy/token of one call site under a candidate (levels, scheme).

    entry is a calibrate_act_tree site record: `rows` is the summed MVM row
    count over the calibration batch (layers × batch × tokens [× expert
    capacity]), `m` the output columns, `k` the reduction depth — so the
    site runs rows·m K-deep single-column MVMs per n_tokens tokens.
    """
    macro = cfg.cim.macro
    if adc_levels is not None:
        macro = dataclasses.replace(macro, adc_levels=adc_levels)
    if scheme is not None:
        macro = dataclasses.replace(macro, scheme=Scheme(scheme))
    rep = energy_mod.mvm_energy(macro, entry["k"])
    m = entry["m"] or 1
    return rep.e_mvm_j * m * entry["rows"] / max(n_tokens, 1)


def energy_per_token_j(tree: dict, cfg, overrides: dict, n_tokens: int) -> float:
    """Total model energy/token under per-site overrides ({} = uniform)."""
    total = 0.0
    for name, entry in tree["sites"].items():
        ov = overrides.get(name)
        total += site_energy_per_token_j(
            entry, cfg,
            adc_levels=ov.adc_levels if ov else None,
            scheme=ov.scheme if ov else None,
            n_tokens=n_tokens)
    return total


# ---------------------------------------------------------------------------
# accuracy proxies
# ---------------------------------------------------------------------------
def _sqnr_db(cfg, k: int, *, adc_levels: int, scheme: str, seed: int) -> float:
    """Quantization-only SQNR screen at the site's reduction depth (small
    seeded Monte-Carlo — a coarse filter before the model-level KL probe)."""
    from repro.core.sqnr import simulate_sqnr
    macro = dataclasses.replace(cfg.cim.macro, adc_levels=adc_levels,
                                scheme=Scheme(scheme))
    res = simulate_sqnr(macro, k=max(k, 1), n_samples=1 << 10,
                        batch=1 << 10, seed=seed)
    return res.sqnr_db


def _logits(params, tokens, cfg, mod):
    """Eager forward log-probs under a candidate CIM config (live per-site
    dispatch: site_overrides resolve inside the model's matmuls). The LM
    stack's forward returns hidden states; the head projection (itself a
    CIM site, resolving any "head" override) is applied here."""
    out = mod.forward(params, {"tokens": jnp.asarray(tokens, jnp.int32)},
                      cfg, train=False)
    h = out[0] if isinstance(out, tuple) else out
    if isinstance(params, dict) and "tok" in params:
        from repro.models.common import unembed
        h = unembed(params["tok"], h, cfg)
    return jax.nn.log_softmax(jnp.asarray(h, jnp.float32), axis=-1)


def logit_kl(base_logp: jax.Array, cand_logp: jax.Array) -> float:
    """Mean next-token KL(base ‖ candidate) over all probe positions."""
    p = jnp.exp(base_logp)
    return float(jnp.mean(jnp.sum(p * (base_logp - cand_logp), axis=-1)))


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------
def _probe_cfg(cfg, overrides: dict, tree: dict):
    """The eager probe config: unrolled/einsum like calibration, with the
    per-site static grids + candidate overrides installed."""
    site_overrides = tuple(sorted(
        (name, _site_precision(name, overrides.get(name), tree))
        for name in tree["sites"]))
    cal = _calibration_cfg(cfg)
    cim = dataclasses.replace(
        cal.cim, site_overrides=site_overrides,
        act=dataclasses.replace(
            cal.cim.act, static_scale=tree["default"]["scale"],
            static_zero_point=tree["default"]["zero_point"]))
    return cal.replace(cim=cim)


def _site_precision(name: str, ov: SitePrecision | None,
                    tree: dict) -> SitePrecision:
    """Fold the site's calibrated static grid into its (possibly None)
    search override — every site always carries its own grid."""
    entry = tree["sites"][name]
    base = ov or SitePrecision()
    return dataclasses.replace(base, act_scale=entry["scale"],
                               act_zero_point=entry["zero_point"])


def search(params, cal_tokens, cfg, *, holdout_tokens=None, seed: int = 0,
           kl_budget: float = 0.08, max_sqnr_drop_db: float = 9.5,
           bit_candidates=ADC_BIT_CANDIDATES, schemes=("bp",),
           try_per_channel: bool = True, percentile: float = 1.0,
           mod=None) -> dict:
    """Greedy per-site precision descent → deployment manifest (dict).

    Deterministic under a fixed `seed` (it keys the SQNR Monte-Carlo and the
    synthetic holdout batch): same inputs → identical manifest.

    Both accuracy gates anchor on references, not on the candidate alone —
    changing ADC levels redraws the whole quantization grid, so a candidate
    differs from the native-levels run by the quantization error itself and
    a candidate-vs-native distance would reject everything:

    * SQNR screen: the site's candidate SQNR (at its reduction depth K) must
      stay within `max_sqnr_drop_db` of the NATIVE-resolution SQNR at the
      same K — a per-site coarseness floor from quantization theory alone.
    * KL probe: the model's held-out next-token KL against the FLOAT
      reference may exceed the uniform-native config's KL by at most
      `kl_budget` ("iso-accuracy-proxy": the mixed config tracks the float
      model as well as uniform native does, within the budget).

    Sites are visited in descending uniform-energy share; per site,
    candidates run coarsest-first ((levels ascending) × schemes ×
    per-channel) and the first that passes both gates wins, so every
    accepted override monotonically lowers energy at bounded proxy drift.
    """
    if mod is None:
        from repro.models import registry
        mod = registry.get_module(cfg)
    if holdout_tokens is None:
        import numpy as np
        rng = np.random.RandomState(seed + 101)
        holdout_tokens = rng.randint(0, cfg.vocab, size=(2, 12))

    tree = calibrate_act_tree(params, cal_tokens, cfg, percentile=percentile,
                              mod=mod)
    n_tokens = int(jnp.asarray(cal_tokens).size)
    base_levels = cfg.cim.macro.adc_levels
    base_scheme = cfg.cim.macro.scheme.value

    # float reference + the iso-accuracy BASELINE: uniform native precision
    # on the per-site static grids (the grids are the calibration fix, not
    # the search's savings — the energy win is measured grid-for-grid)
    float_cfg = _calibration_cfg(cfg)
    float_cfg = float_cfg.replace(
        cim=dataclasses.replace(float_cfg.cim, enabled=False))
    ref_logp = _logits(params, holdout_tokens, float_cfg, mod)
    kl_uniform = logit_kl(ref_logp,
                          _logits(params, holdout_tokens,
                                  _probe_cfg(cfg, {}, tree), mod))
    uniform_pj = energy_per_token_j(tree, cfg, {}, n_tokens)

    # candidate ladder: coarsest first, native resolution excluded (it is
    # the baseline); schemes beyond bp multiply ADC conversions (Eq. 4), so
    # they are enumerated but can only win if bp's candidates all fail
    levels_ladder = sorted({adc_levels_for_bits(b) for b in bit_candidates
                            if adc_levels_for_bits(b) < base_levels})
    share = {n: site_energy_per_token_j(e, cfg, n_tokens=n_tokens)
             for n, e in tree["sites"].items()}
    native_sqnr = {k: _sqnr_db(cfg, k, adc_levels=base_levels,
                               scheme=base_scheme, seed=seed)
                   for k in {e["k"] for e in tree["sites"].values()}}
    overrides: dict[str, SitePrecision] = {}
    trace = []
    kl_now = kl_uniform
    for name in sorted(tree["sites"], key=lambda n: -share[n]):
        entry = tree["sites"][name]
        floor_db = native_sqnr[entry["k"]] - max_sqnr_drop_db
        picked = None
        for levels in levels_ladder:
            cands = [(levels, sch, pc)
                     for sch in schemes
                     for pc in ((False, True) if try_per_channel
                                else (False,))]
            # within one resolution, cheapest first (scheme energy order)
            cands.sort(key=lambda c: site_energy_per_token_j(
                entry, cfg, adc_levels=c[0], scheme=c[1],
                n_tokens=n_tokens))
            for levels_c, scheme_c, pc in cands:
                if _sqnr_db(cfg, entry["k"], adc_levels=levels_c,
                            scheme=scheme_c, seed=seed) < floor_db:
                    continue
                cand = SitePrecision(adc_levels=levels_c, scheme=scheme_c,
                                     per_channel=pc or None)
                trial = dict(overrides)
                trial[name] = cand
                kl = logit_kl(ref_logp,
                              _logits(params, holdout_tokens,
                                      _probe_cfg(cfg, trial, tree), mod))
                if kl <= kl_uniform + kl_budget:
                    picked, kl_now = cand, kl
                    break
            if picked is not None:
                break
        if picked is not None:
            overrides[name] = picked
            trace.append({"site": name, "adc_levels": picked.adc_levels,
                          "scheme": picked.scheme,
                          "per_channel": bool(picked.per_channel),
                          "kl": kl_now})

    mixed_pj = energy_per_token_j(tree, cfg, overrides, n_tokens)
    sites = {}
    for name, entry in tree["sites"].items():
        ov = overrides.get(name)
        sites[name] = {
            "act_scale": entry["scale"],
            "act_zero_point": entry["zero_point"],
            "adc_levels": ov.adc_levels if ov else base_levels,
            "scheme": (ov.scheme if ov and ov.scheme else base_scheme),
            "per_channel": bool(ov.per_channel) if ov else False,
            "k": entry["k"], "m": entry["m"], "calls": entry["calls"],
        }
    return {
        "schema": MANIFEST_SCHEMA,
        "arch": cfg.arch,
        "seed": seed,
        "act_qmax": tree["qmax"],
        "base_adc_levels": base_levels,
        "default": {"act_scale": tree["default"]["scale"],
                    "act_zero_point": tree["default"]["zero_point"]},
        "sites": sites,
        "metrics": {
            "uniform_pj_per_token": uniform_pj * 1e12,
            "mixed_pj_per_token": mixed_pj * 1e12,
            "energy_win": uniform_pj / max(mixed_pj, 1e-30),
            "kl_uniform": kl_uniform,   # KL(float ‖ uniform native grid)
            "kl_proxy": kl_now,         # KL(float ‖ final mixed config)
            "kl_budget": kl_budget,
            "trace": trace,
        },
    }


# ---------------------------------------------------------------------------
# manifest I/O — mirrors kernels.autotune's tune-cache fallback discipline
# ---------------------------------------------------------------------------
def save_manifest(path: str, manifest: dict) -> None:
    """Atomic write (tmp + rename), like autotune.save_cache."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def load_manifest(path: str, *, arch: str | None = None) -> dict | None:
    """Load a deployment manifest; ANY problem (missing file, malformed
    JSON, unknown schema version, wrong arch) degrades to None — uniform
    defaults — with a warning, mirroring the tune cache: a stale or corrupt
    precision file must never take serving down."""
    try:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != MANIFEST_SCHEMA:
            raise ValueError(f"schema {doc.get('schema')!r} != "
                             f"{MANIFEST_SCHEMA!r}")
        if arch is not None and doc.get("arch") != arch:
            raise ValueError(f"manifest arch {doc.get('arch')!r} != "
                             f"serving arch {arch!r} (stale manifest)")
        if not isinstance(doc.get("sites"), dict):
            raise ValueError("missing per-site table")
        return doc
    except (OSError, ValueError) as e:
        warnings.warn(f"ignoring precision manifest {path!r}: {e} — "
                      "serving with uniform precision defaults")
        return None


def manifest_overrides(manifest: dict) -> tuple:
    """CIMConfig.site_overrides from a manifest (hashable tuple-of-pairs,
    sorted by site name for a deterministic static-arg identity)."""
    out = []
    for name in sorted(manifest.get("sites", {})):
        s = manifest["sites"][name]
        out.append((name, SitePrecision(
            act_scale=float(s["act_scale"]),
            act_zero_point=float(s.get("act_zero_point", 0.0)),
            adc_levels=int(s["adc_levels"]),
            scheme=str(s.get("scheme", "bp")),
            per_channel=bool(s.get("per_channel", False)) or None)))
    return tuple(out)


def apply_manifest(cim_cfg, manifest: dict | None):
    """The serving-side application: per-site overrides + the whole-model
    default static grid. None (failed load) returns cim_cfg unchanged —
    the uniform-defaults degradation path."""
    if manifest is None:
        return cim_cfg
    act = dataclasses.replace(
        cim_cfg.act,
        static_scale=float(manifest["default"]["act_scale"]),
        static_zero_point=float(manifest["default"].get("act_zero_point",
                                                        0.0)))
    return dataclasses.replace(cim_cfg, act=act,
                               site_overrides=manifest_overrides(manifest))


def pareto_points(manifest: dict) -> list[dict]:
    """(energy/token, kl) points for the TREND.md Pareto table: the uniform
    baseline and the searched mixed config."""
    m = manifest["metrics"]
    levels = manifest.get("base_adc_levels", 362)
    return [
        {"config": f"uniform 4b×4b BP ({levels}-level ADC)",
         "pj_per_token": m["uniform_pj_per_token"],
         "kl": m.get("kl_uniform", 0.0)},
        {"config": "mixed (per-site ADC levels, searched)",
         "pj_per_token": m["mixed_pj_per_token"], "kl": m["kl_proxy"]},
    ]
