"""§Perf hillclimb driver: lower named variants of the three chosen cells,
measure the roofline terms (extrapolated exact costs) and dump JSON.

    PYTHONPATH=src python -m repro.launch.perf --cell A1 [--out experiments/perf]

Cells (chosen from the 40-cell baseline table):
  A — deepseek-v3-671b × train_4k   (worst fit: 176 GiB/chip, memory-dom.)
  B — llama3-8b × prefill_32k       (most collective-bound dense cell)
  C — llama3-8b × decode_32k        (the paper's technique: 4-bit CIM serving)
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json

from repro.configs.registry import ARCHS
from repro.launch.dryrun import run_cell


def _ds(**kw):
    cfg = ARCHS["deepseek-v3-671b"]
    moe_kw = {k: v for k, v in kw.items() if k in ("ep_mode",
                                                   "capacity_factor")}
    other = {k: v for k, v in kw.items() if k not in moe_kw}
    if moe_kw:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, **moe_kw))
    return cfg.replace(**other) if other else cfg


def _ll(**kw):
    return ARCHS["llama3-8b"].replace(**kw) if kw else ARCHS["llama3-8b"]


# variant table: id → (arch, shape, cim, cfg_override, hypothesis)
VARIANTS = {
    # --- cell A: deepseek train (dominant term: memory; 176 GiB/chip) ----
    "A0": ("deepseek-v3-671b", "train_4k", "off", None,
           "baseline (psum-EP, dots-remat)"),
    "A1": ("deepseek-v3-671b", "train_4k", "off", _ds(ep_mode="a2a"),
           "a2a EP: seq-sharded dispatch — buffers /16, psum(T·D) → 2×a2a "
           "of routed tokens only; predict temp −60 %+, collective −30 %"),
    "A2": ("deepseek-v3-671b", "train_4k", "off",
           _ds(ep_mode="a2a", remat_policy="nothing"),
           "+ full remat: stop saving dot outputs inside MoE layers; "
           "predict temp −40 % more, compute +~25 % (recompute)"),
    "A4": ("deepseek-v3-671b", "train_4k", "off",
           _ds(ep_mode="a2a", remat_policy="nothing", ce_chunks=8),
           "+ chunked cross-entropy (8 seq chunks, remat'd): the [65k, 8k] "
           "per-chip logits (fwd+bwd f32) never fully materialize; predict "
           "temp −4–6 GiB, other terms ≈ flat"),
    # --- cell B: llama3 prefill (dominant term: collective, AR-heavy) ----
    "B0": ("llama3-8b", "prefill_32k", "off", None,
           "baseline (GSPMD picks ring all-reduce for TP outputs)"),
    "B1": ("llama3-8b", "prefill_32k", "off", _ll(tp_reduce_scatter=True),
           "explicit psum_scatter on wo/w_down: AR(2×) → RS(1×); predict "
           "wire bytes −~45 %"),
    "B2": ("llama3-8b", "prefill_32k", "off",
           _ll(tp_reduce_scatter=True, attn_triangular_max=32),
           "+ triangular q-chunk unroll at nq=32: skip fully-masked causal "
           "blocks; predict attention FLOPs −~2×, t_comp −30 %"),
    # --- iteration 2 -------------------------------------------------------
    "A3": ("deepseek-v3-671b", "train_4k", "off",
           _ds(ep_mode="a2a", remat_policy="nothing"),
           "+ gradient-accumulation microbatch=8: live activations /8; "
           "predict temp −50 %+, collective ≈ flat (weight gathers ×8 "
           "amortized by remat recompute already)", {"microbatch": 32}),
    "B3": ("llama3-8b", "prefill_32k", "off",
           _ll(tp_reduce_scatter=True, attn_triangular_max=32),
           "serving topology: params TP-only (replicated over data, no "
           "FSDP) — inference has no optimizer state; predict all-gather "
           "bytes −80 %+", {"fsdp_off": True}),
    # --- cell C: the paper's technique — 4-bit CIM serving ---------------
    "C0": ("llama3-8b", "decode_32k", "off", None,
           "float bf16 decode baseline"),
    "C1": ("llama3-8b", "decode_32k", "bp", None,
           "paper-faithful BP CIM decode (quantize-on-the-fly from bf16): "
           "adds quant ops; memory term ≈ baseline (still reads bf16 W)"),
    "C2": ("llama3-8b", "decode_32k", "bp-prequant", None,
           "offline-quantized stored codes, nibble-packed uint8 (two u4 "
           "per byte, the SRAM-density format): weight bytes /4 vs bf16; "
           "predict memory term −~60 %"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=None,
                    help="variant id (A0..C2); repeatable; default all")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    ids = args.cell or sorted(VARIANTS)
    for vid in ids:
        spec = VARIANTS[vid]
        arch, shape, cim, cfg_override, hyp = spec[:5]
        extra = spec[5] if len(spec) > 5 else {}
        from repro.launch import dryrun as dr
        from repro.parallel import sharding as sh
        dr.TC_OVERRIDES = {k: v for k, v in extra.items()
                           if k == "microbatch"}
        if extra.get("fsdp_off"):
            sh.set_fsdp(False)
        try:
            r = run_cell(arch, shape, "single", cim=cim, out_dir=None,
                         analysis="extrapolate", cfg_override=cfg_override)
        finally:
            sh.set_fsdp(True)
            dr.TC_OVERRIDES = {}
        r["variant"] = vid
        r["hypothesis"] = hyp
        os.makedirs(args.out, exist_ok=True)
        with open(os.path.join(args.out, f"{vid}.json"), "w") as f:
            json.dump(r, f, indent=1)
        if r["status"] == "ok":
            rl = r["roofline"]
            print(f"[{vid}] dom={rl['dominant']} frac={rl['roofline_fraction']:.4f}"
                  f" tC={rl['t_compute_s']:.3f} tM={rl['t_memory_s']:.3f}"
                  f" tX={rl['t_collective_s']:.3f}"
                  f" temp={r['memory_analysis']['temp_size_in_bytes'] / 2**30:.1f}GiB",
                  flush=True)
        else:
            print(f"[{vid}] {r['status']}: {r.get('error', '')[:200]}",
                  flush=True)


if __name__ == "__main__":
    main()
