"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: AxisType.Auto landed in 0.5.x;
    older toolchains take no axis_types argument (same Auto semantics)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: "data" = DP/FSDP, "model" = TP/EP/SP. "pod" is a pure outer data
    axis (gradients cross pods once per step — DCN-friendly; all other
    collectives stay on intra-pod ICI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests."""
    return make_mesh((data, model), ("data", "model"))


def make_host_smoke_mesh():
    """data×model mesh over ALL available host devices — the CI smoke
    topology shared by `launch.dryrun --mesh host` and `launch.serve
    --mesh host` (REPRO_DRYRUN_DEVICES / REPRO_SERVE_DEVICES set the
    placeholder device count before first jax init). Returns
    (mesh, data, model): model is the largest of 4/2/1 dividing the device
    count, so EP/TP shards exist whenever more than one device does."""
    n = jax.device_count()
    model = next(m for m in (4, 2, 1) if n % m == 0)
    return make_host_mesh(n // model, model), n // model, model
