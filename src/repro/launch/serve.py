"""Serving launcher: continuous-batching decode over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --requests 8 --slots 4 --max-new 16 [--cim bp]

  # paged-KV engine: block pool + chunked prefill through the unified step
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --paged --prefill-chunk 8 --block-size 16 [--cim bp-prequant]

  # Pallas paged-attention kernel (block gather + online softmax in VMEM;
  # interpret mode off-TPU) + static calibrated input-DAC scales
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --paged --attn kernel [--cim bp --act-scale static]

  # consume a tuning cache from `kernel_bench --autotune`: dispatchers read
  # it via $REPRO_TUNE_CACHE; a tuned pool block size applies when
  # --block-size is not pinned explicitly
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --paged --attn kernel --tune-cache tune_cache.json

  # prefix-sharing pool (default on for --paged): repeated prompts map onto
  # cached trie blocks; --n-samples forks N continuations copy-on-write off
  # one shared prefill; --watermark tunes the admission headroom
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --paged --n-samples 4 [--no-prefix-sharing] [--watermark 0.1]

  # speculative decoding: the ngram drafter proposes K tokens per decode
  # lane, the target verifies them in ONE C=K+1 step; greedy streams are
  # bit-identical to plain decode. --temperature/--top-k/--sample-seed
  # switch the synthetic requests to seeded per-request sampling
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --paged --drafter ngram --spec-k 4 [--temperature 0.8 --top-k 40] \
      [--trie-watermark 0.5]

  # per-site mixed analog precision: apply a precision_search deployment
  # manifest (from `kernel_bench --precision-manifest` or
  # analysis.precision_search.save_manifest) through CIMConfig
  # site_overrides; a missing/malformed/stale manifest warns and serves
  # uniform defaults
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --paged --cim bp --precision-manifest precision_manifest.json

  # telemetry export (runtime.telemetry / runtime.obs): Perfetto-loadable
  # Chrome trace (one track per slot + a scheduler track), Prometheus
  # text snapshot, JSONL event log; --arrival poisson replaces the
  # submit-all-at-once burst with seeded exponential inter-arrival gaps.
  # --arch defaults to internlm2-1.8b --smoke, so the minimal invocation is:
  PYTHONPATH=src python -m repro.launch.serve --paged \
      --trace-out trace.json --metrics-out metrics.prom \
      [--events-out events.jsonl] \
      [--arrival poisson --arrival-rate 8 --arrival-seed 0]

  REPRO_SERVE_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --arch internlm2-1.8b --smoke --cim bp-noisy --mesh host [--paged]
      # EXECUTES (not just compiles) the shard_map-wrapped fused stochastic
      # kernels end-to-end on a small host mesh
"""
from __future__ import annotations

# Before ANY jax import: jax locks the device count at first init, so the
# optional multi-host-device serving mesh needs the flag set here.
import os
if os.environ.get("REPRO_SERVE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        f"{os.environ['REPRO_SERVE_DEVICES']} "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, SMOKES
from repro.core.cim_matmul import CIMConfig
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.server import Request, Server, ServingConfig
from repro.runtime.speculative import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS),
                    default="internlm2-1.8b",
                    help="model architecture (default internlm2-1.8b so "
                         "the bare telemetry invocation works)")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the smoke-scale config (default on; "
                         "--full for the real geometry)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="use the full ARCHS config instead of the smoke "
                         "scale")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV engine: block-pool cache + chunked "
                         "prefill through the unified jit'd step (decode is "
                         "the C=1 compilation); composes with --cim "
                         "bp-prequant (PackedCodes weights) and --mesh host")
    ap.add_argument("--block-size", type=int, default=None,
                    help="tokens per KV block (paged engine); default 16, "
                         "or the tuned layout when --tune-cache has one "
                         "for this window")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="kernel tuning cache from `kernel_bench "
                         "--autotune` — exported as $REPRO_TUNE_CACHE so "
                         "the attention/MVM dispatchers pick up tuned "
                         "configs, and consulted for a tuned paged-pool "
                         "block size when --block-size is not given")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="usable blocks in the pool (default: slot-cache "
                         "parity, slots × max-len / block-size)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens per chunk through the unified step")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="max new tokens per step across all lanes "
                         "(default: slots + prefill chunk)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the prefix trie (paged engine): every "
                         "request prefills its full prompt even when an "
                         "identical token prefix is already cached")
    ap.add_argument("--watermark", type=float, default=None,
                    help="free-block headroom fraction the paged admission "
                         "keeps in reserve (default 1/16; 0 disables — "
                         "admission then leans entirely on preemption)")
    ap.add_argument("--n-samples", type=int, default=1,
                    help="parallel samples per request (paged engine): one "
                         "shared prefill, N continuations forked "
                         "copy-on-write off the cached prefix")
    ap.add_argument("--drafter", default="off", metavar="SPEC",
                    help="speculative-decoding drafter "
                         "(runtime.speculative registry; paged engine): "
                         "off = plain decode, ngram = prompt-lookup "
                         "self-speculation, model:<name> = a small draft "
                         "model from configs.registry — the target "
                         "verifies all drafts in one C=spec-k+1 step; "
                         "token streams stay distribution-identical "
                         "(bit-identical under greedy)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="drafted tokens per decode lane per verify step "
                         "(default 4; only meaningful with --drafter)")
    ap.add_argument("--trie-watermark", type=float, default=None,
                    help="prefix-cache capacity fraction: when the trie "
                         "caches more than this fraction of the pool, an "
                         "LRU sweep (run every step, idle ones included) "
                         "drains it to half that — keeps long-lived "
                         "servers from pinning the pool in cold cache "
                         "(default: no sweep)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for the synthetic requests "
                         "(0 = greedy; >0 samples the softmax with a "
                         "per-request seeded PRNG — bit-reproducible and "
                         "batch-composition invariant)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k highest logits "
                         "(0 = full vocab; needs --temperature > 0)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base sampling seed; request i uses seed + i")
    ap.add_argument("--attn", choices=("auto", "exact", "kernel"),
                    default="auto",
                    help="paged attention backend (kernels.paged_attention "
                         "registry): exact = window gather + one-pass "
                         "softmax (the [B,C,KH,G,W]-score reference), "
                         "kernel = Pallas flash decode/prefill over the "
                         "block tables (interpret mode off-TPU), auto = "
                         "kernel unless REPRO_FORCE_JNP=1 pins exact")
    ap.add_argument("--act-scale", choices=("dynamic", "static"),
                    default="dynamic",
                    help="static = calibrate one fixed input-DAC grid "
                         "(analysis.calibrate amax sweep over a synthetic "
                         "batch) so each lane's CIM quantization is "
                         "independent of batch composition; needs --cim")
    ap.add_argument("--precision-manifest", default=None, metavar="PATH",
                    dest="precision_manifest",
                    help="mixed-precision deployment manifest "
                         "(analysis.precision_search JSON): installs "
                         "per-call-site (static grid, ADC levels, scheme, "
                         "per-channel) overrides into the CIM config; a "
                         "missing/malformed/stale file warns and serves "
                         "uniform defaults; needs --cim")
    ap.add_argument("--cim", choices=("off", "bp", "bp-noisy", "bp-prequant"),
                    default="off",
                    help="bp-noisy = NOISY converter chain with "
                         "noise_seed=0; backend=auto resolves to the fused "
                         "stochastic Pallas kernel (interpret mode off-TPU) "
                         "— on a mesh (--mesh host) the engine wraps it in "
                         "shard_map, so sharded serving no longer falls "
                         "back to the jnp scan backend")
    ap.add_argument("--arrival", choices=("batch", "poisson"),
                    default="batch",
                    help="request arrival process: batch = submit all up "
                         "front (the historical behavior), poisson = "
                         "seeded exponential inter-arrival gaps paced in "
                         "real time — the seed of the ROADMAP traffic "
                         "harness, so the SLO numbers see bursty "
                         "admission instead of one burst")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="mean requests/s for --arrival poisson")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="numpy RNG seed for the arrival gaps "
                         "(deterministic schedule per seed)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the request "
                         "lifecycle + scheduler steps — drag it into "
                         "https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text-exposition snapshot "
                         "(TTFT/ITL/accept-length/step-wall histograms, "
                         "event + kernel counters, pool gauges)")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the raw structured event log + step "
                         "snapshots as JSONL")
    ap.add_argument("--mesh", choices=("none", "host"), default="none",
                    help="host = shard serving over a data×model mesh of "
                         "the available host devices (set "
                         "REPRO_SERVE_DEVICES=N for N placeholder CPU "
                         "devices) — executes the mesh-sharded CIM engine "
                         "end-to-end")
    args = ap.parse_args()

    if args.tune_cache:
        os.environ["REPRO_TUNE_CACHE"] = args.tune_cache
    if args.block_size is None:
        args.block_size = 16
        if args.paged and args.tune_cache:
            from repro.kernels import autotune
            tuned = autotune.lookup("paged_attn",
                                    autotune.attn_family(args.max_len, 1),
                                    "kernel")
            if tuned and isinstance(tuned.get("block_size"), int) \
                    and args.max_len % tuned["block_size"] == 0:
                args.block_size = tuned["block_size"]
                print(f"tuned paged-pool block_size={args.block_size} "
                      f"(from {args.tune_cache})")

    mesh_ctx = contextlib.nullcontext()
    if args.mesh == "host":
        from repro.launch.mesh import make_host_smoke_mesh
        mesh, data, model = make_host_smoke_mesh()
        sharding.set_mesh(mesh)
        mesh_ctx = mesh
        print(f"serving on host mesh data={data} model={model}")

    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    if args.cim == "bp-noisy":
        import dataclasses
        from repro.core.macro import SimLevel
        cim = CIMConfig(enabled=True, noise_seed=0)
        cfg = cfg.replace(cim=dataclasses.replace(
            cim, macro=dataclasses.replace(cim.macro,
                                           sim_level=SimLevel.NOISY)))
    elif args.cim != "off":
        cfg = cfg.replace(cim=CIMConfig(enabled=True))
    params = registry.init_params(jax.random.PRNGKey(0), cfg,
                                  max_seq=args.max_len)
    if args.precision_manifest and args.cim == "off":
        ap.error("--precision-manifest needs a --cim mode")
    act_scale = act_zero_point = None
    if args.act_scale == "static":
        if args.cim == "off":
            ap.error("--act-scale static needs a --cim mode")
        from repro.analysis.calibrate import calibrate_act_scale
        cal_rng = np.random.RandomState(7)
        cal_tokens = cal_rng.randint(0, cfg.vocab, size=(2, 16))
        cal = calibrate_act_scale(params, cal_tokens, cfg)
        act_scale = cal["scale"]
        act_zero_point = cal["zero_point"]
        print(f"calibrated static act_scale={act_scale:.6f} "
              f"zero_point={act_zero_point:.0f} "
              f"(max span {cal['span']:.4f} over {len(cal['spans'])} "
              f"matmul sites)")
    serving = ServingConfig.from_flags(args, act_scale=act_scale,
                                       act_zero_point=act_zero_point)
    server = Server(params, cfg, serving)

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.randint(4, 17))
        prompt = rng.randint(0, cfg.vocab, size=plen).tolist()
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new,
                            n_samples=args.n_samples,
                            sampling=SamplingParams(
                                temperature=args.temperature,
                                top_k=args.top_k,
                                seed=args.sample_seed + i)))
    due = None
    if args.arrival == "poisson":
        arr_rng = np.random.RandomState(args.arrival_seed)
        gaps = arr_rng.exponential(1.0 / max(args.arrival_rate, 1e-9),
                                   size=len(reqs))
        due = np.cumsum(gaps)
        print(f"arrival=poisson rate={args.arrival_rate}/s "
              f"seed={args.arrival_seed} span={due[-1]:.2f}s")
    t0 = time.monotonic()
    with mesh_ctx:
        if due is None:
            for r in reqs:
                server.submit(r)
        else:
            # real-time pacing: submit each request at its arrival time;
            # step the server while waiting so in-flight lanes keep
            # decoding between arrivals (idle gaps just sleep)
            i = 0
            while i < len(reqs):
                now = time.monotonic() - t0
                if now >= due[i]:
                    server.submit(reqs[i])
                    i += 1
                elif any(r is not None for r in server.slot_req):
                    server.step()
                else:
                    time.sleep(min(float(due[i]) - now, 0.002))
        server.run_until_drained()
    dt = time.monotonic() - t0
    done = [s for r in reqs for s in (r, *r.samples)]
    total_new = sum(len(r.output) for r in done)
    for r in done:
        print(f"req{r.rid}: prompt_len={len(r.prompt)} -> {r.output}")
    print(f"{args.requests} requests x{args.n_samples}, {total_new} tokens, "
          f"{server.steps_run} decode steps, {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s)")
    m = server.metrics.summary()
    kv = server.kv_cache_bytes()
    ttft = [r.ttft_s for r in done]
    lat = [r.latency_s for r in done]
    print(f"engine={'paged' if args.paged else 'slots'} "
          f"attn={args.attn if args.paged else '-'} "
          f"decode={m['decode_tok_s']:.1f} tok/s "
          f"prefill={m['prefill_tok_s']:.1f} tok/s "
          f"kv_bytes total={kv['total']} in_use={kv['in_use']}")
    print(f"ttft p50={np.median(ttft) * 1e3:.1f}ms "
          f"max={max(ttft) * 1e3:.1f}ms | latency "
          f"p50={np.median(lat) * 1e3:.1f}ms max={max(lat) * 1e3:.1f}ms")
    if args.paged:
        st = server.alloc.stats
        print(f"blocks: pool={st.num_blocks} peak={st.peak_in_use} "
              f"shared={st.shared} allocs={st.total_allocs} "
              f"frees={st.total_frees}")
        print(f"sharing: prefix_hit_tokens={m['prefix_hit_tokens']} "
              f"cow_forks={m['cow_forks']} "
              f"preemptions={m['preemptions']} "
              f"peak_active={m['peak_active']} "
              f"trie_sweep_freed={m['trie_sweep_freed']}")
        if args.drafter != "off":
            hist = ",".join(f"{a}:{n}" for a, n in m["accept_hist"].items())
            print(f"speculative: drafter={args.drafter} "
                  f"spec_k={server.serving.spec_k} "
                  f"verify_steps={m['spec_steps']} "
                  f"accept_rate={m['accept_rate']:.2f} "
                  f"mean_accept_len={m['mean_accept_len']:.2f} "
                  f"accept_hist=[{hist}]")

    tel = server.telemetry
    if tel.enabled and tel.ttft.n:
        print(f"slo: ttft p50={tel.ttft.percentile(50) * 1e3:.1f}ms "
              f"p99={tel.ttft.percentile(99) * 1e3:.1f}ms | "
              f"itl p50={tel.itl.percentile(50) * 1e3:.1f}ms "
              f"p99={tel.itl.percentile(99) * 1e3:.1f}ms | "
              f"step_wall p50={tel.step_wall.percentile(50) * 1e3:.1f}ms")
    if args.trace_out or args.metrics_out or args.events_out:
        import json
        from repro.runtime import obs
        if args.trace_out:
            doc = obs.chrome_trace(tel)
            with open(args.trace_out, "w") as f:
                json.dump(doc, f)
            print(f"wrote {args.trace_out} "
                  f"({len(doc['traceEvents'])} trace events) — load at "
                  f"https://ui.perfetto.dev")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                f.write(obs.prometheus_text(tel, server))
            print(f"wrote {args.metrics_out}")
        if args.events_out:
            n = obs.write_events_jsonl(tel, args.events_out)
            print(f"wrote {args.events_out} ({n} lines)")


if __name__ == "__main__":
    main()
