"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
compiles, fits, and report its roofline terms — without real hardware.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single [--cim bp] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  REPRO_DRYRUN_DEVICES=8 PYTHONPATH=src python -m repro.launch.dryrun \
      --arch qwen2-moe-a2.7b --shape decode_32k --mesh host \
      --cim bp-prequant --ep a2a       # CI-sized smoke on 8 host devices

--mesh host builds a small data×model mesh over however many host devices
exist (REPRO_DRYRUN_DEVICES placeholder CPUs) — the CI dryrun-smoke
configuration exercising the shard_map-wrapped fused kernels and the
a2a/EP MoE decode cell without 256-chip compile times.
"""
# The VERY FIRST lines (before ANY other import, incl. repro.*): jax locks
# the device count on first init; the dry-run needs 512 placeholders (or a
# CI-sized count via REPRO_DRYRUN_DEVICES).
import os
_N_DEV = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_N_DEV} "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import Roofline, model_flops
from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import ARCHS, cell_is_runnable
from repro.core.cim_matmul import CIMConfig
from repro.core.macro import SimLevel
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.parallel import sharding
from repro.runtime.trainer import make_train_step


# ---------------------------------------------------------------------------
# sharding of abstract inputs
# ---------------------------------------------------------------------------
def _with_shardings(tree, spec_tree, mesh):
    def one(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(one, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_shardings(batch_abs, mesh):
    baxes = sharding.resolve("batch")
    def one(sds):
        spec = sharding.spec_for(sds.shape,
                                 ("batch",) + (None,) * (sds.ndim - 1))
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    del baxes
    return jax.tree.map(one, batch_abs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_shardings(cache_abs, mesh):
    """Decode-cache sharding: batch over batch axes when divisible (then the
    sequence axis goes over "model" = SP decode); otherwise the sequence axis
    spreads over (data, model) — the long_500k single-sequence layout."""
    import math
    baxes = sharding.resolve("batch") or ()
    bsize = math.prod(mesh.shape[a] for a in baxes) if baxes else 1

    def one_path(kp, sds):
        name = str(getattr(kp[-1], "key", kp[-1]))
        nd = len(sds.shape)
        if nd == 0:
            spec = P()
        elif name in ("k", "v", "latent"):
            batch_ok = sds.shape[1] % max(bsize, 1) == 0
            seq_log = "seq_tp" if batch_ok else "seq"
            logical = [None, "batch" if batch_ok else None, seq_log] \
                + [None] * (nd - 3)
            spec = sharding.spec_for(sds.shape, logical)
        elif name == "S":
            spec = sharding.spec_for(sds.shape,
                                     (None, "batch", "tp") + (None,) * (nd - 3))
        elif name == "conv":
            spec = sharding.spec_for(sds.shape, (None, "batch", None, "tp"))
        elif name in ("tm_x", "cm_x"):
            spec = sharding.spec_for(sds.shape,
                                     (None, "batch") + (None,) * (nd - 2))
        else:
            spec = P()
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))

    flat = jax.tree_util.tree_flatten_with_path(cache_abs)
    leaves = [one_path(kp, leaf) for kp, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def params_shardings(params_abs, mesh):
    spec_tree = sharding.tree_param_specs(params_abs)
    return _with_shardings(params_abs, spec_tree, mesh)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------
def choose_optimizer(params_abs) -> str:
    from repro.analysis.roofline import count_params
    return "adafactor" if count_params(params_abs) > 3e10 else "adamw"


# train-step knobs for §Perf variants (e.g. {"microbatch": 8}); the launch
# CLI keeps defaults — only repro.launch.perf mutates this.
TC_OVERRIDES: dict = {}


def build_cell(arch: str, shape_name: str, mesh, *, cim: str = "off",
               unroll: bool = False, cfg_override=None, ep: str | None = None):
    """Returns (step_fn, abstract_args tuple, cfg, params_abs)."""
    cfg = cfg_override or ARCHS[arch]
    if cim == "bp-noisy":
        # stochastic QAT/eval cell: NOISY converter chain with a fixed
        # noise_seed → seeded-reproducible draws. backend="auto" resolves
        # to the fused stochastic Pallas kernel, which the engine wraps in
        # shard_map on the sharded dry-run meshes (core.engine._sharded_mvm
        # — a bare pallas_call cannot be GSPMD-partitioned, which used to
        # pin the jnp scan backend here).
        cfg = cfg.replace(cim=CIMConfig(
            enabled=True, backend="auto", noise_seed=0,
            macro=dataclasses.replace(CIMConfig().macro,
                                      sim_level=SimLevel.NOISY)))
    elif cim != "off":
        cfg = cfg.replace(cim=CIMConfig(enabled=True, backend="scan"))
    if ep and cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, ep_mode=ep))
    prequant = cim == "bp-prequant"
    if unroll:
        # exact FLOPs/bytes for the roofline: XLA cost_analysis counts while
        # bodies once, so analysis builds unroll the layer stacks
        cfg = cfg.replace(scan_layers=False)
    shape = SHAPES[shape_name]
    mod = registry.get_module(cfg)
    max_seq = shape.seq_len + (8 if shape.kind != "train" else 0)
    params_abs = registry.abstract_params(cfg, max_seq=max_seq)
    if prequant:  # serving with offline-quantized stored codes (§Perf P3)
        from repro.models.quantize import abstract_quantized_params
        params_abs = abstract_quantized_params(params_abs, cfg)
    p_sh = params_shardings(params_abs, mesh)
    batch_abs = registry.input_specs(cfg, shape)
    b_sh = batch_shardings(batch_abs, mesh)

    if shape.kind == "train":
        tc = TrainConfig(optimizer=choose_optimizer(params_abs),
                         **TC_OVERRIDES)
        step, opt = make_train_step(cfg, tc)
        state_abs = {"params": params_abs,
                     "opt": jax.eval_shape(opt.init, params_abs)}
        state_sh = {"params": p_sh,
                    "opt": _with_shardings(
                        state_abs["opt"],
                        sharding.tree_param_specs(state_abs["opt"]), mesh)}
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=NamedSharding(mesh, P()))
        fn = jax.jit(step, donate_argnums=(0,))
        return fn, (state_sh, b_sh, rng), cfg, params_abs

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return mod.prefill(params, batch, cfg)
        fn = jax.jit(prefill_fn)
        return fn, (p_sh, b_sh), cfg, params_abs

    # decode: one new token against a seq_len-deep cache
    cache_abs = jax.eval_shape(
        lambda: mod.init_cache(cfg, shape.global_batch, shape.seq_len))
    # the running position is seq_len-1 (cache almost full — worst case)
    c_sh = cache_shardings(cache_abs, mesh)

    def decode_fn(params, tokens, cache):
        return mod.decode_step(params, tokens, cache, cfg)

    fn = jax.jit(decode_fn, donate_argnums=(2,))
    return fn, (p_sh, b_sh["tokens"], c_sh), cfg, params_abs


# ---------------------------------------------------------------------------
# exact-cost extrapolation: XLA cost_analysis counts while bodies once, and
# fully unrolling 61 layers × 512 ways is compile-prohibitive on 1 CPU core.
# Layers within a stack are HLO-identical, so per-layer cost is EXACTLY the
# difference of two small unrolled builds; totals extrapolate linearly in the
# stack depths. Validated against a full 24-layer unroll (<2% deviation).
# ---------------------------------------------------------------------------
def _layer_knobs(cfg):
    """[(apply_fn(cfg, k), base_count, full_count)] per homogeneous stack."""
    if cfg.family in ("dense", "vlm", "moe") and not cfg.encoder_layers:
        if cfg.moe and cfg.moe.first_dense:
            fd = cfg.moe.first_dense

            def set_moe(c, k):  # k routed-expert layers, 1 dense layer
                return c.replace(n_layers=1 + k,
                                 moe=dataclasses.replace(c.moe, first_dense=1))

            def set_dense(c, k):  # k dense layers, 1 moe layer
                return c.replace(n_layers=k + 1,
                                 moe=dataclasses.replace(c.moe, first_dense=k))

            return [(set_moe, 1, cfg.n_layers - fd), (set_dense, 1, fd)]
        return [(lambda c, k: c.replace(n_layers=k), 1, cfg.n_layers)]
    if cfg.family == "audio":  # enc-dec: two stacks
        return [
            (lambda c, k: c.replace(n_layers=k), 1, cfg.n_layers),
            (lambda c, k: c.replace(encoder_layers=k), 1, cfg.encoder_layers),
        ]
    if cfg.family == "ssm":
        return [(lambda c, k: c.replace(n_layers=k), 1, cfg.n_layers)]
    raise ValueError(cfg.family)


def _cost_dict(cost) -> dict:
    """compiled.cost_analysis() → dict across jax versions (older releases
    return a one-dict-per-device list)."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def _measure_costs(arch, shape_name, mesh, *, cim, cfg_variant):
    fn, args, _, _ = build_cell(arch, shape_name, mesh, cim=cim,
                                unroll=True, cfg_override=cfg_variant)
    with mesh:
        compiled = fn.lower(*args).compile()
        cost = _cost_dict(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "coll_total": float(coll.total_bytes)}
    for k, v in coll.bytes_by_kind.items():
        out[f"coll_{k}"] = float(v)
    return out


def extrapolated_costs(arch, shape_name, mesh, *, cim="off",
                       cfg_base=None) -> dict:
    """Exact per-step costs via per-layer differencing of unrolled builds."""
    cfg = cfg_base or ARCHS[arch]
    if cim != "off":
        cfg = cfg.replace(cim=CIMConfig(enabled=True, backend="scan"))
    if cfg.family == "hybrid":
        # coupled knobs (mamba depth, weight-shared attn applications):
        # F(L, A) = F0 + L·Fm + A·Fs from three small builds
        mk = lambda n, se: cfg.replace(
            n_layers=n, ssm=dataclasses.replace(cfg.ssm, shared_every=se))
        m1 = _measure_costs(arch, shape_name, mesh, cim=cim,
                            cfg_variant=mk(1, 0))
        m2 = _measure_costs(arch, shape_name, mesh, cim=cim,
                            cfg_variant=mk(2, 0))
        ms = _measure_costs(arch, shape_name, mesh, cim=cim,
                            cfg_variant=mk(2, 2))
        apps = cfg.n_layers // cfg.ssm.shared_every
        total = {}
        for k in set(m1) | set(m2) | set(ms):
            fm = m2.get(k, 0.0) - m1.get(k, 0.0)
            fs = ms.get(k, 0.0) - m2.get(k, 0.0)
            total[k] = max(m1.get(k, 0.0) + (cfg.n_layers - 1) * fm
                           + apps * fs, 0.0)
        return total
    knobs = _layer_knobs(cfg)
    base_cfg = cfg
    for apply_fn, b, _ in knobs:
        base_cfg = apply_fn(base_cfg, b)
    base = _measure_costs(arch, shape_name, mesh, cim=cim,
                          cfg_variant=base_cfg)
    total = dict(base)
    for apply_fn, b, full in knobs:
        var_cfg = base_cfg
        for f2, b2, _ in knobs:          # keep other knobs at base
            if f2 is not apply_fn:
                var_cfg = f2(var_cfg, b2)
        var_cfg = apply_fn(var_cfg, b + 1)
        plus = _measure_costs(arch, shape_name, mesh, cim=cim,
                              cfg_variant=var_cfg)
        for k in set(base) | set(plus):
            per_layer = plus.get(k, 0.0) - base.get(k, 0.0)
            total[k] = total.get(k, 0.0) + (full - b) * per_layer
    return {k: max(v, 0.0) for k, v in total.items()}


def _host_mesh():
    """CI smoke topology over the REPRO_DRYRUN_DEVICES placeholder devices."""
    from repro.launch.mesh import make_host_smoke_mesh
    mesh, data, model = make_host_smoke_mesh()
    return mesh, f"host{data}x{model}"


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             cim: str = "off", out_dir: str | None = None,
             analysis: str = "scan", cfg_override=None,
             ep: str | None = None) -> dict:
    shape = SHAPES[shape_name]
    cfg = ARCHS[arch]
    runnable, why = cell_is_runnable(cfg, shape)
    mesh = None
    if mesh_kind == "host":
        mesh, mesh_name = _host_mesh()
    else:
        mesh_name = {"single": "pod16x16", "multi": "pod2x16x16"}[mesh_kind]
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + \
        (f"__cim-{cim}" if cim != "off" else "") + \
        (f"__ep-{ep}" if ep else "") + \
        ("__xp" if analysis == "extrapolate" else "")
    result: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "cim": cim, "cell": cell_id}
    if ep:
        result["ep"] = ep
        if runnable and not cfg.moe:
            runnable, why = False, f"--ep {ep} needs a MoE arch"
    if runnable and cim == "bp-prequant" and shape.kind == "train":
        runnable, why = False, \
            "bp-prequant is a serving flow (stored codes are not trainable)"
    if not runnable:
        result["status"] = "skipped"
        result["reason"] = why
        _dump(result, out_dir, cell_id)
        return result

    if mesh is None:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    sharding.set_mesh(mesh)
    try:
        t0 = time.monotonic()
        fn, args, cfg2, params_abs = build_cell(arch, shape_name, mesh,
                                                cim=cim,
                                                cfg_override=cfg_override,
                                                ep=ep)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = _cost_dict(compiled.cost_analysis())
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
        coll = collective_bytes(hlo)
        chips = mesh.devices.size
        flops = float(cost.get("flops", 0.0))
        bytes_ = float(cost.get("bytes accessed", 0.0))
        coll_total = float(coll.total_bytes)
        coll_detail = {"bytes": coll.bytes_by_kind,
                       "counts": coll.count_by_kind}
        cost_basis = "scanned(while-bodies-counted-once)"
        if analysis == "extrapolate":
            ext = extrapolated_costs(arch, shape_name, mesh, cim=cim,
                                     cfg_base=cfg_override)
            flops, bytes_ = ext["flops"], ext["bytes"]
            coll_total = ext["coll_total"]
            coll_detail = {"bytes": {k[5:]: v for k, v in ext.items()
                                     if k.startswith("coll_") and
                                     k != "coll_total"}}
            cost_basis = "unrolled-per-layer-extrapolation"
        rl = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=flops, hlo_bytes=bytes_,
            collective_bytes=coll_total,
            model_flops=model_flops(cfg2, shape, params_abs),
            peak_bytes_per_chip=_peak_bytes(mem),
            collective_detail=coll_detail,
        )
        result.update({
            "status": "ok", "cost_basis": cost_basis,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory_analysis": _mem_dict(mem),
            "roofline": rl.to_dict(),
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc(limit=25)
    finally:
        sharding.set_mesh(None)
    _dump(result, out_dir, cell_id)
    return result


def _peak_bytes(mem) -> float:
    for attr in ("peak_memory_in_bytes",):
        if hasattr(mem, attr):
            return float(getattr(mem, attr))
    # host-platform memory analysis exposes totals instead
    tot = 0.0
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        tot += float(getattr(mem, attr, 0.0))
    alias = float(getattr(mem, "alias_size_in_bytes", 0.0))
    return tot - alias


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = float(getattr(mem, attr))
    return out


def _dump(result: dict, out_dir: str | None, cell_id: str):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
            json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both", "host"),
                    default="single",
                    help="single/multi = the production 256/512-chip "
                         "meshes; host = a small data×model mesh over the "
                         "available host devices (REPRO_DRYRUN_DEVICES) — "
                         "the CI smoke topology")
    ap.add_argument("--cim", choices=("off", "bp", "bp-noisy", "bp-prequant"),
                    default="off",
                    help="bp = quantize-on-the-fly BP CIM; bp-noisy = same "
                         "with the NOISY converter chain and noise_seed=0 "
                         "(seeded-reproducible stochastic cells on the "
                         "shard_map-wrapped fused Pallas backend); "
                         "bp-prequant = serving flow with offline "
                         "nibble-packed u4 stored codes (1/4 the bf16 "
                         "weight bytes)")
    ap.add_argument("--ep", choices=("psum", "a2a"), default=None,
                    help="override MoEConfig.ep_mode for MoE archs: a2a = "
                         "all-to-all token-dispatch expert parallelism "
                         "(decode steps use the chunked a2a variant)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--analysis", choices=("scan", "extrapolate"),
                    default="scan",
                    help="extrapolate = exact roofline costs from small "
                         "unrolled builds (single-pod analysis pass)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    for a, s, m in cells:
        r = run_cell(a, s, m, cim=args.cim, out_dir=args.out,
                     analysis=args.analysis, ep=args.ep)
        status = r["status"]
        extra = ""
        if status == "ok":
            rl = r["roofline"]
            extra = (f" dom={rl['dominant']} frac={rl['roofline_fraction']:.3f}"
                     f" mem/chip={r['memory_analysis'].get('temp_size_in_bytes', 0) / 2**30:.2f}GiB"
                     f" compile={r['compile_s']}s")
        elif status == "error":
            extra = " " + r["error"].splitlines()[0][:120]
        print(f"[{status:7s}] {r['cell']}{extra}", flush=True)


if __name__ == "__main__":
    main()
