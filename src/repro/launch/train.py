"""Training launcher.

Full-scale configs target the production mesh (this is what a real cluster
job would run); --smoke runs the reduced config end-to-end on local devices,
which is what the CPU container can execute.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --batch 8 --seq 128 [--cim bp] [--ckpt /tmp/ckpt]
"""
from __future__ import annotations

import argparse
import json

from repro.configs.base import ShapeConfig, TrainConfig
from repro.configs.registry import ARCHS, SMOKES
from repro.core.cim_matmul import CIMConfig
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on local devices")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--cim", choices=("off", "bp"), default="off")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    if args.cim == "bp":
        cfg = cfg.replace(cim=CIMConfig(enabled=True))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tc = TrainConfig(steps=args.steps, lr=args.lr,
                     microbatch=args.microbatch,
                     grad_compression=args.grad_compression,
                     checkpoint_every=max(args.steps // 4, 1))
    trainer = Trainer(cfg, shape, tc, args.ckpt)
    out = trainer.run()
    for m in out["metrics"]:
        print(json.dumps(m))
    print(f"done: {out['final_step']} steps; "
          f"stragglers={trainer.straggler_steps}")


if __name__ == "__main__":
    main()
