"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) and helpers.

Logical axis vocabulary (MaxText-style, mapped onto the production mesh from
launch/mesh.py):

  "batch" → ("pod", "data") / ("data",)   data parallelism (pod = outer DP)
  "fsdp"  → ("data",)                     parameter sharding (ZeRO-3 via GSPMD
                                          all-gather on use)
  "tp"    → ("model",)                    Megatron tensor parallelism (heads,
                                          mlp hidden, vocab)
  "expert"→ ("model",)                    expert parallelism (routed experts)
  "seq"   → ("model",) or ("data","model") sequence/context parallelism for
                                          long-KV decode
  None    → replicated

Every helper checks divisibility of the dim against the mesh axis size and
silently drops the annotation when it doesn't divide (e.g. 8 KV heads on a
16-way model axis → replicate, the standard Megatron fallback).

The active mesh is installed process-wide by launch code via set_mesh();
models never import mesh objects, only logical names — so the same model code
lowers for the single-pod and multi-pod meshes and runs unsharded on CPU
tests (set_mesh(None) → every helper is a no-op).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_MESH: Optional[Mesh] = None
_FSDP: bool = True


# Depth of shard_map bodies currently being traced. shard_map regions must
# not nest, and the CIM engine's mesh dispatch must know when a layer matmul
# is already executing per-shard (e.g. inside the MoE expert-parallel
# shard_map) so it runs the plain kernel instead of wrapping a second
# shard_map around it. Every repo shard_map call site goes through the
# wrapper below, which brackets the body trace — a plain counter is enough
# because tracing is single-threaded per jit trace.
_SHARD_DEPTH: list[int] = [0]


def in_shard_context() -> bool:
    """True while a shard_map body (opened via this module) is tracing —
    i.e. the current code already runs per-shard."""
    return _SHARD_DEPTH[0] > 0


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map across jax versions.

    Newer jax exposes it at the top level with a `check_vma` flag; 0.4.x
    has jax.experimental.shard_map.shard_map with the same semantics under
    `check_rep`. All repo call sites go through this wrapper, which also
    marks the body trace so `in_shard_context()` reports per-shard
    execution (the CIM engine's nesting guard).
    """
    @functools.wraps(f)
    def body(*args, **kwargs):
        _SHARD_DEPTH[0] += 1
        try:
            return f(*args, **kwargs)
        finally:
            _SHARD_DEPTH[0] -= 1

    native = getattr(jax, "shard_map", None)
    if native is not None:
        return native(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def set_fsdp(enabled: bool) -> None:
    """Serving topology (§Perf B3): inference has no optimizer state, so
    parameters can shard fully over "model" and replicate over "data" —
    removing every FSDP all-gather from the step at the cost of params×data
    HBM (fine when params/TP ≤ a few GB)."""
    global _FSDP
    _FSDP = enabled


def get_mesh() -> Optional[Mesh]:
    return _MESH


def _axis_size(name: str) -> int:
    if _MESH is None or name not in _MESH.axis_names:
        return 1
    return _MESH.shape[name]


def resolve(logical: Optional[str]) -> Optional[tuple[str, ...]]:
    """Logical axis name → tuple of mesh axes (or None = replicated)."""
    if logical is None or _MESH is None:
        return None
    names = _MESH.axis_names
    table = {
        "batch": tuple(a for a in ("pod", "data") if a in names),
        "fsdp": ("data",) if ("data" in names and _FSDP) else (),
        "tp": ("model",) if "model" in names else (),
        "expert": ("model",) if "model" in names else (),
        "seq": tuple(a for a in ("data", "model") if a in names),
        "seq_tp": ("model",) if "model" in names else (),
    }
    axes = table.get(logical, ())
    return axes or None


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]]) -> PartitionSpec:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    assert len(shape) == len(logical), (shape, logical)
    entries = []
    for dim, name in zip(shape, logical):
        axes = resolve(name)
        if axes is None:
            entries.append(None)
            continue
        total = math.prod(_axis_size(a) for a in axes)
        if total > 1 and dim % total == 0:
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    if _MESH is None:
        return x
    spec = spec_for(x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def sharding_for(shape: Sequence[int], logical: Sequence[Optional[str]]):
    if _MESH is None:
        return None
    return NamedSharding(_MESH, spec_for(shape, logical))


# ---------------------------------------------------------------------------
# Parameter rules: leaf-name → logical axes (innermost dims; a leading stacked
# "layers" dim is auto-prepended with None by axes_for).
# ---------------------------------------------------------------------------
PARAM_RULES: dict[str, tuple] = {
    # embeddings / heads
    "embed": ("tp", "fsdp"),          # [V, D] vocab×embed
    "head": ("fsdp", "tp"),           # [D, V]
    "pos_embed": (None, "fsdp"),      # [S, D] learned positions
    # attention
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("fsdp", "tp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",), "bo": (None,),
    # mlp — down/out projections store N-over-"tp" like the up projections:
    # the CIM engine shards EVERY mvm the same way (K over "data", output
    # channels over "model" — sharding.mvm_plan), and the jnp scan backend
    # reshapes K into [groups, n_rows, N] whose group boundaries never align
    # with a K-split. Keeping N on "model" lets GSPMD carry the stored
    # sharding through pad+reshape into the grouped scan / shard_map in_spec
    # with a local slice only (the Megatron row-parallel (K,"tp") layout
    # forced an involuntary full rematerialization of every scanned
    # down-projection on the 512-chip mesh).
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"), "w_down": ("fsdp", "tp"),
    # norms / scalars
    "scale": (None,), "bias": (None,), "w_lambda": (None,),
    # MLA
    "w_dq": ("fsdp", "tp"), "w_uq": ("fsdp", "tp"),
    "w_dkv": ("fsdp", None), "w_uk": ("fsdp", "tp"), "w_uv": ("fsdp", "tp"),
    "w_kr": ("fsdp", None), "w_proj": ("fsdp", "tp"),
    # MoE (leading E dim = expert parallel; D dim FSDP)
    "router": ("fsdp", None),
    "e_gate": ("expert", "fsdp", None), "e_up": ("expert", "fsdp", None),
    "e_down": ("expert", None, "fsdp"),
    # SSM / RWKV
    "w_in": ("fsdp", "tp"), "w_out": ("fsdp", "tp"),
    "w_x": ("fsdp", "tp"), "conv_w": (None, "tp"), "conv_b": ("tp",),
    "a_log": ("tp",), "dt_bias": ("tp",), "d_skip": ("tp",),
    "w_r": ("fsdp", "tp"), "w_k": ("fsdp", "tp"), "w_v": ("fsdp", "tp"),
    "w_g": ("fsdp", "tp"),
    "decay_w0": ("tp",), "decay_a": ("fsdp", None), "decay_b": (None, "tp"),
    "bonus_u": ("tp",), "mu": (None, None),
    "w_dt": ("fsdp", "tp"), "w_bc": ("fsdp", None),
    "norm_g": ("tp",),
}


def axes_for(path: tuple[str, ...], ndim: int) -> tuple:
    """Logical axes for a param at `path` (keys joined), arity-adjusted.

    Params that live under a stacked-layers subtree carry a leading L dim
    (never sharded — layers are scanned); detected by 'layers' in the path.

    Optimizer-state leaves inherit the parent parameter's rules: adamw m/v
    mirror the params tree (last key IS the param name); adafactor factored
    stats live at <param>/vr (row means: drop last dim) and <param>/vc
    (col means: drop second-to-last) — without this the 671B-class factored
    stats would be replicated and blow per-chip HBM.
    """
    name = path[-1]
    if name in ("vr", "vc") and len(path) >= 2:
        base = PARAM_RULES.get(path[-2])
        if base is not None:
            rules = base[:-1] if name == "vr" else base[:-2] + base[-1:]
            stacked = any("layers" in p for p in path[:-1])
            if stacked:
                rules = (None,) + tuple(rules)
            if len(rules) < ndim:
                rules = (None,) * (ndim - len(rules)) + tuple(rules)
            return tuple(rules[:ndim])
    if name == "v" and len(path) >= 2 and path[-2] in PARAM_RULES:
        name = path[-2]  # adafactor unfactored scalar stat
    if name.endswith("_q"):     # offline-quantized codes shard like the fp
        name = name[:-2]        # weight they replace
    elif name.endswith("_scale"):
        return (None,) * ndim   # per-matrix scales are tiny → replicate
    rules = PARAM_RULES.get(name)
    if rules is None:
        rules = (None,) * ndim
    stacked = any("layers" in p for p in path[:-1])
    if stacked:
        rules = (None,) + tuple(rules)
    if len(rules) < ndim:  # pad leading dims (e.g. extra stacking) with None
        rules = (None,) * (ndim - len(rules)) + tuple(rules)
    return tuple(rules[:ndim])


def tree_param_specs(params) -> dict:
    """params pytree → matching tree of PartitionSpec via PARAM_RULES."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def one(kp, leaf):
        path = tuple(getattr(k, "key", str(k)) for k in kp)
        return spec_for(leaf.shape, axes_for(path, leaf.ndim))

    specs = {jax.tree_util.keystr(kp): one(kp, leaf) for kp, leaf in flat}
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(
        treedef, [one(kp, leaf) for kp, leaf in flat])


# ---------------------------------------------------------------------------
# Mesh partition plan for one sharded MVM (the CIM engine's fused dispatch).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MVMPlan:
    """How one x[..., K] @ w[K, M] MVM maps onto the active mesh.

    ctr_axes shard the contraction (K) — the multi-macro tiling of the
    paper's Sec. V: each shard evaluates its own macro groups and the
    partial MVMs are psum'd AFTER the per-shard ADC transfer + Eq. 7
    correction. row_axes shard the leading activation dim, col_axes the
    output-channel (M) dim; empty tuples mean replicated.
    """

    ctr_axes: tuple = ()
    row_axes: tuple = ()
    col_axes: tuple = ()

    def x_spec(self, ndim: int) -> PartitionSpec:
        lead = [None] * (ndim - 1)
        if self.row_axes and ndim > 1:
            lead[0] = self.row_axes if len(self.row_axes) > 1 \
                else self.row_axes[0]
        return PartitionSpec(*lead, _ent(self.ctr_axes))

    def w_spec(self) -> PartitionSpec:
        return PartitionSpec(_ent(self.ctr_axes), _ent(self.col_axes))

    def out_spec(self, ndim: int) -> PartitionSpec:
        lead = [None] * (ndim - 1)
        if self.row_axes and ndim > 1:
            lead[0] = self.row_axes if len(self.row_axes) > 1 \
                else self.row_axes[0]
        return PartitionSpec(*lead, _ent(self.col_axes))


def _ent(axes: tuple):
    return None if not axes else (axes if len(axes) > 1 else axes[0])


def mvm_plan(x_shape: Sequence[int], k: int, m: int, *,
             k_unit: int = 1) -> MVMPlan:
    """Partition plan for one MVM on the active mesh (identity w/o a mesh).

    Policy: the contraction goes over "data" when K divides (in units of
    `k_unit` rows — 2 for nibble-packed weights so no byte is split across
    shards); the output channels go over "model" when M divides; the leading
    activation dim goes over "pod" (and over "data" too when the contraction
    left it free). Non-divisible dims stay replicated — the same silent
    fallback spec_for applies to parameters.
    """
    if _MESH is None:
        return MVMPlan()
    names = _MESH.axis_names
    ctr: tuple = ()
    if "data" in names:
        size = _MESH.shape["data"]
        if size > 1 and k % (size * k_unit) == 0:
            ctr = ("data",)
    col: tuple = ()
    if "model" in names and m % _MESH.shape["model"] == 0:
        col = ("model",)
    row: tuple = ()
    if len(x_shape) > 1:
        lead = x_shape[0]
        for ax in ("pod",) + (("data",) if not ctr else ()):
            if ax in names and lead % (_MESH.shape[ax]
                                       * math.prod(_MESH.shape[a]
                                                   for a in row)) == 0:
                row = row + (ax,)
    return MVMPlan(ctr_axes=ctr, row_axes=row, col_axes=col)


def tree_shardings(params):
    """params pytree (arrays or ShapeDtypeStructs) → NamedSharding tree."""
    if _MESH is None:
        return None
    mesh = _MESH
    specs = tree_param_specs(params)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))
