"""GPipe-style pipeline parallelism over a mesh "stage" axis.

Provided as the PP building block for depth-dominated configs (the
production dry-run meshes use DP×TP×EP only — at ≤61 layers with scanned
stacks PP is not needed to fit, so this module is exercised by tests rather
than the default launch path).

Schedule: classic fill-drain loop. At tick t, stage s processes microbatch
(t − s); activations hop stage→stage+1 through jax.lax.ppermute. All stages
run the same program (SPMD), each applying its own slice of the stacked
stage parameters.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_micro, *, mesh,
                   axis: str = "stage"):
    """Run `n_micro` microbatches through `n_stages` pipeline stages.

    stage_fn(params_slice, h) → h            (one stage's computation)
    stage_params: pytree with leading [n_stages] dim, sharded on `axis`
    x_micro: [n_micro, mb, ...] microbatched inputs (replicated)
    Returns [n_micro, mb, ...] outputs (as produced by the LAST stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    assert n_micro >= n_stages, "need ≥ n_stages microbatches to fill"

    def per_stage(params_local, xs):
        params_local = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1
        h = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def body(carry, t):
            h_in, outs = carry
            # stage 0 ingests microbatch t (when valid); others use h_in
            feed = jnp.where(t < n_micro, t, 0)
            h_cur = jnp.where(sid == 0, xs[feed], h_in)
            active = (t - sid >= 0) & (t - sid < n_micro)
            h_out = stage_fn(params_local, h_cur)
            h_out = jnp.where(active, h_out, h_cur)
            # last stage records its finished microbatch
            mb = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = active & (sid == n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, h_out, outs[mb]), mb, 0)
            # hop to the next stage
            h_next = jax.lax.ppermute(
                h_out, axis, [(i, (i + 1) % n_stages)
                              for i in range(n_stages)])
            return (h_next, outs), None

        (_, outs), _ = jax.lax.scan(body, (h, outs), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.ppermute(
            outs, axis, [((n_stages - 1 + i) % n_stages, i)
                         for i in range(n_stages)])
        return outs

    from repro.parallel.sharding import shard_map
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params,
                               is_leaf=lambda x: hasattr(x, "shape")), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)
