"""Distributed-optimization collectives: gradient compression with error
feedback, and helpers shared by shard_map code.

int8 gradient all-reduce (1-bit-Adam-family trick, 4× wire reduction vs f32):
each participant quantizes its local gradient to int8 with a per-tensor
scale, the psum runs on int32 (exact), and the unrepresented residue is
carried into the next step's gradient (error feedback) so the compression
bias does not accumulate — the property tests/test_collectives.py checks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """x (f32/bf16) → (int8 codes, f32 scale). Symmetric per-tensor."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str, err: jax.Array):
    """psum(x) over `axis_name` through an int8 wire, with error feedback.

    Returns (mean-reduced f32 result, new error residue). Call inside
    shard_map. The int32 psum of int8 codes is exact; the only loss is the
    local quantization, which err carries to the next call.
    """
    xf = x.astype(jnp.float32) + err
    # agree on one scale first (one tiny pmax) so int32 psum of codes is exact
    scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n, new_err


def compress_decompress(x: jax.Array, err: jax.Array):
    """Single-participant Q→DQ with error feedback (simulates the wire
    format inside a GSPMD train step where the all-reduce is implicit)."""
    xf = x.astype(jnp.float32) + err
    q, scale = quantize_int8(xf)
    y = dequantize_int8(q, scale)
    return y.astype(x.dtype), xf - y
