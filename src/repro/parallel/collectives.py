"""Distributed-optimization collectives: gradient compression with error
feedback, expert-parallel all-to-all token exchange, and helpers shared by
shard_map code.

int8 gradient all-reduce (1-bit-Adam-family trick, 4× wire reduction vs f32):
each participant quantizes its local gradient to int8 with a per-tensor
scale, the psum runs on int32 (exact), and the unrepresented residue is
carried into the next step's gradient (error feedback) so the compression
bias does not accumulate — the property tests/test_collectives.py checks.

a2a_dispatch / a2a_combine are the static-capacity expert-parallel token
exchange (DeepSeek-style EP): every source rank packs its routed tokens
into per-expert capacity slots and the pair of all_to_alls moves ONLY those
slots — top_k/E of the bytes a psum-combine would move. Both run inside
shard_map over the expert mesh axis; the slot layouts they assume are
documented on the functions and owned by models/moe.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def a2a_dispatch(send: jax.Array, axis_name: str) -> jax.Array:
    """EP dispatch: route capacity-slotted tokens to their expert's rank.

    send [E_pad, cap, D] per source rank (slot (e, c) = c-th token this
    source routed to global expert e). Returns [E_local, ep·cap, D] per
    expert rank: its E_local experts' slots from every source,
    source-major along the capacity axis — recv[e, s·cap + c] is source
    s's slot (rank·E_local + e, c).
    """
    return jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=1,
                              tiled=True)


def a2a_combine(out: jax.Array, axis_name: str) -> jax.Array:
    """Inverse exchange of a2a_dispatch for the expert outputs.

    out [E_local, ep·cap, D] per expert rank (same layout a2a_dispatch
    delivered). Returns [E_pad, cap, D] per source rank — every token lands
    back in exactly the slot its source packed it into, so the combine
    scatter is collective-free local indexing.
    """
    return jax.lax.all_to_all(out, axis_name, split_axis=1, concat_axis=0,
                              tiled=True)


def quantize_int8(x: jax.Array):
    """x (f32/bf16) → (int8 codes, f32 scale). Symmetric per-tensor."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str, err: jax.Array):
    """psum(x) over `axis_name` through an int8 wire, with error feedback.

    Returns (mean-reduced f32 result, new error residue). Call inside
    shard_map. The int32 psum of int8 codes is exact; the only loss is the
    local quantization, which err carries to the next call.
    """
    xf = x.astype(jnp.float32) + err
    # agree on one scale first (one tiny pmax) so int32 psum of codes is exact
    scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n, new_err


def compress_decompress(x: jax.Array, err: jax.Array):
    """Single-participant Q→DQ with error feedback (simulates the wire
    format inside a GSPMD train step where the all-reduce is implicit)."""
    xf = x.astype(jnp.float32) + err
    q, scale = quantize_int8(xf)
    y = dequantize_int8(q, scale)
    return y.astype(x.dtype), xf - y
