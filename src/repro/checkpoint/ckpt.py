"""Mesh-independent, atomic, keep-N checkpointing.

Design points for the 1000+ node posture:
  * checkpoints are written UNSHARDED per leaf (host-gathered numpy), so a
    run can resume on a different device count / mesh shape — elastic
    scaling and shrink-on-failure both reduce to "load with new shardings";
  * writes are atomic (tmp dir + rename) so a preemption mid-write never
    corrupts the latest checkpoint;
  * keep-N retention, newest-first recovery, and a JSON index carrying step,
    dtype map (bf16 is stored as uint16 views — npz has no bf16) and user
    metadata (e.g. data-pipeline step for exact resume).

At real multi-host scale the host-gather becomes per-host shard files; the
manager API (save/restore/latest_step) is the stable surface.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(kp): leaf for kp, leaf in flat}


def save_pytree(path: str, tree, *, metadata: dict | None = None) -> None:
    """Atomically write `tree` to `path` (a directory)."""
    tmp = path + f".tmp.{os.getpid()}.{int(time.time() * 1e6)}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for i, (k, v) in enumerate(sorted(flat.items())):
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
        arrays[f"a{i}"] = a
    keys = [k for k, _ in sorted(flat.items())]
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump({"keys": keys, "dtypes": dtypes,
                   "metadata": metadata or {}}, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str, like, *, shardings=None):
    """Load into the structure of `like` (arrays or ShapeDtypeStructs).

    shardings: optional matching pytree of NamedSharding — leaves are
    device_put with them (elastic reshard onto the current mesh).
    """
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    arrays = {k: data[f"a{i}"] for i, k in enumerate(index["keys"])}

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None)[0]
    for j, (kp, leaf) in enumerate(flat_like[0]):
        k = jax.tree_util.keystr(kp)
        if k not in arrays:
            raise KeyError(f"checkpoint missing leaf {k}")
        a = arrays[k]
        want = jnp.dtype(leaf.dtype)
        if index["dtypes"][k] == "bfloat16":
            a = a.view(jnp.bfloat16)
        a = a.astype(want) if a.dtype != want else a
        if a.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {k}: ckpt {a.shape} vs "
                             f"model {leaf.shape}")
        sh = shard_flat[j] if shard_flat is not None else None
        leaves.append(jax.device_put(a, sh) if sh is not None
                      else jnp.asarray(a))
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    return tree, index["metadata"]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.count(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree, *, metadata: dict | None = None) -> None:
        md = dict(metadata or {})
        md["step"] = step
        save_pytree(self._step_dir(step), tree, metadata=md)
        for old in self.steps()[:-self.keep]:
            shutil.rmtree(self._step_dir(old), ignore_errors=True)

    def restore(self, like, *, step: int | None = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(self._step_dir(step), like, shardings=shardings)
