"""Model registry: ModelConfig.family → implementation module.

Uniform module API:
  init(key, cfg, *, max_seq=0) → params
  train_loss(params, batch, cfg, rng) → scalar
  prefill(params, batch, cfg, max_len) → (logits, cache)
  decode_step(params, tokens, cache, cfg) → (logits, cache)
  init_cache(cfg, batch, max_len) → cache pytree
  input_specs(cfg, shape) → {name: ShapeDtypeStruct}
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig, ShapeConfig

from . import mamba2, rwkv6, transformer

_FAMILY = {
    "dense": transformer, "moe": transformer, "vlm": transformer,
    "audio": transformer, "ssm": rwkv6, "hybrid": mamba2,
}


def get_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(key, cfg: ModelConfig, *, max_seq: int = 0):
    mod = get_module(cfg)
    if mod is transformer:
        return transformer.init(key, cfg, max_seq=max_seq)
    return mod.init(key, cfg)


def abstract_params(cfg: ModelConfig, *, max_seq: int = 0):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, max_seq=max_seq),
        jax.random.PRNGKey(0))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    return get_module(cfg).input_specs(cfg, shape)
