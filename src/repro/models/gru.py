"""The paper's custom KWS/wake-word GRU (§V-C, Fig. 20).

A 0.16M-parameter gated recurrent unit whose input and hidden dimensions are
both 144 — sized so every gate matmul is exactly one macro-depth (N = 144
rows) per input half, "perfectly fitting into the SRAM". Audio frames
(stubbed MFCC features per the brief's frontend rule) stream through the
recurrence; a linear head classifies keywords.

Every gate matmul routes through the CIM-switchable dense layer, so the same
model trains in float and deploys on the simulated macro (the paper runs it
at 4b×4b with the 8.5-bit ADC and reports 91.9 % / 99.9 % on Speech
Commands / Hey Snips).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cim_matmul import cim_matmul, cim_matmul_ste


def gru_config(*, cim=None, n_classes: int = 16) -> ModelConfig:
    from repro.core.cim_matmul import CIMConfig
    return ModelConfig(
        arch="kws-gru-144", family="audio", n_layers=1, d_model=144,
        n_heads=1, n_kv_heads=1, d_ff=144, vocab=n_classes,
        dtype="float32", cim=cim or CIMConfig())


def init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(2 * d)
    mk = lambda k: (jax.random.normal(k, (2 * d, d), jnp.float32) * s)
    return {"w_z": mk(ks[0]), "w_r": mk(ks[1]), "w_h": mk(ks[2]),
            "b_z": jnp.zeros((d,)), "b_r": jnp.zeros((d,)),
            "b_h": jnp.zeros((d,)),
            "head": (jax.random.normal(ks[3], (d, cfg.vocab), jnp.float32)
                     / math.sqrt(d))}


def _mm(p, name: str, x, cfg: ModelConfig, train: bool):
    """Gate/head matmul, CIM-switchable like common.dense: float weights in
    training/eval, offline-quantized stored codes (`<name>_q`, int8 or
    nibble-packed uint8, with per-matrix or per-channel `<name>_scale`)
    when the params were run through models.quantize.quantize_params — the
    deployed on-chip-residence flow (§V-C: the whole GRU fits in 64 macros'
    SRAM). With cfg.cim.noise_seed set, NOISY/FULL gate MVMs run the fused
    stochastic kernel — the wake-word robustness study at kernel speed."""
    from repro.core import quant
    if cfg.cim.enabled and name + "_q" in p:
        from repro.core.cim_matmul import cim_matmul_prequant
        with quant.act_site(name):
            return cim_matmul_prequant(x, p[name + "_q"], p[name + "_scale"],
                                       cfg.cim)
    if cfg.cim.enabled:
        fn = cim_matmul_ste if train else cim_matmul
        with quant.act_site(name):
            return fn(x, p[name], cfg.cim)
    return x @ p[name]


def gru_cell(p, x_t, h, cfg: ModelConfig, *, train: bool):
    """One GRU step. x_t, h: [B, 144]."""
    xh = jnp.concatenate([x_t, h], axis=-1)              # [B, 288] = 2 groups
    z = jax.nn.sigmoid(_mm(p, "w_z", xh, cfg, train) + p["b_z"])
    r = jax.nn.sigmoid(_mm(p, "w_r", xh, cfg, train) + p["b_r"])
    xrh = jnp.concatenate([x_t, r * h], axis=-1)
    h_tilde = jnp.tanh(_mm(p, "w_h", xrh, cfg, train) + p["b_h"])
    return (1 - z) * h + z * h_tilde


def forward(p, frames: jax.Array, cfg: ModelConfig, *, train: bool = False):
    """frames [B, T, 144] (stub MFCC embeddings) → logits [B, n_classes]."""
    b = frames.shape[0]
    h0 = jnp.zeros((b, cfg.d_model), frames.dtype)

    def step(h, x_t):
        return gru_cell(p, x_t, h, cfg, train=train), None

    h, _ = jax.lax.scan(step, h0, jnp.moveaxis(frames, 1, 0))
    return _mm(p, "head", h, cfg, train)


def train_loss(p, batch, cfg: ModelConfig, rng=None):
    logits = forward(p, batch["frames"], cfg, train=True)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["labels"][:, None],
                                         axis=1))
