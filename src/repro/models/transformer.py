"""Decoder-family transformer: dense GQA LMs, MoE LMs (qwen2/deepseek),
VLM-prefix LMs (internvl2) and enc-dec audio (whisper).

One parameterized implementation so the CIM execution mode, sharding rules,
remat policy, caches and the dry-run lowering path are shared across
architectures. Layer stacks are lax.scan'd over stacked weights (61-layer
512-way SPMD must compile on one CPU core).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel.sharding import constrain

from . import common, mla, moe
from .common import (attention_apply, attention_init, cross_entropy, dense,
                     dtype_of, embed_init, embed_lookup, mlp_apply, mlp_init,
                     norm, norm_init, unembed)


# ---------------------------------------------------------------------------
# layer init
# ---------------------------------------------------------------------------
def _layer_init(key, cfg: ModelConfig, *, ffn: str, d_model=None) -> dict:
    """One decoder layer. ffn: "dense" | "moe" | "dense_wide" (deepseek)."""
    ks = jax.random.split(key, 4)
    p = {"norm1": norm_init(d_model or cfg.d_model, dtype=dtype_of(cfg),
                            kind=cfg.norm),
         "norm2": norm_init(d_model or cfg.d_model, dtype=dtype_of(cfg),
                            kind=cfg.norm)}
    if cfg.mla is not None:
        p["attn"] = mla.init(ks[0], cfg)
    else:
        p["attn"] = attention_init(ks[0], cfg, d_model=d_model)
    if ffn == "moe":
        p["ffn"] = moe.init(ks[1], cfg)
    elif ffn == "dense_wide":
        p["ffn"] = mlp_init(ks[1], cfg, d_ff=cfg.moe.d_ff_dense)
    else:
        p["ffn"] = mlp_init(ks[1], cfg)
    if cfg.cross_attention:
        p["norm_x"] = norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm)
        p["xattn"] = attention_init(ks[2], cfg)
    return p


def _stack(layers: list[dict]) -> dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init(key: jax.Array, cfg: ModelConfig, *, max_seq: int = 0) -> dict:
    ks = jax.random.split(key, 8)
    params: dict = {"tok": embed_init(ks[0], cfg),
                    "final_norm": norm_init(cfg.d_model, dtype=dtype_of(cfg),
                                            kind=cfg.norm)}
    n_dense_wide = cfg.moe.first_dense if cfg.moe else 0
    n_moe = cfg.n_layers - n_dense_wide if cfg.moe else 0

    if n_dense_wide:
        params["dense_layers"] = _stack(
            [_layer_init(jax.random.fold_in(ks[1], i), cfg, ffn="dense_wide")
             for i in range(n_dense_wide)])
    main_ffn = "moe" if cfg.moe else "dense"
    n_main = n_moe if cfg.moe else cfg.n_layers
    params["layers"] = _stack(
        [_layer_init(jax.random.fold_in(ks[2], i), cfg, ffn=main_ffn)
         for i in range(n_main)])

    if cfg.encoder_layers:
        enc_cfg = cfg.replace(cross_attention=False)
        params["enc_layers"] = _stack(
            [_layer_init(jax.random.fold_in(ks[3], i), enc_cfg, ffn="dense")
             for i in range(cfg.encoder_layers)])
        params["enc_norm"] = norm_init(cfg.d_model, dtype=dtype_of(cfg),
                                       kind=cfg.norm)
        params["enc_pos"] = {"pos_embed": _pos_table(ks[4], cfg.encoder_len,
                                                     cfg)}
    if cfg.pos_embed == "learned":
        assert max_seq > 0, "learned positions need max_seq at init"
        params["dec_pos"] = {"pos_embed": _pos_table(ks[5], max_seq, cfg)}

    if cfg.mtp:  # deepseek multi-token prediction: one extra block + proj
        params["mtp"] = {
            "proj": common.dense_init(ks[6], 2 * cfg.d_model, cfg.d_model,
                                      dtype=dtype_of(cfg), name_w="w_proj"),
            "block": _layer_init(ks[7], cfg, ffn="dense_wide" if cfg.moe
                                 else "dense"),
            "norm_h": norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm),
            "norm_e": norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm),
        }
    return params


def _pos_table(key, n: int, cfg: ModelConfig):
    return (jax.random.normal(key, (n, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype_of(cfg))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _layer_fwd(lp: dict, h: jax.Array, cfg: ModelConfig, *, positions,
               train: bool, causal: bool = True,
               enc_out: Optional[jax.Array] = None,
               rng: Optional[jax.Array] = None):
    if cfg.mla is not None:
        a, _ = mla.apply(lp["attn"], norm(lp["norm1"], h, cfg), cfg,
                         positions=positions, train=train)
    else:
        a, _ = attention_apply(lp["attn"], norm(lp["norm1"], h, cfg), cfg,
                               positions=positions, train=train,
                               causal=causal)
    h = h + a
    if enc_out is not None:
        x, _ = attention_apply(lp["xattn"], norm(lp["norm_x"], h, cfg), cfg,
                               positions=positions, train=train,
                               causal=False, kv_x=enc_out)
        h = h + x
    hn = norm(lp["norm2"], h, cfg)
    if "router" in lp["ffn"]:
        f, aux = moe.apply(lp["ffn"], hn, cfg, train=train, rng=rng)
    else:
        f, aux = mlp_apply(lp["ffn"], hn, cfg, train=train), 0.0
    return h + f, aux


def _run_stack(stacked: dict, h: jax.Array, cfg: ModelConfig, *, positions,
               train: bool, causal: bool = True, enc_out=None, rng=None):
    """lax.scan over stacked layer weights, with optional remat."""
    def body(carry, lp):
        hh, aux_acc = carry
        hh, aux = _layer_fwd(lp, hh, cfg, positions=positions, train=train,
                             causal=causal, enc_out=enc_out, rng=rng)
        return (hh, aux_acc + aux), None

    body_fn = jax.checkpoint(
        body, policy=common.remat_policy(cfg)
    ) if (cfg.remat and train) else body
    (h, aux), _ = common.scan_layers(body_fn, (h, 0.0), stacked,
                                     unroll=not cfg.scan_layers)
    return h, aux


def _encode(params: dict, frames: jax.Array, cfg: ModelConfig, *,
            train: bool) -> jax.Array:
    """Whisper encoder over precomputed (stub) conv-frontend frames."""
    pos = params["enc_pos"]["pos_embed"][: frames.shape[1]]
    h = frames.astype(dtype_of(cfg)) + pos
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])
    h, _ = _run_stack(params["enc_layers"], h, cfg, positions=positions,
                      train=train, causal=False)
    return norm(params["enc_norm"], h, cfg)


def _embed_inputs(params, batch, cfg: ModelConfig, *, offset: int = 0):
    """Token embeddings (+learned positions, +VLM image prefix)."""
    tokens = batch["tokens"]
    x = embed_lookup(params["tok"], tokens, cfg)
    if cfg.n_image_tokens and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype)
        img = constrain(img, "batch", None, None)
        x = jnp.concatenate([img, x], axis=1)
    b, t = x.shape[:2]
    positions = offset + jnp.broadcast_to(jnp.arange(t), (b, t))
    if cfg.pos_embed == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"]["pos_embed"],
                                             offset, t, 0)
    return x, positions


def forward(params: dict, batch: dict, cfg: ModelConfig, *,
            train: bool, rng=None):
    """Full-sequence forward → (hidden [B,T,D], aux_loss, enc_out)."""
    x, positions = _embed_inputs(params, batch, cfg)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encode(params, batch["frames"], cfg, train=train)
    aux_total = 0.0
    if "dense_layers" in params:
        x, aux = _run_stack(params["dense_layers"], x, cfg,
                            positions=positions, train=train, rng=rng)
        aux_total += aux
    x, aux = _run_stack(params["layers"], x, cfg, positions=positions,
                        train=train, enc_out=enc_out, rng=rng)
    aux_total += aux
    return norm(params["final_norm"], x, cfg), aux_total, enc_out


def train_loss(params: dict, batch: dict, cfg: ModelConfig,
               rng: Optional[jax.Array] = None) -> jax.Array:
    h, aux, _ = forward(params, batch, cfg, train=True, rng=rng)
    labels = batch["labels"]
    if cfg.n_image_tokens and "image_embeds" in batch:
        h = h[:, cfg.n_image_tokens:]  # loss on text positions only
    loss = _lm_loss(params, h, labels, cfg)
    if cfg.mtp:
        loss = loss + cfg.mtp_weight * _mtp_loss(params, h, batch, cfg)
    return loss + 0.01 * aux


def _lm_loss(params, h, labels, cfg: ModelConfig):
    """Next-token CE; with cfg.ce_chunks > 1 the [tokens, vocab] logits are
    produced and consumed one sequence chunk at a time (remat'd), so the
    full tensor never lives in HBM (§Perf A4)."""
    n = cfg.ce_chunks
    t = h.shape[1]
    if n <= 1 or t % n != 0:
        return cross_entropy(unembed(params["tok"], h, cfg, train=True),
                             labels)
    hc = h.reshape(h.shape[0], n, t // n, h.shape[2]).swapaxes(0, 1)
    lc = labels.reshape(labels.shape[0], n, t // n).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_nll(hx, lx):
        logits = unembed(params["tok"], hx, cfg, train=True)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - picked)

    def body(acc, xs):
        hx, lx = xs
        return acc + chunk_nll(hx, lx), None

    total, _ = jax.lax.scan(body, jnp.zeros(()), (hc, lc),
                            unroll=True if not cfg.scan_layers else 1)
    return total / (labels.shape[0] * t)


def _mtp_loss(params, h, batch, cfg: ModelConfig):
    """DeepSeek-V3 MTP: predict token t+2 from (hidden_t ∥ embed(token_{t+1}))
    through one extra transformer block sharing embedding and head."""
    mp = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    # positions t predicts labels[t+1] = tokens[t+2]
    h_in = norm(mp["norm_h"], h[:, :-1], cfg)
    e_next = norm(mp["norm_e"],
                  embed_lookup(params["tok"], tokens[:, 1:], cfg), cfg)
    merged = dense(mp["proj"], jnp.concatenate([h_in, e_next], -1), cfg,
                   train=True, w="w_proj", b=None)
    b, t = merged.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    h2, _ = _layer_fwd(mp["block"], merged, cfg, positions=positions,
                       train=True)
    logits2 = unembed(params["tok"], h2, cfg, train=True)
    return cross_entropy(logits2, labels[:, 1:])


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Abstract cache pytree (zeros); layout matches decode_step."""
    dt = dtype_of(cfg)
    n_wide = cfg.moe.first_dense if cfg.moe else 0
    n_main = cfg.n_layers - n_wide
    if cfg.mla is not None:
        lat = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        mk = lambda L: {"latent": jnp.zeros((L, batch, max_len, lat), dt)}
    else:
        kvd = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        mk = lambda L: {"k": jnp.zeros((L,) + kvd, dt),
                        "v": jnp.zeros((L,) + kvd, dt)}
    cache = {"pos": jnp.zeros((), jnp.int32), "layers": mk(n_main)}
    if n_wide:
        cache["dense_layers"] = mk(n_wide)
    if cfg.cross_attention:
        cache["cross"] = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_len,
                            cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_len,
                            cfg.n_kv_heads, cfg.head_dim), dt)}
    return cache


def _layer_decode(lp: dict, h: jax.Array, layer_cache: dict,
                  cfg: ModelConfig, *, positions, pos_idx,
                  cross_cache=None):
    if cfg.mla is not None:
        a, new_c = mla.apply(lp["attn"], norm(lp["norm1"], h, cfg), cfg,
                             positions=positions, cache=layer_cache,
                             cache_index=pos_idx)
    else:
        a, new_c = attention_apply(lp["attn"], norm(lp["norm1"], h, cfg), cfg,
                                   positions=positions, cache=layer_cache,
                                   cache_index=pos_idx)
    h = h + a
    if cross_cache is not None:
        x, _ = attention_apply(lp["xattn"], norm(lp["norm_x"], h, cfg), cfg,
                               positions=positions, kv_x=h,  # unused w/ cache
                               cache=cross_cache)
        h = h + x
    hn = norm(lp["norm2"], h, cfg)
    if "router" in lp["ffn"]:
        f, _ = moe.apply(lp["ffn"], hn, cfg, train=False)
    else:
        f = mlp_apply(lp["ffn"], hn, cfg)
    return h + f, new_c


def _decode_stack(stacked, caches, h, cfg, *, positions, pos_idx,
                  cross=None):
    def body(hh, xs):
        if cross is None:
            lp, lc = xs
            xc = None
        else:
            lp, lc, xc = xs
        hh, new_c = _layer_decode(lp, hh, lc, cfg, positions=positions,
                                  pos_idx=pos_idx, cross_cache=xc)
        return hh, new_c

    xs = (stacked, caches) if cross is None else (stacked, caches, cross)
    return common.scan_layers(body, h, xs, unroll=not cfg.scan_layers)


def decode_step(params: dict, tokens: jax.Array, cache: dict,
                cfg: ModelConfig):
    """One decode step: tokens [B,1] → (logits [B,V], updated cache)."""
    pos = cache["pos"]
    x, positions = _embed_inputs(params, {"tokens": tokens}, cfg)
    positions = positions + pos
    if cfg.pos_embed == "learned":  # re-slice at the dynamic position
        x = embed_lookup(params["tok"], tokens, cfg)
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"]["pos_embed"], pos, 1, 0)

    new_cache = dict(cache)
    if "dense_layers" in params:
        x, nc = _decode_stack(params["dense_layers"], cache["dense_layers"],
                              x, cfg, positions=positions, pos_idx=pos)
        new_cache["dense_layers"] = nc
    cross = cache.get("cross")
    x, nc = _decode_stack(params["layers"], cache["layers"], x, cfg,
                          positions=positions, pos_idx=pos, cross=cross)
    new_cache["layers"] = nc
    x = norm(params["final_norm"], x, cfg)
    logits = unembed(params["tok"], x[:, 0], cfg)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def prefill(params: dict, batch: dict, cfg: ModelConfig,
            max_len: int | None = None):
    """Process a full prompt; returns (last-token logits, filled cache).

    Implemented as the training forward plus per-layer K/V collection —
    GSPMD-friendly (no sequential decode loop over the prompt).
    """
    x, positions = _embed_inputs(params, batch, cfg)
    b, t = x.shape[:2]
    max_len = max_len or t
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encode(params, batch["frames"], cfg, train=False)

    def collect(stacked, h):
        def body(hh, lp):
            if cfg.mla is not None:
                hn = norm(lp["norm1"], hh, cfg)
                a, kv = mla.apply(lp["attn"], hn, cfg, positions=positions,
                                  return_cache=True)
            else:
                hn = norm(lp["norm1"], hh, cfg)
                a, kv = attention_apply(lp["attn"], hn, cfg,
                                        positions=positions, causal=True,
                                        cache={})  # request prefill cache
            hh = hh + a
            if enc_out is not None:
                xo, xkv = attention_apply(lp["xattn"],
                                          norm(lp["norm_x"], hh, cfg), cfg,
                                          positions=positions, causal=False,
                                          kv_x=enc_out, cache={})
                hh = hh + xo
                kv = {**kv, "xk": xkv["k"], "xv": xkv["v"]}
            hn2 = norm(lp["norm2"], hh, cfg)
            if "router" in lp["ffn"]:
                f, _ = moe.apply(lp["ffn"], hn2, cfg, train=False)
            else:
                f = mlp_apply(lp["ffn"], hn2, cfg)
            return hh + f, kv

        return common.scan_layers(body, h, stacked,
                                  unroll=not cfg.scan_layers)

    cache: dict = {"pos": jnp.full((), t, jnp.int32)}
    h = x
    if "dense_layers" in params:
        h, kv = collect(params["dense_layers"], h)
        cache["dense_layers"] = _pad_cache(kv, max_len)
    h, kv = collect(params["layers"], h)
    if cfg.cross_attention:
        cache["cross"] = {"k": kv.pop("xk"), "v": kv.pop("xv")}
    cache["layers"] = _pad_cache(kv, max_len)
    h = norm(params["final_norm"], h, cfg)
    logits = unembed(params["tok"], h[:, -1], cfg)
    return logits, cache


def _pad_cache(kv: dict, max_len: int) -> dict:
    def pad(a):  # [L, B, T, ...] → [L, B, max_len, ...]
        pad_t = max_len - a.shape[2]
        if pad_t <= 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[2] = (0, pad_t)
        return jnp.pad(a, widths)

    return jax.tree.map(pad, kv)


# ---------------------------------------------------------------------------
# serving: paged KV cache (block pool + block tables)
# ---------------------------------------------------------------------------
def supports_paged(cfg: ModelConfig) -> bool:
    """Paged serving covers the GQA transformer archs (dense / MoE / VLM
    text decode). MLA latent caches and whisper cross-attention keep the
    dense slot cache for now (ROADMAP serving section tracks both)."""
    return cfg.mla is None and not cfg.cross_attention


def init_paged_cache(cfg: ModelConfig, num_blocks: int,
                     block_size: int) -> dict:
    """Physical KV block pools [L, NB, bs, KH, dh] (zeros).

    One pool per layer stack; NB includes the trash block (physical id 0).
    Unlike init_cache there is no per-slot batch axis — slots share the pool
    through their block tables, so resident bytes scale with allocated
    blocks, not n_slots × max_len.
    """
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV serving not implemented for arch {cfg.arch!r} "
            "(MLA latent / cross-attention caches)")
    dt = dtype_of(cfg)
    n_wide = cfg.moe.first_dense if cfg.moe else 0
    n_main = cfg.n_layers - n_wide
    kvd = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    mk = lambda L: {"k": jnp.zeros((L,) + kvd, dt),
                    "v": jnp.zeros((L,) + kvd, dt)}
    cache = {"layers": mk(n_main)}
    if n_wide:
        cache["dense_layers"] = mk(n_wide)
    return cache


def cow_copy_block(cache: dict, src: jax.Array, dst: jax.Array) -> dict:
    """Copy one physical block's K/V (every layer) from `src` to `dst`.

    The copy-on-write primitive behind prefix sharing (runtime.server):
    before a lane writes into a block another holder also maps, the
    scheduler acquires a private block and duplicates the shared contents
    here, then remaps the lane's table. src/dst are traced int32 scalars
    so every fork shares one compilation; the server jits this with the
    cache donated, making it an in-place device copy. Pools are
    [L, NB, bs, KH, dh], so the block axis is axis 1 on every leaf.
    """
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), cache)


def _layer_paged(lp: dict, h: jax.Array, layer_pool: dict, cfg: ModelConfig,
                 *, positions, flat_idx, tables, kv_len):
    a, new_pool = common.paged_attention_apply(
        lp["attn"], norm(lp["norm1"], h, cfg), cfg, positions=positions,
        cache=layer_pool, flat_idx=flat_idx, tables=tables, kv_len=kv_len)
    h = h + a
    hn = norm(lp["norm2"], h, cfg)
    if "router" in lp["ffn"]:
        f, _ = moe.apply(lp["ffn"], hn, cfg, train=False)
    else:
        f = mlp_apply(lp["ffn"], hn, cfg)
    return h + f, new_pool


def _paged_stack(stacked, pools, h, cfg, *, positions, flat_idx, tables,
                 kv_len):
    def body(hh, xs):
        lp, lc = xs
        hh, new_pool = _layer_paged(lp, hh, lc, cfg, positions=positions,
                                    flat_idx=flat_idx, tables=tables,
                                    kv_len=kv_len)
        return hh, new_pool

    return common.scan_layers(body, h, (stacked, pools),
                              unroll=not cfg.scan_layers)


def paged_step(params: dict, tokens: jax.Array, cache: dict,
               tables: jax.Array, lens: jax.Array, valid: jax.Array,
               cfg: ModelConfig, all_logits: bool = False):
    """One unified serving step over the paged pool: prefill chunks and
    decode are the SAME function (decode is the C=1 compilation).

    tokens [B, C] — C=1 for a pure-decode step, the prefill chunk width
    otherwise; a mixed batch runs decode slots as valid=1 lanes inside a
    C-wide call. lens [B] = tokens already in each slot's cache; valid [B]
    = new tokens this step (0 = idle lane). Writes each slot's new K/V at
    its true positions through its block table (masked lanes → the trash
    block), attends per-slot through the attention backend selected by
    cfg.attn_backend (kernels.paged_attention: "exact" window softmax vs
    the Pallas flash "kernel" whose live scores are one [C·G, bs] tile),
    and returns (logits, updated pool). By default logits are [B, V] taken
    at each slot's LAST valid position — prefill lanes only ever need
    their final chunk's last row. `all_logits=True` (a trace-time flag:
    the server jits it as a separate compilation) unembeds EVERY chunk
    position instead, returning [B, C, V] — the speculative-decoding
    verify shape, where one C=K+1 call scores all K drafted tokens plus
    the bonus position. The host scheduler decides whose logits mean
    anything this step (decode slots every step; prefilling slots only on
    their final chunk).
    """
    b, c = tokens.shape
    block_size = jax.tree_util.tree_leaves(cache)[0].shape[2]
    window = tables.shape[1] * block_size
    positions = lens[:, None] + jnp.arange(c)[None, :]          # [B, C]

    x = embed_lookup(params["tok"], tokens, cfg)
    if cfg.pos_embed == "learned":
        x = x + params["dec_pos"]["pos_embed"][
            jnp.clip(positions, 0, params["dec_pos"]["pos_embed"].shape[0] - 1)]

    # write targets: logical position → (physical block, offset); lanes
    # beyond `valid` (and beyond the window) land in the trash block
    pos_w = jnp.minimum(positions, window - 1)
    blk = jnp.take_along_axis(tables, pos_w // block_size, axis=1)
    flat_idx = blk * block_size + pos_w % block_size
    in_valid = jnp.arange(c)[None, :] < valid[:, None]
    flat_idx = jnp.where(in_valid & (positions < window), flat_idx, 0)
    kv_len = lens + valid

    new_cache = dict(cache)
    if "dense_layers" in params:
        x, np_ = _paged_stack(params["dense_layers"], cache["dense_layers"],
                              x, cfg, positions=positions, flat_idx=flat_idx,
                              tables=tables, kv_len=kv_len)
        new_cache["dense_layers"] = np_
    x, np_ = _paged_stack(params["layers"], cache["layers"], x, cfg,
                          positions=positions, flat_idx=flat_idx,
                          tables=tables, kv_len=kv_len)
    new_cache["layers"] = np_
    x = norm(params["final_norm"], x, cfg)
    if all_logits:
        return unembed(params["tok"], x, cfg), new_cache        # [B, C, V]
    last = jnp.maximum(valid - 1, 0)                            # [B]
    h_last = jnp.take_along_axis(
        x, last[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = unembed(params["tok"], h_last, cfg)
    return logits, new_cache


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs: dict = {}
    if shape.kind == "train":
        t = s - cfg.n_image_tokens if cfg.n_image_tokens else s
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, t), jnp.int32)}
    elif shape.kind == "prefill":
        t = s - cfg.n_image_tokens if cfg.n_image_tokens else s
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.n_image_tokens and shape.kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    return specs
