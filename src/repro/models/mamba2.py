"""Mamba2 (SSD) blocks and the Zamba2 hybrid (Mamba2 backbone with a
weight-shared attention block every `shared_every` layers).

Train/prefill use the chunked SSD schedule (intra-chunk matmuls with scalar
per-head decays + inter-chunk state scan); decode is the exact O(1)-state
recurrence — which is why zamba2 runs the long_500k cell.

Per DESIGN.md: SSD state math is digital; in/out/xBC/dt projections and the
shared block's matmuls route through the CIM-switchable dense layer.
Simplification (noted in DESIGN.md): Zamba2's two alternating shared blocks
and the concat-with-embedding input are reduced to one shared block applied
on the residual stream.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel.sharding import constrain

from . import common
from .common import (attention_apply, attention_init, cross_entropy, dense,
                     dtype_of, embed_init, embed_lookup, mlp_apply, mlp_init,
                     norm, norm_init, unembed)


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return d_in, n_heads, conv_dim


def _mamba_init(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, n_h, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {}
    # fused in-projection: [z | x | B | C | dt]
    p.update(common.dense_init(ks[0], d, 2 * d_in + 2 * s.d_state + n_h,
                               dtype=dt, name_w="w_in"))
    p["conv_w"] = (jax.random.normal(ks[1], (s.conv_kernel, conv_dim),
                                     jnp.float32) * 0.1).astype(dt)
    p["conv_b"] = jnp.zeros((conv_dim,), dt)
    p["a_log"] = jnp.log(jnp.linspace(1.0, 16.0, n_h)).astype(jnp.float32)
    p["dt_bias"] = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[2], (n_h,), jnp.float32,
                                   math.log(1e-3), math.log(1e-1))))
    ).astype(jnp.float32)
    p["d_skip"] = jnp.ones((n_h,), jnp.float32)
    p["norm_g"] = jnp.ones((d_in,), dt)
    p.update(common.dense_init(ks[3], d_in, d, dtype=dt,
                               scale=1.0 / math.sqrt(d_in * 2 * cfg.n_layers),
                               name_w="w_out"))
    return p


def init(key, cfg: ModelConfig, **_) -> dict:
    ks = jax.random.split(key, 4)
    layers = [
        {"norm1": norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm),
         "ssm": _mamba_init(jax.random.fold_in(ks[0], i), cfg)}
        for i in range(cfg.n_layers)]
    params = {"tok": embed_init(ks[1], cfg),
              "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
              "final_norm": norm_init(cfg.d_model, dtype=dtype_of(cfg),
                                      kind=cfg.norm)}
    if cfg.ssm.shared_every:
        params["shared"] = {
            "norm1": norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm),
            "attn": attention_init(ks[2], cfg),
            "norm2": norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm),
            "mlp": mlp_init(ks[3], cfg),
        }
    return params


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
            state: jax.Array | None):
    """Causal depthwise conv. x [B,T,C]; state [B,k−1,C] carries history."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    return jax.nn.silu(out), xp[:, -(k - 1):]


def ssd_chunked(xh, dt, a, B, C, *, chunk: int, state0=None,
                unroll: bool = False):
    """Chunked SSD. xh [B,T,H,dh], dt [B,T,H], a [H] (<0), B/C [B,T,N].

    y_i = Σ_{j≤i} exp(l_i−l_j)·(C_i·B_j)·dt_j·x_j + C_i·(exp(l_i)·S₀)
    with l = cumsum(a·dt). All exponents ≤ 0 — numerically clean.
    """
    b, t, h, dh = xh.shape
    n = B.shape[-1]
    pad = (-t) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // chunk
    xc = xh.reshape(b, nc, chunk, h, dh).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)
    l = jnp.cumsum(a * dtc, axis=2)                   # [B,NC,C,H] (≤0, decr.)

    if state0 is None:
        state0 = jnp.zeros((b, h, dh, n), jnp.float32)

    def body(S, xs):
        xcc, dcc, bcc, ccc, lcc = xs
        # decay matrix exp(l_i − l_j) for j ≤ i  (else 0)
        dec = jnp.exp(lcc[:, :, None, :] - lcc[:, None, :, :])   # [B,C,C,H]
        mask = jnp.tril(jnp.ones((lcc.shape[1], lcc.shape[1]), bool))
        dec = jnp.where(mask[None, :, :, None], dec, 0.0)
        cb = jnp.einsum("bin,bjn->bij", ccc, bcc)                # C_i·B_j
        att = cb[..., None] * dec * dcc[:, None, :, :]           # [B,i,j,H]
        y = jnp.einsum("bijh,bjhd->bihd", att, xcc)
        # inter-chunk: y_i += (C_i·exp(l_i)) @ S
        y = y + jnp.einsum("bin,bhdn,bih->bihd", ccc, S, jnp.exp(lcc))
        # state update: S' = exp(l_C)·S + Σ_j dt_j·exp(l_C−l_j)·x_j ⊗ B_j
        wC = jnp.exp(lcc[:, -1])                                  # [B,H]
        kj = dcc * jnp.exp(lcc[:, -1, None, :] - lcc)             # [B,C,H]
        S_add = jnp.einsum("bjh,bjhd,bjn->bhdn", kj, xcc, bcc)
        S_new = wC[..., None, None] * S + S_add
        return S_new, y

    xs = tuple(jnp.moveaxis(v, 1, 0) for v in (xc, dtc, Bc, Cc, l))
    state, ys = jax.lax.scan(body, state0, xs, unroll=True if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, h, dh)[:, :t]
    return y, state


def _mamba_block(p, x, cfg: ModelConfig, *, train, cache=None,
                 chunked=True):
    """x [B,T,D] → (y, new_cache {"conv": [B,k−1,convdim], "S": [B,H,dh,N]})."""
    s = cfg.ssm
    d_in, n_h, conv_dim = _dims(cfg)
    b, t, _ = x.shape
    proj = dense(p, x, cfg, train=train, w="w_in", b=None)
    z, xbc, dt_raw = jnp.split(proj, [d_in, d_in + conv_dim], axis=-1)
    c = cache or {}
    xbc, conv_state = _conv1d(xbc, p["conv_w"].astype(xbc.dtype),
                              p["conv_b"].astype(xbc.dtype), c.get("conv"))
    xh, B, C = jnp.split(xbc, [d_in, d_in + s.d_state], axis=-1)
    xh = constrain(xh.reshape(b, t, n_h, s.head_dim), "batch", None, "tp", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    if chunked:
        y, S = ssd_chunked(xh, dt, a, B, C, chunk=s.chunk, state0=c.get("S"),
                           unroll=not cfg.scan_layers)
    else:  # exact decode recurrence
        x1 = xh[:, 0].astype(jnp.float32)
        dt1, B1, C1 = dt[:, 0], B[:, 0].astype(jnp.float32), \
            C[:, 0].astype(jnp.float32)
        decay = jnp.exp(a * dt1)                                   # [B,H]
        S = c["S"] * decay[..., None, None] + jnp.einsum(
            "bh,bhd,bn->bhdn", dt1, x1, B1)
        y = jnp.einsum("bhdn,bn->bhd", S, C1)[:, None]
    y = y + p["d_skip"][..., None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_g"])
    out = dense(p, y, cfg, train=train, w="w_out", b=None)
    return constrain(out, *common.res_axes(cfg)), \
        {"conv": conv_state, "S": S}


def _gated_norm(y, z, g):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-5)
    return (yf * g.astype(jnp.float32)).astype(y.dtype)


# ---------------------------------------------------------------------------
# zamba2 hybrid plumbing
# ---------------------------------------------------------------------------
def _shared_block(sp, h, cfg: ModelConfig, *, positions, train,
                  cache=None, pos_idx=0):
    a, new_kv = attention_apply(sp["attn"], norm(sp["norm1"], h, cfg), cfg,
                                positions=positions, train=train,
                                cache=cache, cache_index=pos_idx)
    h = h + a
    h = h + mlp_apply(sp["mlp"], norm(sp["norm2"], h, cfg), cfg, train=train)
    return h, new_kv


def _n_shared_apps(cfg: ModelConfig) -> int:
    se = cfg.ssm.shared_every
    return cfg.n_layers // se if se else 0


def _slice_layers(params, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], params["layers"])


def _forward(params, tokens, cfg: ModelConfig, *, train, caches=None,
             shared_kv=None, pos0=0, chunked=True):
    """Shared forward. caches: stacked per-layer SSM caches or None.
    shared_kv: stacked [A, ...] KV caches for the shared block (decode)."""
    x = embed_lookup(params["tok"], tokens, cfg)
    b, t = x.shape[:2]
    positions = pos0 + jnp.broadcast_to(jnp.arange(t), (b, t))
    se = cfg.ssm.shared_every or cfg.n_layers + 1
    new_caches, new_shared = [], []

    def run_span(h, lo, hi, span_caches):
        stacked = _slice_layers(params, lo, hi)

        def body(hh, xs):
            lp, c = xs if span_caches is not None else (xs, None)
            hh, nc = _mamba_block(lp["ssm"], norm(lp["norm1"], hh, cfg), cfg,
                                  train=train, cache=c, chunked=chunked)
            return hh, nc

        body_fn = jax.checkpoint(
            body, policy=common.remat_policy(cfg)
        ) if (cfg.remat and train) else body
        xs = (stacked, span_caches) if span_caches is not None else stacked
        return common.scan_layers(body_fn, h, xs,
                                  unroll=not cfg.scan_layers)

    h = x
    app = 0
    # prefill (caches given, no decode-time shared kv) must COLLECT the
    # weight-shared attention block's K/V per application for later decode
    collect_shared = caches is not None and shared_kv is None
    for lo in range(0, cfg.n_layers, se):
        hi = min(lo + se, cfg.n_layers)
        span_c = None if caches is None else \
            jax.tree.map(lambda a: a[lo:hi], caches)
        h, nc = run_span(h, lo, hi, span_c)
        new_caches.append(nc)
        if cfg.ssm.shared_every and hi - lo == se and app < _n_shared_apps(cfg):
            if shared_kv is not None:
                kv = jax.tree.map(lambda a: a[app], shared_kv)
            else:
                kv = {} if collect_shared else None
            h, new_kv = _shared_block(params["shared"], h, cfg,
                                      positions=positions, train=train,
                                      cache=kv, pos_idx=pos0)
            if new_kv is not None:
                new_shared.append(new_kv)
            app += 1
    h = norm(params["final_norm"], h, cfg)
    caches_out = jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_caches) \
        if caches is not None or not train else None
    shared_out = jax.tree.map(lambda *xs: jnp.stack(xs), *new_shared) \
        if new_shared else None
    return h, caches_out, shared_out


def train_loss(params, batch, cfg: ModelConfig, rng=None):
    h, _, _ = _forward(params, batch["tokens"], cfg, train=True)
    logits = unembed(params["tok"], h, cfg, train=True)
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    d_in, n_h, conv_dim = _dims(cfg)
    s = cfg.ssm
    L = cfg.n_layers
    dt = dtype_of(cfg)
    cache = {"pos": jnp.zeros((), jnp.int32),
             "layers": {
                 "conv": jnp.zeros((L, batch, s.conv_kernel - 1, conv_dim), dt),
                 "S": jnp.zeros((L, batch, n_h, s.head_dim, s.d_state),
                                jnp.float32)}}
    apps = _n_shared_apps(cfg)
    if apps:
        cache["shared"] = {
            "k": jnp.zeros((apps, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((apps, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dt)}
    return cache


def prefill(params, batch, cfg: ModelConfig, max_len=None):
    tokens = batch["tokens"]
    b, t = tokens.shape
    max_len = max_len or t
    zero = init_cache(cfg, b, max_len)
    h, caches, shared_kv = _forward(params, tokens, cfg, train=False,
                                    caches=zero["layers"], chunked=True)
    logits = unembed(params["tok"], h[:, -1], cfg)
    cache = {"pos": jnp.full((), t, jnp.int32), "layers": caches}
    if shared_kv is not None:
        def pad(a):
            widths = [(0, 0)] * a.ndim
            widths[2] = (0, max_len - a.shape[2])
            return jnp.pad(a, widths)
        cache["shared"] = jax.tree.map(pad, shared_kv)
    return logits, cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    h, caches, shared_kv = _forward(
        params, tokens, cfg, train=False, caches=cache["layers"],
        shared_kv=cache.get("shared"), pos0=cache["pos"], chunked=False)
    logits = unembed(params["tok"], h[:, 0], cfg)
    out = {"pos": cache["pos"] + 1, "layers": caches}
    if shared_kv is not None:
        out["shared"] = shared_kv
    return logits, out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
