"""Shared model components: CIM-switchable dense layers, norms, RoPE,
chunked (flash-style) attention, MLPs, embeddings and KV caches.

Every weight matmul routes through `dense()` so the paper's analog-CIM
execution mode (core.cim_matmul) is a single config switch for all ten
architectures — the framework-level integration the brief asks for.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core.cim_matmul import cim_matmul, cim_matmul_ste
from repro.parallel.sharding import constrain

Params = dict


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def res_axes(cfg: ModelConfig) -> tuple:
    """Sharding of [B, T, D] residual-stream activations: batch over DP axes
    and (with seq_shard) tokens over "model" — Megatron-style sequence
    parallelism; spec_for drops the token axis automatically when T doesn't
    divide (decode T=1)."""
    return ("batch", "seq_tp" if cfg.seq_shard else None, None)


def scan_layers(body, carry, stacked, *, unroll: bool):
    """lax.scan over stacked layer weights, or straight-line unroll.

    Unrolled form exists for the roofline pass: XLA cost_analysis counts a
    while body once regardless of trip count, so analysis cells lower with
    unroll=True (bigger HLO, exact FLOPs/bytes).
    """
    if not unroll:
        return jax.lax.scan(body, carry, stacked)
    length = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    ys = []
    for i in range(length):
        xs = jax.tree.map(lambda a: a[i], stacked)
        carry, y = body(carry, xs)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, *, dtype, bias: bool = False,
               scale: float | None = None, name_w: str = "w",
               name_b: str = "b") -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {name_w: (jax.random.normal(key, (d_in, d_out), jnp.float32)
                  * scale).astype(dtype)}
    if bias:
        p[name_b] = jnp.zeros((d_out,), dtype)
    return p


def norm_init(d: int, *, dtype, kind: str) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# primitive layers
# ---------------------------------------------------------------------------
def dense(p: Params, x: jax.Array, cfg: ModelConfig, *, train: bool = False,
          w: str = "w", b: str | None = "b") -> jax.Array:
    """y = x @ W (+bias) — on the simulated PICO-RAM macro when cfg.cim.enabled.

    CIM runs in f32 (integer-code arithmetic); the float path runs in the
    model compute dtype. Output is cast back to the compute dtype.

    The CIM branches run inside a `quant.act_site(w)` scope: the weight name
    (layer-index-free by construction — layers share names) is the call-site
    identity the calibration profile records and per-site precision
    overrides (CIMConfig.site_overrides) resolve against.
    """
    if cfg.cim.enabled and (w + "_q") in p:
        # serving path: offline-quantized stored codes — int8 containers or
        # nibble-packed uint8 (1/4 the bf16 HBM bytes); the execution
        # engine (core.engine) dispatches either format to its backend.
        # w_scale is per-matrix or per-channel ([..., 1, M]) transparently;
        # cfg.cim.noise_seed routes NOISY/FULL evals to the fused
        # stochastic kernel with seeded-reproducible draws.
        from repro.core.cim_matmul import cim_matmul_prequant
        with quant.act_site(w):
            y = cim_matmul_prequant(x.astype(jnp.float32), p[w + "_q"],
                                    p[w + "_scale"], cfg.cim)
        y = y.astype(dtype_of(cfg))
    elif cfg.cim.enabled:
        fn = cim_matmul_ste if train else cim_matmul
        with quant.act_site(w):
            y = fn(x.astype(jnp.float32), p[w].astype(jnp.float32), cfg.cim)
        y = y.astype(dtype_of(cfg))
    else:
        y = jnp.einsum("...k,km->...m", x, p[w])
    if b is not None and b in p:
        y = y + p[b]
    return y


def _rs_applicable(cfg: ModelConfig, x: jax.Array) -> bool:
    from repro.parallel import sharding as _sh
    mesh = _sh.get_mesh()
    if not (cfg.tp_reduce_scatter and not cfg.cim.enabled
            and mesh is not None and "model" in mesh.axis_names
            and x.ndim == 3
            and x.shape[1] % mesh.shape["model"] == 0
            and x.shape[2] % mesh.shape["model"] == 0):
        return False
    baxes = _sh.resolve("batch") or ()
    bsize = math.prod(mesh.shape[a] for a in baxes) if baxes else 1
    return x.shape[0] % max(bsize, 1) == 0


def dense_rs(p: Params, x: jax.Array, cfg: ModelConfig, *, w: str,
             b: str | None = None) -> jax.Array:
    """TP output projection with an explicit reduce-scatter epilogue.

    x [B, T, in] with `in` sharded over "model" (heads / ffn hidden);
    returns [B, T, out] with T sharded over "model" (the SP layout the next
    norm runs in). GSPMD lowers the same computation as all-reduce (+implicit
    reshard) = 2× the wire bytes; psum_scatter is the Megatron-SP schedule.
    """
    from jax.sharding import PartitionSpec as P
    from repro.parallel import sharding as _sh
    mesh = _sh.get_mesh()
    batch_axes = _sh.resolve("batch")
    weight = p[w]

    fsdp = _sh.resolve("fsdp") is not None \
        and "data" in mesh.axis_names and mesh.shape["data"] > 1 \
        and weight.shape[1] % mesh.shape["data"] == 0

    def fn(x_l, w_l):
        if fsdp:
            w_l = jax.lax.all_gather(w_l, "data", axis=1, tiled=True)
        part = jnp.einsum("btk,km->btm", x_l, w_l)
        return jax.lax.psum_scatter(part, "model", scatter_dimension=1,
                                    tiled=True)

    w_spec = P("model", "data" if fsdp else None)
    y = _sh.shard_map(
        fn, mesh=mesh,
        in_specs=(P(batch_axes, None, "model"), w_spec),
        out_specs=P(batch_axes, "model", None),
        check_vma=False,
    )(x, weight)
    if b is not None and b in p:
        y = y + p[b]
    return y


def norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float,
         rope_dims: int) -> jax.Array:
    """Rotary embedding on the leading `rope_dims` of the head dim.

    x: [B, T, H, dh]; positions: [B, T] absolute positions.
    """
    if rope_dims <= 0:
        return x
    half = rope_dims // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xpass = x[..., :rope_dims], x[..., rope_dims:]
    x1, x2 = xr[..., :half], xr[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([rot.astype(x.dtype), xpass], -1)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — pure JAX, O(chunk²) live memory
# ---------------------------------------------------------------------------
def _attn_block(q, k, v, mask, scale):
    """One (q-chunk × kv-chunk) block. q:[B,Cq,KH,G,dh] k/v:[B,Ckv,KH,dh]."""
    s = jnp.einsum("bqkgd,bckd->bqkgc", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int,
                      q_offset: jax.Array | int = 0,
                      kv_valid: jax.Array | int | None = None,
                      triangular_max: int = 8,
                      unroll: bool = False) -> jax.Array:
    """Online-softmax attention: q [B,Tq,H,dh] × k,v [B,Tk,KH,dh] → [B,Tq,H,dh].

    GQA folded as H = KH × G. Scans kv chunks (and q chunks when Tq is
    large); when the q-chunk count is small and causal, unrolls a triangular
    loop so no fully-masked block is ever computed (exact-FLOPs training).
    """
    b, tq, h, dh = q.shape
    _, tk, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / math.sqrt(dh)
    ckv = min(chunk, tk)
    cq = min(chunk, tq)
    pad_kv = (-tk) % ckv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    pad_q = (-tq) % cq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nkv = (tk + pad_kv) // ckv
    nq = (tq + pad_q) // cq
    kv_valid = tk if kv_valid is None else kv_valid

    qs = q.reshape(b, nq, cq, kh, g, dh)
    ks = k.reshape(b, nkv, ckv, kh, dh)
    vs = v.reshape(b, nkv, ckv, kh, dh)
    q_idx_base = jnp.asarray(q_offset) + jnp.arange(cq)

    def kv_scan(qi_abs, q_blk, j_lo, j_hi):
        """Online softmax over kv chunks j ∈ [j_lo, j_hi)."""
        def body(carry, j):
            m_acc, l_acc, o_acc = carry
            kj = j * ckv + jnp.arange(ckv)
            mask = kj[None, :] < jnp.minimum(
                jnp.asarray(kv_valid),
                (qi_abs[:, None] + 1) if causal else jnp.iinfo(jnp.int32).max)
            mask = jnp.broadcast_to(mask[None], (b, cq, ckv))
            m, l, o = _attn_block(q_blk, ks[:, j], vs[:, j], mask, scale)
            m_new = jnp.maximum(m_acc, m)
            a_old = jnp.exp(m_acc - m_new)
            a_new = jnp.exp(m - m_new)
            return (m_new, l_acc * a_old + l * a_new,
                    o_acc * a_old[..., None] + o * a_new[..., None]), None

        init = (jnp.full((b, cq, kh, g), -jnp.inf, jnp.float32),
                jnp.zeros((b, cq, kh, g), jnp.float32),
                jnp.zeros((b, cq, kh, g, dh), jnp.float32))
        (m_f, l_f, o_f), _ = jax.lax.scan(body, init, jnp.arange(j_lo, j_hi),
                                          unroll=True if unroll else 1)
        return o_f / jnp.maximum(l_f, 1e-30)[..., None]

    if causal and nq <= triangular_max and isinstance(q_offset, int) \
            and q_offset == 0 and cq % ckv == 0:
        # Triangular unroll: q chunk i only visits kv chunks covering [0, i·cq+cq)
        outs = []
        for i in range(nq):
            qi_abs = i * cq + q_idx_base
            j_hi = (i + 1) * cq // ckv
            outs.append(kv_scan(qi_abs, qs[:, i], 0, j_hi))
        out = jnp.stack(outs, 1)
    else:
        def q_body(_, i):
            qi_abs = i * cq + q_idx_base
            return None, kv_scan(qi_abs, qs[:, i], 0, nkv)
        _, out = jax.lax.scan(q_body, None, jnp.arange(nq),
                              unroll=True if unroll else 1)
        out = jnp.moveaxis(out, 0, 1)  # [B, nq, cq, KH, G, dh]

    out = out.reshape(b, nq * cq, h, dh)[:, :tq]
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q [B,1,H,dh] × caches [B,S,KH,dh] → [B,1,H,dh]. Full-S einsum (no scan):
    GSPMD partitions the S reduction across the "seq" axes, turning the
    softmax into two tiny all-reduces — the production long-context layout.
    """
    b, _, h, dh = q.shape
    _, s, kh, _ = k_cache.shape
    g = h // kh
    qg = q.reshape(b, kh, g, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dh)
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged KV cache: block-pool scatter/gather + windowed attention
# ---------------------------------------------------------------------------
def paged_write(pool: jax.Array, new: jax.Array,
                flat_idx: jax.Array) -> jax.Array:
    """Scatter per-token K or V rows into a block pool.

    pool [NB, bs, KH, dh]; new [B, C, KH, dh]; flat_idx [B, C] indexes the
    flattened (NB·bs) token-slot axis. Masked lanes arrive pre-pointed at
    the trash block (flat index 0..bs-1) by the caller, so no separate mask
    is needed here — duplicate trash writes land in storage that is never
    read with non-zero attention weight.
    """
    nb, bs = pool.shape[:2]
    flat = pool.reshape(nb * bs, *pool.shape[2:])
    flat = flat.at[flat_idx.reshape(-1)].set(
        new.reshape(-1, *new.shape[2:]).astype(pool.dtype))
    return flat.reshape(pool.shape)


def paged_gather(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather each slot's window from the block pool.

    pool [NB, bs, KH, dh]; tables [B, MB] physical block ids. Returns the
    contiguous per-slot view [B, MB·bs, KH, dh] — the same window shape the
    dense slot cache gave decode_attention, so the per-position math (and,
    for decode, the bits) match the unpaged path. Unallocated table entries
    point at the trash block; those positions sit at >= the slot's length
    and are masked before any softmax.
    """
    b, mb = tables.shape
    win = pool[tables]                       # [B, MB, bs, KH, dh]
    return win.reshape(b, mb * pool.shape[1], *pool.shape[2:])


def paged_prefill_attention(q: jax.Array, k_win: jax.Array, v_win: jax.Array,
                            positions: jax.Array,
                            kv_len: jax.Array) -> jax.Array:
    """Causal attention of a prompt chunk against its gathered window.

    q [B,C,H,dh] × k/v windows [B,W,KH,dh] → [B,C,H,dh]; positions [B,C] is
    each query's absolute position (lens + chunk offset), kv_len [B] the
    tokens valid in the window INCLUDING this chunk's writes. Exact (one-
    pass) softmax over the full window rather than the online-softmax of
    chunked_attention: the result is then independent of how the prompt was
    chunked — the invariance the chunked-prefill equivalence tests pin —
    and decode (C=1) keeps using decode_attention so its bits match the
    dense-cache path. W is one request's max context, so this path
    materializes a [B,C,KH,G,W] score tensor — it is the "exact" entry of
    the attention-backend registry (kernels.paged_attention); the "kernel"
    backend is the Pallas flash path whose live scores are one [C·G, bs]
    tile (the TPU-scale serving configuration).
    """
    b, cq, h, dh = q.shape
    w = k_win.shape[1]
    kh = k_win.shape[2]
    g = h // kh
    qg = q.reshape(b, cq, kh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg, k_win,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dh)
    pos_s = jnp.arange(w)[None, None, :]
    mask = (pos_s <= positions[:, :, None]) & (pos_s < kv_len[:, None, None])
    scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(v_win.dtype), v_win,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, cq, h, dh).astype(q.dtype)


def paged_attention_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                          positions: jax.Array, cache: dict,
                          flat_idx: jax.Array, tables: jax.Array,
                          kv_len: jax.Array):
    """Self-attention over a paged KV pool — the unified prefill/decode step.

    x [B, C, D] (C = 1 for decode, the prefill chunk width otherwise);
    cache {"k": [NB, bs, KH, dh], "v": ...} is ONE layer's physical pool.
    Projects and RoPEs this step's tokens at their true per-slot positions,
    scatters them into the pool at flat_idx (masked lanes → trash block),
    and attends with per-slot lengths through the attention-backend
    registry (kernels.paged_attention, selected by cfg.attn_backend):
    "exact" gathers the window and runs the one-pass softmax, "kernel" is
    the Pallas flash path that consumes the pool + tables directly. On the
    kernel path, decode (C = 1) also scatters this step's K/V rows through
    the fused Pallas write kernel instead of the host-visible `.at[].set`
    (bit-identical pools outside the never-attended trash block).
    Returns (y, updated layer pool).
    """
    from repro.kernels.paged_attention import (paged_attention,
                                               choose_attn_backend,
                                               get_attn_backend,
                                               fused_paged_write)
    from repro.parallel import sharding
    b, c, _ = x.shape
    dh = cfg.head_dim
    q = dense(p, x, cfg, w="wq", b="bq").reshape(b, c, cfg.n_heads, dh)
    q = constrain(q, "batch", None, "tp", None)
    k1 = dense(p, x, cfg, w="wk", b="bk").reshape(b, c, cfg.n_kv_heads, dh)
    v1 = dense(p, x, cfg, w="wv", b="bv").reshape(b, c, cfg.n_kv_heads, dh)
    if cfg.pos_embed == "rope":
        q = rope(q, positions, cfg.rope_theta, _rope_dims(cfg))
        k1 = rope(k1, positions, cfg.rope_theta, _rope_dims(cfg))
    fused = (c == 1
             and get_attn_backend(choose_attn_backend(cfg.attn_backend)).pallas
             and sharding.get_mesh() is None
             and not sharding.in_shard_context())
    if fused:
        k_pool, v_pool = fused_paged_write(cache["k"], cache["v"], k1, v1,
                                           flat_idx)
    else:
        k_pool = paged_write(cache["k"], k1, flat_idx)
        v_pool = paged_write(cache["v"], v1, flat_idx)
    o = paged_attention(q, k_pool, v_pool, tables, positions=positions,
                        kv_len=kv_len, backend=cfg.attn_backend)
    o = o.reshape(b, c, cfg.n_heads * dh)
    o = constrain(o, "batch", None, "tp")
    y = dense(p, o, cfg, w="wo", b="bo")
    return constrain(y, *res_axes(cfg)), {"k": k_pool, "v": v_pool}


# ---------------------------------------------------------------------------
# attention layer (projections + cache plumbing)
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig, *, d_model: int | None = None) -> Params:
    d = d_model or cfg.d_model
    dh = cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {}
    p.update(dense_init(ks[0], d, cfg.n_heads * dh, dtype=dt,
                        bias=cfg.qkv_bias, name_w="wq", name_b="bq"))
    p.update(dense_init(ks[1], d, cfg.n_kv_heads * dh, dtype=dt,
                        bias=cfg.qkv_bias, name_w="wk", name_b="bk"))
    p.update(dense_init(ks[2], d, cfg.n_kv_heads * dh, dtype=dt,
                        bias=cfg.qkv_bias, name_w="wv", name_b="bv"))
    p.update(dense_init(ks[3], cfg.n_heads * dh, d, dtype=dt,
                        scale=1.0 / math.sqrt(cfg.n_heads * dh * 2 * cfg.n_layers),
                        name_w="wo", name_b="bo"))
    return p


def attention_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                    positions: jax.Array, train: bool = False,
                    causal: bool = True,
                    kv_x: jax.Array | None = None,
                    cache: Optional[dict] = None,
                    cache_index: jax.Array | int = 0):
    """Self/cross attention. Returns (y, new_kv_cache_entries | None).

    cache: {"k": [B,S,KH,dh], "v": ...} — decode writes the new token at
    cache_index and attends over the first cache_index+1 entries.
    """
    b, t, _ = x.shape
    dh = cfg.head_dim
    src = x if kv_x is None else kv_x
    q = dense(p, x, cfg, train=train, w="wq", b="bq")
    q = q.reshape(b, t, cfg.n_heads, dh)
    q = constrain(q, "batch", None, "tp", None)
    if cfg.pos_embed == "rope" and kv_x is None:
        q = rope(q, positions, cfg.rope_theta, _rope_dims(cfg))

    new_cache = None
    if cache is not None and kv_x is None and t == 1:
        # decode: project current token, write into cache
        k1 = dense(p, src, cfg, train=train, w="wk", b="bk")
        v1 = dense(p, src, cfg, train=train, w="wv", b="bv")
        k1 = k1.reshape(b, 1, cfg.n_kv_heads, dh)
        v1 = v1.reshape(b, 1, cfg.n_kv_heads, dh)
        if cfg.pos_embed == "rope":
            k1 = rope(k1, positions, cfg.rope_theta, _rope_dims(cfg))
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_cache_dtype(k1, cache), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], k_cache_dtype(v1, cache), (0, cache_index, 0, 0))
        k_cache = constrain(k_cache, "batch", "seq_tp", None, None)
        v_cache = constrain(v_cache, "batch", "seq_tp", None, None)
        o = decode_attention(q, k_cache, v_cache,
                             jnp.asarray(cache_index) + 1)
        new_cache = {"k": k_cache, "v": v_cache}
    elif cache is not None and kv_x is not None and "k" in cache:
        # cross-attention decode: cache holds precomputed encoder K/V
        o = decode_attention(q, cache["k"], cache["v"], cache["k"].shape[1])
        new_cache = cache
    else:
        k = dense(p, src, cfg, train=train, w="wk", b="bk")
        v = dense(p, src, cfg, train=train, w="wv", b="bv")
        k = k.reshape(b, src.shape[1], cfg.n_kv_heads, dh)
        v = v.reshape(b, src.shape[1], cfg.n_kv_heads, dh)
        if cfg.pos_embed == "rope" and kv_x is None:
            k = rope(k, positions, cfg.rope_theta, _rope_dims(cfg))
        k = constrain(k, "batch", None, "tp", None)
        v = constrain(v, "batch", None, "tp", None)
        o = chunked_attention(q, k, v, causal=causal and kv_x is None,
                              chunk=cfg.attn_chunk,
                              triangular_max=cfg.attn_triangular_max,
                              unroll=not cfg.scan_layers)
        if cache is not None:  # prefill: hand back the filled cache
            new_cache = {"k": k, "v": v}

    o = o.reshape(b, t, cfg.n_heads * dh)
    o = constrain(o, "batch", None, "tp")
    if _rs_applicable(cfg, o):
        y = dense_rs(p, o, cfg, w="wo", b="bo")
    else:
        y = dense(p, o, cfg, train=train, w="wo", b="bo")
    return constrain(y, *res_axes(cfg)), new_cache


def k_cache_dtype(x, cache):
    return x.astype(cache["k"].dtype)


def _rope_dims(cfg: ModelConfig) -> int:
    d = int(cfg.head_dim * cfg.rope_pct)
    return d - (d % 2)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, *, d_ff: int | None = None,
             d_model: int | None = None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.mlp == "swiglu":
        p.update(dense_init(ks[0], d, f, dtype=dt, name_w="w_gate"))
    p.update(dense_init(ks[1], d, f, dtype=dt, name_w="w_up"))
    p.update(dense_init(ks[2], f, d, dtype=dt,
                        scale=1.0 / math.sqrt(f * 2 * cfg.n_layers),
                        name_w="w_down"))
    return p


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
              train: bool = False) -> jax.Array:
    up = dense(p, x, cfg, train=train, w="w_up", b=None)
    up = constrain(up, "batch", None, "tp")
    if cfg.mlp == "swiglu":
        gate = dense(p, x, cfg, train=train, w="w_gate", b=None)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    if _rs_applicable(cfg, h):
        y = dense_rs(p, h, cfg, w="w_down")
    else:
        y = dense(p, h, cfg, train=train, w="w_down", b=None)
    return constrain(y, *res_axes(cfg))


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------
def embed_init(key, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    p = {"embed": (jax.random.normal(key, (cfg.vocab, cfg.d_model),
                                     jnp.float32) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(jax.random.fold_in(key, 1),
                                       (cfg.d_model, cfg.vocab), jnp.float32)
                     / math.sqrt(cfg.d_model)).astype(dt)
    return p


def embed_lookup(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    # The stored table is [V/"tp", D/"fsdp"] (PARAM_RULES): gathering from an
    # operand sharded on the collapsed slice dim, into an output that must
    # land batch-sharded, makes the SPMD partitioner fall back to involuntary
    # full rematerialization. Reshard first into a gather-friendly layout:
    # batch-shard the token ids and move the table's model split onto the
    # offset dim ([V, D/"tp"] — "tp" is disjoint from the batch axes, and
    # offset-dim sharding passes straight through a gather). Each device then
    # gathers only its own batch rows, and the output reshards to res_axes
    # with one small activation all-gather instead of a table remat.
    table = constrain(p["embed"], None, "tp")
    x = table[constrain(tokens, "batch", None)]
    return constrain(x, *res_axes(cfg))


def unembed(p: Params, h: jax.Array, cfg: ModelConfig, *,
            train: bool = False) -> jax.Array:
    if cfg.cim.enabled and "head_q" in p:
        from repro.core.cim_matmul import cim_matmul_prequant
        with quant.act_site("head"):
            logits = cim_matmul_prequant(h.astype(jnp.float32), p["head_q"],
                                         p["head_scale"], cfg.cim)
    else:
        w = p["embed"].T if cfg.tie_embeddings else p.get("head")
        if cfg.cim.enabled:
            fn = cim_matmul_ste if train else cim_matmul
            with quant.act_site("head"):
                logits = fn(h.astype(jnp.float32), w.astype(jnp.float32),
                            cfg.cim)
        else:
            logits = jnp.einsum("...d,dv->...v", h, w)
    logits = logits.astype(jnp.float32)
    axes = ("batch",) + (None,) * (logits.ndim - 2) + ("tp",)
    return constrain(logits, *axes)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Token-mean CE. logits [.., V] f32, labels [..] int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
