"""RWKV6 ("Finch") — attention-free LM with data-dependent per-channel decay.

The WKV6 recurrence  S_t = diag(w_t)·S_{t−1} + k_tᵀv_t,
                     y_t = r_t·(S_{t−1} + diag(u)·k_tᵀv_t)
is evaluated in chunked-parallel form for train/prefill (intra-chunk
matmuls + inter-chunk scan — the TPU-friendly linear-attention schedule) and
as the exact O(1)-state recurrence for decode, which is what makes the
long_500k cell run where softmax-attention archs are skipped.

Per DESIGN.md §Arch-applicability: the recurrence itself is element-wise
state math (not an MVM against stored weights) so it stays digital; all
R/K/V/G/decay-LoRA/output projections and the channel-mix FFN route through
the CIM-switchable dense layer.

Simplification noted in DESIGN.md: the 5-way ddlerp token-shift mixers are
reduced to static learned μ per projection; the data-dependent decay LoRA
(Finch's core novelty) is kept.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.parallel.sharding import constrain

from . import common
from .common import cross_entropy, dense, dtype_of, embed_init, embed_lookup, \
    norm, norm_init, unembed

LOG_DECAY_FLOOR = -5.0  # per-step log-decay clamp for chunk-form stability


def _time_mix_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    n_h = d // hd
    r = cfg.ssm.decay_lora_rank
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    p = {"mu": jnp.full((5, d), 0.5, dt)}  # r,k,v,g,w token-shift mixes
    for i, name in enumerate(("w_r", "w_k", "w_v", "w_g")):
        p.update(common.dense_init(ks[i], d, d, dtype=dt, name_w=name))
    p["decay_w0"] = jnp.linspace(-6.0, -0.5, d).astype(jnp.float32)
    p["decay_a"] = (jax.random.normal(ks[4], (d, r), jnp.float32) * 0.01).astype(dt)
    p["decay_b"] = (jax.random.normal(ks[5], (r, d), jnp.float32) * 0.01).astype(dt)
    p["bonus_u"] = jnp.zeros((d,), jnp.float32)
    p.update(common.dense_init(ks[6], d, d, dtype=dt,
                               scale=1.0 / math.sqrt(d * 2 * cfg.n_layers),
                               name_w="w_out"))
    p["norm_g"] = jnp.ones((d,), dt)  # per-head group-norm scale
    return p


def _channel_mix_init(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    p = {"mu": jnp.full((2, d), 0.5, dt)}
    p.update(common.dense_init(ks[0], d, f, dtype=dt, name_w="w_up"))
    p.update(common.dense_init(ks[1], f, d, dtype=dt,
                               scale=1.0 / math.sqrt(f * 2 * cfg.n_layers),
                               name_w="w_down"))
    p.update(common.dense_init(ks[2], d, d, dtype=dt, name_w="w_r"))
    return p


def init(key, cfg: ModelConfig, **_) -> dict:
    ks = jax.random.split(key, 3)
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.fold_in(ks[0], i)
        layers.append({
            "norm1": norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm),
            "tm": _time_mix_init(jax.random.fold_in(kk, 0), cfg),
            "norm2": norm_init(cfg.d_model, dtype=dtype_of(cfg), kind=cfg.norm),
            "cm": _channel_mix_init(jax.random.fold_in(kk, 1), cfg),
        })
    return {"tok": embed_init(ks[1], cfg),
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
            "final_norm": norm_init(cfg.d_model, dtype=dtype_of(cfg),
                                    kind=cfg.norm)}


def _token_shift(x: jax.Array, prev: jax.Array | None):
    """xs_t = x_{t−1}; position 0 sees `prev` (zeros at sequence start)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _decay(p, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel log-decay (negative), Finch eq. w_t."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_a"].astype(jnp.float32)) \
        @ p["decay_b"].astype(jnp.float32)
    logw = -jnp.exp(jnp.clip(p["decay_w0"] + lora, -8.0, 1.0))
    return jnp.clip(logw, LOG_DECAY_FLOOR, -1e-4)


def _group_norm(y: jax.Array, scale: jax.Array, n_heads: int) -> jax.Array:
    b, t, d = y.shape
    yh = y.reshape(b, t, n_heads, d // n_heads).astype(jnp.float32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, -1, keepdims=True) + 1e-5)
    return (yh.reshape(b, t, d) * scale.astype(jnp.float32)).astype(y.dtype)


def wkv6_chunked(r, k, v, logw, u, *, chunk: int, state0=None,
                 unroll: bool = False):
    """Chunked-parallel WKV6. r,k,v,logw [B,T,H,dh] → (y, final state).

    All within-chunk exponents are differences of cumulative log-decays
    (≤ |chunk·LOG_DECAY_FLOOR|), safe in f32 with chunk ≤ 32.
    """
    b, t, h, dh = r.shape
    pad = (-t) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=-1e-4)
    nc = (t + pad) // chunk
    shp = (b, nc, chunk, h, dh)
    rc, kc, vc = (a.reshape(shp).astype(jnp.float32) for a in (r, k, v))
    lw = logw.reshape(shp)
    cum = jnp.cumsum(lw, axis=2)                      # inclusive Σ log w

    if state0 is None:
        state0 = jnp.zeros((b, h, dh, dh), jnp.float32)

    def body(S, xs):
        rcc, kcc, vcc, lwc, cumc = xs                # [B, C, H, dh]
        a_ex = cumc - lwc                             # exclusive cumsum
        r_dec = rcc * jnp.exp(a_ex)                   # r_i ⊙ Π_{l<i} w
        k_dec = kcc * jnp.exp(-cumc)                  # k_j ⊘ Π_{l≤j} w
        # intra-chunk attention (strictly causal) + bonus diagonal
        att = jnp.einsum("bihd,bjhd->bhij", r_dec, k_dec)
        att = jnp.tril(att, k=-1)
        diag = jnp.einsum("bihd,bihd->bhi", rcc * u, kcc)
        y = jnp.einsum("bhij,bjhd->bihd", att, vcc) \
            + diag.transpose(0, 2, 1)[..., None] * vcc
        # inter-chunk from carried state
        y = y + jnp.einsum("bihk,bhkv->bihv", r_dec, S)
        # state update: S' = diag(W_C)·S + Σ_j (k_j·W_C/W_j) ⊗ v_j
        wc = jnp.exp(cumc[:, -1])                     # [B, H, dh]
        S_add = jnp.einsum("bjhk,bjhv->bhkv", k_dec, vcc)
        S_new = wc[..., None] * (S + S_add)
        return S_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in
               (rc, kc, vc, lw.astype(jnp.float32), cum.astype(jnp.float32)))
    state, ys = jax.lax.scan(body, state0, xs, unroll=True if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, h, dh)[:, :t]
    return y, state


def _time_mix(p, x, cfg: ModelConfig, *, train, prev_x=None, state=None,
              chunked=True):
    """Returns (out, (last_x, state))."""
    b, t, d = x.shape
    hd = cfg.ssm.head_dim
    h = d // hd
    xs = _token_shift(x, prev_x) if chunked else prev_x
    mu = p["mu"].astype(x.dtype)
    mix = lambda i: x + mu[i] * (xs - x)
    rr = dense(p, mix(0), cfg, train=train, w="w_r", b=None)
    kk = dense(p, mix(1), cfg, train=train, w="w_k", b=None)
    vv = dense(p, mix(2), cfg, train=train, w="w_v", b=None)
    gg = dense(p, mix(3), cfg, train=train, w="w_g", b=None)
    logw = _decay(p, mix(4))                          # [B,T,D] f32
    sh = (b, t, h, hd)
    r4, k4, v4 = (a.reshape(sh) for a in (rr, kk, vv))
    r4 = constrain(r4, "batch", None, "tp", None)
    lw4 = logw.reshape(sh)
    u4 = p["bonus_u"].reshape(h, hd)

    if chunked:
        y, state = wkv6_chunked(r4, k4, v4, lw4, u4, chunk=cfg.ssm.chunk,
                                state0=state, unroll=not cfg.scan_layers)
    else:  # exact single-token recurrence (decode)
        r1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (r4, k4, v4))
        w1 = jnp.exp(lw4[:, 0].astype(jnp.float32))
        kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
        y = jnp.einsum("bhk,bhkv->bhv", r1, state + u4[..., None] * kv)
        state = w1[..., None] * state + kv
        y = y[:, None]
    y = _group_norm(y.reshape(b, t, d).astype(x.dtype), p["norm_g"], h)
    y = y * jax.nn.silu(gg)
    out = dense(p, y, cfg, train=train, w="w_out", b=None)
    return constrain(out, *common.res_axes(cfg)), (x[:, -1:], state)


def _channel_mix(p, x, cfg: ModelConfig, *, train, prev_x=None,
                 chunked=True):
    xs = _token_shift(x, prev_x) if chunked else prev_x
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    kk = jax.nn.relu(dense(p, xk, cfg, train=train, w="w_up", b=None)) ** 2
    vv = dense(p, kk, cfg, train=train, w="w_down", b=None)
    rr = jax.nn.sigmoid(dense(p, xr, cfg, train=train, w="w_r", b=None))
    return constrain(rr * vv, *common.res_axes(cfg)), x[:, -1:]


def _layer(lp, h, cfg, *, train, cache=None, chunked=True):
    """cache: {"tm_x", "cm_x": [B,1,D], "S": [B,H,dh,dh]} or None."""
    c = cache or {}
    a, (tm_x, S) = _time_mix(lp["tm"], norm(lp["norm1"], h, cfg), cfg,
                             train=train, prev_x=c.get("tm_x"),
                             state=c.get("S"), chunked=chunked)
    h = h + a
    f, cm_x = _channel_mix(lp["cm"], norm(lp["norm2"], h, cfg), cfg,
                           train=train, prev_x=c.get("cm_x"), chunked=chunked)
    h = h + f
    return h, {"tm_x": tm_x, "cm_x": cm_x, "S": S}


def _run(params, x, cfg: ModelConfig, *, train, caches=None, chunked=True):
    def body(hh, xs):
        lp, c = xs if caches is not None else (xs, None)
        hh, new_c = _layer(lp, hh, cfg, train=train, cache=c, chunked=chunked)
        return hh, new_c

    body_fn = jax.checkpoint(
        body, policy=common.remat_policy(cfg)
    ) if (cfg.remat and train) else body
    xs = (params["layers"], caches) if caches is not None else params["layers"]
    return common.scan_layers(body_fn, x, xs, unroll=not cfg.scan_layers)


def train_loss(params, batch, cfg: ModelConfig, rng=None):
    x = embed_lookup(params["tok"], batch["tokens"], cfg)
    h, _ = _run(params, x, cfg, train=True)
    h = norm(params["final_norm"], h, cfg)
    logits = unembed(params["tok"], h, cfg, train=True)
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    L = cfg.n_layers
    dt = dtype_of(cfg)
    return {"pos": jnp.zeros((), jnp.int32),
            "layers": {"tm_x": jnp.zeros((L, batch, 1, d), dt),
                       "cm_x": jnp.zeros((L, batch, 1, d), dt),
                       "S": jnp.zeros((L, batch, h, hd, hd), jnp.float32)}}


def prefill(params, batch, cfg: ModelConfig, max_len=None):
    x = embed_lookup(params["tok"], batch["tokens"], cfg)
    h, caches = _run(params, x, cfg, train=False,
                     caches=init_cache(cfg, x.shape[0], 0)["layers"],
                     chunked=True)
    h = norm(params["final_norm"], h, cfg)
    logits = unembed(params["tok"], h[:, -1], cfg)
    cache = {"pos": jnp.full((), batch["tokens"].shape[1], jnp.int32),
             "layers": caches}
    return logits, cache


def decode_step(params, tokens, cache, cfg: ModelConfig):
    x = embed_lookup(params["tok"], tokens, cfg)
    h, new_layers = _run(params, x, cfg, train=False,
                         caches=cache["layers"], chunked=False)
    h = norm(params["final_norm"], h, cfg)
    logits = unembed(params["tok"], h[:, 0], cfg)
    return logits, {"pos": cache["pos"] + 1, "layers": new_layers}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
