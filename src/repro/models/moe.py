"""Mixture-of-Experts FFN with expert parallelism.

Production layout (1000+ chip posture):
  * routed expert weights [E, D, F]: E sharded over "model" (EP), D over
    "data" (FSDP — all-gathered on use);
  * token activations replicated over "model" between blocks (TP residual
    stream), sharded over batch axes;
  * baseline EP combine: each model rank computes its local experts' tokens
    and the outputs are psum'd over "model" ("replicated-dispatch EP") —
    simple and correct for every T including single-token decode;
  * optimized EP (ep_mode="a2a", §Perf): all-to-all token dispatch with
    static capacity (DeepSeek-style), via parallel.collectives.a2a_dispatch
    / a2a_combine. Two layouts share one dispatch core: prefill/train
    shards the sequence over "model" (t % ep == 0); decode (t too short to
    seq-shard — the single-token step) splits the data-shard's tokens into
    ep chunks, each model rank dispatching its own chunk and an all_gather
    reassembling the outputs — only routed tokens (top_k/E of the bytes)
    cross the EP axis either way.
  * shared experts (qwen2 / deepseek) run as a dense TP FFN outside the
    EP region (they process every token — no routing needed).

Routed expert weights may be offline-quantized (models.quantize): int8
containers or the nibble-packed serving format, which rides through the EP
shard_map as an `engine.PackedCodes` container (a registered pytree, so
expert shard specs apply to its code bytes and carried per-expert scales
leaf-wise) — 4-bit expert weights at rest under expert parallelism.

Experts are padded to a multiple of the model-axis size (qwen2's 60 → 64);
pad experts receive no tokens (router logits exist only for real experts).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.cim_matmul import cim_matmul, cim_matmul_ste
from repro.core.engine import PackedCodes
from repro.parallel import collectives, sharding
from repro.parallel.sharding import constrain

from . import common

EP_PAD = 16  # pad expert count to a multiple of the model-axis size


def padded_experts(n: int) -> int:
    return -(-n // EP_PAD) * EP_PAD


def init(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    e_pad = padded_experts(m.n_experts)
    d, f = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 6)
    dt = common.dtype_of(cfg)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f * 2 * cfg.n_layers)
    p = {
        "router": (jax.random.normal(ks[0], (d, m.n_experts), jnp.float32)
                   * 0.02),
        "e_gate": (jax.random.normal(ks[1], (e_pad, d, f), jnp.float32)
                   * scale_in).astype(dt),
        "e_up": (jax.random.normal(ks[2], (e_pad, d, f), jnp.float32)
                 * scale_in).astype(dt),
        "e_down": (jax.random.normal(ks[3], (e_pad, f, d), jnp.float32)
                   * scale_out).astype(dt),
    }
    if m.n_shared:
        p["shared"] = common.mlp_init(ks[4], cfg, d_ff=m.d_ff_shared)
        if m.shared_gate:
            p["shared"]["w_sg"] = (jax.random.normal(ks[5], (d, 1),
                                                     jnp.float32) * 0.02
                                   ).astype(dt)
    return p


# ---------------------------------------------------------------------------
# routing + static-capacity dispatch (pure shape-static ops)
# ---------------------------------------------------------------------------
def _route(x2: jax.Array, router_w: jax.Array, top_k: int):
    """x2 [T, D] → (probs [T, E], ids [T, k], weights [T, k])."""
    logits = x2.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, -1, keepdims=True), 1e-9)   # renormalize top-k
    return probs, ids, weights


def _positions_in_expert(ids_flat: jax.Array, e_pad: int):
    """Slot index of each (token, choice) within its expert's buffer."""
    onehot = jax.nn.one_hot(ids_flat, e_pad, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot            # exclusive cumsum
    return jnp.take_along_axis(pos, ids_flat[:, None], axis=1)[:, 0]


def _expert_weights(p: dict, name: str, cfg: ModelConfig) -> dict:
    """One routed-expert weight as a small dict: {"w": float [E, K, M]};
    after models.quantize.quantize_params either {"q": int8 stored codes,
    "s": per-expert scales [E, 1, 1]} or — for the nibble-packed serving
    format — {"pk": engine.PackedCodes} carrying the uint8 code bytes
    [E, ceil(K/2), M] AND the scales in one self-describing container the
    execution engine consumes directly. Stored codes are only meaningful on
    the macro, so (like common.dense and gru._mm) they are picked up only
    when cfg.cim.enabled."""
    if cfg.cim.enabled and name + "_q" in p:
        q, s = p[name + "_q"], p[name + "_scale"]
        if q.dtype == jnp.uint8:   # nibble-packed: two u4 codes per byte
            k = cfg.d_model if name in ("e_gate", "e_up") \
                else cfg.moe.d_ff_expert
            return {"pk": PackedCodes(q, k, s)}
        return {"q": q, "s": s}
    return {"w": p[name]}


def _e_local(wp: dict) -> int:
    """Local (per-shard) expert count of an _expert_weights dict."""
    v = next(iter(wp.values()))
    return (v.data if isinstance(v, PackedCodes) else v).shape[0]


def _expert_specs(wp: dict, w_spec) -> dict:
    """shard_map in_specs matching an _expert_weights dict. Stored codes
    shard exactly like the float weight they replace (nibble packing halves
    the K dim but never splits a byte); scales ride the expert axis only —
    both per-expert [E, 1, 1] and per-channel [E, 1, M] shapes (the M axis
    stays unsharded either way). PackedCodes is a pytree, so its spec is a
    like-structured container: w_spec for the code bytes, expert-axis-only
    for the carried scales."""
    s_spec = P("model", None, None)
    if "pk" in wp:
        return {"pk": PackedCodes(w_spec, wp["pk"].k, s_spec)}
    if "q" in wp:
        return {"q": w_spec, "s": s_spec}
    return {"w": w_spec}


def _gather_expert(wp: dict, axis: int) -> dict:
    """FSDP all-gather of an expert weight's sharded K/M dim (ZeRO-3)."""
    if "pk" in wp:
        pk = wp["pk"]
        data = jax.lax.all_gather(pk.data, "data", axis=axis, tiled=True)
        return {"pk": PackedCodes(data, pk.k, pk.scale)}
    key = "q" if "q" in wp else "w"
    return {**wp, key: jax.lax.all_gather(wp[key], "data", axis=axis,
                                          tiled=True)}


def _expert_ffn(buf: jax.Array, wg, wu, wd, cfg: ModelConfig, train: bool):
    """Batched expert MLP: buf [E, C, D] → [E, C, D] (CIM-aware).

    wg/wu/wd are _expert_weights dicts; the CIM path vmaps the engine's
    layer entry point over the expert axis (prequant stored codes or
    quantize-on-the-fly float weights). While a calibration span recorder is
    open (quant.recording_active()) the expert axis is unrolled in Python
    instead: under vmap every activation span is a tracer, which used to
    leave ALL routed-expert call sites silently missing from the profile —
    the unroll keeps spans concrete and records them under the e_gate /
    e_up / e_down site names."""
    if cfg.cim.enabled:
        from repro.core import quant

        def one(xb, wp):
            if "pk" in wp:   # nibble-packed container (carries its scales)
                from repro.core.cim_matmul import cim_matmul_prequant
                return cim_matmul_prequant(xb.astype(jnp.float32), wp["pk"],
                                           None, cfg.cim)
            if "q" in wp:
                from repro.core.cim_matmul import cim_matmul_prequant
                return cim_matmul_prequant(xb.astype(jnp.float32), wp["q"],
                                           wp["s"], cfg.cim)
            mm = cim_matmul_ste if train else cim_matmul
            return mm(xb.astype(jnp.float32), wp["w"].astype(jnp.float32),
                      cfg.cim)

        if quant.recording_active():
            def f(xb, wp, site):
                with quant.act_site(site):
                    return jnp.stack([
                        one(xb[e], jax.tree.map(lambda a: a[e], wp))
                        for e in range(xb.shape[0])])
        else:
            def f(xb, wp, site):
                with quant.act_site(site):
                    return jax.vmap(one)(xb, wp)
        h = jax.nn.silu(f(buf, wg, "e_gate")) * f(buf, wu, "e_up")
        return f(h, wd, "e_down").astype(buf.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg["w"])) \
        * jnp.einsum("ecd,edf->ecf", buf, wu["w"])
    return jnp.einsum("ecf,efd->ecd", h, wd["w"])


def _local_moe(x2, router_w, wg, wu, wd, cfg: ModelConfig, *, train: bool,
               capacity: int, e_offset: int = 0, stats: bool = False):
    """Dispatch x2's tokens to the experts in wg/wu/wd (a contiguous slice
    [e_offset, e_offset + E_local)), compute, and combine. Tokens routed
    elsewhere contribute zero — callers psum across expert shards.

    Returns (y2 [T, D], aux_loss); with stats=True the second element is
    instead the UN-normalized router stats (me_sum [E], pe_sum [E]) so a
    sharded caller can psum them for an exact global load-balance loss
    (the same contract _a2a_core exposes).
    """
    t, d = x2.shape
    e_local = _e_local(wg)
    e_pad = padded_experts(cfg.moe.n_experts)
    k = cfg.moe.top_k

    probs, ids, weights = _route(x2, router_w, k)
    ids_flat = ids.reshape(-1)                            # [T·k]
    pos = _positions_in_expert(ids_flat, e_pad)           # [T·k]
    local = (ids_flat >= e_offset) & (ids_flat < e_offset + e_local)
    keep = (pos < capacity) & local
    slot = jnp.where(keep, (ids_flat - e_offset) * capacity + pos,
                     e_local * capacity)                  # overflow slot
    token_idx = jnp.repeat(jnp.arange(t), k)

    buf = jnp.zeros((e_local * capacity + 1, d), x2.dtype)
    buf = buf.at[slot].set(x2[token_idx])                 # drop beyond capacity
    out = _expert_ffn(buf[:-1].reshape(e_local, capacity, d),
                      wg, wu, wd, cfg, train)
    out_flat = jnp.concatenate(
        [out.reshape(e_local * capacity, d),
         jnp.zeros((1, d), out.dtype)], 0)
    y_choices = out_flat[slot] * weights.reshape(-1)[:, None].astype(out.dtype)
    y2 = jnp.zeros((t, d), out.dtype).at[token_idx].add(y_choices)

    # Switch-style load-balance loss (real experts only).
    if stats:
        me_sum = jnp.sum(jax.nn.one_hot(ids_flat, cfg.moe.n_experts,
                                        dtype=jnp.float32), axis=0)
        pe_sum = jnp.sum(probs, axis=0)
        return y2, (me_sum, pe_sum)
    me = jnp.mean(jax.nn.one_hot(ids_flat, cfg.moe.n_experts,
                                 dtype=jnp.float32), axis=0)
    pe = jnp.mean(probs, axis=0)
    aux = cfg.moe.n_experts * jnp.sum(me * pe)
    return y2, aux


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens * m.top_k * m.capacity_factor
                      / padded_experts(m.n_experts)))
    return max(8, -(-c // 8) * 8)


def apply(p: dict, x: jax.Array, cfg: ModelConfig, *, train: bool = False,
          rng: Optional[jax.Array] = None):
    """MoE FFN: x [B, T, D] → (y [B, T, D], aux_loss)."""
    b, t, d = x.shape
    mesh = sharding.get_mesh()
    y_shared = _shared_expert(p, x, cfg, train) if cfg.moe.n_shared else 0.0
    wg = _expert_weights(p, "e_gate", cfg)
    wu = _expert_weights(p, "e_up", cfg)
    wd = _expert_weights(p, "e_down", cfg)

    if mesh is None or "model" not in mesh.axis_names \
            or padded_experts(cfg.moe.n_experts) % mesh.shape["model"] != 0:
        batch_axes = (sharding.resolve("batch") or ()) \
            if mesh is not None else ()
        n_b = math.prod(mesh.shape[a] for a in batch_axes) \
            if batch_axes else 1
        if mesh is None or sharding.in_shard_context() or n_b <= 1 \
                or b % n_b:
            # truly local: no mesh, already tracing per-shard, or the
            # batch cannot divide — every device computes the full set
            x2 = x.reshape(b * t, d)
            cap = _capacity(b * t, cfg)
            y2, aux = _local_moe(x2, p["router"], wg, wu, wd,
                                 cfg, train=train, capacity=cap)
            return y_shared + y2.reshape(b, t, d).astype(x.dtype), aux
        # Non-divisible experts under an active mesh: the expert axis
        # cannot shard, but the batch still can. Run the full expert set
        # per shard on its batch slice INSIDE shard_map — the in-shard
        # guard keeps the vmapped CIM expert kernels off nested mesh
        # dispatch — and psum the raw router stats over the batch axes
        # for an exact global load-balance loss.
        cap = _capacity((b // n_b) * t, cfg)
        ntot = b * t

        def fb_fn(x_l, router_w, wg_l, wu_l, wd_l):
            bl, tl, dl = x_l.shape
            y2, (me_sum, pe_sum) = _local_moe(
                x_l.reshape(bl * tl, dl), router_w, wg_l, wu_l, wd_l,
                cfg, train=train, capacity=cap, stats=True)
            me_sum = jax.lax.psum(me_sum, batch_axes)
            pe_sum = jax.lax.psum(pe_sum, batch_axes)
            aux = cfg.moe.n_experts * jnp.sum(
                me_sum / (ntot * cfg.moe.top_k) * (pe_sum / ntot))
            return y2.reshape(bl, tl, dl), aux

        def _rep(tree):
            return jax.tree.map(lambda l: P(*(None,) * jnp.ndim(l)), tree)

        x_spec = P(batch_axes, None, None)
        y2, aux = sharding.shard_map(
            fb_fn, mesh=mesh,
            in_specs=(x_spec, _rep(p["router"]), _rep(wg), _rep(wu),
                      _rep(wd)),
            out_specs=(x_spec, P()),
            check_vma=False,
        )(x, p["router"], wg, wu, wd)
        return y_shared + y2.astype(x.dtype), aux

    # --- expert-parallel shard_map --------------------------------------
    batch_axes = sharding.resolve("batch") or ()
    b_local = b // math.prod(mesh.shape[a] for a in batch_axes) if batch_axes else b
    if cfg.moe.ep_mode == "a2a":
        y2, aux = _a2a_moe(p, x, cfg, mesh, batch_axes, b_local, train)
        return y_shared + y2.astype(x.dtype), aux
    cap = _capacity(b_local * t, cfg)

    fsdp = sharding.resolve("fsdp") is not None \
        and "data" in mesh.axis_names and mesh.shape["data"] > 1

    def shard_fn(x_l, router_w, wg_l, wu_l, wd_l):
        rank = jax.lax.axis_index("model")
        e_local = _e_local(wg_l)
        # FSDP all-gather of the local experts' D-shards (ZeRO-3 on use).
        if fsdp:
            wg_l = _gather_expert(wg_l, 1)
            wu_l = _gather_expert(wu_l, 1)
            wd_l = _gather_expert(wd_l, 2)
        bl, tl, dl = x_l.shape
        y2, aux = _local_moe(x_l.reshape(bl * tl, dl), router_w,
                             wg_l, wu_l, wd_l, cfg, train=train,
                             capacity=cap, e_offset=rank * e_local)
        y2 = jax.lax.psum(y2, "model")
        # aux must be replicated across every mesh axis for the P() out_spec
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return y2.reshape(bl, tl, dl), aux

    x_spec = P(batch_axes if batch_axes else None, None, None)
    dax = "data" if fsdp else None
    out = sharding.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  _expert_specs(wg, P("model", dax, None)),
                  _expert_specs(wu, P("model", dax, None)),
                  _expert_specs(wd, P("model", None, dax))),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], wg, wu, wd)
    y2, aux = out
    return y_shared + y2.astype(x.dtype), aux


def _a2a_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert capacity per SOURCE shard (static dispatch buffers)."""
    e_pad = padded_experts(cfg.moe.n_experts)
    c = int(math.ceil(n_tokens * cfg.moe.top_k * cfg.moe.capacity_factor
                      / e_pad))
    return max(8, -(-c // 8) * 8)


def _a2a_core(x2: jax.Array, router_w, wg, wu, wd, cfg: ModelConfig, *,
              cap: int, train: bool, valid: jax.Array | None = None):
    """Shared a2a-EP dispatch core: runs INSIDE a shard_map over "model".

    Routes the rank's own tokens x2 [T_rank, D], packs them into the
    static-capacity slot layout, exchanges via collectives.a2a_dispatch,
    runs the local experts, and combines through collectives.a2a_combine.
    `valid` masks padding rows (decode chunking): invalid tokens neither
    consume capacity nor contribute output or router statistics.

    Returns (y2 [T_rank, D], me_sum [E], pe_sum [E], n_valid) with the
    UN-normalized router load stats so the caller can psum them over
    "model" for an exact global load-balance loss.
    """
    tloc, dl = x2.shape
    e_pad = padded_experts(cfg.moe.n_experts)
    k = cfg.moe.top_k

    probs, ids, weights = _route(x2, router_w, k)
    ids_flat = ids.reshape(-1)
    if valid is not None:
        # invalid (padding) rows route to the out-of-range sentinel BEFORE
        # the capacity cumsum, so they never occupy a slot a valid token
        # needs (one_hot of e_pad is the zero row)
        valid_flat = jnp.repeat(valid, k)
        ids_flat = jnp.where(valid_flat, ids_flat, e_pad)
        weights = weights * valid[:, None].astype(weights.dtype)
    pos = _positions_in_expert(ids_flat, e_pad)
    keep = pos < cap
    if valid is not None:
        keep = keep & valid_flat
    slot = jnp.where(keep, ids_flat * cap + pos, e_pad * cap)
    token_idx = jnp.repeat(jnp.arange(tloc), k)
    send = jnp.zeros((e_pad * cap + 1, dl), x2.dtype)
    send = send.at[slot].set(x2[token_idx])
    send = send[:-1].reshape(e_pad, cap, dl)
    recv = collectives.a2a_dispatch(send, "model")
    out = _expert_ffn(recv, wg, wu, wd, cfg, train)  # [e_local, ep·cap, D]
    back = collectives.a2a_combine(out, "model")     # original slot layout
    back = back.reshape(e_pad * cap, dl)
    back = jnp.concatenate([back, jnp.zeros((1, dl), back.dtype)], 0)
    y_choices = back[slot] * weights.reshape(-1)[:, None].astype(back.dtype)
    y2 = jnp.zeros((tloc, dl), back.dtype).at[token_idx].add(y_choices)

    onehot = jax.nn.one_hot(ids_flat, cfg.moe.n_experts, dtype=jnp.float32)
    if valid is not None:
        onehot = onehot * jnp.repeat(valid, k).astype(jnp.float32)[:, None]
        pe_sum = jnp.sum(probs * valid[:, None].astype(jnp.float32), axis=0)
        n_valid = jnp.sum(valid.astype(jnp.float32))
    else:
        pe_sum = jnp.sum(probs, axis=0)
        n_valid = jnp.float32(tloc)
    return y2, jnp.sum(onehot, axis=0), pe_sum, n_valid


def _a2a_aux(me_sum, pe_sum, n_valid, cfg: ModelConfig, mesh):
    """Exact load-balance loss over the "model" token split; averaged
    (GShard-estimator-style) over the remaining mesh axes so the P()
    out_spec sees a replicated value."""
    me_sum = jax.lax.psum(me_sum, "model")
    pe_sum = jax.lax.psum(pe_sum, "model")
    n = jax.lax.psum(n_valid, "model")
    me = me_sum / jnp.maximum(n * cfg.moe.top_k, 1.0)
    pe = pe_sum / jnp.maximum(n, 1.0)
    aux = cfg.moe.n_experts * jnp.sum(me * pe)
    other = tuple(a for a in mesh.axis_names if a != "model")
    return jax.lax.pmean(aux, other) if other else aux


def _a2a_moe(p: dict, x: jax.Array, cfg: ModelConfig, mesh, batch_axes,
             b_local: int, train: bool):
    """All-to-all dispatch EP (DeepSeek-style), §Perf optimization.

    Prefill/train (t divisible by the model-axis size): tokens shard over
    BOTH batch axes and "model" (sequence split), so per-device dispatch
    buffers shrink by the model-axis size vs psum-EP and the psum of the
    full activation is replaced by the static-capacity all_to_all pair that
    moves only routed tokens (top_k/E of the traffic).

    Decode (t too short to seq-shard — the single-token step): tokens stay
    replicated over "model"; each model rank takes an ep-th CHUNK of the
    data-shard's tokens (zero-padded, masked), dispatches only that chunk
    through the same a2a core, and one all_gather over "model" reassembles
    the outputs — routed-token a2a traffic plus a 1/ep-sized gather instead
    of a full-activation psum.
    """
    b, t, d = x.shape
    ep = mesh.shape["model"]
    seq_sharded = t % ep == 0

    fsdp = sharding.resolve("fsdp") is not None \
        and "data" in mesh.axis_names and mesh.shape["data"] > 1

    if seq_sharded:
        cap = _a2a_capacity(b_local * (t // ep), cfg)
        x_spec = P(batch_axes if batch_axes else None, "model", None)
    else:
        tloc = b_local * t
        chunk = -(-tloc // ep)
        cap = _a2a_capacity(chunk, cfg)
        x_spec = P(batch_axes if batch_axes else None, None, None)

    def shard_fn(x_l, router_w, wg, wu, wd):
        if fsdp:
            wg = _gather_expert(wg, 1)
            wu = _gather_expert(wu, 1)
            wd = _gather_expert(wd, 2)
        bl, tl, dl = x_l.shape
        x2 = x_l.reshape(bl * tl, dl)
        if seq_sharded:
            y2, me_sum, pe_sum, n_valid = _a2a_core(
                x2, router_w, wg, wu, wd, cfg, cap=cap, train=train)
        else:
            tloc = x2.shape[0]
            chunk = -(-tloc // ep)
            x2p = jnp.pad(x2, ((0, ep * chunk - tloc), (0, 0)))
            rank = jax.lax.axis_index("model")
            mine = jax.lax.dynamic_slice_in_dim(x2p, rank * chunk, chunk, 0)
            valid = rank * chunk + jnp.arange(chunk) < tloc
            y_mine, me_sum, pe_sum, n_valid = _a2a_core(
                mine, router_w, wg, wu, wd, cfg, cap=cap, train=train,
                valid=valid)
            y2 = jax.lax.all_gather(y_mine, "model", axis=0,
                                    tiled=True)[:tloc]
        aux = _a2a_aux(me_sum, pe_sum, n_valid, cfg, mesh)
        return y2.reshape(bl, tl, dl), aux

    dax = "data" if fsdp else None
    wg = _expert_weights(p, "e_gate", cfg)
    wu = _expert_weights(p, "e_up", cfg)
    wd = _expert_weights(p, "e_down", cfg)
    y2, aux = sharding.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None),
                  _expert_specs(wg, P("model", dax, None)),
                  _expert_specs(wu, P("model", dax, None)),
                  _expert_specs(wd, P("model", None, dax))),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], wg, wu, wd)
    return y2, aux


def _shared_expert(p: dict, x: jax.Array, cfg: ModelConfig, train: bool):
    y = common.mlp_apply(p["shared"], x, cfg, train=train)
    if cfg.moe.shared_gate:
        g = jax.nn.sigmoid(
            jnp.einsum("btd,dk->btk", x, p["shared"]["w_sg"].astype(x.dtype)))
        y = y * g
    return constrain(y, *common.res_axes(cfg))
