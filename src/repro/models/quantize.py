"""Offline weight quantization for serving: bf16 params → stored 4-bit codes
+ scales, per Eq. 7's W̃ encoding.

This is the deployment flow of a CIM system (weights are programmed into the
SRAM once) and a §Perf memory-term optimization on TPU. Two container
formats, consumed transparently by `core.engine` via `cim_matmul_prequant`:

  packed=True (default) — nibble-packed uint8 [..., ceil(K/2), M]: two u4
      codes per byte, the wire/HBM format matching the macro's 4-bit SRAM
      storage density (559 Kb/mm²). Decode reads 1/4 the weight bytes of
      bf16.
  packed=False — int8 [..., K, M], one code per byte (half the bf16 bytes);
      kept for A/B benchmarking of the packing win.

Scales follow cfg.cim.weight.per_channel: per-matrix [..., 1, 1] (default)
or per-output-channel [..., 1, M] — consumers (common.dense, gru._mm,
moe._expert_weights) pass `w_scale` through untouched and the execution
engine broadcasts either shape in the dequant epilogue.

Embeddings stay float (a lookup, not an MVM on the macro); norms/biases
stay float.
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.core.cim_matmul import quantize_weight_offline

# dense-layer weight leaves that route through the macro (see PARAM_RULES)
QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "head",
    "w_dq", "w_uq", "w_dkv", "w_uk", "w_uv", "w_kr", "w_proj",
    "w_in", "w_out", "w_x", "w_r", "w_k", "w_v", "w_g",
    "w_z", "w_h",                       # KWS GRU gates
    "e_gate", "e_up", "e_down",         # routed MoE experts [E, K, M]
}


def quantize_params(params: dict, cfg: ModelConfig, *,
                    packed: bool = True) -> dict:
    """Replace quantizable float leaves `w` with `w_q` (+ `w_scale`).

    `w_q` is nibble-packed uint8 when `packed` (the default serving format)
    or an int8 code-per-byte container otherwise. Works on concrete arrays
    and (via jax.eval_shape at the caller) on abstract trees for the
    dry-run.
    """
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if isinstance(v, dict):
                out[k] = quantize_params(v, cfg, packed=packed)
            elif k in QUANTIZABLE and getattr(v, "ndim", 0) >= 2:
                # the weight name is the call-site identity: per-site
                # precision overrides (e.g. per-channel scales from a
                # deployment manifest) apply at offline-quantization time
                from repro.core import quant
                with quant.act_site(k):
                    codes, scale = quantize_weight_offline(v, cfg.cim)
                if packed:
                    from repro.kernels.ops import pack_codes
                    codes = pack_codes(codes)
                out[k + "_q"] = codes
                out[k + "_scale"] = scale
            else:
                out[k] = v
        return out
    return params


def abstract_quantized_params(params_abs, cfg: ModelConfig, *,
                              packed: bool = True):
    return jax.eval_shape(
        lambda p: quantize_params(p, cfg, packed=packed), params_abs)
