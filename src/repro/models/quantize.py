"""Offline weight quantization for serving: bf16 params → stored 4-bit codes
(int8 containers) + scales, per Eq. 7's W̃ encoding.

This is the deployment flow of a CIM system (weights are programmed into the
SRAM once) and a §Perf memory-term optimization on TPU: decode reads half
the weight bytes. Embeddings stay float (a lookup, not an MVP on the macro);
norms/biases stay float.
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.core.cim_matmul import quantize_weight_offline

# dense-layer weight leaves that route through the macro (see PARAM_RULES)
QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "head",
    "w_dq", "w_uq", "w_dkv", "w_uk", "w_uv", "w_kr", "w_proj",
    "w_in", "w_out", "w_x", "w_r", "w_k", "w_v", "w_g",
}


def quantize_params(params: dict, cfg: ModelConfig) -> dict:
    """Replace quantizable float leaves `w` with `w_q` (int8) + `w_scale`.

    Works on concrete arrays and (via jax.eval_shape at the caller) on
    abstract trees for the dry-run.
    """
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if isinstance(v, dict):
                out[k] = quantize_params(v, cfg)
            elif k in QUANTIZABLE and getattr(v, "ndim", 0) >= 2:
                codes, scale = quantize_weight_offline(v, cfg.cim)
                out[k + "_q"] = codes
                out[k + "_scale"] = scale
            else:
                out[k] = v
        return out
    return params


def abstract_quantized_params(params_abs, cfg: ModelConfig):
    return jax.eval_shape(lambda p: quantize_params(p, cfg), params_abs)
