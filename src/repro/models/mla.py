"""DeepSeek-V3 Multi-head Latent Attention (MLA).

Train/prefill reconstruct per-head K/V from the compressed latent and run
standard chunked attention. Decode uses the *absorbed* formulation: the
KV cache stores only the (kv_lora_rank + rope) latent per position — the
whole point of MLA (576 dims instead of 128 heads × 256), which keeps the
32k/500k-context caches small — and the query is absorbed through W_uk so
scores are taken directly against the latent.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain

from . import common
from .common import dense, dtype_of, norm_init, res_axes, rope


def init(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    p = {}
    p.update(common.dense_init(ks[0], d, m.q_lora_rank, dtype=dt, name_w="w_dq"))
    p["q_norm"] = norm_init(m.q_lora_rank, dtype=dt, kind="rmsnorm")
    p.update(common.dense_init(ks[1], m.q_lora_rank, h * qk, dtype=dt,
                               name_w="w_uq"))
    p.update(common.dense_init(ks[2], d, m.kv_lora_rank, dtype=dt,
                               name_w="w_dkv"))
    p["kv_norm"] = norm_init(m.kv_lora_rank, dtype=dt, kind="rmsnorm")
    p.update(common.dense_init(ks[3], m.kv_lora_rank,
                               h * m.qk_nope_head_dim, dtype=dt, name_w="w_uk"))
    p.update(common.dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim,
                               dtype=dt, name_w="w_uv"))
    p.update(common.dense_init(ks[5], d, m.qk_rope_head_dim, dtype=dt,
                               name_w="w_kr"))
    p.update(common.dense_init(
        ks[6], h * m.v_head_dim, d, dtype=dt,
        scale=1.0 / math.sqrt(h * m.v_head_dim * 2 * cfg.n_layers),
        name_w="wo", name_b=None))
    return p


def _project_q(p, x, cfg: ModelConfig, positions, train):
    m = cfg.mla
    b, t, _ = x.shape
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = common.norm(p["q_norm"], dense(p, x, cfg, train=train, w="w_dq",
                                        b=None), cfg.replace(norm="rmsnorm"))
    q = dense(p, cq, cfg, train=train, w="w_uq", b=None)
    q = q.reshape(b, t, cfg.n_heads, qk)
    q = constrain(q, "batch", None, "tp", None)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta, m.qk_rope_head_dim)
    return q_nope, q_rope


def _latent(p, x, cfg: ModelConfig, positions, train):
    """Compressed KV latent + shared rope key: [B,T,lora], [B,T,rope]."""
    m = cfg.mla
    ckv = common.norm(p["kv_norm"], dense(p, x, cfg, train=train, w="w_dkv",
                                          b=None), cfg.replace(norm="rmsnorm"))
    kr = dense(p, x, cfg, train=train, w="w_kr", b=None)
    kr = rope(kr[:, :, None, :], positions, cfg.rope_theta,
              m.qk_rope_head_dim)[:, :, 0, :]
    return ckv, kr


def apply(p: dict, x: jax.Array, cfg: ModelConfig, *, positions,
          train: bool = False, cache: Optional[dict] = None,
          cache_index=0, return_cache: bool = False):
    """MLA attention. Returns (y, cache_entries | None).

    cache entries: {"latent": [B, S, kv_lora + rope]}.
    """
    m = cfg.mla
    b, t, _ = x.shape
    q_nope, q_rope = _project_q(p, x, cfg, positions, train)

    if cache is not None and t == 1 and not return_cache and "latent" in cache:
        # ---- absorbed decode over the latent cache ----
        ckv, kr = _latent(p, x, cfg, positions, train)
        new_lat = jnp.concatenate([ckv, kr], -1)          # [B, 1, lat]
        lat_cache = jax.lax.dynamic_update_slice(
            cache["latent"], new_lat.astype(cache["latent"].dtype),
            (0, cache_index, 0))
        lat_cache = constrain(lat_cache, "batch", "seq_tp", None)
        # absorb q through W_uk: q_abs[b,h,r] = Σ_d q_nope[b,h,d]·W_uk[r,(h,d)]
        w_uk = p["w_uk"].reshape(m.kv_lora_rank, cfg.n_heads,
                                 m.qk_nope_head_dim)
        q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        q_full = jnp.concatenate(
            [q_abs, jnp.broadcast_to(q_rope[:, 0].astype(jnp.float32),
                                     (b, cfg.n_heads, m.qk_rope_head_dim))],
            -1)                                           # [B, H, lat]
        # scores against the latent ("single latent KV head", scaled by the
        # true per-head qk dim, not the latent width)
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        scores = jnp.einsum("bhr,bsr->bhs", q_full,
                            lat_cache.astype(jnp.float32)) / math.sqrt(qk_dim)
        mask = jnp.arange(lat_cache.shape[1])[None, None, :] \
            <= jnp.asarray(cache_index)
        scores = jnp.where(mask, scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhs,bsr->bhr", attn,
                         lat_cache[..., :m.kv_lora_rank].astype(jnp.float32))
        w_uv = p["w_uv"].reshape(m.kv_lora_rank, cfg.n_heads, m.v_head_dim)
        o = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
        o = o.reshape(b, 1, cfg.n_heads * m.v_head_dim).astype(x.dtype)
        y = dense(p, o, cfg, train=train, w="wo", b=None)
        return constrain(y, *res_axes(cfg)), {"latent": lat_cache}

    # ---- train / prefill: reconstruct K, V and run chunked attention ----
    ckv, kr = _latent(p, x, cfg, positions, train)
    k_nope = dense(p, ckv, cfg, train=train, w="w_uk", b=None)
    k_nope = k_nope.reshape(b, t, cfg.n_heads, m.qk_nope_head_dim)
    v = dense(p, ckv, cfg, train=train, w="w_uv", b=None)
    v = v.reshape(b, t, cfg.n_heads, m.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                  (b, t, cfg.n_heads, m.qk_rope_head_dim))],
        -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = constrain(k, "batch", None, "tp", None)
    v = constrain(v, "batch", None, "tp", None)
    # pad V's head dim up to the QK dim so one attention primitive serves both
    pad = k.shape[-1] - v.shape[-1]
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else v
    o = common.chunked_attention(q, k, v_p, causal=True, chunk=cfg.attn_chunk,
                                 triangular_max=cfg.attn_triangular_max,
                                 unroll=not cfg.scan_layers)
    o = o[..., :m.v_head_dim]
    o = o.reshape(b, t, cfg.n_heads * m.v_head_dim)
    y = dense(p, o, cfg, train=train, w="wo", b=None)
    entries = None
    if return_cache:
        entries = {"latent": jnp.concatenate([ckv, kr], -1)}
    return constrain(y, *res_axes(cfg)), entries
