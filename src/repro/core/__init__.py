"""PICO-RAM core: the paper's contribution as composable JAX modules.

Layers:
  quant      — DAC/weight quantizers + STE (Eq. 5, Eq. 7 encoding)
  macro      — macro + operating-point (PVT) configuration
  adc / dac  — behavioural converter models (transfer, INL, noise, energy)
  schemes    — BP / WBS / BS analog MVM flows (Eq. 1, 2)
  engine     — unified execution engine: backend registry + execute_mvm
  cim_matmul — float-in/float-out layer entry point (+ STE for QAT)
  energy     — Eq. 4 energy / throughput / density model
  sqnr       — Monte-Carlo SQNR harness (Eq. 3, Fig. 2)
"""
from .cim_matmul import (BP_IDEAL, OFF, CIMConfig, cim_matmul,
                         cim_matmul_prequant, cim_matmul_ste)
from .engine import (PackedCodes, available_backends, choose_backend,
                     execute_mvm, get_backend, register_backend)
from .macro import (GEOMETRY, PROTOTYPE, MacroConfig, MacroGeometry,
                    OperatingPoint, Scheme, SimLevel)
from .quant import ActQuantConfig, WeightQuantConfig
from .schemes import bp_mvm, bs_mvm, cim_mvm_codes, exact_mvm_codes, wbs_mvm

__all__ = [
    "BP_IDEAL", "OFF", "CIMConfig", "cim_matmul", "cim_matmul_prequant",
    "cim_matmul_ste",
    "PackedCodes", "available_backends", "choose_backend", "execute_mvm",
    "get_backend", "register_backend",
    "GEOMETRY", "PROTOTYPE", "MacroConfig", "MacroGeometry", "OperatingPoint",
    "Scheme", "SimLevel", "ActQuantConfig", "WeightQuantConfig",
    "bp_mvm", "bs_mvm", "cim_mvm_codes", "exact_mvm_codes", "wbs_mvm",
]
