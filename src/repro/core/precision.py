"""Precision extension: higher-precision MVM on the 4-bit macro (paper §V:
"the macro completes 4-bit analog MVM in a single clock cycle, yet can
support higher precision by leveraging the peripheral digital serial
processing [26], [28]").

An 8-bit × 8-bit MVM decomposes into nibbles:
    X = 16·X_hi + X_lo,  W̃ = 16·W̃_hi + W̃_lo   (all nibbles ∈ [0,15])
    Σ X W̃ = Σ_{i,j} 16^{i+j} · Q( X_i · W̃_j )
i.e. four bit-parallel analog passes + digital shift-and-add — the nibble
analogue of WBS/BS, but each pass retains the full 4b×4b BP efficiency.
Signed 8-bit weights use the Eq. 7 offset with o = 128.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .macro import MacroConfig
from .schemes import bp_mvm, signed_correction


def adc_levels_for_bits(bits: float) -> int:
    """ADC quantization levels for a (possibly fractional) bit count.

    The paper's TD-ADC is 8.5-bit / 362-level (2^8.5 ≈ 362.04); the
    mixed-precision autotuner enumerates its per-site resolution candidates
    on this bit axis, since TD-ADC energy scales ~linearly with LEVELS
    (Walden), i.e. exponentially with bits — the knob that buys the
    per-layer energy/accuracy trade.
    """
    return max(2, int(round(2.0 ** bits)))


def adc_bits_for_levels(levels: int) -> float:
    """Inverse of adc_levels_for_bits (exact log2)."""
    import math
    return math.log2(levels)


# Candidate ADC resolutions for the per-site precision search: the native
# 8.5-bit converter and progressively coarser settings down to 5 bits (below
# that, BP partial sums at N = 144 rows are quantization-dominated for every
# layer shape we serve — see core.sqnr's Fig. 2 sweep).
ADC_BIT_CANDIDATES = (8.5, 8.0, 7.5, 7.0, 6.5, 6.0, 5.5, 5.0)


def split_nibbles(codes: jax.Array):
    """8-bit unsigned codes → (hi, lo) 4-bit nibbles."""
    ci = codes.astype(jnp.int32)
    return (ci >> 4).astype(codes.dtype), (ci & 15).astype(codes.dtype)


def extended_mvm_codes(x_codes8: jax.Array, w_codes8: jax.Array,
                       cfg: MacroConfig, *, key=None) -> jax.Array:
    """ŷ ≈ Σ X̃·W̃ for 8-bit unsigned codes via 4 nibble passes on the
    4-bit macro. x [..., K], w [K, M]."""
    xh, xl = split_nibbles(x_codes8)
    wh, wl = split_nibbles(w_codes8)
    out = 0.0
    for i, xi in ((1, xh), (0, xl)):
        for j, wj in ((1, wh), (0, wl)):
            kk = None if key is None else jax.random.fold_in(key, i * 2 + j)
            out = out + (16.0 ** (i + j)) * bp_mvm(xi, wj, cfg, key=kk)
    return out


def extended_matmul(x: jax.Array, w: jax.Array, cfg: MacroConfig, *,
                    key=None) -> jax.Array:
    """Float 8b×8b CIM matmul: affine 8-bit activations (zero-point folded
    into the digital correction), symmetric signed 8-bit weights."""
    xs = jax.lax.stop_gradient(x)
    span = jnp.maximum(jnp.max(xs) - jnp.minimum(jnp.min(xs), 0.0), 1e-8)
    s_x = span / 255.0
    zp = jnp.round(jnp.clip(-jnp.min(xs) / s_x, 0, 255))
    x_codes = jnp.clip(jnp.round(x / s_x) + zp, 0, 255)

    amax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    s_w = amax / 127.0
    w_codes = jnp.clip(jnp.round(w / s_w), -128, 127) + 128.0

    y = extended_mvm_codes(x_codes, w_codes, cfg, key=key)
    y = signed_correction(y, x_codes, w_codes, w_offset=128, x_zero_point=zp)
    return y * s_x * s_w
