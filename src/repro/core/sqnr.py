"""Monte-Carlo SQNR analysis of CIM schemes (paper §II-A, Eq. 3, Fig. 2).

Reproduces the paper's semi-empirical study: W, X are 4-bit integers sampled
from a truncated Gaussian; y = Σ W X over K = R·R·C elements; ŷ follows the
exact per-scheme computing flow including partial-sum accumulation across
macros when K > N; SQNR = Σ y² / Σ (y − ŷ)².

Circuit components are assumed ideal (SimLevel.IDEAL) — the study isolates
quantization effects, as the paper does.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .energy import mvm_energy
from .macro import MacroConfig, Scheme, SimLevel
from .schemes import cim_mvm_codes, exact_mvm_codes, signed_correction


def sample_truncated_gaussian_codes(key: jax.Array, shape, bits: int,
                                    signed: bool) -> jax.Array:
    """4-bit integers from a truncated Gaussian, as the paper samples W, X.

    Signed codes span [-2^(b-1), 2^(b-1)-1]; unsigned [0, 2^b - 1]. σ is a
    third of the half-range so the distribution is meaningfully bell-shaped
    but the tails are exercised.
    """
    if signed:
        lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        sigma = hi / 1.5
        g = jax.random.truncated_normal(key, lo / sigma, hi / sigma, shape) * sigma
    else:
        hi = (1 << bits) - 1
        mean, sigma = hi / 2.0, hi / 3.0
        lo_t, hi_t = (0 - mean) / sigma, (hi - mean) / sigma
        g = jax.random.truncated_normal(key, lo_t, hi_t, shape) * sigma + mean
    return jnp.round(g)


@dataclasses.dataclass(frozen=True)
class SqnrResult:
    sqnr_db: float
    energy_per_mvm_j: float
    tops_per_w: float


@partial(jax.jit, static_argnames=("cfg", "k", "batch", "signed_weights"))
def _sqnr_batch(key: jax.Array, cfg: MacroConfig, k: int, batch: int,
                signed_weights: bool):
    kx, kw, kn = jax.random.split(key, 3)
    x = sample_truncated_gaussian_codes(kx, (batch, k), cfg.act_bits,
                                        signed=False)
    if signed_weights:
        w_signed = sample_truncated_gaussian_codes(kw, (k, 1),
                                                   cfg.weight_bits, signed=True)
        offset = 1 << (cfg.weight_bits - 1)
        w_codes = w_signed + offset
    else:
        w_codes = sample_truncated_gaussian_codes(kw, (k, 1), cfg.weight_bits,
                                                  signed=False)
        offset = 0

    noise_key = kn if cfg.sim_level != SimLevel.IDEAL else None
    y_hat = cim_mvm_codes(x, w_codes, cfg, key=noise_key)
    y_ref = exact_mvm_codes(x, w_codes)
    if offset:
        zp = jnp.zeros(())
        y_hat = signed_correction(y_hat, x, w_codes, w_offset=offset,
                                  x_zero_point=zp)
        y_ref = signed_correction(y_ref, x, w_codes, w_offset=offset,
                                  x_zero_point=zp)
    return jnp.sum(y_ref ** 2), jnp.sum((y_ref - y_hat) ** 2)


def simulate_sqnr(cfg: MacroConfig, *, k: int = 144, n_samples: int = 1 << 16,
                  batch: int = 1 << 12, seed: int = 0,
                  signed_weights: bool = True,
                  dual_threshold: bool = False) -> SqnrResult:
    """Monte-Carlo SQNR (Eq. 3) + Eq. 4 energy for one hardware config.

    dual_threshold defaults to False here: the paper's §II-A analysis uses the
    E_ADC/(N·E_MAC) = 3.0 ratio measured on CAP-RAM [28] (no dual-threshold
    gating); with it, BP/WBS/BS at levels 1024/256/32 are exactly iso-energy,
    as Fig. 2(b) assumes. PICO-RAM's own macro metrics use True.
    """
    sig = err = 0.0
    key = jax.random.PRNGKey(seed)
    for i in range(max(1, n_samples // batch)):
        s, e = _sqnr_batch(jax.random.fold_in(key, i), cfg, k, batch,
                           signed_weights)
        sig += float(s)
        err += float(e)
    sqnr_db = 10.0 * jnp.log10(sig / jnp.maximum(err, 1e-12))
    rep = mvm_energy(cfg, k, dual_threshold=dual_threshold)
    return SqnrResult(sqnr_db=float(sqnr_db), energy_per_mvm_j=rep.e_mvm_j,
                      tops_per_w=rep.tops_per_w)


def sweep(base: MacroConfig, axis: str, values, **kw) -> list[tuple]:
    """Sweep one MacroConfig field (paper Fig. 2a: n_rows; Fig. 2b: adc_levels)
    for each scheme; returns (scheme, value, SqnrResult) tuples."""
    out = []
    for scheme in (Scheme.BP, Scheme.WBS, Scheme.BS):
        for v in values:
            cfg = dataclasses.replace(base, scheme=scheme, **{axis: v})
            out.append((scheme.value, v, simulate_sqnr(cfg, **kw)))
    return out
