"""Bit-Parallel / Weight-Bit-Serial / Bit-Serial analog MVM flows.

This is the computational core of the paper (Eq. 1, 2, 7). All three schemes
share the same grouped integer MAC against offset-encoded unsigned codes; they
differ in *where the ADC quantizer sits*:

  BP  (Eq. 1):  ŷ = Σ_g Q_g( Σ_{i∈g} W̃_i X̃_i )                    1 ADC/group
  WBS:          ŷ = Σ_g Σ_p 2^p Q_g( Σ_{i∈g} W^p_i X̃_i )          B_W ADC/group
  BS  (Eq. 2):  ŷ = Σ_g Σ_p Σ_q 2^{p+q} Q_g( Σ_{i∈g} W^p_i X^q_i ) B_A·B_W ADC/group

with groups of N = 144 rows (partial-sum accumulation across macros when
K > N, paper §II-A) and Q the TD-ADC transfer with full scale matched to the
per-pass operand bit widths. The signed/affine correction (Eq. 7 generalized
to activation zero points) is applied digitally outside, see
`signed_correction`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adc import adc_quantize
from .macro import MacroConfig, Scheme
from .quant import bit_planes


def pad_and_group(x: jax.Array, n_rows: int, axis: int = -1):
    """Zero-pad the reduction axis to a multiple of N and split into groups.

    Zero codes are exact no-ops in the analog array (an unselected row's
    C_MOM holds no DAC charge), so padding is free and bit-exact.
    """
    k = x.shape[axis]
    groups = max(1, -(-k // n_rows))
    pad = groups * n_rows - k
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis % x.ndim] = (0, pad)
        x = jnp.pad(x, widths)
    new_shape = x.shape[:axis % x.ndim] + (groups, n_rows) + x.shape[axis % x.ndim + 1:]
    return x.reshape(new_shape), groups


def _grouped_mac(xg: jax.Array, wg: jax.Array) -> jax.Array:
    """Per-group integer MAC: xg [..., G, N] × wg [G, N, M] → [..., G, M].

    This is the analog charge accumulation on the MAC line; computed exactly
    (charge-domain accumulation is linear, R² = 0.9999 per Fig. 9 — the
    nonlinearity lives in the ADC model).
    """
    return jnp.einsum("...gn,gnm->...gm", xg, wg,
                      preferred_element_type=jnp.float32)


def _adc_sum(v: jax.Array, cfg: MacroConfig, key, ba: int, bw: int,
             inl_seed: int) -> jax.Array:
    """Quantize each group's analog value and digitally accumulate groups."""
    q = adc_quantize(v, cfg, key=key, act_bits_active=ba,
                     weight_bits_active=bw, inl_seed=inl_seed)
    return jnp.sum(q, axis=-2)  # digital partial-sum accumulation over G


def bp_mvm(x_codes: jax.Array, w_codes: jax.Array, cfg: MacroConfig, *,
           key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """Bit-parallel (this work): one analog pass, one ADC per group."""
    xg, _ = pad_and_group(x_codes, cfg.n_rows)
    wg, _ = pad_and_group(w_codes, cfg.n_rows, axis=0)
    v = _grouped_mac(xg, wg)
    return _adc_sum(v, cfg, key, cfg.act_bits, cfg.weight_bits, inl_seed)


def wbs_mvm(x_codes: jax.Array, w_codes: jax.Array, cfg: MacroConfig, *,
            key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """Weight-bit-serial baseline: B_W analog passes over weight bit planes."""
    xg, _ = pad_and_group(x_codes, cfg.n_rows)
    planes = bit_planes(w_codes, cfg.weight_bits)  # [B_W, K, M]
    out = 0.0
    for p in range(cfg.weight_bits):
        wg, _ = pad_and_group(planes[p], cfg.n_rows, axis=0)
        v = _grouped_mac(xg, wg)
        kp = None if key is None else jax.random.fold_in(key, p)
        out = out + (2 ** p) * _adc_sum(v, cfg, kp, cfg.act_bits, 1, inl_seed)
    return out


def bs_mvm(x_codes: jax.Array, w_codes: jax.Array, cfg: MacroConfig, *,
           key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """Fully bit-serial baseline: B_A·B_W binary analog passes (Eq. 2)."""
    x_planes = bit_planes(x_codes, cfg.act_bits)    # [B_A, ..., K]
    w_planes = bit_planes(w_codes, cfg.weight_bits)  # [B_W, K, M]
    out = 0.0
    for p in range(cfg.weight_bits):
        wg, _ = pad_and_group(w_planes[p], cfg.n_rows, axis=0)
        for q in range(cfg.act_bits):
            xg, _ = pad_and_group(x_planes[q], cfg.n_rows)
            v = _grouped_mac(xg, wg)
            kpq = None if key is None else jax.random.fold_in(key, p * 16 + q)
            out = out + (2 ** (p + q)) * _adc_sum(v, cfg, kpq, 1, 1, inl_seed)
    return out


_SCHEME_FNS = {Scheme.BP: bp_mvm, Scheme.WBS: wbs_mvm, Scheme.BS: bs_mvm}


def cim_mvm_codes(x_codes: jax.Array, w_codes: jax.Array, cfg: MacroConfig, *,
                  key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """Dispatch on the configured multi-bit scheme.

    x_codes [..., K] unsigned DAC codes; w_codes [K, M] unsigned stored codes.
    Returns ŷ ≈ Σ X̃ W̃ (float32, in integer MAC units).
    """
    return _SCHEME_FNS[cfg.scheme](x_codes, w_codes, cfg, key=key,
                                   inl_seed=inl_seed)


def exact_mvm_codes(x_codes: jax.Array, w_codes: jax.Array) -> jax.Array:
    """Infinite-resolution reference: y = Σ X̃ W̃ with no ADC (15-bit ADC limit
    in the paper's terms). Ground truth for SQNR (Eq. 3)."""
    return jnp.einsum("...k,km->...m", x_codes, w_codes,
                      preferred_element_type=jnp.float32)


def signed_correction(y_codes: jax.Array, x_codes: jax.Array,
                      w_codes: jax.Array | None = None, *, w_offset: int,
                      x_zero_point: jax.Array,
                      sum_w: jax.Array | None = None,
                      k: int | None = None) -> jax.Array:
    """Digital correction generalizing Eq. 7 to affine activations.

    With X = s_x (X̃ − z) and W = s_w (W̃ − o):
      Σ X W / (s_x s_w) = Σ X̃ W̃ − o Σ X̃ − z Σ W̃ + o z K
    The Σ X̃ term is the paper's shared adder tree; Σ W̃ is precomputable at
    weight-load time — pass it as `sum_w` (with the logical reduction
    length `k`) when the stored codes are not materialized, e.g. the
    engine's nibble-packed weight path. All exact integer arithmetic — no
    analog error.
    """
    if sum_w is None:
        sum_w = jnp.sum(w_codes, axis=-2)                   # [..., M]
    if k is None:
        k = x_codes.shape[-1]
    sum_x = jnp.sum(x_codes, axis=-1, keepdims=True)       # [..., 1]
    return (y_codes - w_offset * sum_x - x_zero_point * sum_w
            + w_offset * x_zero_point * k)
