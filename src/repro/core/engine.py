"""Unified CIM execution engine: one backend registry for every datapath.

The paper's central claim (§III-A) is that ONE set of in-array MOM
capacitors serves every pipeline stage in situ — DAC charge loading, the
analog MAC, the 8:4:2:1 shift-and-add, and TD-ADC sampling — instead of a
per-stage datapath. This module is the software mirror of that claim: every
layer-level matmul (`cim_matmul`, `cim_matmul_prequant`, `cim_matmul_ste`)
funnels through a single `execute_mvm` entry point that owns backend
selection, reduction padding, the grouped MVM, the Eq. 7 digital correction
and dequantization. Backends only differ in how the DAC→MAC→ADC core is
evaluated:

  backend          paper datapath stage it models                 runs on
  ---------------  ---------------------------------------------  ---------
  "einsum"         whole [.., G, M] pre-ADC charge tensor at       any; small
                   once: C-DAC drive + per-group MAC line, then    layers /
                   one vectorized ADC transfer (supports the       tests; all
                   stochastic NOISY/FULL converter models)         schemes
  "scan"           group-sequential partial-sum accumulation       any; large
                   (§II-A "accumulated across macros when          layers,
                   K > N") with O(M) live memory                   BP scheme
  "pallas"         fused TPU kernel: per-group MAC + ADC applied   TPU (or
                   in VMEM registers, never spilling pre-ADC       interpret
                   partials to HBM — the in-situ capacitor reuse   mode on
                   made literal                                    CPU)
  "pallas_packed"  same, with weights stored as nibble pairs       TPU (or
                   (two u4 codes per byte) and unpacked in VMEM    interpret)
                   — the TPU analogue of the paper's 559 Kb/mm²
                   4-bit SRAM storage density
  "pallas_noisy"   stochastic fused kernel: the NOISY/FULL         TPU (or
                   TD-ADC transfer (thermal σ + INL instance)      interpret)
                   with per-conversion noise drawn IN VMEM from
                   a counter-based PRNG — PVT/QAT noise studies
                   at fused-kernel throughput
  "pallas_noisy_packed"  stochastic + nibble-packed weights; the   TPU (or
                   noise draw is independent of the container,     interpret)
                   so it is bit-identical to pallas_noisy under
                   the same seed

The digital epilogue (Eq. 7 offset/zero-point correction, × s_x·s_w
dequantization) is shared by all backends, exactly as the paper's adder
tree + digital shift-and-add is shared by all schemes.

noise_seed semantics
--------------------
`CIMConfig.noise_seed` (or the `noise_seed=` override on `execute_mvm`)
names one stochastic-instance of the converter chain. It is the ONLY way to
reach the fused stochastic kernels through `backend="auto"`:

  * auto + BP + NOISY/FULL + noise_seed set → "pallas_noisy[_packed]";
    without a seed the jnp backends (einsum, or scan for large layers) run,
    drawing noise from the optional `key` argument exactly as before.
  * The same seed is bit-reproducible: outputs are a pure function of
    (operands, config, noise_seed, inl_seed) in BOTH compiled and interpret
    mode — the kernel PRNG is counter-based (see kernels/cim_mvm.py), not
    the hardware RNG. Corollary: two same-shaped MVMs under one
    (noise_seed, inl_seed) draw the SAME noise realization; thread a
    distinct inl_seed per layer/step (the Fig. 18 instance knob) when a
    study needs decorrelated conversions across calls.
  * jnp backends given a noise_seed (and no explicit key) derive
    key = PRNGKey(noise_seed), so einsum/scan runs are seeded-reproducible
    too; the jnp and fused DRAWS differ (different PRNGs) but agree in
    distribution — the engine tests pin mean/variance agreement.

per-channel weight scales
-------------------------
`s_w` may be per-matrix (scalar / [..., 1, 1]) or per-output-channel
([..., 1, M], emitted by `quantize_weight_offline` under
`WeightQuantConfig.per_channel`). The Eq. 7 integer correction is
scale-free, so per-channel dequant is exactly `y_int · s_x · s_w[..., 0, :]`
— broadcast over the M axis after the correction. `PackedCodes` can carry
its channel scales (`scale` field) so the packed wire format stays
self-describing.

mesh-native dispatch
--------------------
A bare `pallas_call` cannot be GSPMD-partitioned, so when a mesh is active
(`parallel.sharding.get_mesh()`) every pallas backend routes through
`parallel.sharding.shard_map`: the contraction axis splits over "data"
(each shard is its own bank of macros — the paper's Sec. V multi-macro
tiling), output channels over "model", and the partial MVMs are psum'd
AFTER the in-kernel ADC transfer + per-shard Eq. 7 correction, so the
analog semantics per shard match the single-device kernel exactly. The
stochastic kernels salt their traced seed with the shard's
`jax.lax.axis_index` (see `kernels.cim_mvm.salt_seed`), so shards draw
decorrelated converter instances; the salt is 0 on a 1-device mesh, making
that call bit-identical to the unsharded kernel. Callers already running
per-shard (inside a repo shard_map, e.g. the MoE expert-parallel region)
are detected via `sharding.in_shard_context()` and get the plain kernel.

`REPRO_FORCE_JNP=1` in the environment forces `backend="auto"` to resolve
to the jnp backends only (einsum/scan) — the escape hatch for environments
where interpret-mode Pallas is unavailable; explicit backend names are
honored unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Callable, Protocol

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.parallel import sharding

from .adc import adc_quantize
from .macro import MacroConfig, Scheme, SimLevel
from .schemes import cim_mvm_codes, pad_and_group, signed_correction


# ---------------------------------------------------------------------------
# weight containers
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedCodes:
    """Nibble-packed stored weight codes: two u4 codes per uint8 byte.

    data [..., ceil(K/2), M] uint8 (row 2i low nibble, 2i+1 high); `k` is
    the logical reduction length before pack-padding. This is the at-rest /
    HBM format — 4 bits per weight, like the SRAM array itself.

    `scale` optionally carries the dequantization scale(s) alongside the
    codes — per-matrix ([..., 1, 1] / scalar) or per-output-channel
    ([..., 1, M]) — making the container self-describing: `execute_mvm`
    falls back to it when no explicit `s_w` is supplied.
    """

    data: jax.Array
    k: int
    scale: jax.Array | None = None

    def tree_flatten(self):
        return (self.data, self.scale), self.k

    @classmethod
    def tree_unflatten(cls, k, children):
        return cls(children[0], k, children[1])

    @property
    def n_cols(self) -> int:
        return self.data.shape[-1]


def unpack(weights: PackedCodes) -> jax.Array:
    """PackedCodes → dense f32 codes [..., K, M] (drops pack-padding)."""
    from repro.kernels.ops import unpack_codes
    return unpack_codes(weights.data, weights.k)


# ---------------------------------------------------------------------------
# backend protocol + registry
# ---------------------------------------------------------------------------
class CIMBackend(Protocol):
    """Evaluates ŷ ≈ Σ_g ADC(Σ_{i∈g} X̃ W̃) in integer-MAC units.

    x_codes [..., K] unsigned DAC codes; weights are dense codes [K, M]
    (or PackedCodes for packed-capable backends). Returns float32 [..., M].
    Stochastic draws come from `key` (jnp backends) or `noise_seed` (the
    fused stochastic kernels); deterministic backends ignore both.
    """

    def __call__(self, x_codes: jax.Array, weights, cfg: MacroConfig, *,
                 key: jax.Array | None, inl_seed: int,
                 noise_seed=None) -> jax.Array: ...


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    fn: Callable
    schemes: frozenset          # schemes the backend implements
    sim_levels: frozenset       # converter fidelities it can model
    packed: bool = False        # consumes PackedCodes natively


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(name: str, *, schemes, sim_levels, packed: bool = False):
    """Register a CIMBackend under `name` (decorator)."""
    def deco(fn):
        _REGISTRY[name] = BackendSpec(name, fn, frozenset(schemes),
                                      frozenset(sim_levels), packed)
        return fn
    return deco


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown CIM backend {name!r}; "
                         f"registered: {sorted(_REGISTRY)}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


_ALL_SCHEMES = (Scheme.BP, Scheme.WBS, Scheme.BS)
_ALL_LEVELS = (SimLevel.IDEAL, SimLevel.NOISY, SimLevel.FULL)


@register_backend("einsum", schemes=_ALL_SCHEMES, sim_levels=_ALL_LEVELS)
def _einsum_backend(x_codes, w_codes, cfg: MacroConfig, *, key=None,
                    inl_seed=0, noise_seed=None):
    del noise_seed  # jnp backends draw from `key` (derived in execute_mvm)
    return cim_mvm_codes(x_codes, w_codes, cfg, key=key, inl_seed=inl_seed)


@register_backend("scan", schemes=_ALL_SCHEMES, sim_levels=_ALL_LEVELS)
def _scan_backend(x_codes, w_codes, cfg: MacroConfig, *, key=None,
                  inl_seed=0, noise_seed=None):
    del noise_seed
    """Group-sequential BP MVM: identical math to schemes.bp_mvm, O(M) live
    memory. WBS/BS run their own per-bit-plane loops on the einsum path (BP
    is the paper's deployed scheme), so non-BP requests fall through.
    """
    if cfg.scheme != Scheme.BP:
        return _einsum_backend(x_codes, w_codes, cfg, key=key,
                               inl_seed=inl_seed)
    xg, g = pad_and_group(x_codes, cfg.n_rows)          # [..., G, N]
    wg, _ = pad_and_group(w_codes, cfg.n_rows, axis=0)  # [G, N, M]
    xg = jnp.moveaxis(xg, -2, 0)                        # [G, ..., N]
    keys = (jax.random.split(key, g) if key is not None
            else jnp.zeros((g, 2), dtype=jnp.uint32))

    def body(acc, operands):
        xs, ws, ks = operands
        v = jnp.einsum("...n,nm->...m", xs, ws,
                       preferred_element_type=jnp.float32)
        kk = ks if key is not None else None
        q = adc_quantize(v, cfg, key=kk, inl_seed=inl_seed)
        return acc + q, None

    out_shape = x_codes.shape[:-1] + (w_codes.shape[-1],)
    acc0 = jnp.zeros(out_shape, dtype=jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xg, wg, keys))
    return acc


# pallas_call has no JVP/VJP rule, but `backend="auto"` must keep
# cim_matmul differentiable (PTQ calibration / sensitivity sweeps grad
# through the analog pipeline without the STE wrapper). Forward runs the
# fused kernel; backward is the VJP of the numerically-identical einsum
# pipeline (IDEAL transfer — same clip/round/LSB math).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _pallas_mvm(x_codes, w_codes, cfg: MacroConfig):
    from repro.kernels.ops import cim_mvm_pallas
    return cim_mvm_pallas(x_codes, w_codes, cfg)


def _pallas_mvm_fwd(x_codes, w_codes, cfg):
    return _pallas_mvm(x_codes, w_codes, cfg), (x_codes, w_codes)


def _pallas_mvm_bwd(cfg, res, g):
    x_codes, w_codes = res
    _, vjp = jax.vjp(lambda x, w: _einsum_backend(x, w, cfg), x_codes,
                     w_codes)
    return vjp(g)


_pallas_mvm.defvjp(_pallas_mvm_fwd, _pallas_mvm_bwd)


@register_backend("pallas", schemes=(Scheme.BP,), sim_levels=(SimLevel.IDEAL,))
def _pallas_backend(x_codes, w_codes, cfg: MacroConfig, *, key=None,
                    inl_seed=0, noise_seed=None):
    del key, inl_seed, noise_seed  # deterministic IDEAL transfer only
    return _pallas_mvm(x_codes, w_codes, cfg)


@register_backend("pallas_packed", schemes=(Scheme.BP,),
                  sim_levels=(SimLevel.IDEAL,), packed=True)
def _pallas_packed_backend(x_codes, weights: PackedCodes, cfg: MacroConfig, *,
                           key=None, inl_seed=0, noise_seed=None):
    del key, inl_seed, noise_seed
    return _packed_mvm(x_codes, weights.data, weights.k, cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _packed_mvm(x_codes, w_packed, k: int, cfg: MacroConfig):
    from repro.kernels.ops import cim_mvm_pallas_packed
    return cim_mvm_pallas_packed(x_codes, w_packed, cfg)


def _packed_mvm_fwd(x_codes, w_packed, k, cfg):
    return _packed_mvm(x_codes, w_packed, k, cfg), (x_codes, w_packed)


def _packed_mvm_bwd(k, cfg, res, g):
    # stored integer codes are not trainable; only the activation side
    # carries a cotangent (input-saliency style uses)
    x_codes, w_packed = res
    from repro.kernels.ops import unpack_codes
    w_codes = unpack_codes(w_packed, k)
    _, vjp = jax.vjp(lambda x: _einsum_backend(x, w_codes, cfg), x_codes)
    return vjp(g)[0], None


_packed_mvm.defvjp(_packed_mvm_fwd, _packed_mvm_bwd)


# ---------------------------------------------------------------------------
# stochastic fused backends (NOISY/FULL transfer, in-kernel PRNG)
# ---------------------------------------------------------------------------
def _resolve_noise_seed(noise_seed, key):
    """int32 scalar seed for the fused stochastic kernels.

    Prefers the explicit noise_seed (the reproducibility contract); falls
    back to folding the jnp PRNG key's bits when only `key` was supplied, so
    explicit backend="pallas_noisy" keeps working from the legacy key-based
    call sites.
    """
    if noise_seed is not None:
        return jnp.asarray(noise_seed, jnp.int32)
    if key is not None:
        kd = key
        if jnp.issubdtype(jnp.asarray(kd).dtype, jax.dtypes.prng_key):
            kd = jax.random.key_data(kd)
        return jnp.reshape(kd, (-1,))[-1].astype(jnp.int32)
    raise ValueError(
        "stochastic Pallas backend needs CIMConfig.noise_seed (or an "
        "explicit PRNG key) — at IDEAL sim level use pallas/pallas_packed")


# Like _pallas_mvm: the kernel has no VJP rule, but auto-selected backends
# must keep cim_matmul differentiable. Backward is the VJP of the einsum
# pipeline's deterministic STE transfer (key=None → no noise term; the
# noise enters additively pre-rounding, so its STE derivative is identity
# anyway).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _noisy_mvm(x_codes, w_codes, seed, cfg: MacroConfig, inl_seed: int):
    from repro.kernels.ops import cim_mvm_pallas_noisy
    return cim_mvm_pallas_noisy(x_codes, w_codes, cfg, noise_seed=seed,
                                inl_seed=inl_seed)


def _noisy_mvm_fwd(x_codes, w_codes, seed, cfg, inl_seed):
    return _noisy_mvm(x_codes, w_codes, seed, cfg, inl_seed), (x_codes,
                                                               w_codes)


def _noisy_mvm_bwd(cfg, inl_seed, res, g):
    x_codes, w_codes = res
    _, vjp = jax.vjp(lambda x, w: _einsum_backend(x, w, cfg,
                                                  inl_seed=inl_seed),
                     x_codes, w_codes)
    return (*vjp(g), None)


_noisy_mvm.defvjp(_noisy_mvm_fwd, _noisy_mvm_bwd)


@register_backend("pallas_noisy", schemes=(Scheme.BP,),
                  sim_levels=(SimLevel.NOISY, SimLevel.FULL))
def _pallas_noisy_backend(x_codes, w_codes, cfg: MacroConfig, *, key=None,
                          inl_seed=0, noise_seed=None):
    seed = _resolve_noise_seed(noise_seed, key)
    return _noisy_mvm(x_codes, w_codes, seed, cfg, inl_seed)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _noisy_packed_mvm(x_codes, w_packed, seed, k: int, cfg: MacroConfig,
                      inl_seed: int):
    from repro.kernels.ops import cim_mvm_pallas_noisy_packed
    return cim_mvm_pallas_noisy_packed(x_codes, w_packed, cfg,
                                       noise_seed=seed, inl_seed=inl_seed)


def _noisy_packed_mvm_fwd(x_codes, w_packed, seed, k, cfg, inl_seed):
    return (_noisy_packed_mvm(x_codes, w_packed, seed, k, cfg, inl_seed),
            (x_codes, w_packed))


def _noisy_packed_mvm_bwd(k, cfg, inl_seed, res, g):
    # stored codes carry no cotangent (see _packed_mvm_bwd)
    x_codes, w_packed = res
    from repro.kernels.ops import unpack_codes
    w_codes = unpack_codes(w_packed, k)
    _, vjp = jax.vjp(lambda x: _einsum_backend(x, w_codes, cfg,
                                               inl_seed=inl_seed), x_codes)
    return vjp(g)[0], None, None


_noisy_packed_mvm.defvjp(_noisy_packed_mvm_fwd, _noisy_packed_mvm_bwd)


@register_backend("pallas_noisy_packed", schemes=(Scheme.BP,),
                  sim_levels=(SimLevel.NOISY, SimLevel.FULL), packed=True)
def _pallas_noisy_packed_backend(x_codes, weights: PackedCodes,
                                 cfg: MacroConfig, *, key=None, inl_seed=0,
                                 noise_seed=None):
    seed = _resolve_noise_seed(noise_seed, key)
    return _noisy_packed_mvm(x_codes, weights.data, seed, weights.k, cfg,
                             inl_seed)


# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------
# Materializing the [rows, G, M] pre-ADC tensor beyond this switches the
# jnp path from einsum to the group-sequential scan.
_EINSUM_BYTES_CEILING = 64 << 20


def _force_jnp() -> bool:
    """REPRO_FORCE_JNP=1: auto-selection never picks a Pallas kernel — the
    escape hatch for environments without interpret-mode Pallas support.
    Read at trace time; explicit backend names bypass it."""
    return os.environ.get("REPRO_FORCE_JNP", "").strip().lower() \
        in ("1", "true", "yes")


def choose_backend(cfg, x_codes: jax.Array, weights) -> str:
    """Resolve cfg.backend ("auto" or explicit) to a registered backend name.

    Auto policy (see also the scheme × sim-level matrix in ROADMAP.md):
      * IDEAL + BP → the fused Pallas kernel — "pallas_packed" when the
        weights are nibble-packed, else "pallas" (interpret mode executes
        the same kernel body on CPU, keeping tests honest);
      * NOISY/FULL + BP with a noise_seed → the fused stochastic kernel
        ("pallas_noisy" / "pallas_noisy_packed") — on a sharded mesh too:
        execute_mvm wraps the kernel in shard_map (see _sharded_mvm), so
        auto no longer needs to demote to scan there;
      * otherwise (no seed, WBS/BS baselines, REPRO_FORCE_JNP=1) → jnp
        backends, scanning the reduction groups once the pre-ADC tensor
        would exceed ~64 MB (the escape hatch is unchanged under a mesh —
        the bound is on the global pre-ADC tensor).

    `cfg` is the layer-level CIMConfig (duck-typed: .backend, .macro and
    optionally .noise_seed).
    """
    macro: MacroConfig = cfg.macro
    packed = isinstance(weights, PackedCodes)
    if cfg.backend != "auto":
        return get_backend(cfg.backend).name
    if macro.scheme == Scheme.BP and not _force_jnp():
        if macro.sim_level == SimLevel.IDEAL:
            return "pallas_packed" if packed else "pallas"
        if getattr(cfg, "noise_seed", None) is not None:
            return "pallas_noisy_packed" if packed else "pallas_noisy"
    k = weights.k if packed else weights.shape[-2]
    m = weights.n_cols if packed else weights.shape[-1]
    groups = -(-k // macro.n_rows)
    rows = math.prod(x_codes.shape[:-1]) if x_codes.ndim > 1 else 1
    big = rows * groups * m * 4 > _EINSUM_BYTES_CEILING
    return "scan" if (big and macro.scheme == Scheme.BP) else "einsum"


# ---------------------------------------------------------------------------
# mesh-native dispatch: shard_map-wrapped fused kernels
# ---------------------------------------------------------------------------
def _under_vmap(*arrays) -> bool:
    """True when any operand is a vmap batch tracer — shard_map cannot nest
    under vmap, so the engine falls back to the plain per-call kernel (the
    pre-mesh behaviour) there."""
    try:
        from jax.interpreters.batching import BatchTracer
    except ImportError:  # pragma: no cover - future jax reorganisations
        return False
    return any(isinstance(a, BatchTracer) for a in arrays)


def _sharded_mvm(spec: BackendSpec, x_codes, weights, cfg, *, key, inl_seed,
                 noise_seed, x_zero_point):
    """One MVM on the active mesh: per-shard fused kernels under shard_map.

    The software mirror of the paper's Sec. V multi-macro tiling: the
    contraction axis is split over the "data" mesh axis — each shard is its
    own bank of macros, evaluating the DAC→MAC→ADC transfer (and, for the
    stochastic backends, drawing ITS OWN converter noise) entirely locally —
    and the partial MVMs are `psum`'d only AFTER the in-kernel ADC transfer
    and the per-shard Eq. 7 correction, so per-shard analog semantics are
    exactly the single-device kernel's. Output channels split over "model",
    the leading activation dim over the batch axes (see sharding.mvm_plan).

    Seed contract: the traced kernel seed is salted with the shard's linear
    `jax.lax.axis_index` through `kernels.cim_mvm.salt_seed`, so shards draw
    decorrelated converter instances (Fig. 18's instance spread, one
    instance per macro bank). The salt is 0 on a 1-device mesh — that call
    is bit-identical to the unsharded kernel. Composes with the static
    inl_seed salt (per-layer/per-step decorrelation) unchanged.

    Returns the GLOBAL Eq. 7-corrected integer output [..., M]; dequant
    stays in execute_mvm. Every per-shard correction term is a sum over
    local reduction rows, so the psum over contraction shards rebuilds the
    full correction; only the o·z·K constant is added once, outside.
    """
    from repro.kernels.ops import packed_col_sums, salt_seed
    macro: MacroConfig = cfg.macro
    mesh = sharding.get_mesh()
    packed = isinstance(weights, PackedCodes)
    stochastic = SimLevel.IDEAL not in spec.sim_levels
    data = weights.data if packed else weights.astype(jnp.float32)
    k_logical = weights.k if packed else data.shape[-2]
    m_cols = data.shape[-1]
    plan = sharding.mvm_plan(x_codes.shape, k_logical, m_cols,
                             k_unit=2 if packed else 1)
    n_ctr = math.prod(mesh.shape[a] for a in plan.ctr_axes) \
        if plan.ctr_axes else 1
    k_local = k_logical // n_ctr
    seed = _resolve_noise_seed(noise_seed, key) if stochastic \
        else jnp.zeros((), jnp.int32)
    zp = jnp.asarray(x_zero_point, jnp.float32)
    w_offset = cfg.weight.offset

    # Only axes that actually partition this MVM may enter the seed salt:
    # two shards along them hold different coordinates or different macro
    # groups, so each needs its own PRNG stream. Shards along an UNUSED
    # mesh axis compute the identical replicated problem — salting those
    # would make "replicated" outputs differ per device (out_spec lies,
    # check_vma=False would hide it).
    salt_axes = tuple(a for a in mesh.axis_names
                      if a in plan.ctr_axes + plan.row_axes + plan.col_axes)

    def shard_fn(x_l, w_l, zp_l, seed_l):
        idx = jnp.zeros((), jnp.int32)
        for a in salt_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a).astype(jnp.int32)
        weights_l = PackedCodes(w_l, k_local) if packed else w_l
        seed_shard = salt_seed(seed_l, idx) if stochastic else None
        y_codes = spec.fn(x_l, weights_l, macro, key=None, inl_seed=inl_seed,
                          noise_seed=seed_shard)
        sum_w = packed_col_sums(w_l) if packed else jnp.sum(w_l, axis=-2)
        y_int = signed_correction(y_codes, x_l, None, w_offset=w_offset,
                                  x_zero_point=zp_l, sum_w=sum_w, k=0)
        if plan.ctr_axes:
            y_int = jax.lax.psum(y_int, plan.ctr_axes)
        return y_int

    y_int = sharding.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(plan.x_spec(x_codes.ndim), plan.w_spec(),
                  PartitionSpec(*([None] * zp.ndim)), PartitionSpec()),
        out_specs=plan.out_spec(x_codes.ndim),
        check_vma=False,
    )(x_codes, data, zp, seed)
    return y_int + w_offset * zp * k_logical


# ---------------------------------------------------------------------------
# the single entry point
# ---------------------------------------------------------------------------
_ENERGY_CACHE: dict = {}    # (macro, k) -> e_mvm_j, see _record_dispatch


def _record_dispatch(name: str, x_codes, weights, macro) -> None:
    """Observability hook: count the backend pick and accumulate the
    paper-model CIM energy for this MVM under the active PR-9 site name.

    Runs at jax TRACE time (execute_mvm executes Python once per compiled
    shape under jit), so KERNEL_COUNTERS records traced calls — one per
    compilation, not one per step; see telemetry.KernelCounters. Energy is
    Eq. 4 per K-deep dot product (energy.mvm_energy) times the traced
    call's dot count (batch rows x output columns)."""
    from repro.core.quant import current_site
    from repro.runtime.telemetry import KERNEL_COUNTERS
    KERNEL_COUNTERS.count_backend(name)
    if isinstance(weights, PackedCodes):
        k, m = weights.k, int(weights.data.shape[-1])
    else:
        k, m = int(weights.shape[-2]), int(weights.shape[-1])
    rows = 1
    for d in x_codes.shape[:-1]:
        rows *= int(d)
    key = (macro, k)
    e_dot = _ENERGY_CACHE.get(key)
    if e_dot is None:
        try:
            from repro.core.energy import mvm_energy
            e_dot = mvm_energy(macro, k).e_mvm_j
        except Exception:
            e_dot = 0.0   # energy model inapplicable — still count dots
        _ENERGY_CACHE[key] = e_dot
    KERNEL_COUNTERS.add_site_energy(current_site() or "<unsited>",
                                    e_dot * rows * m, rows * m)


def execute_mvm(x_codes: jax.Array, weights, cfg, *,
                s_x: jax.Array, s_w: jax.Array | None, x_zero_point: jax.Array,
                key: jax.Array | None = None, inl_seed: int = 0,
                backend: str | None = None,
                noise_seed=None) -> jax.Array:
    """Run one MVM through the full simulated datapath and dequantize.

    x_codes [..., K] unsigned DAC codes; weights are dense stored codes
    [K, M] (float32 / int8 container) or PackedCodes. `cfg` is the
    layer-level CIMConfig (macro + quantizer configs). Owns: backend
    selection, reduction padding (inside the backends — zero codes are
    unselected SRAM rows), the grouped MVM, the Eq. 7 signed/affine
    correction, and the × s_x·s_w dequantization. Returns float32 [..., M].

    `s_w` may be per-matrix or per-output-channel ([..., 1, M]); pass None
    to use the scales a PackedCodes container carries. `noise_seed`
    overrides cfg.noise_seed for this call (see module docstring).
    """
    macro: MacroConfig = cfg.macro
    if noise_seed is None:
        noise_seed = getattr(cfg, "noise_seed", None)
    if macro.sim_level == SimLevel.IDEAL:
        key = None  # no stochastic terms at the ideal sim level
        noise_seed = None
    elif key is None and noise_seed is not None:
        # seeded reproducibility on the jnp backends too: einsum/scan given
        # only a noise_seed draw from the derived key (DCE'd when the fused
        # kernel runs — it consumes the integer seed directly). inl_seed is
        # folded in, mirroring the fused kernel's counter salt: repeated
        # same-shaped MVMs under one (noise_seed, inl_seed) reuse one noise
        # realization BY DESIGN (that is what bit-reproducibility means);
        # thread a distinct inl_seed per layer/step to decorrelate them.
        key = jax.random.fold_in(jax.random.PRNGKey(noise_seed), inl_seed)
    name = backend or choose_backend(cfg, x_codes, weights)
    _record_dispatch(name, x_codes, weights, macro)
    spec = get_backend(name)
    if macro.scheme not in spec.schemes:
        raise ValueError(f"backend {name!r} does not implement scheme "
                         f"{macro.scheme}; use einsum/scan")
    if macro.sim_level not in spec.sim_levels:
        if SimLevel.IDEAL in spec.sim_levels:
            raise ValueError(
                f"backend {name!r} is deterministic; sim level "
                f"{macro.sim_level} needs a stochastic backend "
                f"(einsum/scan/pallas_noisy)")
        raise ValueError(
            f"backend {name!r} models the stochastic converter chain only; "
            f"sim level {macro.sim_level} runs on pallas/pallas_packed or "
            f"the jnp backends")

    packed = isinstance(weights, PackedCodes)
    if s_w is None:
        s_w = weights.scale if packed else None
        if s_w is None:
            raise ValueError("execute_mvm needs s_w (or a PackedCodes "
                             "container carrying its scale)")
    # normalize the weight container to what the backend consumes
    if packed and not spec.packed:
        weights = unpack(weights)
        packed = False
    elif not packed and spec.packed:
        from repro.kernels.ops import pack_codes
        w_codes = weights.astype(jnp.float32)
        weights = PackedCodes(pack_codes(w_codes), w_codes.shape[-2])
        packed = True

    mesh = sharding.get_mesh()
    if (name.startswith("pallas") and mesh is not None
            and not sharding.in_shard_context()
            and not _under_vmap(x_codes,
                                weights.data if packed else weights)):
        # mesh-native dispatch: a bare pallas_call cannot be GSPMD-
        # partitioned, so under an active mesh the fused kernels run
        # per-shard inside shard_map (correction included — see
        # _sharded_mvm); already-per-shard callers (e.g. the MoE EP
        # shard_map) fall through to the plain kernel below.
        y_int = _sharded_mvm(spec, x_codes, weights, cfg, key=key,
                             inl_seed=inl_seed, noise_seed=noise_seed,
                             x_zero_point=x_zero_point)
    else:
        if packed:
            y_codes = spec.fn(x_codes, weights, macro, key=key,
                              inl_seed=inl_seed, noise_seed=noise_seed)
            from repro.kernels.ops import packed_col_sums
            sum_w = packed_col_sums(weights.data)
            k = weights.k
        else:
            w_codes = weights.astype(jnp.float32)
            y_codes = spec.fn(x_codes, w_codes, macro, key=key,
                              inl_seed=inl_seed, noise_seed=noise_seed)
            sum_w = jnp.sum(w_codes, axis=-2)
            k = w_codes.shape[-2]
        y_int = signed_correction(y_codes, x_codes, None,
                                  w_offset=cfg.weight.offset,
                                  x_zero_point=x_zero_point, sum_w=sum_w,
                                  k=k)
    # Per-channel scales arrive broadcast-shaped against the stored codes
    # ([..., 1, M]); drop the reduction axis so they broadcast against the
    # [..., M] output instead (Eq. 7 is scale-free integer arithmetic, so
    # dequant is the only place the channel axis matters).
    s_w_out = s_w
    if cfg.weight.per_channel and getattr(s_w, "ndim", 0) >= 2:
        s_w_out = s_w[..., 0, :]
    return y_int * s_x * s_w_out
