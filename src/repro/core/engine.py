"""Unified CIM execution engine: one backend registry for every datapath.

The paper's central claim (§III-A) is that ONE set of in-array MOM
capacitors serves every pipeline stage in situ — DAC charge loading, the
analog MAC, the 8:4:2:1 shift-and-add, and TD-ADC sampling — instead of a
per-stage datapath. This module is the software mirror of that claim: every
layer-level matmul (`cim_matmul`, `cim_matmul_prequant`, `cim_matmul_ste`)
funnels through a single `execute_mvm` entry point that owns backend
selection, reduction padding, the grouped MVM, the Eq. 7 digital correction
and dequantization. Backends only differ in how the DAC→MAC→ADC core is
evaluated:

  backend          paper datapath stage it models                 runs on
  ---------------  ---------------------------------------------  ---------
  "einsum"         whole [.., G, M] pre-ADC charge tensor at       any; small
                   once: C-DAC drive + per-group MAC line, then    layers /
                   one vectorized ADC transfer (supports the       tests; all
                   stochastic NOISY/FULL converter models)         schemes
  "scan"           group-sequential partial-sum accumulation       any; large
                   (§II-A "accumulated across macros when          layers,
                   K > N") with O(M) live memory                   BP scheme
  "pallas"         fused TPU kernel: per-group MAC + ADC applied   TPU (or
                   in VMEM registers, never spilling pre-ADC       interpret
                   partials to HBM — the in-situ capacitor reuse   mode on
                   made literal                                    CPU)
  "pallas_packed"  same, with weights stored as nibble pairs       TPU (or
                   (two u4 codes per byte) and unpacked in VMEM    interpret)
                   — the TPU analogue of the paper's 559 Kb/mm²
                   4-bit SRAM storage density

The digital epilogue (Eq. 7 offset/zero-point correction, × s_x·s_w
dequantization) is shared by all backends, exactly as the paper's adder
tree + digital shift-and-add is shared by all schemes.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Protocol

import jax
import jax.numpy as jnp

from .adc import adc_quantize
from .macro import MacroConfig, Scheme, SimLevel
from .schemes import cim_mvm_codes, pad_and_group, signed_correction


# ---------------------------------------------------------------------------
# weight containers
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedCodes:
    """Nibble-packed stored weight codes: two u4 codes per uint8 byte.

    data [..., ceil(K/2), M] uint8 (row 2i low nibble, 2i+1 high); `k` is
    the logical reduction length before pack-padding. This is the at-rest /
    HBM format — 4 bits per weight, like the SRAM array itself.
    """

    data: jax.Array
    k: int

    def tree_flatten(self):
        return (self.data,), self.k

    @classmethod
    def tree_unflatten(cls, k, children):
        return cls(children[0], k)

    @property
    def n_cols(self) -> int:
        return self.data.shape[-1]


def unpack(weights: PackedCodes) -> jax.Array:
    """PackedCodes → dense f32 codes [..., K, M] (drops pack-padding)."""
    from repro.kernels.ops import unpack_codes
    return unpack_codes(weights.data, weights.k)


# ---------------------------------------------------------------------------
# backend protocol + registry
# ---------------------------------------------------------------------------
class CIMBackend(Protocol):
    """Evaluates ŷ ≈ Σ_g ADC(Σ_{i∈g} X̃ W̃) in integer-MAC units.

    x_codes [..., K] unsigned DAC codes; weights are dense codes [K, M]
    (or PackedCodes for packed-capable backends). Returns float32 [..., M].
    """

    def __call__(self, x_codes: jax.Array, weights, cfg: MacroConfig, *,
                 key: jax.Array | None, inl_seed: int) -> jax.Array: ...


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    fn: Callable
    schemes: frozenset          # schemes the backend implements
    sim_levels: frozenset       # converter fidelities it can model
    packed: bool = False        # consumes PackedCodes natively


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(name: str, *, schemes, sim_levels, packed: bool = False):
    """Register a CIMBackend under `name` (decorator)."""
    def deco(fn):
        _REGISTRY[name] = BackendSpec(name, fn, frozenset(schemes),
                                      frozenset(sim_levels), packed)
        return fn
    return deco


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown CIM backend {name!r}; "
                         f"registered: {sorted(_REGISTRY)}") from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


_ALL_SCHEMES = (Scheme.BP, Scheme.WBS, Scheme.BS)
_ALL_LEVELS = (SimLevel.IDEAL, SimLevel.NOISY, SimLevel.FULL)


@register_backend("einsum", schemes=_ALL_SCHEMES, sim_levels=_ALL_LEVELS)
def _einsum_backend(x_codes, w_codes, cfg: MacroConfig, *, key=None,
                    inl_seed=0):
    return cim_mvm_codes(x_codes, w_codes, cfg, key=key, inl_seed=inl_seed)


@register_backend("scan", schemes=_ALL_SCHEMES, sim_levels=_ALL_LEVELS)
def _scan_backend(x_codes, w_codes, cfg: MacroConfig, *, key=None,
                  inl_seed=0):
    """Group-sequential BP MVM: identical math to schemes.bp_mvm, O(M) live
    memory. WBS/BS run their own per-bit-plane loops on the einsum path (BP
    is the paper's deployed scheme), so non-BP requests fall through.
    """
    if cfg.scheme != Scheme.BP:
        return _einsum_backend(x_codes, w_codes, cfg, key=key,
                               inl_seed=inl_seed)
    xg, g = pad_and_group(x_codes, cfg.n_rows)          # [..., G, N]
    wg, _ = pad_and_group(w_codes, cfg.n_rows, axis=0)  # [G, N, M]
    xg = jnp.moveaxis(xg, -2, 0)                        # [G, ..., N]
    keys = (jax.random.split(key, g) if key is not None
            else jnp.zeros((g, 2), dtype=jnp.uint32))

    def body(acc, operands):
        xs, ws, ks = operands
        v = jnp.einsum("...n,nm->...m", xs, ws,
                       preferred_element_type=jnp.float32)
        kk = ks if key is not None else None
        q = adc_quantize(v, cfg, key=kk, inl_seed=inl_seed)
        return acc + q, None

    out_shape = x_codes.shape[:-1] + (w_codes.shape[-1],)
    acc0 = jnp.zeros(out_shape, dtype=jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xg, wg, keys))
    return acc


# pallas_call has no JVP/VJP rule, but `backend="auto"` must keep
# cim_matmul differentiable (PTQ calibration / sensitivity sweeps grad
# through the analog pipeline without the STE wrapper). Forward runs the
# fused kernel; backward is the VJP of the numerically-identical einsum
# pipeline (IDEAL transfer — same clip/round/LSB math).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _pallas_mvm(x_codes, w_codes, cfg: MacroConfig):
    from repro.kernels.ops import cim_mvm_pallas
    return cim_mvm_pallas(x_codes, w_codes, cfg)


def _pallas_mvm_fwd(x_codes, w_codes, cfg):
    return _pallas_mvm(x_codes, w_codes, cfg), (x_codes, w_codes)


def _pallas_mvm_bwd(cfg, res, g):
    x_codes, w_codes = res
    _, vjp = jax.vjp(lambda x, w: _einsum_backend(x, w, cfg), x_codes,
                     w_codes)
    return vjp(g)


_pallas_mvm.defvjp(_pallas_mvm_fwd, _pallas_mvm_bwd)


@register_backend("pallas", schemes=(Scheme.BP,), sim_levels=(SimLevel.IDEAL,))
def _pallas_backend(x_codes, w_codes, cfg: MacroConfig, *, key=None,
                    inl_seed=0):
    del key, inl_seed  # deterministic IDEAL transfer only
    return _pallas_mvm(x_codes, w_codes, cfg)


@register_backend("pallas_packed", schemes=(Scheme.BP,),
                  sim_levels=(SimLevel.IDEAL,), packed=True)
def _pallas_packed_backend(x_codes, weights: PackedCodes, cfg: MacroConfig, *,
                           key=None, inl_seed=0):
    del key, inl_seed
    return _packed_mvm(x_codes, weights.data, weights.k, cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _packed_mvm(x_codes, w_packed, k: int, cfg: MacroConfig):
    from repro.kernels.ops import cim_mvm_pallas_packed
    return cim_mvm_pallas_packed(x_codes, w_packed, cfg)


def _packed_mvm_fwd(x_codes, w_packed, k, cfg):
    return _packed_mvm(x_codes, w_packed, k, cfg), (x_codes, w_packed)


def _packed_mvm_bwd(k, cfg, res, g):
    # stored integer codes are not trainable; only the activation side
    # carries a cotangent (input-saliency style uses)
    x_codes, w_packed = res
    from repro.kernels.ops import unpack_codes
    w_codes = unpack_codes(w_packed, k)
    _, vjp = jax.vjp(lambda x: _einsum_backend(x, w_codes, cfg), x_codes)
    return vjp(g)[0], None


_packed_mvm.defvjp(_packed_mvm_fwd, _packed_mvm_bwd)


# ---------------------------------------------------------------------------
# backend selection
# ---------------------------------------------------------------------------
# Materializing the [rows, G, M] pre-ADC tensor beyond this switches the
# jnp path from einsum to the group-sequential scan.
_EINSUM_BYTES_CEILING = 64 << 20


def choose_backend(cfg, x_codes: jax.Array, weights) -> str:
    """Resolve cfg.backend ("auto" or explicit) to a registered backend name.

    Auto policy (see also the scheme × sim-level matrix in ROADMAP.md):
      * IDEAL + BP → the fused Pallas kernel — "pallas_packed" when the
        weights are nibble-packed, else "pallas" (interpret mode executes
        the same kernel body on CPU, keeping tests honest);
      * stochastic sim levels or WBS/BS baselines → jnp backends, scanning
        the reduction groups once the pre-ADC tensor would exceed ~64 MB.

    `cfg` is the layer-level CIMConfig (duck-typed: .backend, .macro).
    """
    macro: MacroConfig = cfg.macro
    packed = isinstance(weights, PackedCodes)
    if cfg.backend != "auto":
        return get_backend(cfg.backend).name
    if macro.sim_level == SimLevel.IDEAL and macro.scheme == Scheme.BP:
        return "pallas_packed" if packed else "pallas"
    k = weights.k if packed else weights.shape[-2]
    m = weights.n_cols if packed else weights.shape[-1]
    groups = -(-k // macro.n_rows)
    rows = math.prod(x_codes.shape[:-1]) if x_codes.ndim > 1 else 1
    big = rows * groups * m * 4 > _EINSUM_BYTES_CEILING
    return "scan" if (big and macro.scheme == Scheme.BP) else "einsum"


# ---------------------------------------------------------------------------
# the single entry point
# ---------------------------------------------------------------------------
def execute_mvm(x_codes: jax.Array, weights, cfg, *,
                s_x: jax.Array, s_w: jax.Array, x_zero_point: jax.Array,
                key: jax.Array | None = None, inl_seed: int = 0,
                backend: str | None = None) -> jax.Array:
    """Run one MVM through the full simulated datapath and dequantize.

    x_codes [..., K] unsigned DAC codes; weights are dense stored codes
    [K, M] (float32 / int8 container) or PackedCodes. `cfg` is the
    layer-level CIMConfig (macro + quantizer configs). Owns: backend
    selection, reduction padding (inside the backends — zero codes are
    unselected SRAM rows), the grouped MVM, the Eq. 7 signed/affine
    correction, and the × s_x·s_w dequantization. Returns float32 [..., M].
    """
    macro: MacroConfig = cfg.macro
    if macro.sim_level == SimLevel.IDEAL:
        key = None  # no stochastic terms at the ideal sim level
    name = backend or choose_backend(cfg, x_codes, weights)
    spec = get_backend(name)
    if macro.scheme not in spec.schemes:
        raise ValueError(f"backend {name!r} does not implement scheme "
                         f"{macro.scheme}; use einsum/scan")
    if macro.sim_level not in spec.sim_levels:
        raise ValueError(f"backend {name!r} is deterministic; sim level "
                         f"{macro.sim_level} needs a jnp backend")

    packed = isinstance(weights, PackedCodes)
    if packed and spec.packed:
        y_codes = spec.fn(x_codes, weights, macro, key=key, inl_seed=inl_seed)
        from repro.kernels.ops import packed_col_sums
        sum_w = packed_col_sums(weights.data)
        k = weights.k
    else:
        w_codes = unpack(weights) if packed else weights.astype(jnp.float32)
        if not packed and spec.packed:
            from repro.kernels.ops import pack_codes
            y_codes = spec.fn(x_codes, PackedCodes(pack_codes(w_codes),
                                                   w_codes.shape[-2]),
                              macro, key=key, inl_seed=inl_seed)
        else:
            y_codes = spec.fn(x_codes, w_codes, macro, key=key,
                              inl_seed=inl_seed)
        sum_w = jnp.sum(w_codes, axis=-2)
        k = w_codes.shape[-2]

    y_int = signed_correction(y_codes, x_codes, None,
                              w_offset=cfg.weight.offset,
                              x_zero_point=x_zero_point, sum_w=sum_w, k=k)
    s_w_out = jnp.reshape(s_w, (-1,)) if cfg.weight.per_channel else s_w
    return y_int * s_x * s_w_out
