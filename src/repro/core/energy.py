"""Macro energy / throughput / density model (paper Eq. 4, Fig. 21, Table I).

Eq. 4:
    E_MVM = (K/N) · (B_A/b_A) · ( (B_W/b_W) · E_ADC + B_W · N · E_MAC )

where (b_A, b_W) are the bits processed per analog pass:
    BP : (B_A, B_W)  — one ADC per group, all slices in one shot
    WBS: (B_A, 1)    — B_W serial passes, B_W ADC conversions
    BS : (1, 1)      — B_A·B_W passes/conversions

E_MAC is per (b_A-bit input × 1-bit weight) MAC and does NOT scale with b_A
because the C-DAC is driver-free (§II-A); the in-situ analog shift-and-add is
likewise ~free (§III-B).

Absolute calibration anchors (65 nm prototype, Fig. 21):
    40.2 TOPS/W @ 0.65 V and 18.6 TOPS/W @ 1.2 V for 4b×4b BP, N=144
    → E_MAC(0.65 V) solved below; energy ∝ V^1.26 fits both endpoints.
"""
from __future__ import annotations

import dataclasses

from .adc import (ADC_RATIO_E_ADC_OVER_N_E_MAC, ADC_RATIO_LEVELS,
                  DUAL_THRESHOLD_GATING)
from .macro import GEOMETRY, MacroConfig, OperatingPoint, Scheme

VOLT_REF = 0.65
# Fitted so that, with the ADC level de-rating at 0.65 V (362 → 256 levels,
# macro.effective_adc_levels), the model hits BOTH measured endpoints:
# 40.2 TOPS/W @ 0.65 V and 18.6 TOPS/W @ 1.2 V (Fig. 21).
_VOLT_EXP = 1.0075


def energy_voltage_scale(vdd: float) -> float:
    return (vdd / VOLT_REF) ** _VOLT_EXP


def _solve_e_mac_ref() -> float:
    """Solve E_MAC at 0.65 V from the 40.2 TOPS/W anchor.

    One BP group MVM: K = N = 144, ops = 2·N (MAC = 2 ops, 4b×4b counting):
        E_group = E_ADC + B_W·N·E_MAC,
        E_ADC   = ratio·N·E_MAC · (levels(0.65 V)/128) · (1 − gating)
        TOPS/W  = 2·144 / E_group = 40.2e12.

    Every ADC-side term is DERIVED from core.adc's measured constants and
    the macro's own level de-rating (362 → 256 effective levels at 0.65 V,
    macro.effective_adc_levels) — the single-source-of-truth contract the
    autotuner's (levels, vdd) sweep relies on: adc_energy_j and this anchor
    can no longer drift apart.
    """
    n = MacroConfig().n_rows
    levels_ref = MacroConfig(
        op=OperatingPoint(vdd=VOLT_REF)).effective_adc_levels()
    adc_factor = ADC_RATIO_E_ADC_OVER_N_E_MAC * n \
        * (levels_ref / ADC_RATIO_LEVELS) * (1.0 - DUAL_THRESHOLD_GATING)
    ops = 2.0 * n
    e_group_target = ops / 40.2e12
    return e_group_target / (adc_factor + 4.0 * n)


E_MAC_REF_J = _solve_e_mac_ref()


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    e_mvm_j: float          # energy of one K-deep, 1-output-column MVM
    e_adc_j: float
    e_mac_j: float
    n_adc_conversions: float
    tops_per_w: float       # at the op counting 1 MAC = 2 ops
    bitwise_tops_per_w: float


def scheme_bits(cfg: MacroConfig) -> tuple[int, int]:
    """(b_A, b_W) per analog pass for the configured scheme."""
    if cfg.scheme == Scheme.BP:
        return cfg.act_bits, cfg.weight_bits
    if cfg.scheme == Scheme.WBS:
        return cfg.act_bits, 1
    return 1, 1


def mvm_energy(cfg: MacroConfig, k: int, *, dual_threshold: bool = True) -> EnergyReport:
    """Eq. 4 for a K-deep dot product on one ADC column."""
    from .adc import adc_energy_j

    b_a, b_w = scheme_bits(cfg)
    groups = max(1, -(-k // cfg.n_rows))  # ceil(K/N): partial-sum macros
    vscale = energy_voltage_scale(cfg.op.vdd)
    e_mac = E_MAC_REF_J * vscale
    e_adc = adc_energy_j(cfg, dual_threshold=dual_threshold)

    passes_a = cfg.act_bits / b_a
    passes_w = cfg.weight_bits / b_w
    n_conv = groups * passes_a * passes_w
    e_mvm = groups * passes_a * (passes_w * e_adc
                                 + cfg.weight_bits * cfg.n_rows * e_mac)

    ops = 2.0 * groups * cfg.n_rows  # padded rows still switch
    tops_w = ops / e_mvm / 1e12
    return EnergyReport(
        e_mvm_j=e_mvm,
        e_adc_j=e_adc,
        e_mac_j=e_mac,
        n_adc_conversions=n_conv,
        tops_per_w=tops_w,
        bitwise_tops_per_w=tops_w * cfg.act_bits * cfg.weight_bits,
    )


def macro_throughput_gops(cfg: MacroConfig) -> float:
    """GOPS of one 8-group macro at the PVT clock (Fig. 21 / Table I).

    Per cycle each of the 8 MVM groups completes one N-row 4b×4b MVM
    (BP: single cycle; WBS/BS: divided by the serial pass count).
    """
    b_a, b_w = scheme_bits(cfg)
    passes = (cfg.act_bits / b_a) * (cfg.weight_bits / b_w)
    ops_per_cycle = GEOMETRY.mvm_groups * 2.0 * cfg.n_rows / passes
    return ops_per_cycle * cfg.clock_hz() / 1e9


def compute_density_tops_mm2(cfg: MacroConfig) -> float:
    return macro_throughput_gops(cfg) / 1e3 / GEOMETRY.area_mm2
