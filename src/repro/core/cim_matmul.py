"""Float-in / float-out CIM matmul: the layer-level entry point.

Pipeline (per Eq. 1/7 and §III-D end-to-end flow):

  1. activation quantization  — in-situ C-DAC codes X̃ (u4, affine)
  2. weight quantization      — offset-encoded stored codes W̃ (u4)
  3. grouped analog MAC + ADC — scheme-dependent (BP / WBS / BS)
  4. digital corrections      — Eq. 7 offset/zero-point terms (adder tree)
  5. dequantize               — × s_x s_w

Backends:
  "einsum" — materializes the [..., G, M] pre-ADC tensor (small layers, tests)
  "scan"   — lax.scan over the G reduction groups: O(M) live memory, used for
             large layers; numerically identical
  "pallas" — fused TPU kernel (kernels/cim_mvm.py): groups iterated in VMEM,
             ADC fused into the matmul epilogue — the TPU analogue of the
             paper's "in-situ" capacitor reuse (never spill pre-ADC partials
             to HBM)

Training uses `cim_matmul_ste`: forward value is the full analog pipeline,
backward is the float matmul (the paper's STE QAT, §II-B — BP needs only this
one quantization step, no bit-level GSTE).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from .adc import adc_quantize
from .macro import MacroConfig, Scheme, SimLevel
from .quant import (ActQuantConfig, WeightQuantConfig, act_scale,
                    quantize_act, quantize_weight, weight_scale)
from .schemes import cim_mvm_codes, pad_and_group, signed_correction


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """How (and whether) a model's matmuls run on the simulated macro."""

    enabled: bool = False
    macro: MacroConfig = dataclasses.field(default_factory=MacroConfig)
    act: ActQuantConfig = dataclasses.field(default_factory=ActQuantConfig)
    weight: WeightQuantConfig = dataclasses.field(default_factory=WeightQuantConfig)
    backend: Literal["auto", "einsum", "scan", "pallas"] = "auto"

    def with_scheme(self, scheme: Scheme) -> "CIMConfig":
        return dataclasses.replace(
            self, macro=dataclasses.replace(self.macro, scheme=scheme))


OFF = CIMConfig(enabled=False)
BP_IDEAL = CIMConfig(enabled=True)


def _choose_backend(cfg: CIMConfig, x: jax.Array, w: jax.Array) -> str:
    if cfg.backend != "auto":
        return cfg.backend
    import math
    k, m = w.shape[-2], w.shape[-1]
    groups = -(-k // cfg.macro.n_rows)
    rows = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
    # Materializing [rows, G, M] beyond ~64 MB → scan the groups instead.
    return "scan" if rows * groups * m * 4 > (64 << 20) else "einsum"


def _scan_grouped_mvm(x_codes: jax.Array, w_codes: jax.Array,
                      cfg: MacroConfig, key, inl_seed: int) -> jax.Array:
    """Group-sequential BP MVM: identical math to schemes.bp_mvm, O(M) memory.

    WBS/BS large-layer paths reuse this per bit-plane via schemes' loops, so
    only BP needs a dedicated scan (BP is the paper's deployed scheme).
    """
    assert cfg.scheme == Scheme.BP
    xg, g = pad_and_group(x_codes, cfg.n_rows)          # [..., G, N]
    wg, _ = pad_and_group(w_codes, cfg.n_rows, axis=0)  # [G, N, M]
    xg = jnp.moveaxis(xg, -2, 0)                        # [G, ..., N]
    keys = (jax.random.split(key, g) if key is not None
            else jnp.zeros((g, 2), dtype=jnp.uint32))

    def body(acc, operands):
        xs, ws, ks = operands
        v = jnp.einsum("...n,nm->...m", xs, ws,
                       preferred_element_type=jnp.float32)
        kk = ks if key is not None else None
        q = adc_quantize(v, cfg, key=kk, inl_seed=inl_seed)
        return acc + q, None

    out_shape = x_codes.shape[:-1] + (w_codes.shape[-1],)
    acc0 = jnp.zeros(out_shape, dtype=jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xg, wg, keys))
    return acc


def cim_matmul(x: jax.Array, w: jax.Array, cfg: CIMConfig, *,
               key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """Exact analog-CIM simulation of y = x @ w (no STE wrapper).

    x: [..., K] float; w: [K, M] float. Returns float32 [..., M].
    """
    if not cfg.enabled:
        return jnp.einsum("...k,km->...m", x, w)
    if cfg.macro.sim_level == SimLevel.IDEAL:
        key = None  # no stochastic terms at the ideal sim level

    s_x = act_scale(x, cfg.act)
    x_codes, zp = quantize_act(x, s_x, cfg.act)
    s_w = weight_scale(w, cfg.weight)
    w_codes = quantize_weight(w, s_w, cfg.weight)

    backend = _choose_backend(cfg, x, w)
    if backend == "pallas":
        from repro.kernels.ops import cim_mvm_pallas
        y_codes = cim_mvm_pallas(x_codes, w_codes, cfg.macro)
    elif backend == "scan" and cfg.macro.scheme == Scheme.BP:
        y_codes = _scan_grouped_mvm(x_codes, w_codes, cfg.macro, key, inl_seed)
    else:
        y_codes = cim_mvm_codes(x_codes, w_codes, cfg.macro, key=key,
                                inl_seed=inl_seed)

    y_int = signed_correction(y_codes, x_codes, w_codes,
                              w_offset=cfg.weight.offset, x_zero_point=zp)
    s_w_out = jnp.reshape(s_w, (-1,)) if cfg.weight.per_channel else s_w
    return y_int * s_x * s_w_out


def cim_matmul_prequant(x: jax.Array, w_codes: jax.Array, w_scale: jax.Array,
                        cfg: CIMConfig, *, key: jax.Array | None = None,
                        inl_seed: int = 0) -> jax.Array:
    """CIM matmul against OFFLINE-quantized weights (§Perf serving path).

    w_codes are the stored unsigned 4-bit codes in an int8 container —
    exactly what lives in the SRAM array. Halves weight HBM traffic vs
    quantize-on-the-fly from bf16 (and is the honest deployment flow: a CIM
    chip never sees float weights at inference).
    """
    if cfg.macro.sim_level == SimLevel.IDEAL:
        key = None
    s_x = act_scale(x, cfg.act)
    x_codes, zp = quantize_act(x, s_x, cfg.act)
    w_f = w_codes.astype(jnp.float32)

    backend = _choose_backend(cfg, x, w_f)
    if backend == "pallas":
        from repro.kernels.ops import cim_mvm_pallas
        y_codes = cim_mvm_pallas(x_codes, w_f, cfg.macro)
    elif backend == "scan" and cfg.macro.scheme == Scheme.BP:
        y_codes = _scan_grouped_mvm(x_codes, w_f, cfg.macro, key, inl_seed)
    else:
        y_codes = cim_mvm_codes(x_codes, w_f, cfg.macro, key=key,
                                inl_seed=inl_seed)
    y_int = signed_correction(y_codes, x_codes, w_f,
                              w_offset=cfg.weight.offset, x_zero_point=zp)
    s_w = jnp.reshape(w_scale, (-1,)) if cfg.weight.per_channel else w_scale
    return y_int * s_x * s_w


def quantize_weight_offline(w: jax.Array, cfg: CIMConfig):
    """bf16/f32 weight → (int8 stored codes, scale) for the prequant path.

    Scales are per-matrix: stacked-layer weights [L, ..., K, M] get one scale
    per leading index (broadcastable [L, ..., 1, 1]) so each layer's matrix
    quantizes against its own range.
    """
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=(-2, -1), keepdims=True)
    s_w = jnp.maximum(amax, 1e-8) / cfg.weight.qmax
    codes = quantize_weight(wf, s_w, cfg.weight)
    return codes.astype(jnp.int8), s_w.astype(jnp.float32)


def cim_matmul_ste(x: jax.Array, w: jax.Array, cfg: CIMConfig, *,
                   key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """CIM forward value with float-matmul gradients (STE residual trick).

    y = x@w + sg(cim(x, w) − x@w): forward evaluates to the analog pipeline,
    backward sees only d(x@w) — exactly the paper's BP QAT recipe (§II-B).
    """
    if not cfg.enabled:
        return jnp.einsum("...k,km->...m", x, w)
    y_float = jnp.einsum("...k,km->...m", x, w)
    y_cim = cim_matmul(jax.lax.stop_gradient(x), jax.lax.stop_gradient(w),
                       cfg, key=key, inl_seed=inl_seed)
    return y_float + jax.lax.stop_gradient(y_cim - y_float.astype(y_cim.dtype))
