"""Float-in / float-out CIM matmul: the layer-level entry point.

Pipeline (per Eq. 1/7 and §III-D end-to-end flow):

  1. activation quantization  — in-situ C-DAC codes X̃ (u4, affine)
  2. weight quantization      — offset-encoded stored codes W̃ (u4)
  3. grouped analog MAC + ADC — scheme-dependent (BP / WBS / BS)
  4. digital corrections      — Eq. 7 offset/zero-point terms (adder tree)
  5. dequantize               — × s_x s_w

Steps 3–5 are owned by `core.engine.execute_mvm` — the single execution
engine behind every entry point here. This module only quantizes operands
and forwards; backend dispatch (einsum / scan / pallas / pallas_packed,
`backend="auto"` selection) lives in the engine, see engine.py's
backend-to-datapath table.

Training uses `cim_matmul_ste`: a `jax.custom_vjp` whose forward is the full
analog pipeline and whose backward is the float matmul directly (the paper's
STE QAT, §II-B — BP needs only this one quantization step, no bit-level
GSTE). Serving uses `cim_matmul_prequant` against offline-quantized stored
codes — int8 containers or nibble-packed uint8 (`engine.PackedCodes`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from .engine import PackedCodes, execute_mvm
from .macro import MacroConfig, Scheme
from .quant import (ActQuantConfig, WeightQuantConfig, act_scale,
                    annotate_recorded_shape, current_site, quantize_act,
                    quantize_weight, recording_active, weight_scale)


@dataclasses.dataclass(frozen=True)
class SitePrecision:
    """Per-call-site precision override (one entry of a mixed-precision
    deployment manifest, analysis.precision_search).

    Hashable and frozen so it can ride CIMConfig — itself a jit static arg —
    inside the `site_overrides` tuple. Every field is optional; None keeps
    the uniform base config's value. Applied at trace time by
    `resolve_site_cfg` against the `quant.act_site` scope the models push
    (layer-index-free weight names), so under `scan_layers=True` — where all
    layers share one trace — each site still resolves a single constant
    config.
    """

    act_scale: float | None = None     # static DAC grid scale
    act_zero_point: float | None = None
    adc_levels: int | None = None      # per-site ADC resolution (energy knob)
    scheme: str | None = None          # "bp" | "wbs" | "bs" (macro.Scheme)
    per_channel: bool | None = None    # per-output-channel weight scales

    def apply(self, cfg: "CIMConfig") -> "CIMConfig":
        macro, act, weight = cfg.macro, cfg.act, cfg.weight
        if self.adc_levels is not None:
            macro = dataclasses.replace(macro, adc_levels=self.adc_levels)
        if self.scheme is not None:
            macro = dataclasses.replace(macro, scheme=Scheme(self.scheme))
        if self.act_scale is not None:
            act = dataclasses.replace(
                act, static_scale=self.act_scale,
                static_zero_point=self.act_zero_point or 0.0)
        elif self.act_zero_point is not None:
            act = dataclasses.replace(act,
                                      static_zero_point=self.act_zero_point)
        if self.per_channel is not None:
            weight = dataclasses.replace(weight,
                                         per_channel=self.per_channel)
        return dataclasses.replace(cfg, macro=macro, act=act, weight=weight)


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """How (and whether) a model's matmuls run on the simulated macro.

    `noise_seed` names one stochastic instance of the converter chain at
    NOISY/FULL sim levels: setting it (a) routes backend="auto" to the
    fused stochastic Pallas kernel and (b) makes jnp-backend runs
    seeded-reproducible (engine derives the key from (noise_seed, inl_seed)
    when no explicit key is passed). None (default) keeps the legacy
    behaviour — jnp backends, noise only when a key is supplied. Repeated
    same-shaped MVMs under one (noise_seed, inl_seed) reuse one noise
    realization (the reproducibility contract); vary inl_seed per
    layer/step to decorrelate them.
    """

    enabled: bool = False
    macro: MacroConfig = dataclasses.field(default_factory=MacroConfig)
    act: ActQuantConfig = dataclasses.field(default_factory=ActQuantConfig)
    weight: WeightQuantConfig = dataclasses.field(default_factory=WeightQuantConfig)
    backend: Literal["auto", "einsum", "scan", "pallas", "pallas_packed",
                     "pallas_noisy", "pallas_noisy_packed"] = "auto"
    noise_seed: int | None = None
    # Mixed-precision deployment tree: ((site_name, SitePrecision), ...) —
    # a tuple-of-pairs (not a dict) so the config stays hashable for jit
    # static args. Resolved per matmul by resolve_site_cfg against the
    # quant.act_site scope; sites without an entry run the uniform base
    # config. Populated from a precision manifest
    # (analysis.precision_search / ServingConfig.precision_manifest).
    site_overrides: tuple = ()

    def with_scheme(self, scheme) -> "CIMConfig":
        return dataclasses.replace(
            self, macro=dataclasses.replace(self.macro, scheme=scheme))

    def for_site(self, site: str | None) -> "CIMConfig":
        """The effective config at a named call site (uniform base when the
        site has no override or is unnamed)."""
        if site is not None:
            for name, ov in self.site_overrides:
                if name == site:
                    return ov.apply(
                        dataclasses.replace(self, site_overrides=()))
        return dataclasses.replace(self, site_overrides=()) \
            if self.site_overrides else self


def resolve_site_cfg(cfg: CIMConfig) -> CIMConfig:
    """Per-site override resolution at the quantization entry points: maps
    the enclosing quant.act_site scope through cfg.site_overrides. Runs at
    trace time (the site stack is Python-level), so each call site bakes
    its own constant (levels, scheme, grid) into the jit graph."""
    if not cfg.site_overrides:
        return cfg
    return cfg.for_site(current_site())


OFF = CIMConfig(enabled=False)
BP_IDEAL = CIMConfig(enabled=True)


def cim_matmul(x: jax.Array, w: jax.Array, cfg: CIMConfig, *,
               key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """Exact analog-CIM simulation of y = x @ w (no STE wrapper).

    x: [..., K] float; w: [K, M] float. Returns float32 [..., M].
    """
    if not cfg.enabled:
        return jnp.einsum("...k,km->...m", x, w)
    cfg = resolve_site_cfg(cfg)
    s_x = act_scale(x, cfg.act)
    if recording_active():
        annotate_recorded_shape(w.shape[-1])
    x_codes, zp = quantize_act(x, s_x, cfg.act)
    s_w = weight_scale(w, cfg.weight)
    w_codes = quantize_weight(w, s_w, cfg.weight)
    return execute_mvm(x_codes, w_codes, cfg, s_x=s_x, s_w=s_w,
                       x_zero_point=zp, key=key, inl_seed=inl_seed)


def cim_matmul_prequant(x: jax.Array, w_codes, w_scale: jax.Array | None,
                        cfg: CIMConfig, *, key: jax.Array | None = None,
                        inl_seed: int = 0) -> jax.Array:
    """CIM matmul against OFFLINE-quantized weights (§Perf serving path).

    w_codes are the stored unsigned 4-bit codes — an int8 container [K, M]
    (one code per byte), the nibble-packed uint8 wire format [ceil(K/2), M]
    produced by `models.quantize.quantize_params` / `kernels.ops.pack_codes`
    (two codes per byte, the SRAM-density-faithful layout), or an
    `engine.PackedCodes` container (which may carry its own scales —
    w_scale=None then uses them). Packed halves weight HBM traffic again vs
    int8 (4× vs bf16) — and is the honest deployment flow: a CIM chip never
    sees float weights at inference.

    w_scale is per-matrix or per-output-channel ([..., 1, M], from
    `quantize_weight_offline` under cfg.weight.per_channel).
    """
    cfg = resolve_site_cfg(cfg)
    s_x = act_scale(x, cfg.act)
    x_codes, zp = quantize_act(x, s_x, cfg.act)
    if isinstance(w_codes, PackedCodes):
        weights = w_codes if w_scale is None \
            else PackedCodes(w_codes.data, w_codes.k, w_scale)
    elif w_codes.dtype == jnp.uint8:  # nibble-packed wire format
        weights = PackedCodes(w_codes, x.shape[-1], w_scale)
    else:
        weights = w_codes.astype(jnp.float32)
    return execute_mvm(x_codes, weights, cfg, s_x=s_x, s_w=w_scale,
                       x_zero_point=zp, key=key, inl_seed=inl_seed)


def quantize_weight_offline(w: jax.Array, cfg: CIMConfig):
    """bf16/f32 weight → (int8 stored codes, scale) for the prequant path.

    Scales are per-matrix by default: stacked-layer weights [L, ..., K, M]
    get one scale per leading index (broadcastable [L, ..., 1, 1]) so each
    layer's matrix quantizes against its own range. Under
    cfg.weight.per_channel each OUTPUT channel gets its own scale —
    s_w [..., 1, M], still broadcastable against the codes — which tightens
    the 4-bit grid to every column's range (the standard accuracy win for
    nets whose channel ranges differ by orders of magnitude; columns map to
    distinct MAC lines on the macro, so per-channel s_w is free digital
    post-scaling, not extra analog hardware). Pack with
    `kernels.ops.pack_codes` for the nibble-packed serving format.
    """
    wf = w.astype(jnp.float32)
    cfg = resolve_site_cfg(cfg)   # per-site per_channel (models.quantize
    #                               pushes the weight name as the site)
    axes = (-2,) if cfg.weight.per_channel else (-2, -1)
    amax = jnp.max(jnp.abs(wf), axis=axes, keepdims=True)
    s_w = jnp.maximum(amax, 1e-8) / cfg.weight.qmax
    codes = quantize_weight(wf, s_w, cfg.weight)
    return codes.astype(jnp.int8), s_w.astype(jnp.float32)


# ---------------------------------------------------------------------------
# STE (QAT) wrapper: analog forward, float-matmul backward
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _ste_matmul(x, w, cfg: CIMConfig, inl_seed: int, key):
    return cim_matmul(x, w, cfg, key=key, inl_seed=inl_seed)


def _ste_fwd(x, w, cfg, inl_seed, key):
    return cim_matmul(x, w, cfg, key=key, inl_seed=inl_seed), (x, w)


def _ste_bwd(cfg, inl_seed, res, g):
    # Backward of the FLOAT matmul (Eq. 5's identity-derivative quantizers
    # compose to exactly this): no second analog forward, no residual trick.
    x, w = res
    gx = jnp.einsum("...m,km->...k", g, w).astype(x.dtype)
    gw = jnp.einsum("...k,...m->km", x, g).astype(w.dtype)
    return gx, gw, None


_ste_matmul.defvjp(_ste_fwd, _ste_bwd)


def cim_matmul_ste(x: jax.Array, w: jax.Array, cfg: CIMConfig, *,
                   key: jax.Array | None = None, inl_seed: int = 0) -> jax.Array:
    """CIM forward value with float-matmul gradients (custom VJP).

    Forward evaluates the analog pipeline once; backward sees d(x@w)
    directly — exactly the paper's BP QAT recipe (§II-B). Replaces the
    former `y_float + sg(cim − y_float)` residual trick, which paid a
    second (float) matmul and kept both outputs live under grad.
    """
    if not cfg.enabled:
        return jnp.einsum("...k,km->...m", x, w)
    return _ste_matmul(x, w, cfg, inl_seed, key)
