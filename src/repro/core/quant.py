"""Quantizers and straight-through estimators (STE) for CIM-aware arithmetic.

The paper stores 4-bit weights (signed, offset-encoded per Eq. 7) and drives
4-bit DAC activations. Training uses the standard STE (Eq. 5); the whole point
of bit-parallel CIM (paper §II-B) is that ONE extra quantization step — the
ADC — is inserted into the normal QAT flow, with no bit-level gradient
surgery (GSTE) needed.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


def round_ste(x: jax.Array) -> jax.Array:
    """round() with a straight-through gradient (Eq. 5: d round(x)/dx := 1)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def clip_ste(x: jax.Array, lo, hi) -> jax.Array:
    """clip() whose gradient is 1 inside AND outside the range (pure STE).

    We deliberately pass gradients through the clip (rather than zeroing them
    outside the range) to match the paper's STE (Eq. 5) where the derivative
    of the full quantizer is taken as identity.
    """
    return x + jax.lax.stop_gradient(jnp.clip(x, lo, hi) - x)


def fake_quant_unsigned(x: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Fake-quantize to unsigned `bits` levels with STE: x ≈ scale * q."""
    qmax = (1 << bits) - 1
    q = clip_ste(round_ste(x / scale), 0.0, float(qmax))
    return q * scale


@dataclasses.dataclass(frozen=True)
class ActQuantConfig:
    """Activation (DAC input) quantizer — asymmetric affine to u4 codes."""

    bits: int = 4
    # Calibration percentile mapped to full scale. The paper exploits the
    # same slack through the VTC gain knob (Fig. 15): activations rarely fill
    # the full analog range, so amplifying by `gain` reduces quantization
    # error at the cost of clipping the tail.
    clip_percentile: float = 1.0
    # Static calibrated scale (analysis.calibrate) — the paper's FIXED
    # input-DAC grid (the P-8T charge-domain DAC reference is a constant,
    # not a function of the batch). When set, act_scale returns this value
    # and the zero point is pinned at 0 (unsigned DAC codes; negative tails
    # clip), making each lane's quantization grid independent of what else
    # shares the serving batch — the batch-composition decoupling the
    # runtime.server docstring tracks. None = dynamic per-tensor range.
    static_scale: float | None = None
    # Calibrated zero point for the static grid (analysis.calibrate emits
    # (scale, zero_point) PAIRS): q = clip(round(x/s) + zp, 0, qmax), folded
    # exactly into the digital correction like Eq. 7's weight offset
    # (schemes.signed_correction). 0 (default) keeps the unsigned
    # post-ReLU grid; a calibrated zp > 0 shifts the grid to cover a signed
    # activation's negative tail instead of clipping it — the static/dynamic
    # grid-mismatch fix (the recorder measures span = max − min(·,0), so a
    # zp-less static grid wasted range on values it then clipped).
    static_zero_point: float = 0.0

    @property
    def qmax(self) -> int:
        return (1 << self.bits) - 1


@dataclasses.dataclass(frozen=True)
class WeightQuantConfig:
    """Weight quantizer — symmetric signed 4-bit, offset-encoded (Eq. 7)."""

    bits: int = 4
    per_channel: bool = False  # per-output-channel scales (beyond-paper knob)

    @property
    def qmax(self) -> int:  # +7 for 4-bit
        return (1 << (self.bits - 1)) - 1

    @property
    def qmin(self) -> int:  # -8 for 4-bit
        return -(1 << (self.bits - 1))

    @property
    def offset(self) -> int:  # Eq. 7: W̃ = W + 8 ∈ [0, 15]
        return 1 << (self.bits - 1)


class SpanRecord(float):
    """One recorded activation-range observation: a float (the span,
    max − min(·, 0) — so existing span-list consumers keep working) carrying
    the call-site identity and range/shape metadata the per-site calibration
    tree and the precision autotuner's energy accounting need.

    `site` is the weight name of the enclosing matmul (`act_site` scope) —
    deliberately EXCLUDING the layer index, so the calibration tree keyed on
    it is identical whether the model later runs scanned (one shared trace
    for all layers) or unrolled. `m` (output columns) is attached by
    cim_matmul once the weight shape is known; None when act_scale was
    called outside a matmul.
    """

    site: str | None
    lo: float
    hi: float
    k: int
    rows: int
    m: int | None

    def __new__(cls, span: float, *, site=None, lo=0.0, hi=0.0, k=0,
                rows=0, m=None):
        self = super().__new__(cls, span)
        self.site = site
        self.lo = lo
        self.hi = hi
        self.k = k
        self.rows = rows
        self.m = m
        return self


# Call-site identity: models wrap each CIM-routed matmul in an `act_site`
# scope named after the weight ("wq", "w_up", "e_gate", "head", ...). The
# stack is Python-level, so it works identically in eager calibration and at
# trace time (where cim_matmul resolves per-site precision overrides).
_SITE_STACK: list[str] = []


@contextlib.contextmanager
def act_site(name: str):
    """Name the enclosing CIM call site (layer-index-free weight name)."""
    _SITE_STACK.append(name)
    try:
        yield
    finally:
        _SITE_STACK.pop()


def current_site() -> str | None:
    return _SITE_STACK[-1] if _SITE_STACK else None


# Calibration hook: while a `record_act_spans()` context is open (eager
# forwards only — a traced span raises, see act_scale), act_scale appends
# every activation span it computes, in call order, as a SpanRecord.
# analysis.calibrate turns the recording into static (scale, zero_point)
# grids for ActQuantConfig.
_SPAN_RECORDER: list[list] = []


def recording_active() -> bool:
    """True while any record_act_spans() context is open — model code uses
    this to switch vmapped expert matmuls to an eager unroll so their spans
    are concrete (vmap tracers would otherwise make MoE calibration blind
    to expert call sites)."""
    return bool(_SPAN_RECORDER)


@contextlib.contextmanager
def record_act_spans():
    """Collect per-matmul activation spans (max − min(·, 0)) during eager
    forwards; yields the list being filled (SpanRecord entries — floats
    carrying site/range/shape metadata)."""
    spans: list[SpanRecord] = []
    _SPAN_RECORDER.append(spans)
    try:
        yield spans
    finally:
        # detach by identity: nested recorders hold ==-equal lists (every
        # open recorder receives every span), so list.remove would pop the
        # wrong one
        _SPAN_RECORDER[:] = [r for r in _SPAN_RECORDER if r is not spans]


def act_scale(x: jax.Array, cfg: ActQuantConfig) -> jax.Array:
    """Activation scale: the static calibrated grid when
    cfg.static_scale is set, else the dynamic per-tensor affine range
    (max − min) / qmax.

    For non-negative (post-ReLU) activations — the paper's case — min = 0 and
    dynamic reduces to max/qmax with zero point 0. The dynamic range couples
    every lane's grid to the whole batched tensor (batch-composition
    dependence under batched serving); calibrated static scales are the
    production fix. stop_gradient: scales are not trained.
    """
    if cfg.static_scale is not None:
        return jnp.asarray(cfg.static_scale, jnp.float32)
    xs = jax.lax.stop_gradient(x)
    lo = jnp.minimum(jnp.min(xs), 0.0)
    hi = jnp.max(xs)
    span = jnp.maximum(hi - lo, 1e-8)
    if _SPAN_RECORDER:
        if isinstance(span, jax.core.Tracer):
            # Fail LOUDLY: a silently skipped tracer span used to leave
            # whole call sites (vmapped MoE experts, scanned layers) out of
            # the calibration profile — a profile that looks complete but
            # isn't. Calibration forwards must run eager (scan unrolled,
            # recording_active()-gated expert unroll, no jit/vmap around
            # the forward).
            raise RuntimeError(
                "act_scale saw a traced activation while a span recorder "
                "is open — this call site would be silently missing from "
                "the calibration profile. Run the calibration forward "
                "eagerly (analysis.calibrate unrolls layer scans and MoE "
                "experts; do not wrap it in jit/vmap/scan).")
        rec_entry = SpanRecord(
            float(span), site=current_site(), lo=float(lo), hi=float(hi),
            k=int(x.shape[-1]) if x.ndim else 1,
            rows=int(x.size // x.shape[-1]) if x.ndim else 1)
        for rec in _SPAN_RECORDER:
            rec.append(rec_entry)
    return span / cfg.qmax


def annotate_recorded_shape(m: int) -> None:
    """Attach the matmul's output-column count to the most recent span
    record (called by cim_matmul, which — unlike act_scale — sees the
    weight). The autotuner's per-site energy accounting needs (k, m, rows)
    per call."""
    for rec in _SPAN_RECORDER:
        if rec and rec[-1].m is None:
            rec[-1].m = int(m)


def weight_scale(w: jax.Array, cfg: WeightQuantConfig) -> jax.Array:
    """Symmetric weight scale; per-channel reduces over all but last dim."""
    if cfg.per_channel:
        amax = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    amax = jnp.maximum(amax, 1e-8)
    return jax.lax.stop_gradient(amax / cfg.qmax)


def quantize_act(x: jax.Array, scale: jax.Array, cfg: ActQuantConfig):
    """x → (u4 DAC codes, zero_point).

    Affine/asymmetric: q = clip(round(x/s) + z, 0, 15). The zero point folds
    into the digital correction path exactly like Eq. 7's weight offset — see
    `schemes.signed_correction`. For non-negative x (post-ReLU, the paper's
    case) z = 0 and this reduces to the paper's unsigned DAC codes. Under a
    static calibrated grid BOTH the scale and the zero point are fixed
    constants from calibration (the DAC grid must not depend on the batch):
    zp = 0 keeps the unsigned grid, a calibrated zp > 0 covers the measured
    negative tail that a zero-pinned grid would clip.
    """
    if cfg.static_scale is not None:
        zp = jnp.asarray(float(cfg.static_zero_point), jnp.float32)
        q = clip_ste(round_ste(x / scale) + zp, 0.0, float(cfg.qmax))
        return q, zp
    zp = jnp.round(jnp.clip(-jnp.min(jax.lax.stop_gradient(x)) / scale, 0, cfg.qmax))
    q = clip_ste(round_ste(x / scale) + zp, 0.0, float(cfg.qmax))
    return q, zp


def quantize_weight(w: jax.Array, scale: jax.Array, cfg: WeightQuantConfig):
    """w → unsigned stored codes W̃ ∈ [0, 2^b-1] per the paper's Eq. 7 mapping."""
    q_signed = clip_ste(round_ste(w / scale), float(cfg.qmin), float(cfg.qmax))
    return q_signed + cfg.offset


def bit_planes(q: jax.Array, bits: int) -> jax.Array:
    """Decompose unsigned integer codes into `bits` binary planes.

    Returns shape (bits,) + q.shape, plane p holding bit p (LSB first).
    Used by the BS / WBS baselines (Eq. 2) where each plane is a separate
    analog MAC pass.
    """
    qi = q.astype(jnp.int32)
    planes = [(qi >> p) & 1 for p in range(bits)]
    return jnp.stack(planes, axis=0).astype(q.dtype)
