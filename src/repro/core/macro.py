"""PICO-RAM macro configuration and operating-point (PVT) model.

Mirrors the measured 65-nm prototype (paper §V):
  * 288×144 macro = 8 CIM MVM groups, each 4 slices × 144 clusters × 9 cells
  * N = 144 rows accessed concurrently per analog MVM (computing parallelism)
  * 4-bit activations (in-situ C-DAC) × 4-bit weights (one bit per slice,
    in-situ shift-and-add with 8:4:2:1 capacitive weighting)
  * 8.5-bit dual-threshold time-domain ADC (362 levels), VTC gain 1–4
  * 0.65–1.2 V, −40–105 °C, 2–22 MHz
"""
from __future__ import annotations

import dataclasses
import enum
import math


class Scheme(enum.Enum):
    BP = "bp"    # bit-parallel (this work)
    WBS = "wbs"  # weight-bit-serial baseline
    BS = "bs"    # fully bit-serial baseline


class SimLevel(enum.Enum):
    """Fidelity of the analog simulation.

    IDEAL  — exact transfer curve, no stochastic effects (Fig. 2 SQNR study
             assumption: "ideal circuit components, focus on quantization").
    NOISY  — + thermal noise (σ ≈ 0.4 LSB per conversion, Fig. 16a).
    FULL   — + INL curve and gain error (Fig. 15/17), PVT-scaled (Fig. 18).
    """

    IDEAL = "ideal"
    NOISY = "noisy"
    FULL = "full"


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """Supply voltage / temperature point (the paper's PVT axes)."""

    vdd: float = 0.9        # V, 0.65–1.2
    temp_c: float = 25.0    # °C, −40–105

    def __post_init__(self):
        if not (0.6 <= self.vdd <= 1.25):
            raise ValueError(f"vdd {self.vdd} outside the measured 0.65–1.2 V range")
        if not (-45.0 <= self.temp_c <= 110.0):
            raise ValueError(f"temp {self.temp_c} outside the measured −40–105 °C range")


@dataclasses.dataclass(frozen=True)
class MacroConfig:
    """Static configuration of one simulated PICO-RAM macro."""

    n_rows: int = 144            # N: rows accessed concurrently (one slice)
    act_bits: int = 4            # B_A (C-DAC resolution)
    weight_bits: int = 4         # B_W (slices per MVM group)
    adc_levels: int = 362        # 8.5-bit dual-threshold TD-ADC (2^8.5 ≈ 362)
    gain: float = 1.0            # VTC gain, 1–4 (Fig. 15)
    scheme: Scheme = Scheme.BP
    sim_level: SimLevel = SimLevel.IDEAL
    op: OperatingPoint = dataclasses.field(default_factory=OperatingPoint)

    # Calibrated noise parameters (LSB units, gain=1, 0.9 V, 25 °C).
    # Paper Fig. 16 measures σ at the OUTPUT CODES: thermal RMS 0.4 LSB and
    # total σ_E 0.59 LSB *including* the quantizer's own rounding variance
    # (≈1/12 LSB²). The injected pre-rounding σ is therefore
    # √(0.40² − 1/12) ≈ 0.277 — benchmarks/fig16_noise.py verifies the
    # measured output σ reproduces the paper's 0.40 / 0.59.
    sigma_thermal_lsb: float = 0.277
    inl_amp_lsb: float = 1.10     # end-to-end |INL| bound (Fig. 15)
    dnl_amp_lsb: float = 0.50     # |DNL| bound ≈ +0.56/−0.41 (Fig. 15)

    def __post_init__(self):
        if self.gain < 1.0 or self.gain > 4.0:
            raise ValueError(f"VTC gain {self.gain} outside the 1–4 range")
        if self.adc_levels < 2:
            raise ValueError("adc_levels must be ≥ 2")

    # ---- derived quantities -------------------------------------------------
    @property
    def act_qmax(self) -> int:
        return (1 << self.act_bits) - 1

    @property
    def weight_qmax_unsigned(self) -> int:
        return (1 << self.weight_bits) - 1

    @property
    def adc_bits(self) -> float:
        return math.log2(self.adc_levels)

    def full_scale(self, act_bits_active: int | None = None,
                   weight_bits_active: int | None = None) -> float:
        """Maximum analog MAC level before the ADC for the active bit widths.

        BP drives b_A-bit DAC codes against b_W-bit (offset-encoded) weights:
          FS = (2^b_A − 1)(2^b_W − 1) N.
        WBS/BS pass binary planes on one or both operands, shrinking FS — the
        paper's point is that this does NOT buy accuracy once the digital
        accumulation of per-plane ADC errors is accounted for (§II-A).
        """
        ba = self.act_bits if act_bits_active is None else act_bits_active
        bw = self.weight_bits if weight_bits_active is None else weight_bits_active
        return float(((1 << ba) - 1) * ((1 << bw) - 1) * self.n_rows)

    def adc_lsb(self, act_bits_active: int | None = None,
                weight_bits_active: int | None = None) -> float:
        """Analog units per ADC code, including the VTC gain.

        gain > 1 amplifies the MAC voltage before time conversion, shrinking
        the LSB (finer quantization) while clipping the (rarely reached) top
        of the range — paper Fig. 15/18 and §V-A.
        """
        fs = self.full_scale(act_bits_active, weight_bits_active)
        return fs / (self.gain * (self.adc_levels - 1))

    # ---- PVT behavioural model (calibrated to Fig. 18 / Fig. 21) -----------
    def effective_adc_levels(self) -> int:
        """At 0.65 V the ADC input range shrinks → resolution degrades to
        ~8 bit (paper §V-B). Linear de-rating below 0.75 V."""
        if self.op.vdd >= 0.75:
            return self.adc_levels
        frac = (self.op.vdd - 0.65) / 0.10  # 0 at 0.65 V → 1 at 0.75 V
        lo = 256  # 8-bit floor measured at 0.65 V
        return int(round(lo + frac * (self.adc_levels - lo)))

    def sigma_e_lsb(self) -> float:
        """Total computing-error σ_E in LSB (noise + nonlinearity), PVT-scaled.

        Calibration anchors: σ_E = 0.59 LSB @ (0.9 V, 25 °C, gain 1); Fig. 18
        shows mild growth toward the voltage/temperature corners and Fig. 18's
        gain study shows σ_E grows sublinearly with gain (reference-current
        noise): we fit σ_E(gain) ≈ σ_E·gain^0.35 so that σ_E×LSB_volts still
        *shrinks* with gain, matching the paper's conclusion that higher gain
        is a net win.
        """
        base = 0.59
        v = self.op.vdd
        t = self.op.temp_c
        v_term = 1.0 + 0.55 * max(0.0, 0.80 - v) / 0.15 + 0.10 * max(0.0, v - 1.1)
        t_term = 1.0 + 0.0016 * abs(t - 25.0)
        g_term = self.gain ** 0.35
        return base * v_term * t_term * g_term

    def sigma_thermal(self) -> float:
        """Thermal-only σ (Fig. 16a), PVT-scaled like σ_E."""
        return self.sigma_thermal_lsb * (self.sigma_e_lsb() / 0.59)

    def clock_hz(self) -> float:
        """~Linear 0.65→1.2 V clock (Fig. 21: "2 MHz"→22 MHz). The low end is
        fitted to the measured 3.8 GOPS @ 0.65 V (Table I): 8 groups × 288
        ops × f = 3.8 GOPS → f = 1.65 MHz (the text's 2 MHz is rounded)."""
        return (1.65 + (self.op.vdd - 0.65) / 0.55 * 20.35) * 1e6


# The paper's prototype macro geometry (for area/density/energy accounting).
@dataclasses.dataclass(frozen=True)
class MacroGeometry:
    mvm_groups: int = 8          # TD-ADCs per macro
    slices_per_group: int = 4    # weight bits
    clusters_per_slice: int = 144
    cells_per_cluster: int = 9   # 9 × 6T cells share one MAC unit
    capacity_kb: float = 40.5    # 288 × 144 bits
    area_mm2: float = 0.074
    area_frac_array: float = 0.709
    area_frac_drivers: float = 0.147
    area_frac_adc: float = 0.046

    @property
    def density_kb_mm2(self) -> float:
        return self.capacity_kb / self.area_mm2


PROTOTYPE = MacroConfig()
GEOMETRY = MacroGeometry()
