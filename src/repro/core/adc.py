"""Dual-threshold time-domain ADC behavioural model (paper §IV).

The physical chain — VTC discharge of the combined slice capacitance, folding
flash TDC on a shared 8-phase RO, dual-threshold power gating — is abstracted
to its measured input/output behaviour:

    code = clip( round( v/LSB + INL(v) + ε_thermal ), 0, levels−1 )

with LSB set by the full scale / (gain × levels) (macro.adc_lsb), a smooth
bounded INL curve (Fig. 15: ±1.10 LSB end-to-end), and Gaussian thermal noise
(Fig. 16a: σ ≈ 0.4 LSB RMS). All of it differentiates through via STE so the
same model runs inside CIM-aware QAT.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .macro import MacroConfig, SimLevel
from .quant import clip_ste, round_ste

# --- measured ADC constants: the single source of truth -------------------
# Every consumer (adc_energy_j below, energy._solve_e_mac_ref's absolute
# anchor, the precision autotuner's (levels, vdd) sweep) derives from THESE
# so a behavioural change here moves the whole model coherently instead of
# silently diverging from the Fig. 21 golden.
#
# Dual-threshold comparator power-gating probability (§IV, measured): the
# main conversion path is off 55.8 % of the time.
DUAL_THRESHOLD_GATING = 0.558
# Eq. 4 ratio anchor: E_ADC/(N·E_MAC) = 3.0 at 7-bit (128-level) resolution
# with N = 144 rows — the CAP-RAM-measured point the paper's §II-A energy
# analysis normalizes against (no gating).
ADC_RATIO_E_ADC_OVER_N_E_MAC = 3.0
ADC_RATIO_LEVELS = 128.0
ADC_RATIO_N_ROWS = 144


def inl_curve(code_frac: jax.Array, amp_lsb: float, seed: int = 0) -> jax.Array:
    """Deterministic smooth INL profile in LSB as a function of code ∈ [0,1].

    Shape matches the measured transfer (Fig. 15): a cubic bow that peaks at
    the range ends (worst-case |INL| ≈ amp) with a small mid-range ripple —
    the bound is ±1.10 LSB but the code-averaged rms is ≈ amp/√7 ≈ 0.42,
    which together with the 0.4-LSB thermal term reproduces the measured
    total σ_E = 0.59 (Fig. 16b). `seed` picks a different instance (used by
    the Fig. 18 process-variation bench to emulate 8 MVM groups / 5 chips).
    """
    import numpy as np

    rng = np.random.RandomState(seed * 7919 + 13)
    sign = 1.0 if rng.rand() < 0.5 else -1.0
    ripple_w = 0.12 * rng.randn(2)
    ph = rng.uniform(0, 2 * np.pi, size=2)
    scale = 0.85 + 0.15 * rng.rand()  # instance-to-instance spread (Fig. 18)
    u = 2.0 * code_frac - 1.0
    x = code_frac * (2 * jnp.pi)
    curve = sign * u ** 3 + ripple_w[0] * jnp.sin(2 * x + ph[0]) \
        + ripple_w[1] * jnp.sin(3 * x + ph[1])
    # analytic bound |curve| ≤ 1 + |r1| + |r2| → normalize, then budget the
    # amplitude between the smooth bow and a high-frequency per-code term
    # (the TDC's local layout mismatch → the measured ±0.5-LSB DNL, Fig. 15)
    # so the total stays within the ±amp_lsb INL bound.
    curve = curve / (1.0 + abs(float(ripple_w[0])) + abs(float(ripple_w[1])))
    jit_amp = min(0.24, 0.2 * amp_lsb)
    jitter = jit_amp * jnp.sin(code_frac * 12289.0 + ph[0]) \
        * jnp.sin(code_frac * 5741.0 + ph[1])
    return (amp_lsb - jit_amp) * scale * curve + jitter


def stochastic_transfer_params(cfg: MacroConfig) -> dict:
    """σ / INL settings of the stochastic ADC transfer for cfg.sim_level.

    Single source of truth shared by `adc_quantize` (the jnp reference
    pipeline) and the fused stochastic Pallas kernel
    (`kernels.cim_mvm.cim_mvm_grouped_noisy`): both must inject the same
    pre-rounding thermal σ and the same INL instance so their output
    DISTRIBUTIONS agree (the draws themselves come from different PRNGs).

      NOISY → σ = sigma_thermal_lsb (0.277 pre-rounding), no INL;
      FULL  → σ = sigma_thermal()  (PVT-scaled), + the Fig. 15 INL curve.
    """
    if cfg.sim_level == SimLevel.FULL:
        return {"sigma": float(cfg.sigma_thermal()), "apply_inl": True,
                "inl_amp": float(cfg.inl_amp_lsb)}
    return {"sigma": float(cfg.sigma_thermal_lsb), "apply_inl": False,
            "inl_amp": 0.0}


def adc_quantize(v_analog: jax.Array, cfg: MacroConfig, *,
                 key: jax.Array | None = None,
                 act_bits_active: int | None = None,
                 weight_bits_active: int | None = None,
                 inl_seed: int = 0,
                 dequantize: bool = True) -> jax.Array:
    """Quantize analog MAC values through the TD-ADC transfer curve.

    v_analog is in "integer MAC units" (Σ W̃·X over ≤ N rows). Returns either
    the reconstructed analog value (code × LSB — what the digital side uses
    for shift-and-add / partial-sum accumulation) or the raw code.
    STE rounding keeps the op differentiable for QAT.
    """
    levels = cfg.effective_adc_levels()
    # codes 0..levels−1 span exactly [0, FS/gain]: LSB = FS/(gain·(levels−1))
    lsb = cfg.full_scale(act_bits_active, weight_bits_active) \
        / (cfg.gain * (levels - 1))
    x = v_analog / lsb

    if cfg.sim_level != SimLevel.IDEAL:
        st = stochastic_transfer_params(cfg)
        sigma = st["sigma"]
        if st["apply_inl"]:
            x = x + inl_curve(jnp.clip(x / levels, 0.0, 1.0), st["inl_amp"],
                              inl_seed)
        if key is not None:
            x = x + sigma * jax.random.normal(key, x.shape, dtype=x.dtype)

    code = clip_ste(round_ste(x), 0.0, float(levels - 1))
    return code * lsb if dequantize else code


def adc_energy_j(cfg: MacroConfig, *, dual_threshold: bool = True) -> float:
    """Energy of one TD-ADC conversion (behavioural, calibrated).

    TD-ADC energy scales ~linearly with quantization levels (paper §II-C /
    Walden). The dual-threshold comparator power-gates the main path for a
    measured 55.8 % reduction (§IV). Absolute scale is anchored so that the
    full Eq. 4 macro model reproduces 40.2 TOPS/W @ 0.65 V (see energy.py).
    """
    from .energy import E_MAC_REF_J, VOLT_REF, energy_voltage_scale

    e_adc_7b = ADC_RATIO_E_ADC_OVER_N_E_MAC * ADC_RATIO_N_ROWS * E_MAC_REF_J
    levels = cfg.effective_adc_levels()
    e = e_adc_7b * (levels / ADC_RATIO_LEVELS)
    if dual_threshold:
        e *= (1.0 - DUAL_THRESHOLD_GATING)
    return e * energy_voltage_scale(cfg.op.vdd) / energy_voltage_scale(VOLT_REF)
