"""In-situ capacitive DAC behavioural model (paper §III-C).

The C-DAC reuses the cluster MOM capacitors as a two-phase capacitive voltage
divider (16/8/4/2 clusters per column group encode the 4 input bits), so:

  * it is buffer-free and PVT-insensitive (pure charge redistribution) — in
    the simulation the DAC transfer is exactly linear;
  * its energy is *input-sparsity aware*: a capacitor is only charged when
    the corresponding input bit is 1 (measured 2.4 %–14.6 % of macro energy).

Functionally the DAC is the activation quantizer (quant.quantize_act); this
module adds the energy/statistics model used by benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .macro import MacroConfig


def dac_codes(x_q: jax.Array) -> jax.Array:
    """Identity transfer: codes in [0, 2^B_A − 1] → ideal analog levels.

    The in-situ C-DAC's linearity comes from capacitor matching (R² = 0.9999,
    Fig. 9); mismatch is folded into the end-to-end INL model in adc.py, as
    the paper's own end-to-end measurement does (Fig. 15).
    """
    return x_q


def dac_switched_cap_fraction(x_q: jax.Array, cfg: MacroConfig) -> jax.Array:
    """Fraction of DAC capacitance charged for given codes ∈ [0, qmax].

    Bit b switches a capacitor bank proportional to 2^b (16/8/4/2 clusters).
    Zero inputs charge nothing → energy ∝ popcount-weighted code value.
    """
    qi = x_q.astype(jnp.int32)
    weights = jnp.array([2 ** b for b in range(cfg.act_bits)], dtype=jnp.float32)
    bits = jnp.stack([(qi >> b) & 1 for b in range(cfg.act_bits)], -1).astype(jnp.float32)
    frac = (bits @ weights) / float(cfg.act_qmax)
    return frac


def dac_energy_j(x_q: jax.Array, cfg: MacroConfig) -> jax.Array:
    """DAC energy for one group conversion (all N row DACs), given the code
    statistics in x_q.

    Anchored so the DAC share of total group energy spans the measured
    2.4 %–14.6 % between sparse (90 % zeros) and dense inputs
    (benchmarks/fig21_energy.py checks this).
    """
    from .energy import E_MAC_REF_J, VOLT_REF, energy_voltage_scale

    # per-row full-code charge ≈ 2.4× one MAC event (the DAC charges the
    # same in-situ C_MOM set through the two-phase redistribution)
    e_row_full = 2.4 * E_MAC_REF_J
    scale = energy_voltage_scale(cfg.op.vdd) / energy_voltage_scale(VOLT_REF)
    mean_frac = jnp.mean(dac_switched_cap_fraction(x_q, cfg))
    return cfg.n_rows * mean_frac * e_row_full * scale
