"""Mapping DNN layers onto PICO-RAM macro arrays (paper §V).

The prototype stores 9 weight bits per cluster position (9 × 6T cells share
one MAC unit): one slice holds the ACTIVE bit, the other 8 cells bank
weights of other layers/channels — that's how the macro reaches 559 Kb/mm²
*usable* density and why "the weight storage density may approach a
commercial SRAM" (§III-A). When a model exceeds on-chip capacity the host
reloads banks between layers (§V-C: "reloading the memory is necessary").

This module does the arithmetic a deployment needs:
  * how many macro tiles a weight matrix occupies (144-row × 8-col ADC
    groups per macro, 4-bit weights);
  * bank utilization of the 9-cell clusters;
  * reload traffic/energy when the model doesn't fit the macro budget.
"""
from __future__ import annotations

import dataclasses
import math

from .macro import GEOMETRY, MacroConfig


@dataclasses.dataclass(frozen=True)
class MacroBudget:
    n_macros: int = 64              # macros available on chip
    banks_per_cluster: int = 9      # 9 × 6T cells per cluster

    @property
    def rows(self) -> int:
        return 144

    @property
    def cols(self) -> int:
        return GEOMETRY.mvm_groups   # 8 ADC columns per macro

    def capacity_weights(self) -> int:
        """4-bit weights storable on chip (all banks)."""
        return (self.n_macros * self.rows * self.cols
                * self.banks_per_cluster)


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    name: str
    k: int                          # reduction depth
    m: int                          # output columns
    tiles: int                      # (144-row × 8-col) tile count
    weights: int                    # k × m


def map_layer(name: str, k: int, m: int) -> LayerMapping:
    tiles = math.ceil(k / 144) * math.ceil(m / GEOMETRY.mvm_groups)
    return LayerMapping(name=name, k=k, m=m, tiles=tiles, weights=k * m)


@dataclasses.dataclass(frozen=True)
class ModelMapping:
    layers: tuple
    budget: MacroBudget

    @property
    def total_weights(self) -> int:
        return sum(l.weights for l in self.layers)

    @property
    def resident_fraction(self) -> float:
        """Fraction of the model resident on chip (banked)."""
        return min(1.0, self.budget.capacity_weights()
                   / max(self.total_weights, 1))

    @property
    def fits(self) -> bool:
        return self.total_weights <= self.budget.capacity_weights()

    def reload_bits_per_pass(self) -> int:
        """Weight bits (re)loaded per full forward pass when over budget."""
        overflow = max(0, self.total_weights
                       - self.budget.capacity_weights())
        return overflow * 4

    def bank_utilization(self) -> float:
        """Fraction of 9-cell banks actually holding weights."""
        active_positions = self.budget.n_macros * self.budget.rows \
            * self.budget.cols * self.budget.banks_per_cluster
        return min(1.0, self.total_weights / active_positions)


def map_model(shapes: list[tuple[str, int, int]],
              budget: MacroBudget | None = None) -> ModelMapping:
    """shapes: [(layer_name, K, M)] for every macro-mapped matmul."""
    budget = budget or MacroBudget()
    return ModelMapping(tuple(map_layer(n, k, m) for n, k, m in shapes),
                        budget)


def gru_144_shapes(d: int = 144) -> list[tuple[str, int, int]]:
    """The paper's custom 0.16M-param KWS GRU: input and hidden dims of 144
    'to perfectly fit into the SRAM' (§V-C). Gates: z, r, candidate — each
    [d + d → d]."""
    return [(f"gru_{g}", 2 * d, d) for g in ("z", "r", "h")] + \
        [("head", d, 16)]
