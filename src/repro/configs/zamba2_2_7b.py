"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2,
                  conv_kernel=4, chunk=64, shared_every=6),
    supports_long_context=True,
)

SMOKE = CONFIG.replace(n_layers=6, d_model=128, n_heads=4, n_kv_heads=4,
                       d_ff=256, vocab=512,
                       ssm=SSMConfig(kind="mamba2", d_state=16, head_dim=32,
                                     expand=2, conv_kernel=4, chunk=16,
                                     shared_every=3))
