"""Architecture registry: --arch <id> → (full CONFIG, reduced SMOKE)."""
from __future__ import annotations

from . import (deepseek_v3_671b, granite_3_8b, internlm2_1_8b, internvl2_26b,
               llama3_8b, qwen2_moe_a2_7b, rwkv6_7b, stablelm_3b,
               whisper_large_v3, zamba2_2_7b)
from .base import SHAPES, MeshConfig, ModelConfig, ShapeConfig

_MODULES = (qwen2_moe_a2_7b, deepseek_v3_671b, rwkv6_7b, internvl2_26b,
            llama3_8b, granite_3_8b, internlm2_1_8b, stablelm_3b,
            zamba2_2_7b, whisper_large_v3)

ARCHS: dict[str, ModelConfig] = {m.CONFIG.arch: m.CONFIG for m in _MODULES}
SMOKES: dict[str, ModelConfig] = {m.CONFIG.arch: m.SMOKE for m in _MODULES}


def get(arch: str, *, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(table)}")
    return table[arch]


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) dry-run cell runs, and why not if skipped.

    Per the brief: long_500k needs sub-quadratic attention — skipped for pure
    softmax-attention archs (incl. MLA, which is still full softmax attention
    over the latent cache) and run for SSM/hybrid archs.
    """
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("skip: pure full-softmax-attention arch at 512k context"
                       " (sub-quadratic archs only, per brief)")
    return True, ""
