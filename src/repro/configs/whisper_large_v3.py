"""whisper-large-v3 [audio] — enc-dec transformer backbone; conv frontend is a
STUB (input_specs() provides precomputed frame embeddings) [arXiv:2212.04356].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866,
    encoder_layers=32, encoder_len=1500, cross_attention=True,
    norm="layernorm", mlp="gelu", qkv_bias=True, pos_embed="learned",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                       d_ff=256, vocab=512, encoder_layers=2, encoder_len=32,
                       attn_chunk=64)
