"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, rope_theta=1000000.0,
    moe=MoEConfig(
        n_experts=60, top_k=4, d_ff_expert=1408,
        n_shared=4, d_ff_shared=5632,  # 4 × 1408, sigmoid-gated
        shared_gate=True, capacity_factor=1.25,
    ),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64, vocab=512,
    attn_chunk=64,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=2,
                  d_ff_shared=128, shared_gate=True, capacity_factor=1.25),
)
