"""granite-3-8b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                       d_ff=256, vocab=512, attn_chunk=64)
