"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92544, rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                       d_ff=256, vocab=512, attn_chunk=64)
