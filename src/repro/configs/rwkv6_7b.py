"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay
[arXiv:2404.05892]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,  # 64 × head 64
    d_ff=14336, vocab=65536,
    ssm=SSMConfig(kind="rwkv6", d_state=64, head_dim=64, chunk=32,
                  decay_lora_rank=64),
    supports_long_context=True,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                       d_ff=256, vocab=512,
                       ssm=SSMConfig(kind="rwkv6", d_state=32, head_dim=32,
                                     chunk=16, decay_lora_rank=8))
