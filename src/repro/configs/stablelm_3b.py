"""stablelm-3b [dense] — MHA, partial rotary, LayerNorm + qkv bias
[hf:stabilityai/stablelm-2-1_6b family]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304, rope_theta=10000.0, rope_pct=0.25,
    norm="layernorm", qkv_bias=True,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                       d_ff=256, vocab=512, attn_chunk=64)
