"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, rope_theta=500000.0,
)

# Reduced same-family config for CPU smoke tests (GQA ratio preserved).
SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                       d_ff=256, vocab=512, attn_chunk=64)
