"""internvl2-26b [vlm] — InternViT (stub frontend) + InternLM2-20B backbone
[arXiv:2404.16821].

Per the brief the modality frontend is a STUB: input_specs() provides
precomputed patch embeddings [B, n_image_tokens, d_model] (post-projector),
prepended to the text sequence; seq_len counts the total sequence.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, rope_theta=1000000.0,
    n_image_tokens=256,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                       d_ff=256, vocab=512, n_image_tokens=16, attn_chunk=64)
