"""Configuration dataclasses for models, meshes, shapes and training.

Frozen + hashable so configs can ride through jax.jit static args.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cim_matmul import CIMConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # shared experts (always-on)
    d_ff_shared: int = 0           # total shared width (n_shared × expert width)
    capacity_factor: float = 1.25
    shared_gate: bool = False      # qwen2-moe gates the shared expert path
    # expert-parallel combine: "psum" = replicated-dispatch EP (baseline,
    # works for any token count incl. decode); "a2a" = sequence-sharded
    # dispatch with static-capacity all_to_all (DeepSeek-style, §Perf)
    ep_mode: str = "psum"
    first_dense: int = 0           # leading layers with dense FFN (deepseek: 3)
    d_ff_dense: int = 0            # width of those dense layers
    router_dtype: str = "float32"  # routers stay high precision + digital


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims (arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / RWKV6 recurrent-family dims."""

    kind: str = "mamba2"           # "mamba2" | "rwkv6"
    d_state: int = 64              # mamba2 N / rwkv6 head size
    head_dim: int = 64
    expand: int = 2                # mamba2 d_inner = expand × d_model
    conv_kernel: int = 4
    chunk: int = 32                # chunked-parallel scan length
    decay_lora_rank: int = 64      # rwkv6 data-dependent decay LoRA
    dt_rank: int = 0               # 0 → heads (mamba2 uses per-head dt)
    # zamba2 hybrid: a shared transformer block applied every `shared_every`
    # SSM layers (same parameters each time — Zamba2's weight-shared design).
    shared_every: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str                      # config id, e.g. "llama3-8b"
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 → d_model // n_heads
    rope_theta: float = 10000.0
    rope_pct: float = 1.0          # stablelm: partial rotary (0.25)
    pos_embed: str = "rope"        # rope | learned (whisper)
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qkv_bias: bool = False
    mlp: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp: bool = False              # deepseek multi-token prediction head
    mtp_weight: float = 0.3
    # enc-dec (whisper): encoder consumes precomputed frame embeddings (stub)
    encoder_layers: int = 0
    encoder_len: int = 0           # e.g. 1500 frames
    cross_attention: bool = False
    # vlm: image patch-embedding prefix (stub frontend)
    n_image_tokens: int = 0
    # numerics / technique
    dtype: str = "bfloat16"
    cim: CIMConfig = dataclasses.field(default_factory=CIMConfig)
    # paged-serving attention backend (kernels.paged_attention registry):
    # "auto" resolves to the Pallas flash kernel (REPRO_FORCE_JNP=1 pins
    # the exact jnp reference); "exact"/"kernel" force a backend.
    attn_backend: str = "auto"
    remat: bool = True
    remat_policy: str = "dots"     # dots | nothing (save less, recompute more)
    # causal chunked attention: unroll the q-chunk loop triangularly (skip
    # fully-masked kv blocks) up to this many q chunks; beyond it, fall back
    # to the scan² schedule with masking (≈2× causal FLOPs waste)
    attn_triangular_max: int = 8
    # §Perf: compute the training loss in sequence chunks so the [tokens,
    # vocab] logits tensor is never fully materialized (big-vocab archs:
    # llama3 128k, deepseek 129k). 1 = single pass.
    ce_chunks: int = 1
    attn_chunk: int = 1024         # chunked (flash-style) attention block
    # scan_layers=False unrolls layer loops into straight-line HLO. Needed by
    # the roofline pass: XLA cost_analysis counts a while-loop body ONCE
    # (trip count ignored), so scanned-layer FLOPs/bytes under-report by ~L×.
    # Production runs keep scan (small HLO, fast compiles); analysis cells
    # unroll. Memory analysis is taken from the scanned build.
    scan_layers: bool = True
    # Sequence parallelism for the residual stream between blocks: shard the
    # token axis over "model" where divisible (Megatron-SP layout). Saves
    # L×tokens×d_model×2B/chip of checkpointed activations.
    seq_shard: bool = True
    # §Perf: lower the TP output projections (attention wo / mlp w_down)
    # through an explicit shard_map with psum_scatter instead of letting
    # GSPMD pick (it chooses ring all-reduce ⇒ 2× the wire bytes of a
    # reduce-scatter into the sequence-parallel layout).
    tp_reduce_scatter: bool = False
    supports_long_context: bool = False  # sub-quadratic archs only

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    lr: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    optimizer: str = "adamw"        # adamw | adafactor
    microbatch: int = 0             # >0: gradient accumulation microbatch
    seed: int = 0
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    grad_compression: bool = False  # int8 all-reduce with error feedback
    log_every: int = 10
