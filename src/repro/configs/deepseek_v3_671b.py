"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437].

The assigned d_ff=2048 is the routed-expert width; the first 3 layers use the
paper's dense FFN width 18432. MLA dims follow the DeepSeek-V3 report.
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280, rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=256, top_k=8, d_ff_expert=2048,
        n_shared=1, d_ff_shared=2048,
        first_dense=3, d_ff_dense=18432, capacity_factor=1.25,
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp=True,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64, vocab=512,
    attn_chunk=64,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
                  d_ff_shared=64, first_dense=1, d_ff_dense=256,
                  capacity_factor=1.25),
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
)
