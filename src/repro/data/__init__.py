from .tokens import SyntheticLMDataset, synthetic_batch

__all__ = ["SyntheticLMDataset", "synthetic_batch"]
