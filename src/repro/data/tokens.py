"""Deterministic synthetic data pipelines.

Offline environment → no real corpora. The LM stream is a learnable-structure
synthetic language (orderly Markov-ish sequences with motifs) so training
loss meaningfully decreases; batches are derived purely from (seed, step,
host_id) so the pipeline is elastic: any host count / any restart step
reproduces the identical global batch — the property checkpoint-restart
tests rely on (no data-loader state to snapshot).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    motif_len: int = 8
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v = max(4, self.vocab - 1)
        self.motifs = rng.randint(1, v, size=(self.n_motifs, self.motif_len))

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict:
        """→ {"tokens": [B_host, S], "labels": [B_host, S]} int32 numpy."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) * 977 + self.host_id)
        b, s = self.host_batch, self.seq_len
        seq = np.zeros((b, s + 1), np.int64)
        pos = np.zeros(b, np.int64)
        while pos.min() < s + 1:
            ids = rng.randint(0, self.n_motifs, size=b)
            for i in range(b):
                if pos[i] >= s + 1:
                    continue
                m = self.motifs[ids[i]]
                take = min(self.motif_len, s + 1 - pos[i])
                seq[i, pos[i]:pos[i] + take] = m[:take]
                pos[i] += take
        seq = seq % self.vocab
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, step: int = 0,
                    seed: int = 0) -> dict:
    """One concrete batch matching registry.input_specs (incl. stub fronts)."""
    import jax.numpy as jnp

    ds = SyntheticLMDataset(cfg.vocab, shape.seq_len, shape.global_batch,
                            seed=seed)
    base = ds.batch(step)
    out = {"tokens": jnp.asarray(base["tokens"]),
           "labels": jnp.asarray(base["labels"])}
    rng = np.random.RandomState(seed + 17)
    if cfg.n_image_tokens:
        t = shape.seq_len - cfg.n_image_tokens
        out = {"tokens": out["tokens"][:, :t], "labels": out["labels"][:, :t]}
        out["image_embeds"] = jnp.asarray(
            rng.randn(shape.global_batch, cfg.n_image_tokens,
                      cfg.d_model).astype(np.float32) * 0.02, jnp.bfloat16)
    if cfg.encoder_layers:
        out["frames"] = jnp.asarray(
            rng.randn(shape.global_batch, cfg.encoder_len,
                      cfg.d_model).astype(np.float32) * 0.02, jnp.bfloat16)
    return out
