"""Fault-tolerant training loop.

Features (1000+ node posture, exercised by the integration tests):
  * jit'd train step with optional gradient-accumulation microbatching
    (lax.scan) and int8 gradient compression with error feedback;
  * GSPMD data/model parallelism: batch sharded over the mesh batch axes,
    params over the rule tree — gradient all-reduce is implicit;
  * atomic keep-N checkpoints every N steps + auto-resume: run() survives
    preemptions (simulated by PreemptionError injection in tests) by
    restoring the newest checkpoint and continuing — bitwise identically,
    since the data pipeline is (seed, step)-deterministic;
  * straggler watchdog: per-step wall-times vs a running median; slow steps
    are logged (at real scale this feeds the controller that triggers
    hot-spare swaps).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.data.tokens import SyntheticLMDataset
from repro.models import registry
from repro.optim import adafactor, adamw, apply_updates, cosine_warmup, \
    global_norm_clip
from repro.parallel import sharding
from repro.parallel.collectives import compress_decompress


class PreemptionError(RuntimeError):
    """Raised to simulate a node preemption mid-run (tests)."""


def make_optimizer(tc: TrainConfig):
    lr = cosine_warmup(tc.lr, tc.warmup_steps, tc.steps)
    if tc.optimizer == "adafactor":
        return adafactor(lr, weight_decay=tc.weight_decay)
    return adamw(lr, weight_decay=tc.weight_decay)


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Returns step(state, batch, rng) → (state, metrics). state is a dict
    {"params", "opt", ("err")} — err: compression error-feedback buffers."""
    mod = registry.get_module(cfg)
    opt = make_optimizer(tc)

    def loss_fn(params, batch, rng):
        return mod.train_loss(params, batch, cfg, rng)

    def grads_of(params, batch, rng):
        if tc.microbatch and tc.microbatch < batch["tokens"].shape[0]:
            b = batch["tokens"].shape[0]
            assert b % tc.microbatch == 0
            n = b // tc.microbatch
            micro = jax.tree.map(
                lambda a: a.reshape((n, tc.microbatch) + a.shape[1:]), batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb, rng)
                return jax.tree.map(jnp.add, acc,
                                    {"l": l / n,
                                     "g": jax.tree.map(lambda x: x / n, g)}), None

            zero = {"l": jnp.zeros(()),
                    "g": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)}
            acc, _ = jax.lax.scan(body, zero, micro)
            return acc["l"], acc["g"]
        return jax.value_and_grad(loss_fn)(params, batch, rng)

    def step(state, batch, rng):
        params, opt_state = state["params"], state["opt"]
        loss, grads = grads_of(params, batch, rng)
        grads, gnorm = global_norm_clip(grads, tc.grad_clip)
        if tc.grad_compression:
            pairs = jax.tree.map(compress_decompress, grads, state["err"])
            grads = jax.tree.map(lambda _, pr: pr[0], grads, pairs)
            new_err = jax.tree.map(lambda _, pr: pr[1], grads, pairs)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        new_state = {"params": params, "opt": opt_state}
        if tc.grad_compression:
            new_state["err"] = new_err
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return step, opt


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    shape: ShapeConfig
    tc: TrainConfig
    ckpt_dir: str
    preempt_at: Optional[int] = None      # test hook: raise at this step
    straggler_factor: float = 3.0

    def __post_init__(self):
        self.mgr = CheckpointManager(self.ckpt_dir, keep=self.tc.keep_checkpoints)
        self.step_fn, self.opt = make_train_step(self.cfg, self.tc)
        self.jit_step = jax.jit(self.step_fn, donate_argnums=(0,))
        self.data = SyntheticLMDataset(self.cfg.vocab, self.shape.seq_len,
                                       self.shape.global_batch,
                                       seed=self.tc.seed)
        self.metrics_log: list[dict] = []
        self.straggler_steps: list[int] = []

    def _init_state(self):
        params = registry.init_params(
            jax.random.PRNGKey(self.tc.seed), self.cfg,
            max_seq=self.shape.seq_len + 8)
        state = {"params": params, "opt": self.opt.init(params)}
        if self.tc.grad_compression:
            state["err"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state

    def _restore_or_init(self):
        latest = self.mgr.latest_step()
        if latest is None:
            return self._init_state(), 0
        like = jax.eval_shape(self._init_state)
        shardings = (sharding.tree_shardings(like)
                     if sharding.get_mesh() is not None else None)
        state, md = self.mgr.restore(like, shardings=shardings)
        return state, int(md["step"])

    def run_once(self) -> dict:
        """One attempt (may raise PreemptionError)."""
        state, start = self._restore_or_init()
        times: list[float] = []
        for step in range(start, self.tc.steps):
            if self.preempt_at is not None and step == self.preempt_at:
                self.preempt_at = None  # only once
                raise PreemptionError(f"simulated preemption at step {step}")
            batch = {k: jnp.asarray(v) for k, v in
                     self.data.batch(step).items()}
            rng = jax.random.fold_in(jax.random.PRNGKey(self.tc.seed), step)
            t0 = time.monotonic()
            state, metrics = self.jit_step(state, batch, rng)
            if step % self.tc.log_every == 0 or step == self.tc.steps - 1:
                metrics = {k: float(v) for k, v in metrics.items()}
                self.metrics_log.append({"step": step, **metrics})
            dt = time.monotonic() - t0
            times.append(dt)
            med = float(np.median(times[-32:]))
            if len(times) > 4 and dt > self.straggler_factor * med:
                self.straggler_steps.append(step)
            last_step = step + 1
            if last_step % self.tc.checkpoint_every == 0 \
                    or last_step == self.tc.steps:
                self.mgr.save(last_step, state)
        return {"state": state, "final_step": self.tc.steps,
                "metrics": self.metrics_log}

    def run(self, max_restarts: int = 4) -> dict:
        """Auto-resume loop: restart from the newest checkpoint on failure."""
        for attempt in range(max_restarts + 1):
            try:
                return self.run_once()
            except PreemptionError:
                if attempt == max_restarts:
                    raise
                continue
        raise RuntimeError("unreachable")
