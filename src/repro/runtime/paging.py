"""Paged KV-cache bookkeeping: fixed-size blocks, a free-list allocator,
and per-slot block tables.

The device side (models.transformer.init_paged_cache / paged_step) sees one
physical pool of `num_blocks` blocks per layer — [L, NB, block_size, KH, dh]
— plus an int32 block table [n_slots, max_blocks] mapping each slot's
logical block index to a physical block id. Everything in THIS module is
host-side numpy: allocation decisions are control flow, not compute, exactly
as a production engine keeps its allocator off the accelerator.

Conventions shared with the device step:
  * physical block 0 is the TRASH block — never allocated; masked-out
    (invalid-lane) cache writes are pointed at it, and unallocated block-
    table entries hold 0. Its contents are garbage by design and are never
    read with non-zero attention weight (positions >= slot length are
    masked before the softmax).
  * a slot's window is max_blocks × block_size tokens; block tables are
    dense int32 rows so they ship to the jit'd step as a plain [B, MB]
    operand.

Admission is conservative: `reserve()` claims the worst-case block count of
a request (ceil((prompt + max_new) / block_size)) up front, so a request
admitted under the policy can always extend its table mid-decode —
`allocate()` after a successful reserve cannot fail. This trades a little
pool headroom for never having to preempt a running request (the classic
vLLM-style alternative); the scheduler in runtime.server layers the
token-budget policy on top.
"""
from __future__ import annotations

import dataclasses

import numpy as np

TRASH_BLOCK = 0  # physical block 0: write sink for masked lanes, never allocated


@dataclasses.dataclass
class AllocatorStats:
    num_blocks: int           # usable blocks (excludes the trash block)
    in_use: int = 0
    reserved: int = 0         # claimed by admitted requests, not yet allocated
    peak_in_use: int = 0
    total_allocs: int = 0
    total_frees: int = 0

    @property
    def free(self) -> int:
        return self.num_blocks - self.in_use

    @property
    def available(self) -> int:
        """Blocks neither allocated nor promised to an admitted request."""
        return self.num_blocks - self.in_use - self.reserved


class BlockAllocator:
    """Free-list allocator over physical KV blocks 1..num_blocks.

    LIFO free list: freshly freed blocks are re-issued first, which is the
    adversarial order for stale-contents bugs — a reused block still holds
    the previous request's K/V until overwritten, so the equivalence soak
    test exercises exactly the masking the paged step must get right.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError("need at least 1 usable block beyond the trash "
                             f"block, got num_blocks={num_blocks}")
        # physical ids 1..num_blocks; 0 is the trash block
        self._free: list[int] = list(range(num_blocks, 0, -1))
        self.stats = AllocatorStats(num_blocks=num_blocks)

    # -- admission-time reservation ----------------------------------------
    def can_reserve(self, n: int) -> bool:
        return n <= self.stats.available

    def reserve(self, n: int) -> bool:
        """Claim n blocks for a request without allocating them yet."""
        if not self.can_reserve(n):
            return False
        self.stats.reserved += n
        return True

    def unreserve(self, n: int) -> None:
        assert self.stats.reserved >= n, (self.stats.reserved, n)
        self.stats.reserved -= n

    # -- allocation ---------------------------------------------------------
    def allocate(self, n: int, *, reserved: bool = True) -> list[int]:
        """Pop n physical block ids. With reserved=True (the server's path)
        the blocks were claimed at admission, so exhaustion is a logic bug,
        not an operating condition."""
        if n > len(self._free):
            raise RuntimeError(
                f"KV block pool exhausted: want {n}, free {len(self._free)} "
                f"(reserved {self.stats.reserved}) — admission policy must "
                "reserve before allocating")
        ids = [self._free.pop() for _ in range(n)]
        if reserved:
            self.unreserve(n)
        self.stats.in_use += n
        self.stats.total_allocs += n
        self.stats.peak_in_use = max(self.stats.peak_in_use,
                                     self.stats.in_use)
        return ids

    def free(self, ids: list[int]) -> None:
        for b in ids:
            assert b != TRASH_BLOCK, "freeing the trash block"
            self._free.append(b)
        self.stats.in_use -= len(ids)
        self.stats.total_frees += len(ids)


class SlotTables:
    """Host-side block tables + lengths for a pool of serving slots."""

    def __init__(self, n_slots: int, max_blocks: int, block_size: int):
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.tables = np.full((n_slots, max_blocks), TRASH_BLOCK, np.int32)
        self.lens = np.zeros(n_slots, np.int32)      # tokens written per slot
        self.n_alloc = np.zeros(n_slots, np.int32)   # blocks held per slot

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def grow(self, slot: int, new_len: int, alloc: BlockAllocator) -> None:
        """Extend slot's table so positions [0, new_len) are backed."""
        need = self.blocks_for(new_len)
        have = int(self.n_alloc[slot])
        if need > have:
            ids = alloc.allocate(need - have)
            self.tables[slot, have:need] = ids
            self.n_alloc[slot] = need

    def release(self, slot: int, alloc: BlockAllocator) -> None:
        held = int(self.n_alloc[slot])
        if held:
            alloc.free([int(b) for b in self.tables[slot, :held]])
        self.tables[slot, :] = TRASH_BLOCK
        self.n_alloc[slot] = 0
        self.lens[slot] = 0
