"""Paged KV-cache bookkeeping: refcounted blocks, a prefix trie, and
per-slot block tables.

The device side (models.transformer.init_paged_cache / paged_step) sees one
physical pool of `num_blocks` blocks per layer — [L, NB, block_size, KH, dh]
— plus an int32 block table [n_slots, max_blocks] mapping each slot's
logical block index to a physical block id. Everything in THIS module is
host-side numpy/python: allocation decisions are control flow, not compute,
exactly as a production engine keeps its allocator off the accelerator.

Conventions shared with the device step:
  * physical block 0 is the TRASH block — never allocated; masked-out
    (invalid-lane) cache writes are pointed at it, and unallocated block-
    table entries hold 0. Its contents are garbage by design and are never
    read with non-zero attention weight (positions >= slot length are
    masked before the softmax).
  * a slot's window is max_blocks × block_size tokens; block tables are
    dense int32 rows so they ship to the jit'd step as a plain [B, MB]
    operand.

Block lifecycle (PR 7 — the prefix-sharing redesign):

  * every live block carries a REFCOUNT: one ref per slot table that maps
    it, plus one ref if the prefix trie caches it. `acquire(n)` pops fresh
    blocks at refcount 1; `incref`/`decref` move sharers on and off; a
    block returns to the free list only when its last ref drops. There is
    no reservation ledger any more — admission is watermark-based and the
    scheduler preempts under pressure (runtime.server).
  * the PREFIX TRIE maps chains of full-block token prefixes to the block
    chain that already caches them. K/V content is a pure function of the
    absolute-position token prefix, so two requests sharing a prompt
    prefix can map the SAME physical blocks: zero prefill compute and
    zero new HBM for the shared span. Only FULL blocks are cached — a
    partially filled tail block's future contents depend on tokens the
    next request may not share.
  * sharing makes writes dangerous: a lane must never write into a block
    another holder can read. The scheduler copy-on-write-forks any shared
    block it is about to write (runtime.server._ensure_private via
    models.transformer.cow_copy_block) — the allocator's `refcount()` is
    the is-it-shared oracle.

LIFO free list, as before: freshly freed blocks are re-issued first, the
adversarial order for stale-contents bugs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

TRASH_BLOCK = 0  # physical block 0: write sink for masked lanes, never allocated


@dataclasses.dataclass
class AllocatorStats:
    """Pool accounting. `in_use` counts blocks with refcount >= 1 (this
    includes blocks held only by the prefix trie — evictable cache, not
    leaked memory); `shared` counts blocks with refcount >= 2."""
    num_blocks: int           # usable blocks (excludes the trash block)
    in_use: int = 0
    shared: int = 0           # refcount >= 2: mapped by >1 holder
    peak_in_use: int = 0
    total_allocs: int = 0
    total_frees: int = 0

    @property
    def free(self) -> int:
        return self.num_blocks - self.in_use

    @property
    def private(self) -> int:
        """Blocks held by exactly one holder (refcount == 1)."""
        return self.in_use - self.shared


class BlockAllocator:
    """Refcounted free-list allocator over physical KV blocks 1..num_blocks.

    The PR-7 surface: `acquire(n)` pops n blocks at refcount 1,
    `incref(ids)` adds a holder, `decref(ids)` drops one and frees blocks
    whose count reaches 0 (returning them so callers can account). The
    old reservation API (reserve/unreserve/allocate/free) is gone — the
    server's watermark admission + preemption replaced it.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError("need at least 1 usable block beyond the trash "
                             f"block, got num_blocks={num_blocks}")
        # physical ids 1..num_blocks; 0 is the trash block
        self._free: list[int] = list(range(num_blocks, 0, -1))
        self._ref = np.zeros(num_blocks + 1, np.int64)
        self.stats = AllocatorStats(num_blocks=num_blocks)

    def refcount(self, block: int) -> int:
        return int(self._ref[block])

    def can_acquire(self, n: int) -> bool:
        return n <= len(self._free)

    def acquire(self, n: int) -> list[int]:
        """Pop n fresh physical block ids, each at refcount 1. The server
        checks capacity (and evicts/preempts) first, so exhaustion here is
        a scheduler logic bug, not an operating condition."""
        if n > len(self._free):
            raise RuntimeError(
                f"KV block pool exhausted: want {n}, free {len(self._free)} "
                "— the scheduler must evict or preempt before acquiring")
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            self._ref[b] = 1
        st = self.stats
        st.in_use += n
        st.total_allocs += n
        st.peak_in_use = max(st.peak_in_use, st.in_use)
        return ids

    def incref(self, ids: list[int]) -> None:
        """Add one holder to each block (a slot table mapping it, the
        prefix trie caching it, or a pending fork stash)."""
        for b in ids:
            assert b != TRASH_BLOCK, "refcounting the trash block"
            assert self._ref[b] >= 1, f"incref on unallocated block {b}"
            self._ref[b] += 1
            if self._ref[b] == 2:
                self.stats.shared += 1

    def decref(self, ids: list[int]) -> list[int]:
        """Drop one holder from each block; blocks reaching refcount 0 go
        back on the free list. Returns the freed ids."""
        freed = []
        for b in ids:
            assert b != TRASH_BLOCK, "freeing the trash block"
            assert self._ref[b] >= 1, f"decref on free block {b}"
            self._ref[b] -= 1
            if self._ref[b] == 1:
                self.stats.shared -= 1
            elif self._ref[b] == 0:
                self._free.append(b)
                freed.append(b)
        self.stats.in_use -= len(freed)
        self.stats.total_frees += len(freed)
        return freed


class _TrieNode:
    __slots__ = ("tokens", "block", "parent", "children", "tick")

    def __init__(self, tokens: tuple, block: int, parent):
        self.tokens = tokens          # this block's token chunk (len == bs)
        self.block = block            # physical block id caching it
        self.parent = parent
        self.children: dict[tuple, _TrieNode] = {}
        self.tick = 0                 # LRU clock value of last touch


class PrefixTrie:
    """Token-prefix chain → physical block chain, for prefix-shared
    admission.

    Each node caches ONE full block: the node's path from the root spells
    a token prefix of length depth × block_size, and `node.block` is the
    physical block holding that chunk's K/V (valid because K/V content is
    a pure function of the absolute-position token prefix — RoPE phases
    and projections depend only on the tokens before it).

    The trie holds its OWN reference on every cached block (incref on
    insert), so cached prefixes survive the request that produced them.
    Cached-but-unshared blocks (refcount == 1, the trie's) are the
    evictable pool: `evict()` LRU-frees leaves first, never touching a
    block a live slot still maps. Matching is exact (nested dicts keyed
    by token tuples) — no hash collisions to reason about.
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._root = _TrieNode((), TRASH_BLOCK, None)
        self._by_block: dict[int, _TrieNode] = {}
        self._clock = 0
        self.hits = 0            # match() calls that returned >= 1 block
        self.hit_blocks = 0      # blocks returned across all matches
        self.evictions = 0       # blocks freed by evict()/forget_block()
        self.sweeps = 0          # watermark sweeps that freed something
        self.sweep_freed = 0     # blocks freed by those sweeps

    # -- introspection -----------------------------------------------------
    @property
    def cached_blocks(self) -> int:
        return len(self._by_block)

    def owns(self, block: int) -> bool:
        return block in self._by_block

    def cached_cold(self, alloc: BlockAllocator) -> int:
        """Blocks whose ONLY holder is the trie (refcount == 1): the cold
        prefix cache. Unlike evictable() this ignores subtree structure —
        it answers "how much of the pool is cache, not live state", the
        composition split telemetry and ServerMetrics.to_dict expose."""
        return sum(1 for b in self._by_block if alloc.refcount(b) == 1)

    def evictable(self, alloc: BlockAllocator) -> int:
        """Blocks evict() could free right now: nodes whose block has no
        holder besides the trie AND whose whole subtree is likewise free
        (leaf-first eviction cannot reach past an in-use descendant)."""

        def walk(node) -> tuple[int, bool]:
            count, all_ev = 0, True
            for ch in node.children.values():
                c, ev = walk(ch)
                count += c
                all_ev &= ev
            mine = alloc.refcount(node.block) == 1 and all_ev
            return count + (1 if mine else 0), mine

        return sum(walk(ch)[0] for ch in self._root.children.values())

    # -- lookup / registration --------------------------------------------
    def match(self, tokens: list) -> list[int]:
        """Longest chain of cached full blocks prefixing `tokens`.

        Callers that need at least one token left to prefill (the step
        must run SOME token to produce first-emission logits) pass
        tokens[:-1]."""
        bs = self.block_size
        node, out = self._root, []
        self._clock += 1
        for i in range(len(tokens) // bs):
            child = node.children.get(tuple(tokens[i * bs:(i + 1) * bs]))
            if child is None:
                break
            child.tick = self._clock
            out.append(child.block)
            node = child
        if out:
            self.hits += 1
            self.hit_blocks += len(out)
        return out

    def insert(self, tokens: list, blocks: list[int],
               alloc: BlockAllocator) -> int:
        """Register `blocks` as the cache of `tokens` (full blocks only;
        len(tokens) == len(blocks) × block_size). Chunks already cached
        keep their canonical block — the caller's duplicate stays owned by
        the caller alone (content is identical by purity, so either copy
        serves future matches). Newly registered blocks get the trie's
        ref. Returns how many were newly registered."""
        bs = self.block_size
        assert len(tokens) == len(blocks) * bs, (len(tokens), len(blocks))
        node, added = self._root, 0
        self._clock += 1
        for i, block in enumerate(blocks):
            chunk = tuple(tokens[i * bs:(i + 1) * bs])
            child = node.children.get(chunk)
            if child is None:
                assert block not in self._by_block, \
                    f"block {block} cached under two prefixes"
                child = _TrieNode(chunk, block, node)
                node.children[chunk] = child
                self._by_block[block] = child
                alloc.incref([block])
                added += 1
            child.tick = self._clock
            node = child
        return added

    # -- eviction ----------------------------------------------------------
    def _drop_node(self, node: _TrieNode, alloc: BlockAllocator) -> int:
        """Remove one node (must be childless) and release the trie's ref;
        returns 1 if the block actually went back to the free list."""
        assert not node.children
        del node.parent.children[node.tokens]
        del self._by_block[node.block]
        freed = alloc.decref([node.block])
        self.evictions += len(freed)
        return len(freed)

    def evict(self, n: int, alloc: BlockAllocator) -> int:
        """Free up to n blocks, LRU leaves first (a removed leaf may expose
        its parent as the next candidate). Leaves whose block a live slot
        still maps (refcount > 1) are skipped — dropping them would free
        nothing. Returns blocks actually freed."""
        freed = 0
        while freed < n:
            best = None
            stack = list(self._root.children.values())
            while stack:
                node = stack.pop()
                if node.children:
                    stack.extend(node.children.values())
                elif alloc.refcount(node.block) == 1:
                    if best is None or node.tick < best.tick:
                        best = node
            if best is None:
                break
            freed += self._drop_node(best, alloc)
        return freed

    def sweep(self, alloc: BlockAllocator, high: int, low: int) -> int:
        """High/low-watermark capacity sweep: when the trie caches more
        than `high` blocks, LRU-evict down toward `low` (both absolute
        block counts — the server derives them from a pool fraction,
        ServingConfig.trie_watermark). The point: a long-lived server's
        trie otherwise retains every cold prefix it ever saw, pinning the
        whole pool as cache between bursts; the sweep runs from step()
        even on idle steps, so capacity drains back WITHOUT waiting for
        admission pressure. Best-effort: entries whose block a live slot
        still maps are skipped (evicting them would free nothing).
        Returns blocks actually freed; hysteresis (low < high) keeps the
        sweep from thrashing at the threshold."""
        if low > high:
            raise ValueError(f"low watermark {low} > high {high}")
        if self.cached_blocks <= high:
            return 0
        freed = self.evict(self.cached_blocks - low, alloc)
        if freed:
            self.sweeps += 1
            self.sweep_freed += freed
        return freed

    def forget_block(self, block: int, alloc: BlockAllocator) -> None:
        """Drop the cache entry for `block` (and its whole subtree — the
        children's prefixes extend through it). Used by the scheduler's
        write path: when the only other holder of a to-be-written block is
        the trie, un-caching it beats copy-on-write (no copy, no new
        block). Subtree blocks shared with live slots survive the decref;
        only the cache entries go."""
        node = self._by_block.get(block)
        if node is None:
            return
        # post-order: children before parents (children hold no structural
        # refs on the parent, but _drop_node asserts childlessness)
        def drop(nd):
            for ch in list(nd.children.values()):
                drop(ch)
            self._drop_node(nd, alloc)
        drop(node)

    def flush(self, alloc: BlockAllocator) -> int:
        """Evict every entry (in-use blocks merely lose their cache ref).
        Returns blocks freed."""
        freed = 0
        for ch in list(self._root.children.values()):
            before = self.evictions
            self.forget_block(ch.block, alloc)
            freed += self.evictions - before
        return freed


class SlotTables:
    """Host-side block tables + lengths for a pool of serving slots."""

    def __init__(self, n_slots: int, max_blocks: int, block_size: int):
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.tables = np.full((n_slots, max_blocks), TRASH_BLOCK, np.int32)
        self.lens = np.zeros(n_slots, np.int32)      # tokens written per slot
        self.n_alloc = np.zeros(n_slots, np.int32)   # blocks held per slot

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def held(self, slot: int) -> list[int]:
        return [int(b) for b in self.tables[slot, :int(self.n_alloc[slot])]]

    def assign(self, slot: int, blocks: list[int], n_tokens: int) -> None:
        """Install an existing block chain (a trie-matched prefix or a fork
        stash) covering the slot's first n_tokens. The caller has already
        incref'd `blocks` on this slot's behalf."""
        assert int(self.n_alloc[slot]) == 0, "assign into a dirty slot"
        assert len(blocks) <= self.max_blocks
        self.tables[slot, :len(blocks)] = blocks
        self.n_alloc[slot] = len(blocks)
        self.lens[slot] = n_tokens

    def grow(self, slot: int, new_len: int, alloc: BlockAllocator) -> None:
        """Extend slot's table so positions [0, new_len) are backed."""
        need = self.blocks_for(new_len)
        have = int(self.n_alloc[slot])
        if need > have:
            ids = alloc.acquire(need - have)
            self.tables[slot, have:need] = ids
            self.n_alloc[slot] = need

    def replace(self, slot: int, idx: int, new_block: int,
                alloc: BlockAllocator) -> None:
        """Point logical block idx at a private copy (CoW fork): the slot
        drops its ref on the shared original and maps `new_block` (already
        acquired at refcount 1 by the caller, contents device-copied)."""
        old = int(self.tables[slot, idx])
        assert old != TRASH_BLOCK and idx < int(self.n_alloc[slot])
        self.tables[slot, idx] = new_block
        alloc.decref([old])

    def release(self, slot: int, alloc: BlockAllocator) -> list[int]:
        """Drop the slot's ref on every held block; blocks shared with the
        trie or other holders survive. Returns the blocks actually freed."""
        freed = alloc.decref(self.held(slot))
        self.tables[slot, :] = TRASH_BLOCK
        self.n_alloc[slot] = 0
        self.lens[slot] = 0
        return freed
