"""Telemetry exporters: Chrome trace-event JSON, Prometheus text, JSONL.

Consumes a :class:`repro.runtime.telemetry.Telemetry` instance and renders
it for external tooling:

* :func:`chrome_trace` — Chrome trace-event JSON (the Perfetto / legacy
  ``chrome://tracing`` format): one track per serving slot carrying
  ``req<rid>`` spans from admit/resume to retire/preempt, plus a scheduler
  track with per-``step()`` slices and KV-pool counter series.  Load the
  file at https://ui.perfetto.dev.
* :func:`validate_chrome_trace` — structural schema check used by CI on the
  emitted artifact; also runnable directly::

      python -m repro.runtime.obs trace.json

* :func:`prometheus_text` — Prometheus text-exposition snapshot (histograms
  with ``_bucket``/``_sum``/``_count``, counters, pool gauges, per-site CIM
  energy).
* :func:`write_events_jsonl` — raw event + snapshot log, one JSON object
  per line.

This module is stdlib-only, like ``telemetry`` itself.
"""
from __future__ import annotations

import json
import sys

_PID = 1
_SCHED_TID = 0
# ph values the exporter emits; the validator rejects anything else.
_KNOWN_PH = frozenset({"M", "B", "E", "X", "i", "C"})

# event kinds rendered as instants on the request's slot track
_INSTANT_KINDS = ("prefill_chunk", "first_token", "decode", "spec_verify",
                  "cow_fork")


def _us(t: float, t0: float) -> float:
    return round((t - t0) * 1e6, 3)


def chrome_trace(tel, *, process_name: str = "pico-ram serve") -> dict:
    """Render the telemetry ring buffers as a Chrome trace-event document.

    Track layout: tid 0 is the scheduler (step slices, submit instants,
    KV-pool counters); tid ``slot + 1`` carries that slot's request spans.
    Ring-buffer truncation is handled by construction: an ``E`` whose ``B``
    was evicted is dropped, and spans still open at export time are closed
    with a synthetic ``E`` flagged ``{"truncated": true}``.
    """
    events = list(tel.events)
    snaps = list(tel.snapshots)
    times = [e.t for e in events] + [s.t - s.wall_s for s in snaps]
    t0 = min(times) if times else 0.0
    t_end = max([e.t for e in events] + [s.t for s in snaps], default=0.0)

    out = [
        {"ph": "M", "pid": _PID, "tid": _SCHED_TID, "ts": 0,
         "name": "process_name", "args": {"name": process_name}},
        {"ph": "M", "pid": _PID, "tid": _SCHED_TID, "ts": 0,
         "name": "thread_name", "args": {"name": "scheduler"}},
    ]
    named_tids = {_SCHED_TID}

    def slot_tid(slot: int) -> int:
        tid = slot + 1
        if tid not in named_tids:
            named_tids.add(tid)
            out.append({"ph": "M", "pid": _PID, "tid": tid, "ts": 0,
                        "name": "thread_name",
                        "args": {"name": f"slot {slot}"}})
        return tid

    open_spans: dict[int, list[str]] = {}   # tid -> stack of span names

    for e in events:
        args = {"rid": e.rid}
        if e.data:
            args.update(e.data)
        if e.kind == "submit":
            out.append({"ph": "i", "pid": _PID, "tid": _SCHED_TID,
                        "ts": _us(e.t, t0), "s": "t",
                        "name": f"submit req{e.rid}", "args": args})
        elif e.kind in ("admit", "resume"):
            tid = slot_tid(e.slot)
            name = f"req{e.rid}"
            open_spans.setdefault(tid, []).append(name)
            out.append({"ph": "B", "pid": _PID, "tid": tid,
                        "ts": _us(e.t, t0), "name": name, "cat": e.kind,
                        "args": args})
        elif e.kind in ("retire", "preempt"):
            tid = slot_tid(e.slot)
            name = f"req{e.rid}"
            stack = open_spans.get(tid, [])
            if stack and stack[-1] == name:
                stack.pop()
                out.append({"ph": "E", "pid": _PID, "tid": tid,
                            "ts": _us(e.t, t0), "name": name,
                            "cat": e.kind, "args": args})
            # else: the matching B fell out of the ring buffer — drop the E
        elif e.kind == "decode" and e.data and "lanes" in e.data:
            # batched per-step decode event (Telemetry.decode_step):
            # expand back into one instant per emitting lane
            for rid, slot in e.data["lanes"]:
                out.append({"ph": "i", "pid": _PID, "tid": slot_tid(slot),
                            "ts": _us(e.t, t0), "s": "t", "name": "decode",
                            "args": {"rid": rid}})
        elif e.kind in _INSTANT_KINDS:
            out.append({"ph": "i", "pid": _PID, "tid": slot_tid(e.slot),
                        "ts": _us(e.t, t0), "s": "t", "name": e.kind,
                        "args": args})

    # close spans still open at export time (mid-run export)
    for tid, stack in open_spans.items():
        while stack:
            out.append({"ph": "E", "pid": _PID, "tid": tid,
                        "ts": _us(t_end, t0), "name": stack.pop(),
                        "args": {"truncated": True}})

    for s in snaps:
        ts = _us(s.t - s.wall_s, t0)
        out.append({"ph": "X", "pid": _PID, "tid": _SCHED_TID, "ts": ts,
                    "dur": round(s.wall_s * 1e6, 3),
                    "name": f"step c={s.c}" + (" spec" if s.all_logits else ""),
                    "args": s.to_dict()})
        out.append({"ph": "C", "pid": _PID, "tid": _SCHED_TID,
                    "ts": _us(s.t, t0), "name": "kv_pool",
                    "args": {"free": s.blocks_free,
                             "private": s.blocks_private,
                             "shared": s.blocks_shared,
                             "cached_cold": s.blocks_cached_cold}})
        out.append({"ph": "C", "pid": _PID, "tid": _SCHED_TID,
                    "ts": _us(s.t, t0), "name": "lanes",
                    "args": {"decode": s.decode_lanes,
                             "prefill": s.prefill_lanes,
                             "spec": s.spec_lanes}})

    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"telemetry": tel.summary()}}


def validate_chrome_trace(doc) -> list[str]:
    """Structural schema check on a Chrome trace-event document.

    Returns a list of problems (empty == valid).  Checks: top-level shape,
    required per-event fields, known ``ph`` values, numeric non-negative
    timestamps, ``X`` durations >= 0, and balanced ``B``/``E`` nesting per
    thread track.
    """
    problems: list[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["top level must be an object with a traceEvents list"]
    stacks: dict[tuple, list[str]] = {}
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _KNOWN_PH:
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        for key in ("pid", "tid", "ts", "name"):
            if key not in ev:
                problems.append(f"{where}: missing {key!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event with bad dur {dur!r}")
        elif ph == "B":
            stacks.setdefault((ev.get("pid"), ev.get("tid")),
                              []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.get((ev.get("pid"), ev.get("tid")), [])
            if not stack:
                problems.append(f"{where}: E without open B on its track")
            else:
                opened = stack.pop()
                if opened != ev.get("name"):
                    problems.append(
                        f"{where}: E {ev.get('name')!r} closes B "
                        f"{opened!r}")
        elif ph == "i" and ev.get("s") not in (None, "t", "p", "g"):
            problems.append(f"{where}: bad instant scope {ev.get('s')!r}")
    for (pid, tid), stack in stacks.items():
        if stack:
            problems.append(
                f"track pid={pid} tid={tid}: {len(stack)} unclosed B "
                f"event(s): {stack}")
    return problems


# ---------------------------------------------------------------------------
# Prometheus text exposition


def _prom_hist(lines: list[str], name: str, hist, help_text: str) -> None:
    lines.append(f"# HELP {name} {help_text}")
    lines.append(f"# TYPE {name} histogram")
    cum = 0
    for bound, count in zip(hist.bounds, hist.counts):
        cum += count
        lines.append(f'{name}_bucket{{le="{bound:g}"}} {cum}')
    lines.append(f'{name}_bucket{{le="+Inf"}} {hist.n}')
    lines.append(f"{name}_sum {hist.total:g}")
    lines.append(f"{name}_count {hist.n}")


def prometheus_text(tel, server=None) -> str:
    """Render telemetry (and optionally ``server.metrics``) as a Prometheus
    text-exposition snapshot."""
    lines: list[str] = []
    _prom_hist(lines, "picoram_ttft_seconds", tel.ttft,
               "time to first token")
    _prom_hist(lines, "picoram_itl_seconds", tel.itl,
               "inter-token latency per decode step")
    _prom_hist(lines, "picoram_accept_length", tel.accept_len,
               "accepted draft tokens per spec-decode verify step")
    _prom_hist(lines, "picoram_step_wall_seconds", tel.step_wall,
               "scheduler step wall time")

    lines.append("# HELP picoram_events_total lifecycle trace events by kind")
    lines.append("# TYPE picoram_events_total counter")
    for kind in sorted(tel.counters):
        lines.append(f'picoram_events_total{{kind="{kind}"}} '
                     f"{tel.counters[kind]}")

    k = tel.kernel
    lines.append("# HELP picoram_mvm_dispatch_total traced execute_mvm "
                 "backend picks (one per compiled shape, not per step)")
    lines.append("# TYPE picoram_mvm_dispatch_total counter")
    for name in sorted(k.backend_dispatch):
        lines.append(f'picoram_mvm_dispatch_total{{backend="{name}"}} '
                     f"{k.backend_dispatch[name]}")
    lines.append("# HELP picoram_attn_dispatch_total traced paged-attention "
                 "backend picks")
    lines.append("# TYPE picoram_attn_dispatch_total counter")
    for name in sorted(k.attn_dispatch):
        lines.append(f'picoram_attn_dispatch_total{{backend="{name}"}} '
                     f"{k.attn_dispatch[name]}")
    lines.append("# HELP picoram_tune_cache_total tuning-cache lookups")
    lines.append("# TYPE picoram_tune_cache_total counter")
    for key in sorted(k.tune_cache):
        kernel, outcome = key.rsplit(":", 1)
        lines.append(f'picoram_tune_cache_total{{kernel="{kernel}",'
                     f'outcome="{outcome}"}} {k.tune_cache[key]}')
    lines.append("# HELP picoram_tune_cache_fallback_warnings_total "
                 "malformed tune caches ignored at load")
    lines.append("# TYPE picoram_tune_cache_fallback_warnings_total counter")
    lines.append(f"picoram_tune_cache_fallback_warnings_total "
                 f"{k.fallback_warnings}")
    lines.append("# HELP picoram_drafter_total drafter proposal outcomes")
    lines.append("# TYPE picoram_drafter_total counter")
    for name in sorted(k.drafter):
        lines.append(f'picoram_drafter_total{{event="{name}"}} '
                     f"{k.drafter[name]}")
    lines.append("# HELP picoram_mvm_energy_joules_total paper-model CIM "
                 "MVM energy per weight site across traced calls")
    lines.append("# TYPE picoram_mvm_energy_joules_total counter")
    for site in sorted(k.site_energy):
        lines.append(f'picoram_mvm_energy_joules_total{{site="{site}"}} '
                     f"{k.site_energy[site]['energy_j']:.6e}")
    lines.append("# HELP picoram_mvm_traced_dots_total K-deep dot products "
                 "per weight site across traced calls")
    lines.append("# TYPE picoram_mvm_traced_dots_total counter")
    for site in sorted(k.site_energy):
        lines.append(f'picoram_mvm_traced_dots_total{{site="{site}"}} '
                     f"{k.site_energy[site]['dots']}")

    if server is not None:
        m = server.metrics.to_dict()
        pool_keys = {"blocks_total", "blocks_free", "blocks_private",
                     "blocks_shared", "blocks_cached_cold", "trie_entries"}
        lines.append("# HELP picoram_server_metric aggregate ServerMetrics "
                     "counters")
        lines.append("# TYPE picoram_server_metric gauge")
        for key in sorted(m):
            if key in pool_keys or key == "accept_hist":
                continue
            val = m[key]
            if isinstance(val, (int, float)):
                lines.append(f'picoram_server_metric{{name="{key}"}} '
                             f"{val:g}")
        lines.append("# HELP picoram_kv_blocks KV pool composition")
        lines.append("# TYPE picoram_kv_blocks gauge")
        for state in ("free", "private", "shared", "cached_cold"):
            if f"blocks_{state}" in m:
                lines.append(f'picoram_kv_blocks{{state="{state}"}} '
                             f"{m[f'blocks_{state}']}")
        if "trie_entries" in m:
            lines.append("# HELP picoram_trie_entries prefix-trie cached "
                         "block entries")
            lines.append("# TYPE picoram_trie_entries gauge")
            lines.append(f"picoram_trie_entries {m['trie_entries']}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# JSONL


def write_events_jsonl(tel, path: str) -> int:
    """Write events + step snapshots as JSONL; returns the line count."""
    n = 0
    with open(path, "w") as f:
        for e in tel.events:
            f.write(json.dumps(e.to_dict()) + "\n")
            n += 1
        for s in tel.snapshots:
            f.write(json.dumps(s.to_dict()) + "\n")
            n += 1
    return n


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.runtime.obs <trace.json>",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        doc = json.load(f)
    problems = validate_chrome_trace(doc)
    if problems:
        for p in problems:
            print(f"INVALID: {p}", file=sys.stderr)
        return 1
    print(f"ok: {argv[0]} valid ({len(doc['traceEvents'])} trace events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
