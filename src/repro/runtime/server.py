"""Continuous-batching serving loop: paged KV cache + chunked prefill.

Two engines share one Server front end (submit / step / run_until_drained):

* **paged** (`paged=True`, the production path): a physical pool of
  fixed-size KV blocks shared by all slots, a free-list `BlockAllocator`
  with conservative admission reservations (runtime.paging), and per-slot
  block tables threaded through the model's attention reads/writes
  (models.transformer.paged_step). Resident KV bytes scale with the tokens
  actually cached, not n_slots × max_len. Prefill is CHUNKED through the
  same jit'd step as decode — decode is just the C=1 compilation of the
  unified step, and a mixed batch advances decode lanes (valid=1) inside a
  prefill-chunk-wide call — so there are no per-prompt-bucket prefill jits
  and no host-side cache splicing. A token-budget scheduler caps the new
  tokens per step (decode lanes first — latency — then prompt chunks up to
  the remaining budget). Per-request latency (TTFT, total) and server
  throughput metrics are recorded as requests flow.

* **slot-based** (`paged=False`, the legacy engine, kept as the
  equivalence baseline): a monolithic [n_slots, max_len] cache; requests
  prefill individually (jit'd per prompt-length bucket) and are spliced
  into the batched cache; one shared `pos` clocks every slot. The paged
  soak tests pin the paged engine's outputs against this path and against
  one-request-at-a-time decode. NOTE the shared `pos` means slots admitted
  at different depths attend over zero-K/V gap positions (softmax
  dilution); the paged engine keeps true per-slot positions, so
  equivalence with this path is exact only on depth-aligned schedules —
  see tests/test_server_paged.py.

Greedy sampling; EOS/max-token retirement frees slots (and, for the paged
engine, their blocks — LIFO reuse, so stale block contents are exercised
constantly) for queued requests. One deliberate semantic divergence: the
legacy engine applies neither the max_new_tokens nor the eos_id check to
the token emitted at prefill time (a max_new_tokens=1 request overshoots
to 2 tokens there; an EOS first token keeps decoding); the paged engine
checks both and retires immediately, matching one-request-at-a-time
decode. Unservable requests (prompt ≥ max_len, or a
worst-case block reservation larger than the whole pool) are rejected at
submit() so they can never poison the queue.

Attention backends (paged engine): `Server(attn=...)` selects the paged
step's attention path from the kernels.paged_attention registry — "exact"
(the PR-4 gather + one-pass softmax, the bit-identity anchor), "kernel"
(the Pallas flash kernel: block gather inside the kernel, online softmax in
VMEM, no [B, C, KH, G, W] score tensor), or "auto" (kernel, unless
REPRO_FORCE_JNP=1 pins exact). The kernel path agrees with exact within
float tolerance, so greedy tokens match except on near-tie logits; the
bit-identity soak contracts below are pinned against attn="exact".

The bit-identity contracts above hold for FLOAT models (and for any fixed
schedule). Under `cim.enabled` the engine's dynamic per-tensor act_scale
(core.quant.act_scale — a global max over the batched activation tensor)
couples every lane's quantization grid to the whole batch's content, so
CIM-mode outputs depend on batch COMPOSITION — a pre-existing property of
the seed slot engine that the paged engine inherits identically (both
engines agree under the same schedule; different token budgets can differ
on near-tie logits). The production fix is `Server(act_scale=...)`: a
static calibrated scale (analysis.calibrate) pins one fixed input-DAC grid
(zero point 0) for every lane, making a request's tokens invariant to
batch composition — pinned by tests/test_calibrate.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.runtime.paging import BlockAllocator, SlotTables


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the server:
    rid: int = -1
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # per-request latency metrics (monotonic timestamps)
    t_submit: float = 0.0
    t_first: float = 0.0     # first token emitted (prefill complete)
    t_done: float = 0.0

    @property
    def ttft_s(self) -> float:
        return max(self.t_first - self.t_submit, 0.0)

    @property
    def latency_s(self) -> float:
        return max(self.t_done - self.t_submit, 0.0)


@dataclasses.dataclass
class ServerMetrics:
    steps: int = 0
    decode_tokens: int = 0    # tokens emitted by decode lanes
    prefill_tokens: int = 0   # prompt tokens prefilled (either engine)
    stalled_prefills: int = 0  # prefill lanes given 0 budget in a step
    stalled_decodes: int = 0   # decode lanes dropped by the token budget
    wall_s: float = 0.0       # time inside step() + admission-time prefill

    def summary(self) -> dict:
        w = max(self.wall_s, 1e-9)
        return {"steps": self.steps,
                "decode_tokens": self.decode_tokens,
                "prefill_tokens": self.prefill_tokens,
                "decode_tok_s": self.decode_tokens / w,
                "prefill_tok_s": self.prefill_tokens / w,
                "stalled_prefills": self.stalled_prefills,
                "stalled_decodes": self.stalled_decodes,
                "wall_s": self.wall_s}


class Server:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int,
                 max_len: int, prequant: bool = False, packed: bool = True,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: int | None = None, prefill_chunk: int = 16,
                 token_budget: int | None = None, attn: str = "auto",
                 act_scale: float | None = None):
        """prequant=True re-encodes CIM-routed weights as offline-quantized
        stored codes before serving (models.quantize.quantize_params) —
        nibble-packed uint8 when `packed` (4 bits/weight at rest, the
        SRAM-faithful format), else int8 containers; composes with either
        engine. paged=True selects the paged-KV engine (see module
        docstring): `block_size` tokens per block, `num_blocks` usable
        blocks in the pool (default: parity with the slot cache,
        n_slots × max_len / block_size — size it smaller to realize the
        paged memory win), `prefill_chunk` tokens per prompt chunk and
        `token_budget` max new tokens per step (default: decode lanes +
        one full prefill chunk). `attn` picks the paged attention backend
        ("auto" | "exact" | "kernel" — see module docstring).
        `act_scale` pins a static calibrated activation scale (the value
        from analysis.calibrate.calibrate_act_scale) into the CIM
        quantizer — requires cfg.cim.enabled."""
        from repro.kernels.paged_attention import choose_attn_backend
        choose_attn_backend(attn)   # validate the name up front
        cfg = cfg.replace(attn_backend=attn)
        if act_scale is not None:
            assert cfg.cim.enabled, "static act_scale needs cim.enabled"
            cfg = cfg.replace(cim=dataclasses.replace(
                cfg.cim, act=dataclasses.replace(
                    cfg.cim.act, static_scale=float(act_scale))))
        if prequant:
            assert cfg.cim.enabled, "prequant serving needs cim.enabled"
            from repro.models.quantize import quantize_params
            params = quantize_params(params, cfg, packed=packed)
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.mod = registry.get_module(cfg)
        self.paged = paged
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.queue: list[Request] = []
        self._next_rid = 0
        self.steps_run = 0
        self.metrics = ServerMetrics()

        if paged:
            if not (hasattr(self.mod, "paged_step")
                    and self.mod.supports_paged(cfg)):
                raise NotImplementedError(
                    f"paged serving not supported for arch {cfg.arch!r}")
            if max_len % block_size:
                raise ValueError("max_len must be a multiple of block_size")
            self.block_size = block_size
            max_blocks = max_len // block_size
            if num_blocks is None:
                num_blocks = n_slots * max_blocks
            self.alloc = BlockAllocator(num_blocks)
            self.tables = SlotTables(n_slots, max_blocks, block_size)
            if prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            self.prefill_chunk = prefill_chunk
            self.token_budget = token_budget if token_budget is not None \
                else n_slots + prefill_chunk
            if self.token_budget < 1:
                raise ValueError("token_budget must be >= 1 (a 0 budget "
                                 "would step forever without progress)")
            # pool holds num_blocks usable blocks + the trash block (id 0)
            self.cache = jax.jit(
                lambda: self.mod.init_paged_cache(cfg, num_blocks + 1,
                                                  block_size))()
            self._pstep = jax.jit(
                lambda p, t, c, tb, ln, vd:
                    self.mod.paged_step(p, t, c, tb, ln, vd, cfg))
            self._reserved: dict[int, int] = {}   # slot → blocks reserved
            self._pf_done = np.zeros(n_slots, np.int64)  # prompt tokens fed
            self._rr = 0   # round-robin offset for budget-capped decode
        else:
            self.slot_len = np.zeros(n_slots, np.int32)
            self.cache = jax.jit(
                lambda: self.mod.init_cache(cfg, n_slots, max_len))()
            self._decode = jax.jit(
                lambda p, t, c: self.mod.decode_step(p, t, c, cfg))
            self._prefill = jax.jit(
                lambda p, b: self.mod.prefill(p, b, cfg, max_len=max_len),
                static_argnames=())

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> int:
        # reject unservable requests BEFORE queueing: a poison request at
        # the queue head would otherwise either block admission forever
        # (worst-case reservation larger than the whole pool —
        # run_until_drained would spin) or crash mid-serve and strand the
        # in-flight requests.
        if not req.prompt:
            raise ValueError("empty prompt")
        if self.paged:
            if len(req.prompt) >= self.max_len - 1:
                raise ValueError(
                    f"prompt of {len(req.prompt)} tokens exceeds "
                    f"max_len={self.max_len}")
            need = self._blocks_worst_case(req)
            if need > self.alloc.stats.num_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks worst-case but the "
                    f"pool only has {self.alloc.stats.num_blocks}")
        req.rid = self._next_rid
        req.t_submit = time.monotonic()
        self._next_rid += 1
        self.queue.append(req)
        # admission work (incl. the legacy engine's per-request prefill)
        # counts toward wall_s so both engines' tok/s share one clock
        t0 = time.monotonic()
        self._admit()
        self.metrics.wall_s += time.monotonic() - t0
        return req.rid

    def _admit(self):
        if self.paged:
            self._admit_paged()
            return
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        tokens = jnp.asarray([req.prompt], jnp.int32)
        batch = {"tokens": tokens}
        logits, rcache = self._prefill(self.params, batch)
        first = int(jnp.argmax(logits[0]))
        req.output.append(first)
        req.t_first = time.monotonic()
        self.metrics.prefill_tokens += len(req.prompt)
        self.slot_req[slot] = req
        self.slot_len[slot] = len(req.prompt)
        self.cache = _splice(self.cache, rcache, slot)

    # -- decode loop ----------------------------------------------------------
    def step(self):
        """One serving step; retires finished requests and re-admits."""
        t0 = time.monotonic()
        if self.paged:
            self._step_paged()
        else:
            self._step_slots()
        self.metrics.wall_s += time.monotonic() - t0

    def _step_slots(self):
        """Legacy engine: one decode step for all slots."""
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].output[-1]
        # align the shared cache position to the deepest slot
        pos = int(max(self.slot_len[s] + len(self.slot_req[s].output) - 1
                      for s in active))
        self.cache["pos"] = jnp.asarray(pos, jnp.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(toks),
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in active:
            req = self.slot_req[s]
            req.output.append(int(nxt[s]))
            self.metrics.decode_tokens += 1
            exhausted = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and int(nxt[s]) == req.eos_id
            if exhausted or hit_eos or pos + 1 >= self.max_len - 1:
                req.done = True
                req.t_done = time.monotonic()
                self.slot_req[s] = None
                self.slot_len[s] = 0
        self.steps_run += 1
        self.metrics.steps += 1
        self._admit()

    # -- paged engine ---------------------------------------------------------
    def _blocks_worst_case(self, req: Request) -> int:
        """Conservative reservation: every token the request may ever cache
        (prompt + generated, the final sampled token is never written)."""
        need = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        return self.tables.blocks_for(need)

    def _admit_paged(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue[0]  # pre-validated by submit()
            need = self._blocks_worst_case(req)
            if not self.alloc.reserve(need):
                return  # head-of-line blocks until the pool drains
            self.queue.pop(0)
            self.slot_req[slot] = req
            self._reserved[slot] = need
            self._pf_done[slot] = 0

    def _step_paged(self):
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        prefilling = [s for s in active
                      if self._pf_done[s] < len(self.slot_req[s].prompt)]
        budget = self.token_budget
        # decode lanes first (latency-critical, 1 token each). Under the
        # current policy decode lanes can never exceed the budget — a lane
        # only becomes decode by completing prefill, which itself needs
        # budget, so #decode lanes ≤ token_budget is invariant (pinned by
        # tests). The rotation + stall counter below are future-proofing
        # for policies that break it (preemption, admission bursts): if
        # lanes are ever dropped, no slot starves deterministically and
        # the drops are visible in metrics.
        cands = [s for s in active if s not in prefilling]
        if cands:
            rot = self._rr % len(cands)
            cands = cands[rot:] + cands[:rot]
        self._rr += 1
        decode_lanes = cands[:budget]
        self.metrics.stalled_decodes += len(cands) - len(decode_lanes)
        budget -= len(decode_lanes)
        # ... then prompt chunks from the remaining token budget
        takes: dict[int, int] = {}
        for s in prefilling:
            req = self.slot_req[s]
            take = min(len(req.prompt) - int(self._pf_done[s]),
                       self.prefill_chunk, budget)
            if take <= 0:
                self.metrics.stalled_prefills += 1
                continue
            takes[s] = take
            budget -= take
        # steps whose prefill lanes are all budget-starved run the cheap
        # C=1 decode compilation, not a chunk-wide call for 1-token lanes
        c = self.prefill_chunk if takes else 1
        toks = np.zeros((self.n_slots, c), np.int32)
        valid = np.zeros(self.n_slots, np.int32)
        for s in decode_lanes:
            toks[s, 0] = self.slot_req[s].output[-1]
            valid[s] = 1
        for s, take in takes.items():
            done = int(self._pf_done[s])
            toks[s, :take] = self.slot_req[s].prompt[done:done + take]
            valid[s] = take
        # back every position this step writes (reserved ⇒ cannot fail)
        for s in active:
            if valid[s]:
                self.tables.grow(s, int(self.tables.lens[s]) + int(valid[s]),
                                 self.alloc)
        logits, self.cache = self._pstep(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.tables.tables), jnp.asarray(self.tables.lens),
            jnp.asarray(valid))
        nxt = np.asarray(jnp.argmax(logits, -1))
        now = time.monotonic()
        for s in active:
            if not valid[s]:
                continue
            req = self.slot_req[s]
            self.tables.lens[s] += int(valid[s])
            if s in prefilling:
                self._pf_done[s] += int(valid[s])
                self.metrics.prefill_tokens += int(valid[s])
                if self._pf_done[s] == len(req.prompt):
                    req.output.append(int(nxt[s]))   # first generated token
                    req.t_first = now
                    # one-at-a-time semantics: exhaustion AND EOS apply to
                    # the prefill-emitted token too (the legacy engine
                    # checks neither here — see the module docstring)
                    if (len(req.output) >= req.max_new_tokens
                            or (req.eos_id is not None
                                and req.output[-1] == req.eos_id)):
                        self._retire_paged(s, now)
                continue
            req.output.append(int(nxt[s]))
            self.metrics.decode_tokens += 1
            exhausted = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and int(nxt[s]) == req.eos_id
            full = int(self.tables.lens[s]) + 1 >= self.max_len - 1
            if exhausted or hit_eos or full:
                self._retire_paged(s, now)
        self.steps_run += 1
        self.metrics.steps += 1
        self._admit()

    def _retire_paged(self, slot: int, now: float):
        req = self.slot_req[slot]
        req.done = True
        req.t_done = now
        leftover = self._reserved.pop(slot) - int(self.tables.n_alloc[slot])
        if leftover > 0:
            self.alloc.unreserve(leftover)
        self.tables.release(slot, self.alloc)
        self.slot_req[slot] = None

    def run_until_drained(self, max_steps: int = 10_000):
        while any(self.slot_req) or self.queue:
            self.step()
            if self.steps_run > max_steps:
                raise RuntimeError("serving loop did not drain")

    # -- capacity / reporting -------------------------------------------------
    def kv_cache_bytes(self) -> dict:
        """Resident KV bytes: {"total": pool/cache footprint, "in_use":
        bytes of blocks actually allocated (== total for the slot cache —
        the number the paged engine exists to shrink)}."""
        leaves = jax.tree_util.tree_leaves(self.cache)
        total = int(sum(a.nbytes for a in leaves
                        if hasattr(a, "nbytes") and a.ndim > 0))
        if not self.paged:
            return {"total": total, "in_use": total}
        nb = self.alloc.stats.num_blocks + 1     # pool includes trash block
        per_block = total // nb
        return {"total": total,
                "in_use": per_block * self.alloc.stats.in_use}


def _splice(batched_cache, request_cache, slot: int):
    """Insert a 1-deep request cache into the batched cache at `slot`.

    Both caches share the layout produced by init_cache / prefill; every
    array's batch axis is axis 1 for stacked [L, B, ...] entries. Scalars
    ("pos") take the max so the shared clock covers the deepest slot.
    """
    def one(dst, src):
        if dst.ndim == 0:
            return jnp.maximum(dst, src).astype(dst.dtype)
        # request caches have batch=1 at the same axis as dst's B
        axis = 1 if dst.ndim > 1 else 0
        start = [0] * dst.ndim
        start[axis] = slot
        src = src.astype(dst.dtype)
        if src.shape[axis] != 1:
            src = jnp.take(src, jnp.arange(1), axis=axis)
        # pad/trim sequence axes to dst
        for ax in range(dst.ndim):
            if ax != axis and src.shape[ax] != dst.shape[ax]:
                if src.shape[ax] < dst.shape[ax]:
                    pad = [(0, 0)] * dst.ndim
                    pad[ax] = (0, dst.shape[ax] - src.shape[ax])
                    src = jnp.pad(src, pad)
                else:
                    src = jnp.take(src, jnp.arange(dst.shape[ax]), axis=ax)
        return jax.lax.dynamic_update_slice(dst, src, tuple(start))

    return jax.tree.map(one, batched_cache, request_cache)
