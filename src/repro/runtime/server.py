"""Slot-based batched serving loop (continuous-batching-lite).

A fixed pool of B slots shares one batched KV cache. Requests are prefillled
individually (jit'd per prompt-length bucket) and spliced into the batched
cache at their slot; every step() advances all active slots with one jit'd
decode_step. Greedy sampling; EOS/max-token retirement frees slots for
queued requests — the standard production decode loop shape, minus RPC.

Per-slot position bookkeeping uses one shared `pos` when all slots advance
together; slot-local lengths mask finished slots (their logits are computed
but discarded — the usual padding-slot trade).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the server:
    rid: int = -1
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int,
                 max_len: int, prequant: bool = False, packed: bool = True):
        """prequant=True re-encodes CIM-routed weights as offline-quantized
        stored codes before serving (models.quantize.quantize_params) —
        nibble-packed uint8 when `packed` (4 bits/weight at rest, the
        SRAM-faithful format; 1/4 the bf16 weight HBM traffic per decode
        step), else int8 containers. Requires cfg.cim.enabled."""
        if prequant:
            assert cfg.cim.enabled, "prequant serving needs cim.enabled"
            from repro.models.quantize import quantize_params
            params = quantize_params(params, cfg, packed=packed)
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.mod = registry.get_module(cfg)
        self.cache = jax.jit(
            lambda: self.mod.init_cache(cfg, n_slots, max_len))()
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_len = np.zeros(n_slots, np.int32)
        self.queue: list[Request] = []
        self._next_rid = 0
        self._decode = jax.jit(
            lambda p, t, c: self.mod.decode_step(p, t, c, cfg))
        self._prefill = jax.jit(
            lambda p, b: self.mod.prefill(p, b, cfg, max_len=max_len),
            static_argnames=())
        self.steps_run = 0

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> int:
        req.rid = self._next_rid
        self._next_rid += 1
        self.queue.append(req)
        self._admit()
        return req.rid

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        tokens = jnp.asarray([req.prompt], jnp.int32)
        batch = {"tokens": tokens}
        logits, rcache = self._prefill(self.params, batch)
        first = int(jnp.argmax(logits[0]))
        req.output.append(first)
        self.slot_req[slot] = req
        self.slot_len[slot] = len(req.prompt)
        self.cache = _splice(self.cache, rcache, slot)

    # -- decode loop ----------------------------------------------------------
    def step(self):
        """One decode step for all slots; retire finished requests."""
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].output[-1]
        # align the shared cache position to the deepest slot
        pos = int(max(self.slot_len[s] + len(self.slot_req[s].output) - 1
                      for s in active))
        self.cache["pos"] = jnp.asarray(pos, jnp.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(toks),
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in active:
            req = self.slot_req[s]
            req.output.append(int(nxt[s]))
            exhausted = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and int(nxt[s]) == req.eos_id
            if exhausted or hit_eos or pos + 1 >= self.max_len - 1:
                req.done = True
                self.slot_req[s] = None
                self.slot_len[s] = 0
        self.steps_run += 1
        self._admit()

    def run_until_drained(self, max_steps: int = 10_000):
        while any(self.slot_req) or self.queue:
            self.step()
            if self.steps_run > max_steps:
                raise RuntimeError("serving loop did not drain")


def _splice(batched_cache, request_cache, slot: int):
    """Insert a 1-deep request cache into the batched cache at `slot`.

    Both caches share the layout produced by init_cache / prefill; every
    array's batch axis is axis 1 for stacked [L, B, ...] entries. Scalars
    ("pos") take the max so the shared clock covers the deepest slot.
    """
    def one(dst, src):
        if dst.ndim == 0:
            return jnp.maximum(dst, src).astype(dst.dtype)
        # request caches have batch=1 at the same axis as dst's B
        axis = 1 if dst.ndim > 1 else 0
        start = [0] * dst.ndim
        start[axis] = slot
        src = src.astype(dst.dtype)
        if src.shape[axis] != 1:
            src = jnp.take(src, jnp.arange(1), axis=axis)
        # pad/trim sequence axes to dst
        for ax in range(dst.ndim):
            if ax != axis and src.shape[ax] != dst.shape[ax]:
                if src.shape[ax] < dst.shape[ax]:
                    pad = [(0, 0)] * dst.ndim
                    pad[ax] = (0, dst.shape[ax] - src.shape[ax])
                    src = jnp.pad(src, pad)
                else:
                    src = jnp.take(src, jnp.arange(dst.shape[ax]), axis=ax)
        return jax.lax.dynamic_update_slice(dst, src, tuple(start))

    return jax.tree.map(one, batched_cache, request_cache)
