"""Continuous-batching serving loop: prefix-shared paged KV cache, chunked
prefill, watermark admission with preemption.

Construction goes through ONE config object::

    from repro.runtime.server import Request, Server, ServingConfig
    server = Server(params, cfg, ServingConfig(paged=True, n_slots=8,
                                               max_len=256, block_size=16))

`ServingConfig` consolidates what used to be an 11-keyword constructor
sprawl; validation lives in its `__post_init__`, and `from_flags(args)`
builds one from an argparse namespace (launch.serve). The PR-7 one-release
legacy keyword shim (`Server(params, cfg, n_slots=..., ...)`) is retired:
bare keyword construction now raises TypeError pointing here.

Two engines share one Server front end (submit / step / run_until_drained):

* **paged** (`ServingConfig(paged=True)`, the production path): a physical
  pool of fixed-size KV blocks shared by all slots, a REFCOUNTED
  `BlockAllocator` + `PrefixTrie` (runtime.paging), and per-slot block
  tables threaded through the model's attention reads/writes
  (models.transformer.paged_step). Resident KV bytes scale with the tokens
  actually cached, not n_slots × max_len. Prefill is CHUNKED through the
  same jit'd step as decode — decode is just the C=1 compilation of the
  unified step — and a token-budget scheduler caps new tokens per step
  (decode lanes first, then prompt chunks).

  PR-7 semantics on top of that engine:

  - **prefix sharing**: at admission the request's prompt is matched
    against the trie of previously cached full-block prefixes; the shared
    span maps the SAME physical blocks (zero prefill compute, zero new
    HBM), and only the tail is prefilled. Completed prefills register
    their full prompt blocks back into the trie. K/V content is a pure
    function of the absolute-position token prefix, so on the exact
    attention backend shared-block reuse is bit-identical to recompute.
  - **copy-on-write**: a lane about to write into a block some other
    holder also maps (refcount > 1 — a fork sibling's tail, a pending
    fork stash) first forks it: acquire a private block, device-copy the
    contents (models.transformer.cow_copy_block), remap the table. The
    step's fused write epilogue (kernels.paged_attention.fused_paged_write)
    computes its scatter targets from the REMAPPED table, so it lands in
    the private copy by construction.
  - **watermark admission + preemption**: instead of reserving a
    request's worst-case block count up front, admission only requires
    the prompt's unshared span plus a small watermark of headroom
    (`ServingConfig.watermark`, a fraction of the pool). When decode
    growth outruns the pool mid-flight, the scheduler first evicts
    least-recently-used trie entries, then PREEMPTS the newest-admitted
    lane: its full blocks are registered into the trie, its refs
    released, and the request re-queued at the head with an effective
    prompt of prompt + generated-so-far — resume re-admits through the
    trie, so only the sub-block tail recomputes. Greedy decode is
    deterministic, so a preempted request's final token stream is
    bit-identical to an unpreempted run (pinned by the preemption soak).
  - **parallel sampling**: `Request(n_samples=N)` decodes N greedy
    continuations off ONE prefill — clones share every prompt block and
    CoW-fork the partial tail on their first write. Clone requests are
    created at submit (`req.samples`) and installed, prefill-free, when
    the parent's prefill completes.

* **slot-based** (`paged=False`, the legacy engine, kept as the
  equivalence baseline): a monolithic [n_slots, max_len] cache; requests
  prefill individually (jit'd per prompt-length bucket) and are spliced
  into the batched cache; one shared `pos` clocks every slot. The paged
  soak tests pin the paged engine's outputs against this path and against
  one-request-at-a-time decode. NOTE the shared `pos` means slots admitted
  at different depths attend over zero-K/V gap positions (softmax
  dilution); the paged engine keeps true per-slot positions, so
  equivalence with this path is exact only on depth-aligned schedules —
  see tests/test_server_paged.py.

Sampling is per-request: `Request.sampling` carries a `SamplingParams`
(runtime.speculative) — greedy argmax by default (every bit-identity soak
pins that setting), or seeded temperature/top-k sampling whose draws are
keyed by (request seed, emission index) and therefore bit-reproducible and
batch-composition invariant. EOS/max-token retirement releases slots and
block refs. One deliberate semantic divergence: the legacy engine applies
neither the
max_new_tokens nor the eos_id check to the token emitted at prefill time;
the paged engine checks both and retires immediately, matching
one-request-at-a-time decode. Unservable requests (prompt ≥ max_len, or a
worst-case footprint larger than the whole pool) are rejected at submit()
so they can never poison the queue.

Attention backends (paged engine): `ServingConfig(attn=...)` selects the
paged step's attention path from the kernels.paged_attention registry —
"exact" (gather + one-pass softmax, the bit-identity anchor), "kernel"
(the Pallas flash kernel), or "auto" (kernel, unless REPRO_FORCE_JNP=1
pins exact). The bit-identity contracts (including preemption-resume and
prefix-shared admission) are pinned against attn="exact"; the kernel
backend agrees within float tolerance and has token-equality soaks of its
own.

The bit-identity contracts hold for FLOAT models (and any fixed schedule).
Under `cim.enabled` the engine's dynamic per-tensor act_scale couples every
lane's quantization grid to the whole batch's content, so CIM-mode outputs
depend on batch COMPOSITION — prefix sharing and preemption inherit that
caveat identically. The production fix is `ServingConfig(act_scale=...)`:
a static calibrated scale (analysis.calibrate) pins one fixed input-DAC
grid for every lane — pinned by tests/test_calibrate.py.

Speculative decoding (paged engine, PR 8): `ServingConfig(drafter=...)`
selects a drafter from the runtime.speculative registry ("off" — plain
decode; "ngram" — prompt-lookup self-speculation; "model:<name>" — a small
draft model from configs.registry). Each decode lane's drafter proposes up
to `spec_k` tokens from the lane's committed stream; the target verifies
all of them in ONE C=spec_k+1 `paged_step` (the all-positions-logits
compilation) and the longest agreeing prefix is accepted under exact
rejection sampling (runtime.speculative.verify_token) — token streams are
distribution-identical to plain decode and bit-identical under greedy.
The block pool makes rollback free: the verify step writes its K+1 K/V
entries into the lane's own blocks, and a rejection simply truncates the
committed `kv_len` (rejected positions are overwritten by the next step's
writes and are never readable — attention masks >= kv_len). Drafting,
clamping and accept/reject depend only on the lane's own state, so the
spec path preserves batch-composition invariance and preemption-resume
determinism.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.runtime.paging import BlockAllocator, PrefixTrie, SlotTables
from repro.runtime.speculative import SamplingParams, make_drafter, \
    parse_drafter, sample_token, verify_token
from repro.runtime.telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Everything the Server needs beyond (params, model cfg).

    Engine selection + capacity: `paged` picks the block-pool engine;
    `block_size` tokens per KV block; `num_blocks` usable blocks in the
    pool (default: slot-cache parity, n_slots × max_len / block_size —
    size it smaller to realize the paged memory win). Scheduling:
    `prefill_chunk` prompt tokens per chunk through the unified step;
    `token_budget` max new tokens per step across all lanes (default:
    n_slots + prefill_chunk). Sharing/preemption (paged only):
    `prefix_sharing` enables the trie + CoW machinery; `watermark` is the
    pool fraction admission keeps free as decode headroom (trading
    admission eagerness against preemption churn; 0 admits up to the last
    block). Weights: `prequant` re-encodes CIM-routed weights as stored
    codes (models.quantize), nibble-packed when `packed`. `attn` picks the
    paged attention backend; `act_scale` (+ optional `act_zero_point`) pins
    a static calibrated activation grid (analysis.calibrate) — needs
    cfg.cim.enabled. `precision_manifest` points at a mixed-precision
    deployment manifest (analysis.precision_search): per-call-site
    (grid, ADC levels, scheme, per-channel) overrides installed as
    cfg.cim.site_overrides, with the tune-cache fallback discipline — a
    missing/malformed/stale manifest warns and serves uniform defaults.
    Speculative decoding (paged only): `drafter` picks a proposer from the
    runtime.speculative registry ("off" / "ngram" / "model:<name>") and
    `spec_k` caps drafted tokens per lane per verify step. Trie capacity
    (paged + prefix_sharing): `trie_watermark` is a pool fraction — when
    the prefix cache exceeds it, an LRU sweep drains it to half that, so
    long-lived servers stop pinning the whole pool in cold cache between
    bursts (None disables; eviction then happens only under admission
    pressure). Observability: `telemetry` enables the per-request event
    trace / step snapshots / latency histograms (runtime.telemetry) —
    disable it only to measure its own overhead; the injectable-clock
    Server(telemetry=...) keyword overrides this flag entirely.
    """
    n_slots: int = 4
    max_len: int = 128
    prequant: bool = False
    packed: bool = True
    paged: bool = False
    block_size: int = 16
    num_blocks: Optional[int] = None
    prefill_chunk: int = 16
    token_budget: Optional[int] = None
    attn: str = "auto"
    act_scale: Optional[float] = None
    act_zero_point: Optional[float] = None
    precision_manifest: Optional[str] = None
    prefix_sharing: bool = True
    watermark: float = 1 / 16
    drafter: str = "off"
    spec_k: int = 4
    trie_watermark: Optional[float] = None
    telemetry: bool = True

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.max_len < 2:
            raise ValueError("max_len must be >= 2")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.token_budget is not None and self.token_budget < 1:
            raise ValueError("token_budget must be >= 1 (a 0 budget "
                             "would step forever without progress)")
        if self.paged:
            if self.block_size < 1:
                raise ValueError("block_size must be >= 1")
            if self.max_len % self.block_size:
                raise ValueError("max_len must be a multiple of block_size")
            if self.num_blocks is not None and self.num_blocks < 1:
                raise ValueError("num_blocks must be >= 1")
        if not 0.0 <= self.watermark < 1.0:
            raise ValueError("watermark is a pool fraction in [0, 1)")
        if self.spec_k < 1:
            raise ValueError("spec_k must be >= 1 (tokens drafted per "
                             "verify step)")
        if self.act_zero_point is not None and self.act_scale is None:
            raise ValueError("act_zero_point positions a static grid — it "
                             "needs act_scale (the grid's step) set too")
        from repro.kernels.paged_attention import choose_attn_backend
        choose_attn_backend(self.attn)   # validate the name up front
        name, _ = parse_drafter(self.drafter)   # validate like attn
        if name != "off" and not self.paged:
            raise ValueError("speculative decoding (drafter != 'off') "
                             "needs the paged engine (paged=True)")
        if self.trie_watermark is not None:
            if not 0.0 < self.trie_watermark <= 1.0:
                raise ValueError("trie_watermark is a pool fraction in "
                                 "(0, 1]")
            if not (self.paged and self.prefix_sharing):
                raise ValueError("trie_watermark needs the paged engine "
                                 "with prefix_sharing enabled")

    @classmethod
    def from_flags(cls, args, **overrides) -> "ServingConfig":
        """Build from an argparse namespace (launch.serve's flag names);
        missing attributes keep their defaults, `overrides` win last (the
        launcher passes the calibrated act_scale value this way)."""
        kw = {}
        pairs = [("n_slots", "slots"), ("max_len", "max_len"),
                 ("paged", "paged"), ("block_size", "block_size"),
                 ("num_blocks", "num_blocks"),
                 ("prefill_chunk", "prefill_chunk"),
                 ("token_budget", "token_budget"), ("attn", "attn"),
                 ("watermark", "watermark"), ("drafter", "drafter"),
                 ("spec_k", "spec_k"),
                 ("trie_watermark", "trie_watermark"),
                 ("precision_manifest", "precision_manifest")]
        for field, flag in pairs:
            v = getattr(args, flag, None)
            if v is not None:
                kw[field] = v
        if getattr(args, "no_prefix_sharing", False):
            kw["prefix_sharing"] = False
        if getattr(args, "cim", None) == "bp-prequant":
            kw["prequant"] = True
        kw.update(overrides)
        return cls(**kw)


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    n_samples: int = 1       # paged engine: continuations off one prefill
    # per-request sampling policy (runtime.speculative): greedy default;
    # temperature/top-k draws are keyed by (sampling.seed, emission index)
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # filled by the server:
    rid: int = -1
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    samples: list["Request"] = dataclasses.field(default_factory=list)
    # per-request latency metrics (monotonic timestamps)
    t_submit: float = 0.0
    t_first: float = 0.0     # first token emitted (prefill complete)
    t_done: float = 0.0

    @property
    def ttft_s(self) -> float:
        return max(self.t_first - self.t_submit, 0.0)

    @property
    def latency_s(self) -> float:
        return max(self.t_done - self.t_submit, 0.0)


@dataclasses.dataclass
class ServerMetrics:
    steps: int = 0
    decode_tokens: int = 0    # tokens emitted by decode lanes
    prefill_tokens: int = 0   # prompt tokens actually prefilled
    stalled_prefills: int = 0  # prefill lanes given 0 budget in a step
    stalled_decodes: int = 0   # decode lanes dropped by the token budget
    preemptions: int = 0       # lanes evicted under pool pressure
    prefix_hit_tokens: int = 0  # prefill tokens skipped via shared blocks
    cow_forks: int = 0         # shared blocks privatized before a write
    spec_steps: int = 0        # speculative verify steps run
    draft_tokens: int = 0      # tokens proposed by the drafter
    draft_accepted: int = 0    # proposed tokens accepted by verification
    # accept-length histogram: {accepted drafts per verify step: count}
    accept_hist: dict = dataclasses.field(default_factory=dict)
    trie_sweep_freed: int = 0  # blocks freed by trie watermark sweeps
    peak_active: int = 0       # max concurrently active lanes in a step
    peak_decode_lanes: int = 0  # max lanes past prefill in one step — the
    #                             pool-capacity-limited concurrency (admitted
    #                             lanes can transiently exceed what the pool
    #                             sustains; decode lanes cannot)
    wall_s: float = 0.0       # time inside step() + admission-time prefill
    # pool composition sampled at the end of each paged step (and at
    # construction): blocks_total/free/shared/cached_cold/private +
    # trie_entries — see Server._pool_stats for the split semantics
    pool: dict = dataclasses.field(default_factory=dict)

    def summary(self) -> dict:
        w = max(self.wall_s, 1e-9)
        return {"steps": self.steps,
                "decode_tokens": self.decode_tokens,
                "prefill_tokens": self.prefill_tokens,
                "decode_tok_s": self.decode_tokens / w,
                "prefill_tok_s": self.prefill_tokens / w,
                "stalled_prefills": self.stalled_prefills,
                "stalled_decodes": self.stalled_decodes,
                "preemptions": self.preemptions,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "cow_forks": self.cow_forks,
                "spec_steps": self.spec_steps,
                "draft_tokens": self.draft_tokens,
                "draft_accepted": self.draft_accepted,
                "accept_rate": self.draft_accepted / self.draft_tokens
                if self.draft_tokens else 0.0,
                # mean emissions per verify step (accepted drafts + the
                # correction/bonus token) — tokens-per-target-call, the
                # speculative speedup axis
                "mean_accept_len": 1.0 + self.draft_accepted
                / self.spec_steps if self.spec_steps else 0.0,
                "accept_hist": dict(sorted(self.accept_hist.items())),
                "trie_sweep_freed": self.trie_sweep_freed,
                "peak_active": self.peak_active,
                "peak_decode_lanes": self.peak_decode_lanes,
                "wall_s": self.wall_s}

    def to_dict(self) -> dict:
        """summary() plus the KV-pool composition (shared / private /
        cached-cold block split and prefix-trie entry count) — the
        post-run view the preemption soaks and exporters assert on."""
        return {**self.summary(), **self.pool}


class Server:
    def __init__(self, params, cfg: ModelConfig,
                 serving: ServingConfig | None = None, *,
                 telemetry: Telemetry | None = None, **legacy):
        if legacy:
            # the PR-7 one-release DeprecationWarning shim is retired:
            # keyword construction fails loudly with the migration target
            raise TypeError(
                f"Server() no longer accepts bare keyword arguments "
                f"{sorted(legacy)}; construct a ServingConfig and pass "
                "Server(params, cfg, ServingConfig(...))")
        if serving is None:
            serving = ServingConfig()
        self.serving = serving
        # the telemetry sink is injectable (tests pass a fake clock); a
        # caller-provided instance wins over the ServingConfig.telemetry
        # on/off flag
        self.telemetry = telemetry if telemetry is not None \
            else Telemetry(enabled=serving.telemetry)
        cfg = cfg.replace(attn_backend=serving.attn)
        if serving.act_scale is not None:
            assert cfg.cim.enabled, "static act_scale needs cim.enabled"
            cfg = cfg.replace(cim=dataclasses.replace(
                cfg.cim, act=dataclasses.replace(
                    cfg.cim.act, static_scale=float(serving.act_scale),
                    static_zero_point=float(serving.act_zero_point or 0.0))))
        if serving.precision_manifest is not None:
            assert cfg.cim.enabled, "precision manifest needs cim.enabled"
            from repro.analysis.precision_search import apply_manifest, \
                load_manifest
            manifest = load_manifest(serving.precision_manifest,
                                     arch=cfg.arch)
            # None (missing/malformed/stale) falls through unchanged: the
            # server comes up on uniform defaults, mirroring the tune cache
            cfg = cfg.replace(cim=apply_manifest(cfg.cim, manifest))
        if serving.prequant:
            assert cfg.cim.enabled, "prequant serving needs cim.enabled"
            from repro.models.quantize import quantize_params
            params = quantize_params(params, cfg, packed=serving.packed)
        self.params = params
        self.cfg = cfg
        self.n_slots = serving.n_slots
        self.max_len = serving.max_len
        self.mod = registry.get_module(cfg)
        self.paged = serving.paged
        self.slot_req: list[Optional[Request]] = [None] * self.n_slots
        self.queue: list[Request] = []
        self._next_rid = 0
        self.steps_run = 0
        self.metrics = ServerMetrics()

        if self.paged:
            if not (hasattr(self.mod, "paged_step")
                    and self.mod.supports_paged(cfg)):
                raise NotImplementedError(
                    f"paged serving not supported for arch {cfg.arch!r}")
            self.block_size = serving.block_size
            max_blocks = self.max_len // self.block_size
            num_blocks = serving.num_blocks
            if num_blocks is None:
                num_blocks = self.n_slots * max_blocks
            self.alloc = BlockAllocator(num_blocks)
            self.tables = SlotTables(self.n_slots, max_blocks,
                                     self.block_size)
            self.trie = PrefixTrie(self.block_size) \
                if serving.prefix_sharing else None
            self.prefill_chunk = serving.prefill_chunk
            self.token_budget = serving.token_budget \
                if serving.token_budget is not None \
                else self.n_slots + self.prefill_chunk
            self._watermark = max(1, round(num_blocks * serving.watermark)) \
                if serving.watermark > 0 else 0
            # pool holds num_blocks usable blocks + the trash block (id 0)
            self.cache = jax.jit(
                lambda: self.mod.init_paged_cache(cfg, num_blocks + 1,
                                                  self.block_size))()
            self._pstep = jax.jit(
                lambda p, t, c, tb, ln, vd:
                    self.mod.paged_step(p, t, c, tb, ln, vd, cfg))
            # speculative decoding: the drafter instance (None = off) and
            # the all-positions-logits compilation its verify steps use
            # (one C=spec_k+1 call scores every drafted token at once)
            self.spec_k = serving.spec_k
            self.drafter = make_drafter(serving.drafter, cfg, self.max_len)
            self._pstep_all = jax.jit(
                lambda p, t, c, tb, ln, vd:
                    self.mod.paged_step(p, t, c, tb, ln, vd, cfg,
                                        all_logits=True))
            # trie capacity watermarks (block counts; 0 = sweep disabled)
            self._trie_hi = self._trie_lo = 0
            if self.trie is not None and serving.trie_watermark is not None:
                self._trie_hi = max(1, int(num_blocks
                                           * serving.trie_watermark))
                self._trie_lo = self._trie_hi // 2
            # CoW block copy: one compilation (src/dst are traced scalars),
            # donated pools so the fork is an in-place device copy
            self._cow = jax.jit(
                lambda c, src, dst: self.mod.cow_copy_block(c, src, dst),
                donate_argnums=0)
            self._pf_done = np.zeros(self.n_slots, np.int64)
            self._pf_src: list[Optional[list[int]]] = [None] * self.n_slots
            self._slot_seq = np.zeros(self.n_slots, np.int64)
            self._adm_seq = 0
            self._fork_children: dict[int, list[Request]] = {}
            self._fork_ready: dict[int, dict] = {}
            self._rr = 0   # round-robin offset for budget-capped decode
            self._preempted_rids: set[int] = set()
            self.metrics.pool = self._pool_stats()
        else:
            self.slot_len = np.zeros(self.n_slots, np.int32)
            self.cache = jax.jit(
                lambda: self.mod.init_cache(cfg, self.n_slots,
                                            self.max_len))()
            self._decode = jax.jit(
                lambda p, t, c: self.mod.decode_step(p, t, c, cfg))
            self._prefill = jax.jit(
                lambda p, b: self.mod.prefill(p, b, cfg,
                                              max_len=self.max_len),
                static_argnames=())

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> int:
        # reject unservable requests BEFORE queueing: a poison request at
        # the queue head would otherwise either stall admission forever
        # (a footprint larger than the whole pool — run_until_drained
        # would spin) or crash mid-serve and strand the in-flight
        # requests.
        if not req.prompt:
            raise ValueError("empty prompt")
        if req.n_samples < 1:
            raise ValueError("n_samples must be >= 1")
        if not isinstance(req.sampling, SamplingParams):
            raise ValueError("Request.sampling must be a SamplingParams "
                             f"(runtime.speculative), got "
                             f"{type(req.sampling).__name__}")
        if self.paged:
            if len(req.prompt) >= self.max_len - 1:
                raise ValueError(
                    f"prompt of {len(req.prompt)} tokens exceeds "
                    f"max_len={self.max_len}")
            need = self._blocks_worst_case(req)
            if req.n_samples > 1:
                # a sibling's CoW fork keeps the shared original alive in
                # the stash while the private copy grows
                need += 1
            if need > self.alloc.stats.num_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks worst-case but the "
                    f"pool only has {self.alloc.stats.num_blocks}")
        elif req.n_samples > 1:
            raise ValueError("parallel sampling (n_samples > 1) needs the "
                             "paged engine")
        req.rid = self._next_rid
        req.t_submit = self.telemetry.now()
        self._next_rid += 1
        self.telemetry.submit(req.rid, req.t_submit, len(req.prompt),
                              req.n_samples)
        if self.paged and req.n_samples > 1:
            kids = []
            for i in range(req.n_samples - 1):
                # clones get distinct PRNG streams (seed + sibling index)
                # so sampled parallel continuations actually diverge;
                # greedy clones stay bit-identical to the parent
                c = Request(prompt=list(req.prompt),
                            max_new_tokens=req.max_new_tokens,
                            eos_id=req.eos_id,
                            sampling=dataclasses.replace(
                                req.sampling, seed=req.sampling.seed + i + 1))
                c.rid = self._next_rid
                self._next_rid += 1
                c.t_submit = req.t_submit
                self.telemetry.submit(c.rid, c.t_submit, len(c.prompt), 1)
                kids.append(c)
            req.samples = list(kids)
            self._fork_children[req.rid] = kids
        self.queue.append(req)
        # admission work (incl. the legacy engine's per-request prefill)
        # counts toward wall_s so both engines' tok/s share one clock
        t0 = self.telemetry.now()
        self._admit()
        self.metrics.wall_s += self.telemetry.now() - t0
        return req.rid

    def _admit(self):
        if self.paged:
            self._admit_paged()
            return
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        tokens = jnp.asarray([req.prompt], jnp.int32)
        batch = {"tokens": tokens}
        logits, rcache = self._prefill(self.params, batch)
        first = sample_token(np.asarray(logits[0]), req.sampling,
                             len(req.output))
        req.output.append(first)
        req.t_first = self.telemetry.now()
        self.telemetry.admit(req.rid, slot, req.t_first,
                             prefix_hit_blocks=0,
                             prefill_tokens=len(req.prompt))
        self.telemetry.prefill_chunk(req.rid, slot, req.t_first,
                                     len(req.prompt), len(req.prompt),
                                     len(req.prompt))
        self.telemetry.first_token(req.rid, slot, req.t_first,
                                   req.t_submit)
        self.metrics.prefill_tokens += len(req.prompt)
        self.slot_req[slot] = req
        self.slot_len[slot] = len(req.prompt)
        self.cache = _splice(self.cache, rcache, slot)

    # -- decode loop ----------------------------------------------------------
    def step(self):
        """One serving step; retires finished requests and re-admits."""
        t0 = self.telemetry.now()
        if self.paged:
            self._step_paged()
            # trie capacity policy: the watermark sweep runs every step —
            # including idle ones, where _step_paged returns early — so a
            # long-lived server's cold prefix cache drains between bursts
            if self._trie_hi and self.trie is not None:
                self.metrics.trie_sweep_freed += self.trie.sweep(
                    self.alloc, self._trie_hi, self._trie_lo)
        else:
            self._step_slots()
        self.metrics.wall_s += self.telemetry.now() - t0

    def _step_slots(self):
        """Legacy engine: one decode step for all slots."""
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].output[-1]
        # align the shared cache position to the deepest slot
        pos = int(max(self.slot_len[s] + len(self.slot_req[s].output) - 1
                      for s in active))
        self.cache["pos"] = jnp.asarray(pos, jnp.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(toks),
                                          self.cache)
        rows = np.asarray(logits)
        now = self.telemetry.now()
        for s in active:
            req = self.slot_req[s]
            nxt = sample_token(rows[s], req.sampling, len(req.output))
            req.output.append(nxt)
            self.metrics.decode_tokens += 1
            self.telemetry.emission(req.rid, s, now)
            exhausted = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and nxt == req.eos_id
            if exhausted or hit_eos or pos + 1 >= self.max_len - 1:
                req.done = True
                req.t_done = now
                self.telemetry.retire(req.rid, s, now,
                                      tokens=len(req.output),
                                      latency_s=req.latency_s)
                self.slot_req[s] = None
                self.slot_len[s] = 0
        self.steps_run += 1
        self.metrics.steps += 1
        self._admit()

    # -- paged engine ---------------------------------------------------------
    def _blocks_worst_case(self, req: Request) -> int:
        """Every block the request may ever hold at once (prompt +
        generated; the final sampled token is never written). Used only
        for the submit-time can-this-EVER-fit rejection — admission itself
        is watermark-based."""
        need = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        return self.tables.blocks_for(need)

    def _available(self) -> int:
        """Blocks admission can count on: free now + trie-evictable."""
        n = self.alloc.stats.free
        if self.trie is not None:
            n += self.trie.evictable(self.alloc)
        return n

    def _admit_paged(self):
        while self.queue:
            try:
                slot = self.slot_req.index(None)
            except ValueError:
                return
            req = self.queue[0]
            if req.rid in self._fork_ready:
                # fork clones map already-referenced blocks: zero new HBM,
                # no prefill, no watermark interaction
                self.queue.pop(0)
                self._install_fork(slot, req)
                continue
            # effective prompt: original prompt + anything generated before
            # a preemption (resume is a prefill of the longer prompt; the
            # trie turns most of it into a free match)
            eff = req.prompt + req.output
            matched = self.trie.match(eff[:-1]) if self.trie is not None \
                else []
            need = self.tables.blocks_for(len(eff)) - len(matched)
            headroom = self._watermark if any(
                r is not None for r in self.slot_req) else 0
            if self._available() < need + headroom:
                return  # head-of-line waits; active lanes keep draining
            self.queue.pop(0)
            self.slot_req[slot] = req
            self._slot_seq[slot] = self._adm_seq
            self._adm_seq += 1
            if matched:
                self.alloc.incref(matched)
                self.tables.assign(slot, matched,
                                   len(matched) * self.block_size)
                self.metrics.prefix_hit_tokens += \
                    len(matched) * self.block_size
            self._pf_src[slot] = eff
            self._pf_done[slot] = len(matched) * self.block_size
            # a previously-preempted rid re-admitting is a resume (even if
            # it was preempted mid-prefill, before emitting anything)
            resume = req.rid in self._preempted_rids
            self._preempted_rids.discard(req.rid)
            self.telemetry.admit(
                req.rid, slot, self.telemetry.now(),
                prefix_hit_blocks=len(matched),
                prefill_tokens=len(eff) - len(matched) * self.block_size,
                resume=resume)

    def _install_fork(self, slot: int, req: Request):
        info = self._fork_ready.pop(req.rid)
        self.slot_req[slot] = req
        self._slot_seq[slot] = self._adm_seq
        self._adm_seq += 1
        self.tables.assign(slot, info["blocks"], info["lens"])
        self._pf_src[slot] = []          # nothing to prefill: pure decode
        self._pf_done[slot] = 0
        req.output = list(info["output"])
        now = self.telemetry.now()
        self.telemetry.admit(req.rid, slot, now,
                             prefix_hit_blocks=len(info["blocks"]),
                             prefill_tokens=0, fork=True)
        if not req.t_first:
            req.t_first = now
            self.telemetry.first_token(req.rid, slot, now, req.t_submit)
        self.metrics.prefix_hit_tokens += info["lens"]
        if (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None
                    and req.output[-1] == req.eos_id)):
            self._retire_paged(slot, now)

    def _schedule(self, active):
        """Pick this step's lanes under the token budget: decode first
        (latency-critical, 1 token each), then prompt chunks. Returns
        (decode_lanes, dropped_decodes, takes, starved_prefills)."""
        prefilling = [s for s in active
                      if self._pf_done[s] < len(self._pf_src[s])]
        budget = self.token_budget
        cands = [s for s in active if s not in prefilling]
        if cands:
            rot = self._rr % len(cands)
            cands = cands[rot:] + cands[:rot]
        decode_lanes = cands[:budget]
        dropped = len(cands) - len(decode_lanes)
        budget -= len(decode_lanes)
        takes: dict[int, int] = {}
        starved = 0
        for s in prefilling:
            take = min(len(self._pf_src[s]) - int(self._pf_done[s]),
                       self.prefill_chunk, budget)
            if take <= 0:
                starved += 1
                continue
            takes[s] = take
            budget -= take
        return decode_lanes, dropped, takes, starved

    def _write_plan(self, valid_map: dict[int, int]):
        """Blocks this step must acquire: table growth for new positions,
        plus one private copy per shared block about to be written (CoW).
        Returns (total_new_blocks, [(slot, logical_idx, shared_block)])."""
        bs = self.block_size
        need, copies = 0, []
        for s, v in valid_map.items():
            if not v:
                continue
            lens = int(self.tables.lens[s])
            new_len = lens + v
            need += max(0, self.tables.blocks_for(new_len)
                        - int(self.tables.n_alloc[s]))
            # writes land in logical blocks [lens//bs, (new_len-1)//bs];
            # only already-held blocks can be shared (growth is private)
            for j in range(lens // bs,
                           min((new_len - 1) // bs + 1,
                               int(self.tables.n_alloc[s]))):
                b = int(self.tables.tables[s, j])
                if self.alloc.refcount(b) > 1:
                    copies.append((s, j, b))
                    need += 1
        return need, copies

    def _step_paged(self):
        if not any(r is not None for r in self.slot_req):
            return
        t_begin = self.telemetry.now()
        # plan the step; preempt the newest-admitted lane while the pool
        # cannot back every write (evictable trie entries count as room —
        # they are freed below, before acquiring)
        while True:
            active = [s for s in range(self.n_slots) if self.slot_req[s]]
            if not active:
                return
            decode_lanes, dropped, takes, starved = self._schedule(active)
            spec = self._plan_spec(decode_lanes)
            valid_map = {s: 1 + len(spec.get(s, ())) for s in decode_lanes}
            valid_map.update(takes)
            need, copies = self._write_plan(valid_map)
            if need <= self._available() or len(active) == 1:
                break
            # newest admission loses: FIFO fairness, and its trie overlap
            # makes its resume the cheapest recompute
            victim = max(active, key=lambda s: int(self._slot_seq[s]))
            self._preempt(victim)
        self._rr += 1
        self.metrics.stalled_decodes += dropped
        self.metrics.stalled_prefills += starved
        self.metrics.peak_active = max(self.metrics.peak_active, len(active))
        self.metrics.peak_decode_lanes = max(self.metrics.peak_decode_lanes,
                                             len(decode_lanes))
        # make room, then privatize shared write targets, then back the
        # new positions. With one active lane the submit-time worst-case
        # check guarantees this always fits (see _blocks_worst_case).
        shortfall = need - self.alloc.stats.free
        if shortfall > 0 and self.trie is not None:
            self.trie.evict(shortfall, self.alloc)
        if not self.alloc.can_acquire(need):
            raise RuntimeError(
                f"pool cannot back this step: need {need} blocks, "
                f"free {self.alloc.stats.free} — scheduler invariant "
                "violated")
        for s, j, b in copies:
            [nb] = self.alloc.acquire(1)
            self.cache = self._cow(self.cache, jnp.asarray(b, jnp.int32),
                                   jnp.asarray(nb, jnp.int32))
            self.tables.replace(s, j, nb, self.alloc)
            self.metrics.cow_forks += 1
            self.telemetry.cow_fork(self.slot_req[s].rid, s,
                                    self.telemetry.now(), b, nb)
        for s, v in valid_map.items():
            if v:
                self.tables.grow(s, int(self.tables.lens[s]) + v,
                                 self.alloc)
        # chunk width: steps whose prefill lanes are all budget-starved run
        # the cheap C=1 decode compilation; spec verify lanes always stamp
        # C=spec_k+1 (per-lane clamps shrink `valid`, never the traced
        # shape, so the compiled-shape set stays bounded)
        c = 1
        if takes:
            c = self.prefill_chunk
        if spec:
            c = max(c, self.spec_k + 1)
        toks = np.zeros((self.n_slots, c), np.int32)
        valid = np.zeros(self.n_slots, np.int32)
        for s in decode_lanes:
            toks[s, 0] = self.slot_req[s].output[-1]
            drafts = spec.get(s, ())
            toks[s, 1:1 + len(drafts)] = drafts
            valid[s] = 1 + len(drafts)
        for s, take in takes.items():
            done = int(self._pf_done[s])
            src = self._pf_src[s]
            toks[s, :take] = src[done:done + take]
            valid[s] = take
        # verify steps need the logits at EVERY chunk position (one row
        # per drafted token plus the bonus); everything else keeps the
        # last-position compilation
        pstep = self._pstep_all if spec else self._pstep
        logits, self.cache = pstep(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(self.tables.tables), jnp.asarray(self.tables.lens),
            jnp.asarray(valid))
        rows = np.asarray(logits)               # [B, V] or [B, C, V]
        now = self.telemetry.now()
        dec_lanes: list = []                    # plain-decode emissions this
        retires: list = []                      # step, batched into ONE ring
        for s in active:                        # event after the lane loop
            if not valid[s]:
                continue
            req = self.slot_req[s]
            if s in takes:
                self.tables.lens[s] += int(valid[s])
                self._pf_done[s] += int(valid[s])
                self.metrics.prefill_tokens += int(valid[s])
                self.telemetry.prefill_chunk(req.rid, s, now, int(valid[s]),
                                             int(self._pf_done[s]),
                                             len(self._pf_src[s]))
                if self._pf_done[s] == len(self._pf_src[s]):
                    row = rows[s, int(valid[s]) - 1] if rows.ndim == 3 \
                        else rows[s]
                    # emission index = len(output): 0 for a fresh prompt,
                    # the resume index after preemption — either way the
                    # same (seed, index) PRNG key plain decode would use
                    req.output.append(
                        sample_token(row, req.sampling, len(req.output)))
                    if not req.t_first:
                        req.t_first = now
                        self.telemetry.first_token(req.rid, s, now,
                                                   req.t_submit)
                    else:
                        # resume completion re-emits a token: the ITL
                        # sample spans the preemption gap on purpose
                        self.telemetry.emission(req.rid, s, now)
                    self._register_prefix(s)
                    self._stash_forks(s)
                    # one-at-a-time semantics: exhaustion AND EOS apply to
                    # the prefill-emitted token too (the legacy engine
                    # checks neither here — see the module docstring)
                    if (len(req.output) >= req.max_new_tokens
                            or (req.eos_id is not None
                                and req.output[-1] == req.eos_id)):
                        self._retire_paged(s, now)
                continue
            if s in spec:
                self._apply_verify(s, rows[s], spec[s], now)
                continue
            self.tables.lens[s] += 1
            row = rows[s, 0] if rows.ndim == 3 else rows[s]
            nxt = sample_token(row, req.sampling, len(req.output))
            req.output.append(nxt)
            self.metrics.decode_tokens += 1
            dec_lanes.append((req.rid, s))
            exhausted = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and nxt == req.eos_id
            full = int(self.tables.lens[s]) + 1 >= self.max_len - 1
            if exhausted or hit_eos or full:
                retires.append(s)
        # one batched decode event for the whole step's plain emissions
        # (per-lane ITL samples are still recorded inside), THEN the
        # retires so each rid's ring ends with its retire event
        self.telemetry.decode_step(dec_lanes, now)
        for s in retires:
            self._retire_paged(s, now)
        self.steps_run += 1
        self.metrics.steps += 1
        # sample pool composition + scheduler state once per step; the
        # pool dict also lands on ServerMetrics so to_dict() reflects the
        # post-run split even with telemetry disabled
        t_end = self.telemetry.now()
        pool = self._pool_stats()
        self.metrics.pool = pool
        # positional on purpose (field order == StepSnapshot): the 16-kwarg
        # binding was the most expensive part of the per-step telemetry
        # call and both legs pay it before the enabled check
        self.telemetry.step_snapshot(
            self.steps_run, t_end, t_end - t_begin,             # step/t/wall
            len(active), len(decode_lanes), len(takes),         # lane mix
            len(spec), c, bool(spec),                           # shape
            int(valid.sum()), self.token_budget,                # budget
            pool["blocks_free"], pool["blocks_private"],        # pool split
            pool["blocks_shared"], pool["blocks_cached_cold"],
            pool["trie_entries"])
        self._admit()

    def _plan_spec(self, decode_lanes) -> dict[int, list[int]]:
        """Draft proposals for this step's decode lanes: {slot: tokens}.

        Per-lane k is clamped so the verify step never proposes past the
        request's remaining allowance (the correction/bonus token always
        fits) nor writes past the slot window. Both clamps and the
        proposals themselves are functions of the lane's OWN state, so
        spec scheduling stays batch-composition invariant — a lane drafts
        the same tokens whether it serves alone or in a full batch.
        Lanes clamped to k=0 fall back to plain 1-token decode."""
        if self.drafter is None:
            return {}
        spec = {}
        for s in decode_lanes:
            req = self.slot_req[s]
            lens0 = int(self.tables.lens[s])
            k = min(self.spec_k,
                    req.max_new_tokens - len(req.output) - 1,
                    self.max_len - 2 - lens0)
            if k > 0:
                drafts = self.drafter.propose(req.prompt + req.output, k)
                spec[s] = [int(t) for t in drafts]
        return spec

    def _apply_verify(self, s: int, rows, drafts: list[int], now: float):
        """Commit one lane's verify-step results.

        Walks the per-position target rows in plain-decode order (emission
        index = len(output)): each drafted token is accepted or replaced
        via exact rejection sampling (runtime.speculative.verify_token);
        the first rejection's row already yields the replacement, and a
        fully-accepted run earns the bonus token from the last row.
        Retirement checks (exhaustion / EOS / window-full) run after every
        emission exactly as the plain decode loop would. Rollback is free:
        kv_len is TRUNCATED to the committed prefix (prev token + matched
        drafts); rejected positions stay as garbage past kv_len until the
        next step's writes overwrite them — never readable, attention
        masks >= kv_len."""
        req = self.slot_req[s]
        lens0 = int(self.tables.lens[s])
        matched = emitted = 0
        retire = False
        self.metrics.spec_steps += 1
        self.metrics.draft_tokens += len(drafts)
        for i in range(len(drafts) + 1):
            idx = len(req.output)
            if i < len(drafts):
                tok, ok = verify_token(rows[i], drafts[i], req.sampling,
                                       idx)
            else:   # every draft matched: the bonus row is a free token
                tok, ok = sample_token(rows[i], req.sampling, idx), False
            req.output.append(int(tok))
            emitted += 1
            if ok:
                matched += 1
            self.metrics.decode_tokens += 1
            exhausted = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and int(tok) == req.eos_id
            # plain-decode parity: before this emission the plain loop
            # would have written lens0 + emitted tokens and checked
            # lens + 1 against max_len - 1
            full = lens0 + emitted + 1 >= self.max_len - 1
            if exhausted or hit_eos or full:
                retire = True
                break
            if not ok:
                break
        self.metrics.draft_accepted += matched
        self.metrics.accept_hist[matched] = \
            self.metrics.accept_hist.get(matched, 0) + 1
        self.telemetry.spec_verify(req.rid, s, now, drafted=len(drafts),
                                   accepted=matched, emitted=emitted)
        self.telemetry.emission(req.rid, s, now, tokens=emitted)
        # rollback-by-truncation: the committed K/V covers the fed prev
        # token plus the matched drafts; everything past that is garbage
        self.tables.lens[s] = lens0 + 1 + matched
        if retire:
            self._retire_paged(s, now)

    def _register_prefix(self, slot: int):
        """Cache the completed prefill's full prompt blocks in the trie so
        later requests (and this one, if preempted) map them for free."""
        if self.trie is None:
            return
        src = self._pf_src[slot]
        nfull = len(src) // self.block_size
        if nfull:
            self.trie.insert(src[:nfull * self.block_size],
                             self.tables.held(slot)[:nfull], self.alloc)

    def _stash_forks(self, slot: int):
        """Parent prefill just completed: reference its whole block chain
        once per clone and queue the clones (front — they need zero new
        blocks, so they never block on the watermark)."""
        req = self.slot_req[slot]
        kids = self._fork_children.pop(req.rid, None)
        if not kids:
            return
        held = self.tables.held(slot)
        for c_req in reversed(kids):
            self.alloc.incref(held)
            self._fork_ready[c_req.rid] = {
                "blocks": list(held),
                "lens": int(self.tables.lens[slot]),
                "output": list(req.output)}
            self.queue.insert(0, c_req)

    def _preempt(self, slot: int):
        """Evict a running lane under pool pressure: register its full
        blocks in the trie (so resume re-maps instead of recomputing),
        release its refs, and re-queue it at the head with prompt +
        generated-so-far as the effective prompt. Greedy decode makes the
        resumed stream bit-identical to the unpreempted one."""
        req = self.slot_req[slot]
        lens = int(self.tables.lens[slot])
        if self.trie is not None and lens >= self.block_size:
            nfull = lens // self.block_size
            stream = (req.prompt + req.output)[:nfull * self.block_size]
            self.trie.insert(stream, self.tables.held(slot)[:nfull],
                             self.alloc)
        self.tables.release(slot, self.alloc)
        self.slot_req[slot] = None
        self._pf_src[slot] = None
        self._pf_done[slot] = 0
        self.queue.insert(0, req)
        self.metrics.preemptions += 1
        self._preempted_rids.add(req.rid)
        self.telemetry.preempt(req.rid, slot, self.telemetry.now(),
                               tokens_done=len(req.output))

    def _retire_paged(self, slot: int, now: float):
        req = self.slot_req[slot]
        req.done = True
        req.t_done = now
        self.telemetry.retire(req.rid, slot, now, tokens=len(req.output),
                              latency_s=req.latency_s)
        self.tables.release(slot, self.alloc)
        self.slot_req[slot] = None
        self._pf_src[slot] = None
        self._pf_done[slot] = 0

    def run_until_drained(self, max_steps: int = 10_000):
        while any(self.slot_req) or self.queue:
            before = self.steps_run
            self.step()
            if self.steps_run == before:
                # nothing was active; only admission can make progress
                self._admit()
                if not any(self.slot_req):
                    raise RuntimeError(
                        "admission stalled with an empty batch — the head "
                        "request cannot fit (submit-time checks should "
                        "have rejected it)")
            if self.steps_run > max_steps:
                raise RuntimeError("serving loop did not drain")

    # -- capacity / reporting -------------------------------------------------
    def _pool_stats(self) -> dict:
        """KV-pool composition split (paged engine only).

        `blocks_shared` counts refcount >= 2 blocks (live prefix sharing /
        fork reuse), `blocks_cached_cold` counts blocks whose ONLY
        reference is the trie (evictable cold prefix cache), and
        `blocks_private` is the remainder of in-use blocks — held by
        exactly one live lane. shared + cached_cold + private + free ==
        blocks_total."""
        st = self.alloc.stats
        cold = self.trie.cached_cold(self.alloc) \
            if self.trie is not None else 0
        return {"blocks_total": st.num_blocks,
                "blocks_free": st.free,
                "blocks_shared": st.shared,
                "blocks_cached_cold": cold,
                "blocks_private": st.private - cold,
                "trie_entries": self.trie.cached_blocks
                if self.trie is not None else 0}

    def flush_prefix_cache(self) -> int:
        """Drop every trie entry; blocks still mapped by a live slot just
        lose their cache ref. Returns blocks freed to the pool."""
        if self.paged and self.trie is not None:
            return self.trie.flush(self.alloc)
        return 0

    def kv_cache_bytes(self) -> dict:
        """Resident KV bytes: {"total": pool/cache footprint, "in_use":
        bytes of blocks currently referenced — live request blocks plus
        trie-cached (evictable) prefixes; == total for the slot cache}."""
        leaves = jax.tree_util.tree_leaves(self.cache)
        total = int(sum(a.nbytes for a in leaves
                        if hasattr(a, "nbytes") and a.ndim > 0))
        if not self.paged:
            return {"total": total, "in_use": total}
        nb = self.alloc.stats.num_blocks + 1     # pool includes trash block
        per_block = total // nb
        return {"total": total,
                "in_use": per_block * self.alloc.stats.in_use}


def _splice(batched_cache, request_cache, slot: int):
    """Insert a 1-deep request cache into the batched cache at `slot`.

    Both caches share the layout produced by init_cache / prefill; every
    array's batch axis is axis 1 for stacked [L, B, ...] entries. Scalars
    ("pos") take the max so the shared clock covers the deepest slot.
    """
    def one(dst, src):
        if dst.ndim == 0:
            return jnp.maximum(dst, src).astype(dst.dtype)
        # request caches have batch=1 at the same axis as dst's B
        axis = 1 if dst.ndim > 1 else 0
        start = [0] * dst.ndim
        start[axis] = slot
        src = src.astype(dst.dtype)
        if src.shape[axis] != 1:
            src = jnp.take(src, jnp.arange(1), axis=axis)
        # pad/trim sequence axes to dst
        for ax in range(dst.ndim):
            if ax != axis and src.shape[ax] != dst.shape[ax]:
                if src.shape[ax] < dst.shape[ax]:
                    pad = [(0, 0)] * dst.ndim
                    pad[ax] = (0, dst.shape[ax] - src.shape[ax])
                    src = jnp.pad(src, pad)
                else:
                    src = jnp.take(src, jnp.arange(dst.shape[ax]), axis=ax)
        return jax.lax.dynamic_update_slice(dst, src, tuple(start))

    return jax.tree.map(one, batched_cache, request_cache)
