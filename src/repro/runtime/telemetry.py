"""Serving-stack telemetry: lifecycle event trace, step snapshots, histograms.

This module is the in-process observability core for the paged serving
engine.  It is deliberately stdlib-only (no jax / numpy / repro imports) so
every layer of the stack — ``core.engine`` trace-time hooks included — can
import it without creating a cycle.

Three kinds of state live here:

* **Event trace** — a ring-buffered sequence of structured per-request
  lifecycle events (submit, admit, prefill_chunk, first_token, decode,
  spec_verify, cow_fork, preempt, resume, retire).  Timestamps come from an
  injectable monotonic clock so tests can drive a deterministic fake.
* **Step snapshots** — one :class:`StepSnapshot` per scheduler ``step()``
  sampling pool composition (free / private / shared / cached-cold blocks),
  prefix-trie size, token-budget utilization, lane counts and which compiled
  shape (chunk width ``c``, all-logits or not) ran.
* **Histograms** — fixed-bucket :class:`Histogram` instances for TTFT, ITL,
  spec-decode accept length and step wall time, with p50/p90/p99 estimation
  by linear interpolation inside the winning bucket.

Kernel/engine-layer counters (`KERNEL_COUNTERS`) are a process-wide
singleton because ``execute_mvm`` dispatch happens inside ``jax.jit``
tracing, far away from any `Server` instance.  Those counters count TRACED
calls — jit caching means one count per compiled shape, not one per executed
step — which is exactly what you want for "which backend did the dispatcher
pick" and "what would one traced step cost in CIM energy" questions, and is
documented on the class.

Exporters (Chrome trace-event JSON for Perfetto, Prometheus text
exposition, JSONL) live in :mod:`repro.runtime.obs`.
"""
from __future__ import annotations

import math
import time
from bisect import bisect_left
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import NamedTuple

# ---------------------------------------------------------------------------
# histograms

# Bucket upper bounds (seconds unless noted).  Chosen to straddle both real
# wall clocks (ms..s on CPU jit) and the fake unit-step clocks tests inject.
TTFT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                1.0, 2.5, 5.0, 10.0, 30.0, 60.0)
ITL_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
               0.5, 1.0, 2.5, 10.0)
STEP_BUCKETS = ITL_BUCKETS
# accepted draft tokens per verify step (counts, not seconds)
ACCEPT_BUCKETS = tuple(float(i) for i in range(9))


class Histogram:
    """Fixed-bucket histogram with percentile estimation.

    ``bounds`` are ascending bucket upper edges; an implicit +Inf bucket
    catches overflow.  Percentiles interpolate linearly inside the winning
    bucket, clamped to the observed min/max so single-sample histograms
    report the sample itself rather than a bucket edge.
    """

    def __init__(self, bounds):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly ascending")
        self.counts = [0] * (len(self.bounds) + 1)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, value: float) -> None:
        v = float(value)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def record_many(self, values) -> None:
        """Bulk :meth:`record` — one bound-method call for a whole batch.

        The per-call dispatch is what shows up on the serving hot path
        (``decode_step`` records one ITL sample per lane per step), so the
        loop body binds the attributes once.
        """
        counts, bounds = self.counts, self.bounds
        vmin, vmax, total = self.vmin, self.vmax, self.total
        n = 0
        for value in values:
            v = float(value)
            counts[bisect_left(bounds, v)] += 1
            n += 1
            total += v
            if v < vmin:
                vmin = v
            if v > vmax:
                vmax = v
        self.n += n
        self.total = total
        self.vmin = vmin
        self.vmax = vmax

    def percentile(self, p: float) -> float:
        if self.n == 0:
            return 0.0
        target = max(1, math.ceil((p / 100.0) * self.n))
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            cum += c
            if cum >= target:
                lo = self.bounds[i - 1] if i > 0 else self.vmin
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax)
                if hi <= lo:
                    return lo
                frac = (target - (cum - c)) / c
                return lo + frac * (hi - lo)
        return self.vmax  # pragma: no cover — unreachable

    def summary(self) -> dict:
        if self.n == 0:
            return {"count": 0}
        return {
            "count": self.n,
            "sum": self.total,
            "mean": self.total / self.n,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


# ---------------------------------------------------------------------------
# events + snapshots


# Per-request lifecycle event kinds, in canonical per-rid order:
#   submit -> admit -> prefill_chunk* -> first_token
#          -> (decode | spec_verify | cow_fork)*
#          -> (preempt -> resume -> prefill_chunk* ...)*  -> retire
EVENT_KINDS = frozenset({
    "submit", "admit", "resume", "prefill_chunk", "first_token", "decode",
    "spec_verify", "cow_fork", "preempt", "retire",
})


# Event and StepSnapshot are NamedTuples, not dataclasses: construction is
# on the decode hot path (one Event per emitted token, one StepSnapshot per
# step) and tuple construction is several times cheaper — the difference
# shows directly in the serve_slo telemetry-overhead gate.
class Event(NamedTuple):
    """One structured trace event.  ``data`` holds kind-specific fields."""

    kind: str
    t: float
    rid: int = -1
    slot: int = -1
    data: dict | None = None

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "t": self.t, "rid": self.rid,
             "slot": self.slot}
        if self.data:
            d.update(self.data)
        return d


class StepSnapshot(NamedTuple):
    """Scheduler/pool state sampled once per paged ``step()``."""

    step: int
    t: float
    wall_s: float
    active: int
    decode_lanes: int
    prefill_lanes: int
    spec_lanes: int
    c: int                 # compiled chunk width this step ran with
    all_logits: bool       # True when the spec-verify compilation ran
    budget_used: int
    token_budget: int
    blocks_free: int
    blocks_private: int
    blocks_shared: int
    blocks_cached_cold: int
    trie_entries: int

    def to_dict(self) -> dict:
        return {
            "kind": "step_snapshot", "step": self.step, "t": self.t,
            "wall_s": self.wall_s, "active": self.active,
            "decode_lanes": self.decode_lanes,
            "prefill_lanes": self.prefill_lanes,
            "spec_lanes": self.spec_lanes, "c": self.c,
            "all_logits": self.all_logits,
            "budget_used": self.budget_used,
            "token_budget": self.token_budget,
            "blocks_free": self.blocks_free,
            "blocks_private": self.blocks_private,
            "blocks_shared": self.blocks_shared,
            "blocks_cached_cold": self.blocks_cached_cold,
            "trie_entries": self.trie_entries,
        }


# ---------------------------------------------------------------------------
# kernel / engine counters


@dataclass
class KernelCounters:
    """Process-wide engine/kernel dispatch + energy counters.

    IMPORTANT: the ``execute_mvm`` and paged-attention hooks fire at jax
    TRACE time.  Under ``jax.jit`` a traced function executes Python once
    per compiled shape, so these counters record *traced* calls (one per
    compilation), not per-step executions.  They answer "which backend did
    the dispatcher pick for each shape family" and "what does one traced
    step cost in CIM energy per weight site", not "how many MVMs ran".
    Host-side counters (drafter, tune-cache, fallback warnings) do count
    real calls.
    """

    backend_dispatch: Counter = field(default_factory=Counter)
    attn_dispatch: Counter = field(default_factory=Counter)
    tune_cache: Counter = field(default_factory=Counter)
    fallback_warnings: int = 0
    drafter: Counter = field(default_factory=Counter)
    # site -> {"calls": traced execute_mvm calls, "dots": K-deep dot
    # products per traced call (rows x out-cols), "energy_j": paper-model
    # Eq.4 energy for those dots}
    site_energy: dict = field(default_factory=dict)

    def count_backend(self, name: str) -> None:
        self.backend_dispatch[name] += 1

    def count_attn(self, name: str) -> None:
        self.attn_dispatch[name] += 1

    def tune_lookup(self, kernel: str, hit: bool) -> None:
        self.tune_cache[f"{kernel}:{'hit' if hit else 'miss'}"] += 1

    def count_fallback(self) -> None:
        self.fallback_warnings += 1

    def count_drafter(self, event: str) -> None:
        self.drafter[event] += 1

    def add_site_energy(self, site: str, energy_j: float, dots: int) -> None:
        rec = self.site_energy.setdefault(
            site, {"calls": 0, "dots": 0, "energy_j": 0.0})
        rec["calls"] += 1
        rec["dots"] += int(dots)
        rec["energy_j"] += float(energy_j)

    def snapshot(self) -> dict:
        return {
            "backend_dispatch": dict(self.backend_dispatch),
            "attn_dispatch": dict(self.attn_dispatch),
            "tune_cache": dict(self.tune_cache),
            "fallback_warnings": self.fallback_warnings,
            "drafter": dict(self.drafter),
            "site_energy": {k: dict(v) for k, v in self.site_energy.items()},
        }

    def reset(self) -> None:
        self.backend_dispatch.clear()
        self.attn_dispatch.clear()
        self.tune_cache.clear()
        self.fallback_warnings = 0
        self.drafter.clear()
        self.site_energy.clear()


#: Singleton the engine/kernel hooks write into.  Reset via
#: ``KERNEL_COUNTERS.reset()`` (tests) — serving code only reads it.
KERNEL_COUNTERS = KernelCounters()


# ---------------------------------------------------------------------------
# per-server telemetry


# Pending-buffer auto-flush threshold: bounds memory between reads while
# keeping the replay pass far off the per-step hot path (~3 ops/step, so a
# mid-serve flush fires once per ~1400 steps — a GC-pause-scale hiccup).
_FLUSH_AT = 4096


class Telemetry:
    """Per-:class:`~repro.runtime.server.Server` telemetry sink.

    ``clock`` is any zero-arg callable returning monotonic seconds; tests
    inject a deterministic fake.  With ``enabled=False`` every recording
    method early-returns after serving the clock, so the telemetry-off
    overhead is one attribute check per call site.

    Recording is TWO-PHASE.  The hooks the Server calls from inside
    ``step()`` do nothing but append one small raw tuple to a pending
    list — on a serving step measured in milliseconds every Python
    operation spent aggregating would land directly on TTFT/ITL, and
    in-situ (cold-cache, right after the jitted step) each op costs
    several times its microbenchmark price.  The aggregation — Event
    construction, ring append, histogram bucketing, per-rid ITL marks —
    happens in :meth:`_flush`, which replays the raw tuples in order.
    Every read surface (``events``, ``snapshots``, ``counters``, the
    histograms, :meth:`summary`) is a property/method that flushes
    first, so readers never observe the buffering; a size threshold
    (``_FLUSH_AT``) bounds pending memory on export-free runs.  The
    serve_slo bench gates the hot-phase cost; the deferred replay runs
    at export time (or amortised ~once per 1400 steps mid-serve).
    """

    def __init__(self, *, enabled: bool = True, clock=time.monotonic,
                 capacity: int = 65536, snapshot_capacity: int = 16384):
        self.enabled = bool(enabled)
        self.clock = clock
        self._events: deque[Event] = deque(maxlen=capacity)
        self._snapshots: deque[StepSnapshot] = deque(
            maxlen=snapshot_capacity)
        self._counters: Counter = Counter()  # total events by kind (no cap)
        self._ttft = Histogram(TTFT_BUCKETS)
        self._itl = Histogram(ITL_BUCKETS)
        self._accept_len = Histogram(ACCEPT_BUCKETS)
        self._step_wall = Histogram(STEP_BUCKETS)
        self.kernel = KERNEL_COUNTERS
        self._last_emit: dict[int, float] = {}   # rid -> t (replay state)
        self._pending: list[tuple] = []
        self._replay = {
            "event": self._rp_event, "submit": self._rp_submit,
            "admit": self._rp_admit, "prefill_chunk": self._rp_prefill,
            "first_token": self._rp_first_token,
            "emission": self._rp_emission, "decode": self._rp_decode_step,
            "spec_verify": self._rp_spec_verify, "retire": self._rp_retire,
            "snap": self._rp_snap,
        }

    # -- clock ------------------------------------------------------------
    def now(self) -> float:
        return self.clock()

    # -- hot-phase hooks (called by Server; append raw tuples only) -------
    def event(self, kind: str, rid: int = -1, slot: int = -1,
              t: float | None = None, **data) -> None:
        """Generic event.  ``t=None`` stamps the clock NOW (not at flush)."""
        if not self.enabled:
            return
        p = self._pending
        p.append(("event", kind, rid, slot,
                  self.clock() if t is None else t, data or None))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def submit(self, rid: int, t: float, prompt_len: int,
               n_samples: int) -> None:
        if not self.enabled:
            return
        p = self._pending
        p.append(("submit", rid, t, prompt_len, n_samples))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def admit(self, rid: int, slot: int, t: float, *, prefix_hit_blocks: int,
              prefill_tokens: int, resume: bool = False,
              fork: bool = False) -> None:
        if not self.enabled:
            return
        p = self._pending
        p.append(("admit", rid, slot, t, prefix_hit_blocks, prefill_tokens,
                  resume, fork))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def prefill_chunk(self, rid: int, slot: int, t: float, tokens: int,
                      done: int, total: int) -> None:
        if not self.enabled:
            return
        p = self._pending
        p.append(("prefill_chunk", rid, slot, t, tokens, done, total))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def first_token(self, rid: int, slot: int, t: float,
                    t_submit: float) -> None:
        if not self.enabled:
            return
        p = self._pending
        p.append(("first_token", rid, slot, t, t_submit))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def emission(self, rid: int, slot: int, t: float,
                 tokens: int = 1) -> None:
        """One token emission outside the batched plain-decode path.

        Used by spec-verify (multi-token: the ITL sample is the per-token
        effective latency ``(t - last) / tokens``, the quantity
        speculative decoding improves), resume completions, and the
        legacy slot engine.  Plain decode uses :meth:`decode_step`.
        """
        if not self.enabled:
            return
        p = self._pending
        p.append(("emission", rid, slot, t, tokens))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def decode_step(self, lanes: list, t: float) -> None:
        """Batched decode emissions: ``lanes`` is ``[(rid, slot), ...]``.

        The hottest hook — one call per paged ``step()`` covering every
        plain-decode lane.  Per-lane ITL samples and ``decode`` counter
        semantics are preserved at replay, but the ring gets a SINGLE
        event carrying the lane list (``data={"lanes": [...]}``; rid/slot
        stamp the first lane).  ``obs.chrome_trace`` expands it back into
        one instant per lane, so the exported trace is unchanged.
        """
        if not self.enabled or not lanes:
            return
        p = self._pending
        p.append(("decode", lanes, t))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def spec_verify(self, rid: int, slot: int, t: float, *, drafted: int,
                    accepted: int, emitted: int) -> None:
        if not self.enabled:
            return
        p = self._pending
        p.append(("spec_verify", rid, slot, t, drafted, accepted, emitted))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def cow_fork(self, rid: int, slot: int, t: float, src_block: int,
                 dst_block: int) -> None:
        self.event("cow_fork", rid, slot, t, src_block=src_block,
                   dst_block=dst_block)

    def preempt(self, rid: int, slot: int, t: float,
                tokens_done: int) -> None:
        self.event("preempt", rid, slot, t, tokens_done=tokens_done)

    def retire(self, rid: int, slot: int, t: float, *, tokens: int,
               latency_s: float | None) -> None:
        if not self.enabled:
            return
        p = self._pending
        p.append(("retire", rid, slot, t, tokens, latency_s))
        if len(p) >= _FLUSH_AT:
            self._flush()

    def step_snapshot(self, step, t, wall_s, active, decode_lanes,
                      prefill_lanes, spec_lanes, c, all_logits, budget_used,
                      token_budget, blocks_free, blocks_private,
                      blocks_shared, blocks_cached_cold,
                      trie_entries) -> None:
        # explicit parameter list (not **kw): the kwargs repack showed up
        # in the serve_slo overhead gate, and both legs pay the binding
        if not self.enabled:
            return
        p = self._pending
        p.append(("snap", step, t, wall_s, active, decode_lanes,
                  prefill_lanes, spec_lanes, c, all_logits, budget_used,
                  token_budget, blocks_free, blocks_private, blocks_shared,
                  blocks_cached_cold, trie_entries))
        if len(p) >= _FLUSH_AT:
            self._flush()

    # -- replay (aggregation) phase ---------------------------------------
    def _flush(self) -> None:
        """Replay pending raw tuples, in order, into the read structures."""
        pending = self._pending
        if not pending:
            return
        self._pending = []
        replay = self._replay
        for op in pending:
            replay[op[0]](*op[1:])

    def _rp_event(self, kind, rid, slot, t, data) -> None:
        self._counters[kind] += 1
        self._events.append(Event(kind, t, rid, slot, data))

    def _rp_submit(self, rid, t, prompt_len, n_samples) -> None:
        self._rp_event("submit", rid, -1, t,
                       {"prompt_len": prompt_len, "n_samples": n_samples})

    def _rp_admit(self, rid, slot, t, prefix_hit_blocks, prefill_tokens,
                  resume, fork) -> None:
        data = {"prefix_hit_blocks": prefix_hit_blocks,
                "prefill_tokens": prefill_tokens}
        if fork:
            data["fork"] = True
        self._rp_event("resume" if resume else "admit", rid, slot, t, data)

    def _rp_prefill(self, rid, slot, t, tokens, done, total) -> None:
        self._rp_event("prefill_chunk", rid, slot, t,
                       {"tokens": tokens, "done": done, "total": total})

    def _rp_first_token(self, rid, slot, t, t_submit) -> None:
        ttft = t - t_submit
        self._ttft.record(ttft)
        self._last_emit[rid] = t
        self._rp_event("first_token", rid, slot, t, {"ttft_s": ttft})

    def _rp_emission(self, rid, slot, t, tokens) -> None:
        last = self._last_emit.get(rid)
        if last is not None and t >= last:
            self._itl.record((t - last) / tokens if tokens > 1
                             else t - last)
        self._last_emit[rid] = t
        self._rp_event("decode", rid, slot, t, {"tokens": tokens})

    def _rp_decode_step(self, lanes, t) -> None:
        last = self._last_emit
        samples = []
        for rid, _slot in lanes:
            lt = last.get(rid)
            if lt is not None and t >= lt:
                samples.append(t - lt)
            last[rid] = t
        if samples:
            self._itl.record_many(samples)
        self._counters["decode"] += len(lanes)
        rid0, slot0 = lanes[0]
        self._events.append(Event("decode", t, rid0, slot0,
                                  {"lanes": lanes}))

    def _rp_spec_verify(self, rid, slot, t, drafted, accepted,
                        emitted) -> None:
        self._accept_len.record(accepted)
        self._rp_event("spec_verify", rid, slot, t,
                       {"drafted": drafted, "accepted": accepted,
                        "emitted": emitted})

    def _rp_retire(self, rid, slot, t, tokens, latency_s) -> None:
        self._last_emit.pop(rid, None)
        self._rp_event("retire", rid, slot, t,
                       {"tokens": tokens, "latency_s": latency_s})

    def _rp_snap(self, *fields) -> None:
        self._step_wall.record(fields[2])        # wall_s
        self._snapshots.append(StepSnapshot(*fields))

    # -- read surfaces (flush first, so buffering is never observable) ----
    @property
    def events(self) -> deque:
        self._flush()
        return self._events

    @property
    def snapshots(self) -> deque:
        self._flush()
        return self._snapshots

    @property
    def counters(self) -> Counter:
        self._flush()
        return self._counters

    @property
    def ttft(self) -> Histogram:
        self._flush()
        return self._ttft

    @property
    def itl(self) -> Histogram:
        self._flush()
        return self._itl

    @property
    def accept_len(self) -> Histogram:
        self._flush()
        return self._accept_len

    @property
    def step_wall(self) -> Histogram:
        self._flush()
        return self._step_wall

    # -- management -------------------------------------------------------
    def reset(self) -> None:
        """Clear per-run state (events, snapshots, histograms, ITL marks).

        Does NOT touch :data:`KERNEL_COUNTERS` — that singleton is shared
        across servers and owned by whoever resets it explicitly.
        """
        self._pending.clear()
        self._events.clear()
        self._snapshots.clear()
        self._counters.clear()
        self._last_emit.clear()
        self._ttft = Histogram(TTFT_BUCKETS)
        self._itl = Histogram(ITL_BUCKETS)
        self._accept_len = Histogram(ACCEPT_BUCKETS)
        self._step_wall = Histogram(STEP_BUCKETS)

    def summary(self) -> dict:
        self._flush()
        return {
            "events": dict(self._counters),
            "ttft": self._ttft.summary(),
            "itl": self._itl.summary(),
            "accept_len": self._accept_len.summary(),
            "step_wall": self._step_wall.summary(),
            "kernel": self.kernel.snapshot(),
        }
