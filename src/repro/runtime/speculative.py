"""Per-request sampling + speculative-decoding primitives for the paged
serving engine.

Two API surfaces live here, both consumed by runtime.server:

* **SamplingParams** — the per-request sampling policy carried on
  `Request.sampling`. `temperature=0.0` (the default) is greedy argmax and
  keeps every bit-identity contract the serving tests pin; `temperature>0`
  samples from the (optionally top-k-truncated) softmax with a
  counter-based PRNG keyed by `(seed, emission index)`, so a request's
  token stream is bit-reproducible per (request seed, step) and INVARIANT
  to batch composition — the draws never depend on what else shares the
  batch or on how the scheduler interleaved the lane (preemption-resume
  included). All sampling is host-side numpy over the step's logits row:
  selection is control flow, not compute, exactly like the block
  allocator.

* **the drafter registry** — `off` / `ngram` / `model:<name>` specs
  mirroring the attention-backend registry (kernels.paged_attention) and
  the CIM-backend registry: a frozen spec dataclass, a module-level dict,
  a `register_drafter` decorator, and `parse_drafter` /` make_drafter`
  resolvers that validate names up front (ServingConfig.__post_init__
  calls `parse_drafter` the same way it calls `choose_attn_backend`).
  A drafter proposes K tokens per decode lane from the lane's committed
  token stream alone; the target model verifies all K in ONE C=K+1
  `paged_step` and the longest agreeing prefix is accepted (see
  `verify_token`). Proposals are deterministic functions of the lane's own
  history, which is what makes spec-decode scheduling composition-
  invariant.

Exact rejection sampling: our drafters are deterministic (a point-mass
proposal distribution q), so the classic accept rule `u < p(d)/q(d)`
reduces to `u < p(d)`; on rejection the replacement is drawn from the
residual `p` with the rejected token zeroed, renormalized. The marginal
over (accept, resample) is exactly `p` — spec-decode token streams are
DISTRIBUTION-identical to plain decode, and bit-identical under greedy
(where verification is just an argmax prefix match). Both draws for
emission index j come from the same `(seed, j)` Philox key, so the
verify path never perturbs any other emission's randomness.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from repro.runtime.telemetry import KERNEL_COUNTERS


# ---------------------------------------------------------------------------
# per-request sampling policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How one request turns a logits row into a token.

    temperature: 0.0 = greedy argmax (the default, and the setting every
        bit-identity soak pins); > 0 scales the logits before softmax.
    top_k: 0 = full vocabulary; k > 0 restricts sampling to the k highest
        logits (ties at the k-th value are all kept — deterministic).
    seed: per-request PRNG seed. Emission index j draws from Philox key
        (seed, j), so streams are bit-reproducible per (seed, step) and
        independent of batch composition and scheduling.
    """
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if not isinstance(self.temperature, (int, float)) \
                or not math.isfinite(self.temperature) \
                or self.temperature < 0.0:
            raise ValueError("temperature must be a finite float >= 0 "
                             f"(0 = greedy), got {self.temperature!r}")
        if not isinstance(self.top_k, int) or self.top_k < 0:
            raise ValueError("top_k must be an int >= 0 (0 = full vocab), "
                             f"got {self.top_k!r}")
        if not isinstance(self.seed, int) or self.seed < 0:
            raise ValueError(f"seed must be an int >= 0, got {self.seed!r}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def _probs(logits: np.ndarray, sp: SamplingParams) -> np.ndarray:
    """Target distribution p for one logits row: top-k filter, then
    temperature softmax, in float64 (host-side, bit-stable)."""
    z = np.asarray(logits, np.float64)
    if sp.top_k and sp.top_k < z.shape[-1]:
        kth = np.partition(z, -sp.top_k)[-sp.top_k]
        z = np.where(z < kth, -np.inf, z)
    z = z / sp.temperature
    z = z - z.max()
    p = np.exp(z)
    return p / p.sum()


def _rng(sp: SamplingParams, index: int) -> np.random.Generator:
    """Counter-based PRNG for emission `index`: a fresh Philox stream per
    (request seed, emission index) — no draw ever depends on how many
    tokens any OTHER step or lane consumed."""
    return np.random.Generator(np.random.Philox(key=[sp.seed, index]))


def _inverse_cdf(p: np.ndarray, u: float) -> int:
    tok = int(np.searchsorted(np.cumsum(p), u, side="right"))
    return min(tok, p.shape[-1] - 1)   # guard float cumsum < 1.0


def sample_token(logits: np.ndarray, sp: SamplingParams, index: int) -> int:
    """Sample emission `index` from one logits row under `sp`."""
    if sp.greedy:
        return int(np.argmax(logits))
    return _inverse_cdf(_probs(logits, sp), _rng(sp, index).random())


def verify_token(logits: np.ndarray, draft: int, sp: SamplingParams,
                 index: int) -> tuple[int, bool]:
    """Exact-rejection-sample one drafted token against the target row.

    Returns (token, accepted). Greedy: accept iff the draft IS the argmax.
    Sampled: accept with probability p(draft) (the point-mass-q rejection
    rule); on rejection draw the replacement from the residual (p with the
    draft zeroed, renormalized). Marginal distribution == plain
    `sample_token` — spec-decode is distribution-identical to plain decode.
    """
    draft = int(draft)
    if sp.greedy:
        tok = int(np.argmax(logits))
        return tok, tok == draft
    p = _probs(logits, sp)
    g = _rng(sp, index)
    if g.random() < p[draft]:
        return draft, True
    q = p.copy()
    q[draft] = 0.0
    tot = q.sum()
    if tot <= 0.0:                     # p was a point mass on the draft;
        return draft, True             # rejection prob was 0 — unreachable
    return _inverse_cdf(q / tot, g.random()), False


# ---------------------------------------------------------------------------
# drafter registry (mirrors kernels.paged_attention's backend registry)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DrafterSpec:
    """One registered drafter family. `factory(arg, cfg, max_len)` builds
    the per-server drafter instance (arg = the `:`-suffix of the spec
    string, e.g. the arch name of `model:<name>`; None when absent)."""
    name: str
    factory: Callable
    takes_arg: bool = False


_DRAFTER_REGISTRY: dict[str, DrafterSpec] = {}


def register_drafter(name: str, takes_arg: bool = False):
    def deco(factory):
        _DRAFTER_REGISTRY[name] = DrafterSpec(name, factory, takes_arg)
        return factory
    return deco


def get_drafter(name: str) -> DrafterSpec:
    try:
        return _DRAFTER_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown drafter {name!r}; registered: "
            f"{sorted(_DRAFTER_REGISTRY)}") from None


def parse_drafter(spec: str) -> tuple[str, Optional[str]]:
    """Split + validate a drafter spec string: "off", "ngram", or
    "model:<name>" (a configs.registry smoke arch). Raises ValueError on
    unknown families, a missing required arg, or an unknown model name —
    ServingConfig.__post_init__ calls this so bad flags fail at config
    construction, not mid-serve."""
    if not isinstance(spec, str) or not spec:
        raise ValueError(f"drafter spec must be a non-empty string, "
                         f"got {spec!r}")
    name, _, arg = spec.partition(":")
    ds = get_drafter(name)
    if ds.takes_arg and not arg:
        raise ValueError(f"drafter {name!r} needs an argument: "
                         f"'{name}:<name>'")
    if not ds.takes_arg and arg:
        raise ValueError(f"drafter {name!r} takes no argument, got {spec!r}")
    if name == "model":
        from repro.configs.registry import SMOKES
        if arg not in SMOKES:
            raise ValueError(f"model drafter arch {arg!r} not in "
                             f"configs.registry (have {sorted(SMOKES)})")
    return name, (arg or None)


def make_drafter(spec: str, cfg, max_len: int):
    """Resolve a spec string into a drafter instance (None for "off").
    `cfg` is the TARGET model config (vocab compatibility checks)."""
    name, arg = parse_drafter(spec)
    ds = get_drafter(name)
    return ds.factory(arg, cfg, max_len)


@register_drafter("off")
def _off(arg, cfg, max_len):
    return None


@register_drafter("ngram")
def _ngram(arg, cfg, max_len):
    return NGramDrafter()


@register_drafter("model", takes_arg=True)
def _model(arg, cfg, max_len):
    return ModelDrafter(arg, cfg, max_len)


class NGramDrafter:
    """Self-speculation via prompt lookup: no second model at all.

    To propose the next token, find the most recent PREVIOUS occurrence of
    the stream's longest trailing n-gram (n = max_n down to 1) and predict
    the token that followed it; extend one token at a time so cyclic
    streams (greedy decode's usual steady state) are predicted through the
    whole cycle. No match → repeat the last token. Deterministic in the
    lane's own history — required for composition-invariant scheduling.
    Proposal quality only affects SPEED (accept length); `verify_token`
    keeps the output distribution exact regardless.
    """

    def __init__(self, max_n: int = 3):
        if max_n < 1:
            raise ValueError("max_n must be >= 1")
        self.max_n = max_n

    def _next(self, work: Sequence[int]) -> int:
        top = len(work) - 1          # last index a match may PRECEDE
        for n in range(min(self.max_n, top), 0, -1):
            suffix = tuple(work[-n:])
            for i in range(top - n, -1, -1):
                if tuple(work[i:i + n]) == suffix:
                    KERNEL_COUNTERS.count_drafter("ngram_match")
                    return int(work[i + n])
        KERNEL_COUNTERS.count_drafter("ngram_fallback")
        return int(work[-1])

    def propose(self, tokens: Sequence[int], k: int) -> list[int]:
        work = list(tokens)
        for _ in range(k):
            work.append(self._next(work))
        return work[len(tokens):]


class ModelDrafter:
    """A small greedy draft model from configs.registry behind the same
    `propose(tokens, k)` interface.

    The draft model runs a full padded-forward per proposed token (ONE
    compilation — the stream is right-padded to max_len and the logits row
    is gathered at the last real position, which causal attention keeps
    independent of the padding). That is O(k · L) draft compute per verify
    step — fine for the smoke scale this repo serves; a production drafter
    would keep its own paged cache. Vocabularies must match exactly, or
    proposals could index outside the target's embedding table."""

    def __init__(self, arch: str, target_cfg, max_len: int,
                 params=None, seed: int = 17):
        import jax
        import jax.numpy as jnp
        from repro.configs.registry import SMOKES
        from repro.models import registry as model_registry
        from repro.models.common import unembed

        cfg = SMOKES[arch].replace(dtype="float32")
        if cfg.vocab != target_cfg.vocab:
            raise ValueError(
                f"drafter 'model:{arch}' vocab {cfg.vocab} != target vocab "
                f"{target_cfg.vocab}; proposals must share the token space")
        self.cfg = cfg
        self.max_len = max_len
        self.params = params if params is not None else \
            model_registry.init_params(jax.random.PRNGKey(seed), cfg,
                                       max_seq=max_len)
        mod = model_registry.get_module(cfg)

        def fwd(p, toks, last):
            h, _, _ = mod.forward(p, {"tokens": toks[None, :]}, cfg,
                                  train=False)
            row = jnp.take_along_axis(
                h[0], last[None, None].astype(jnp.int32), axis=0)[0]
            return jnp.argmax(unembed(p["tok"], row, cfg))

        self._fwd = jax.jit(fwd)

    def propose(self, tokens: Sequence[int], k: int) -> list[int]:
        import jax.numpy as jnp
        # keep the newest max_len - k tokens so the k proposals still fit
        work = list(tokens)[-(self.max_len - k):]
        buf = np.zeros(self.max_len, np.int32)
        buf[:len(work)] = work
        out = []
        for i in range(k):
            last = len(work) + i - 1
            nxt = int(self._fwd(self.params, jnp.asarray(buf),
                                jnp.asarray(last)))
            out.append(nxt)
            buf[last + 1] = nxt
        return out
