"""Pure-JAX optimizers (no optax in this environment).

AdamW keeps f32 m/v state (standard for ≤10B-class models); Adafactor keeps
factored f32 second moments (row/col means) so the 671B-class archs fit the
optimizer state in HBM — the state for a [d1, d2] matrix is d1 + d2 floats
instead of 2·d1·d2.

API: opt = adamw(lr_fn, ...); state = opt.init(params);
     updates, state = opt.update(grads, state, params); params += updates.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def global_norm_clip(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                 grads), gn


def apply_updates(params, updates):
    return _tmap(lambda p, u: (p.astype(jnp.float32)
                               + u.astype(jnp.float32)).astype(p.dtype),
                 params, updates)


def adamw(lr_fn: Callable[[jax.Array], jax.Array], *, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.01) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(zeros, params), "v": _tmap(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2)
                  * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        upd = _tmap(
            lambda m_, v_, p: -lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            m, v, params)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adafactor(lr_fn: Callable[[jax.Array], jax.Array], *, decay=0.8,
              eps=1e-30, clip_threshold=1.0, weight_decay=0.0) -> Optimizer:
    """Momentum-free Adafactor (Shazeer & Stern 2018), factored ≥2-D stats."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32), "stats": _tmap(one, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def one(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                precond = gf / (jnp.sqrt(r)[..., None]
                                * jnp.sqrt(vc)[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                precond = gf / jnp.sqrt(v)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-12)
            precond = precond / jnp.maximum(1.0, rms / clip_threshold)
            upd = -lr * (precond + weight_decay * p.astype(jnp.float32))
            return upd, new_s

        flat_g, td = jax.tree.flatten(grads)
        flat_s = td.flatten_up_to(state["stats"])
        flat_p = td.flatten_up_to(params)
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        upd = td.unflatten([o[0] for o in outs])
        stats = td.unflatten([o[1] for o in outs])
        return upd, {"step": step, "stats": stats}

    return Optimizer(init, update)
