"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    """Linear warmup → cosine decay to floor·peak."""
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return lr
