from .optimizers import adafactor, adamw, apply_updates, global_norm_clip
from .schedule import cosine_warmup

__all__ = ["adamw", "adafactor", "apply_updates", "global_norm_clip",
           "cosine_warmup"]
