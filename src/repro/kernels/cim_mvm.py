"""Fused bit-parallel CIM MVM Pallas TPU kernel.

TPU adaptation of the paper's "in-situ" insight (§III-A): PICO-RAM never
moves analog partials off the local MOM capacitors between MAC, shift-and-add
and ADC sampling. The TPU analogue: never spill pre-ADC partial sums to HBM.
Each grid step along the reduction axis processes exactly one N=144-row macro
group on the MXU and applies the ADC transfer (clip + round to the 8.5-bit
grid with VTC gain) in VMEM registers before accumulating into the output
block — the digital partial-sum accumulation of §II-A.

Layout choices (TPU v5e target):
  * grid = (M/bm, N/bn, G): the two output axes are parallel, the group axis
    is sequential ("arbitrary") and innermost so the f32 output block stays
    resident in VMEM across all G groups (revisiting it per group would
    round-trip HBM — the exact failure the paper's in-situ design avoids).
  * The K-block equals the macro depth n_rows = 144. The MXU pads the
    contraction to sublane multiples; we keep the physical group size rather
    than rounding to 128 so the simulated numerics are bit-faithful to the
    macro (padding rows hold zero codes = unselected SRAM rows).
  * bm/bn default to 128×128 MXU-aligned output tiles; VMEM footprint per
    step ≈ bm·144·4 + 144·bn·4 + bm·bn·4 ≈ 213 KB ≪ 16 MB, leaving room for
    the pipeline's double buffering.

The kernel is deterministic (SimLevel.IDEAL transfer). Stochastic error
injection (thermal noise / INL) belongs to QAT experiments and runs on the
jnp backends; a production TPU deployment would never inject noise at
inference time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams across 0.4.x/0.5.x; support
# both so the kernels import under whichever toolchain is baked in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _cim_mvm_kernel(x_ref, w_ref, o_ref, *, inv_lsb: float, lsb: float,
                    levels: int, n_groups: int):
    """One (bm × bn) output tile; sequential loop over macro groups."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Analog MAC: charge accumulation over one 144-row group (exact/linear).
    part = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    # TD-ADC transfer: VTC gain + clip + round onto the 8.5-bit code grid.
    code = jnp.clip(jnp.round(part * inv_lsb), 0.0, float(levels - 1))
    # Digital partial-sum accumulation (the ×LSB reconstruction).
    o_ref[...] += code * lsb


def _cim_mvm_packed_kernel(x_ref, w_ref, o_ref, *, inv_lsb: float, lsb: float,
                           levels: int):
    """Packed-int4 variant: w_ref holds two 4-bit codes per byte along the
    reduction axis (row 2i in the low nibble, 2i+1 in the high nibble).
    Unpacking happens in VMEM right before the MXU dot — weights travel
    HBM→VMEM at 4 bits each, the TPU counterpart of the paper's 4-bit SRAM
    storage density (559 Kb/mm²)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wp = w_ref[...].astype(jnp.int32)                     # [n_rows/2, bn]
    lo = (wp & 15).astype(jnp.float32)
    hi = ((wp >> 4) & 15).astype(jnp.float32)
    half, bn = wp.shape
    w_full = jnp.stack([lo, hi], axis=1).reshape(2 * half, bn)
    part = jnp.dot(x_ref[...], w_full, preferred_element_type=jnp.float32)
    code = jnp.clip(jnp.round(part * inv_lsb), 0.0, float(levels - 1))
    o_ref[...] += code * lsb


@functools.partial(
    jax.jit, static_argnames=("n_rows", "levels", "gain", "full_scale",
                              "bm", "bn", "interpret"))
def cim_mvm_grouped_packed(x_codes: jax.Array, w_packed: jax.Array, *,
                           n_rows: int, levels: int, gain: float,
                           full_scale: float, bm: int = 128, bn: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Packed-weight twin of cim_mvm_grouped. w_packed [K/2, N] uint8."""
    m, k = x_codes.shape
    k2, n = w_packed.shape
    assert k == 2 * k2 and k % n_rows == 0 and n_rows % 2 == 0
    groups = k // n_rows
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0

    lsb = full_scale / (gain * (levels - 1))
    kernel = functools.partial(_cim_mvm_packed_kernel, inv_lsb=1.0 / lsb,
                               lsb=lsb, levels=levels)
    grid = (m // bm, n // bn, groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n_rows), lambda i, j, g: (i, g)),
            pl.BlockSpec((n_rows // 2, bn), lambda i, j, g: (g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_codes.astype(jnp.float32), w_packed.astype(jnp.uint8))


@functools.partial(
    jax.jit, static_argnames=("n_rows", "levels", "gain", "full_scale",
                              "bm", "bn", "interpret"))
def cim_mvm_grouped(x_codes: jax.Array, w_codes: jax.Array, *, n_rows: int,
                    levels: int, gain: float, full_scale: float,
                    bm: int = 128, bn: int = 128,
                    interpret: bool = False) -> jax.Array:
    """ŷ[M, N] = Σ_g ADC( x[M, g·144:(g+1)·144] @ w[g·144:(g+1)·144, N] ).

    x_codes [M, K], w_codes [K, N]; K must already be padded to a multiple of
    n_rows (ops.py handles padding — zero codes are exact no-ops).
    """
    m, k = x_codes.shape
    k2, n = w_codes.shape
    assert k == k2 and k % n_rows == 0, (x_codes.shape, w_codes.shape, n_rows)
    groups = k // n_rows
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, "caller pads M/N to block multiples"

    lsb = full_scale / (gain * (levels - 1))
    kernel = functools.partial(_cim_mvm_kernel, inv_lsb=1.0 / lsb, lsb=lsb,
                               levels=levels, n_groups=groups)
    grid = (m // bm, n // bn, groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n_rows), lambda i, j, g: (i, g)),
            pl.BlockSpec((n_rows, bn), lambda i, j, g: (g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_codes.astype(jnp.float32), w_codes.astype(jnp.float32))
