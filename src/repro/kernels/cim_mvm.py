"""Fused bit-parallel CIM MVM Pallas TPU kernel.

TPU adaptation of the paper's "in-situ" insight (§III-A): PICO-RAM never
moves analog partials off the local MOM capacitors between MAC, shift-and-add
and ADC sampling. The TPU analogue: never spill pre-ADC partial sums to HBM.
Each grid step along the reduction axis processes exactly one N=144-row macro
group on the MXU and applies the ADC transfer (clip + round to the 8.5-bit
grid with VTC gain) in VMEM registers before accumulating into the output
block — the digital partial-sum accumulation of §II-A.

Layout choices (TPU v5e target):
  * grid = (M/bm, N/bn, G): the two output axes are parallel, the group axis
    is sequential ("arbitrary") and innermost so the f32 output block stays
    resident in VMEM across all G groups (revisiting it per group would
    round-trip HBM — the exact failure the paper's in-situ design avoids).
  * The K-block equals the macro depth n_rows = 144. The MXU pads the
    contraction to sublane multiples; we keep the physical group size rather
    than rounding to 128 so the simulated numerics are bit-faithful to the
    macro (padding rows hold zero codes = unselected SRAM rows).
  * bm/bn default to 128×128 MXU-aligned output tiles; VMEM footprint per
    step ≈ bm·144·4 + 144·bn·4 + bm·bn·4 ≈ 213 KB ≪ 16 MB, leaving room for
    the pipeline's double buffering.

Deterministic (SimLevel.IDEAL) and stochastic (NOISY/FULL) variants share
the grid/layout; the stochastic kernels additionally draw the TD-ADC's
thermal-noise sample per conversion IN VMEM — mirroring the dual-threshold
TD-ADC, which samples its comparator noise independently at every
conversion — so QAT noise studies run at fused-kernel throughput instead of
falling back to the einsum/scan jnp paths.

PRNG choice: a counter-based SplitMix32/murmur3-style hash over
(seed, row, col, group) evaluated with plain uint32 vector ops. The
hardware `pltpu.prng_seed`/`prng_random_bits` primitives have NO CPU
interpret-mode lowering on the pinned toolchain (jax 0.4.37 raises
NotImplementedError), and their draws would differ between compiled and
interpret mode anyway. The counter construction gives bit-identical output
on TPU and in CI's interpret mode, and makes every conversion's draw a pure
function of (noise_seed, output coordinate, group) — reproducible per seed
by construction. Gaussians come from the Irwin–Hall sum of 12 uniforms
(exact mean 0 / variance 1; tails truncate at ±6σ, far past anything the
±0.28-LSB thermal term can push through the code rounding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams across 0.4.x/0.5.x; support
# both so the kernels import under whichever toolchain is baked in.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


# ---------------------------------------------------------------------------
# in-kernel counter-based PRNG (uint32 hash — works compiled AND interpreted)
# ---------------------------------------------------------------------------
def _mix32(h):
    """murmur3 finalizer: a bijective uint32 avalanche (all-ops VPU-native)."""
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


_GOLDEN32 = 0x9E3779B9  # 2^32/φ — the SplitMix increment


def salt_seed(seed, salt):
    """Fold a decorrelation `salt` into an int32 kernel seed (XOR with the
    golden-ratio-scrambled salt; salt=0 is the identity).

    One seed names one stochastic converter instance, so any two kernel
    invocations that must draw independent noise need distinct effective
    seeds. Two salts exist, both built from this scheme: the STATIC
    inl_seed (per-layer/per-step decorrelation, applied inside
    `_stochastic_transfer` at trace time) and the TRACED `jax.lax.axis_index`
    salt the engine's mesh dispatch applies per shard, so every shard of a
    sharded MVM models its own macro's converter chain (the Fig. 18
    instance-to-instance spread, one instance per shard). Works on python
    ints and traced int32 scalars; integer multiply wraps mod 2^32, matching
    the in-kernel uint32 arithmetic bit-for-bit.
    """
    if isinstance(salt, int):
        salt &= 0xFFFFFFFF
        if salt >= 0x80000000:
            salt -= 0x100000000
    seed = jnp.asarray(seed, jnp.int32)
    return seed ^ (jnp.asarray(salt, jnp.int32) * jnp.int32(-1640531527))


def _counter_base(seed, rows, cols, group):
    """Per-element uint32 hash state from (seed, global coords, group).

    Full 32-bit words are absorbed sequentially (sponge-style) instead of
    being packed into one index, so no shape is large enough to overflow the
    counter into systematic collisions.
    """
    h = _mix32(seed.astype(jnp.uint32) ^ jnp.uint32(_GOLDEN32))
    h = _mix32(h ^ rows.astype(jnp.uint32))
    h = _mix32(h ^ cols.astype(jnp.uint32))
    h = _mix32(h ^ (group.astype(jnp.uint32) * jnp.uint32(0x01000193)))
    return h


def _normal12(base):
    """Standard-normal draw per element: Irwin–Hall sum of 12 uniforms.

    Draw j is SplitMix-style: mix(base + j·GOLDEN). Exact mean 0 and
    variance 1 — the distributional-agreement contract the engine tests
    check against the jax.random.normal reference path.
    """
    acc = jnp.zeros(base.shape, jnp.float32)
    for j in range(12):
        bits = _mix32(base + jnp.uint32((j + 1) * _GOLDEN32 & 0xFFFFFFFF))
        acc = acc + bits.astype(jnp.float32)
    return acc * jnp.float32(2.0 ** -32) - jnp.float32(6.0)


def _unpack_nibbles(w_ref):
    """VMEM nibble unpack shared by the packed kernels: [half, bn] uint8
    bytes → [2·half, bn] f32 codes (row 2i low nibble, 2i+1 high)."""
    wp = w_ref[...].astype(jnp.int32)
    lo = (wp & 15).astype(jnp.float32)
    hi = ((wp >> 4) & 15).astype(jnp.float32)
    half, bn = wp.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * half, bn)


def _stochastic_transfer(part, *, inv_lsb, lsb, levels, sigma, inl_amp,
                         inl_seed, apply_inl, seed, bm, bn):
    """NOISY/FULL TD-ADC transfer on one [bm, bn] pre-ADC tile, in VMEM.

    Mirrors core.adc.adc_quantize order exactly: scale to LSB units → INL
    (FULL only, the same `inl_curve` instance for a given inl_seed) →
    thermal noise → clip/round → ×LSB reconstruction.
    """
    x = part * inv_lsb
    if apply_inl:
        from repro.core.adc import inl_curve
        x = x + inl_curve(jnp.clip(x / float(levels), 0.0, 1.0), inl_amp,
                          inl_seed)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) \
        + pl.program_id(0) * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) \
        + pl.program_id(1) * bn
    # inl_seed salts the counter (statically): one noise_seed names a chip
    # instance, while distinct inl_seed values decorrelate the draws of
    # same-shaped MVMs — the same per-macro-instance knob Fig. 18 uses.
    # (The engine's mesh dispatch applies the same scheme with a traced
    # per-shard axis_index salt before the seed reaches this kernel.)
    salted = salt_seed(seed, inl_seed).astype(jnp.uint32)
    base = _counter_base(salted, rows, cols, pl.program_id(2))
    x = x + jnp.float32(sigma) * _normal12(base)
    code = jnp.clip(jnp.round(x), 0.0, float(levels - 1))
    return code * lsb


def _cim_mvm_noisy_kernel(seed_ref, x_ref, w_ref, o_ref, *, inv_lsb: float,
                          lsb: float, levels: int, sigma: float,
                          inl_amp: float, inl_seed: int, apply_inl: bool):
    """Stochastic twin of _cim_mvm_kernel: per-conversion noise in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    bm, bn = o_ref.shape
    o_ref[...] += _stochastic_transfer(
        part, inv_lsb=inv_lsb, lsb=lsb, levels=levels, sigma=sigma,
        inl_amp=inl_amp, inl_seed=inl_seed, apply_inl=apply_inl,
        seed=seed_ref[0, 0], bm=bm, bn=bn)


def _cim_mvm_noisy_packed_kernel(seed_ref, x_ref, w_ref, o_ref, *,
                                 inv_lsb: float, lsb: float, levels: int,
                                 sigma: float, inl_amp: float, inl_seed: int,
                                 apply_inl: bool):
    """Stochastic twin of _cim_mvm_packed_kernel (nibble unpack in VMEM).

    The noise draw depends only on (seed, output coordinate, group), never
    on the weight container — so packed and unpacked stochastic kernels are
    bit-identical under the same seed (tested)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(x_ref[...], _unpack_nibbles(w_ref),
                   preferred_element_type=jnp.float32)
    bm, bn = o_ref.shape
    o_ref[...] += _stochastic_transfer(
        part, inv_lsb=inv_lsb, lsb=lsb, levels=levels, sigma=sigma,
        inl_amp=inl_amp, inl_seed=inl_seed, apply_inl=apply_inl,
        seed=seed_ref[0, 0], bm=bm, bn=bn)


@functools.partial(
    jax.jit, static_argnames=("n_rows", "levels", "gain", "full_scale",
                              "sigma", "inl_amp", "inl_seed", "apply_inl",
                              "bm", "bn", "interpret"))
def cim_mvm_grouped_noisy(x_codes: jax.Array, w_codes: jax.Array,
                          seed: jax.Array, *, n_rows: int, levels: int,
                          gain: float, full_scale: float, sigma: float,
                          inl_amp: float = 0.0, inl_seed: int = 0,
                          apply_inl: bool = False, bm: int = 128,
                          bn: int = 128, interpret: bool = False) -> jax.Array:
    """Stochastic twin of cim_mvm_grouped. `seed` is a TRACED int32 scalar
    (no recompile when QAT varies it per step); σ/INL settings are static,
    sourced from core.adc.stochastic_transfer_params."""
    m, k = x_codes.shape
    k2, n = w_codes.shape
    assert k == k2 and k % n_rows == 0, (x_codes.shape, w_codes.shape, n_rows)
    groups = k // n_rows
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, "caller pads M/N to block multiples"

    lsb = full_scale / (gain * (levels - 1))
    kernel = functools.partial(
        _cim_mvm_noisy_kernel, inv_lsb=1.0 / lsb, lsb=lsb, levels=levels,
        sigma=sigma, inl_amp=inl_amp, inl_seed=inl_seed, apply_inl=apply_inl)
    grid = (m // bm, n // bn, groups)
    seed2 = jnp.reshape(seed.astype(jnp.int32), (1, 1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, g: (0, 0)),
            pl.BlockSpec((bm, n_rows), lambda i, j, g: (i, g)),
            pl.BlockSpec((n_rows, bn), lambda i, j, g: (g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(seed2, x_codes.astype(jnp.float32), w_codes.astype(jnp.float32))


@functools.partial(
    jax.jit, static_argnames=("n_rows", "levels", "gain", "full_scale",
                              "sigma", "inl_amp", "inl_seed", "apply_inl",
                              "bm", "bn", "interpret"))
def cim_mvm_grouped_noisy_packed(x_codes: jax.Array, w_packed: jax.Array,
                                 seed: jax.Array, *, n_rows: int, levels: int,
                                 gain: float, full_scale: float, sigma: float,
                                 inl_amp: float = 0.0, inl_seed: int = 0,
                                 apply_inl: bool = False, bm: int = 128,
                                 bn: int = 128,
                                 interpret: bool = False) -> jax.Array:
    """Packed-weight twin of cim_mvm_grouped_noisy. w_packed [K/2, N] u8."""
    m, k = x_codes.shape
    k2, n = w_packed.shape
    assert k == 2 * k2 and k % n_rows == 0 and n_rows % 2 == 0
    groups = k // n_rows
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0

    lsb = full_scale / (gain * (levels - 1))
    kernel = functools.partial(
        _cim_mvm_noisy_packed_kernel, inv_lsb=1.0 / lsb, lsb=lsb,
        levels=levels, sigma=sigma, inl_amp=inl_amp, inl_seed=inl_seed,
        apply_inl=apply_inl)
    grid = (m // bm, n // bn, groups)
    seed2 = jnp.reshape(seed.astype(jnp.int32), (1, 1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, g: (0, 0)),
            pl.BlockSpec((bm, n_rows), lambda i, j, g: (i, g)),
            pl.BlockSpec((n_rows // 2, bn), lambda i, j, g: (g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(seed2, x_codes.astype(jnp.float32), w_packed.astype(jnp.uint8))


def _cim_mvm_kernel(x_ref, w_ref, o_ref, *, inv_lsb: float, lsb: float,
                    levels: int, n_groups: int):
    """One (bm × bn) output tile; sequential loop over macro groups."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Analog MAC: charge accumulation over one 144-row group (exact/linear).
    part = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    # TD-ADC transfer: VTC gain + clip + round onto the 8.5-bit code grid.
    code = jnp.clip(jnp.round(part * inv_lsb), 0.0, float(levels - 1))
    # Digital partial-sum accumulation (the ×LSB reconstruction).
    o_ref[...] += code * lsb


def _cim_mvm_packed_kernel(x_ref, w_ref, o_ref, *, inv_lsb: float, lsb: float,
                           levels: int):
    """Packed-int4 variant: w_ref holds two 4-bit codes per byte along the
    reduction axis (row 2i in the low nibble, 2i+1 in the high nibble).
    Unpacking happens in VMEM right before the MXU dot — weights travel
    HBM→VMEM at 4 bits each, the TPU counterpart of the paper's 4-bit SRAM
    storage density (559 Kb/mm²)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(x_ref[...], _unpack_nibbles(w_ref),
                   preferred_element_type=jnp.float32)
    code = jnp.clip(jnp.round(part * inv_lsb), 0.0, float(levels - 1))
    o_ref[...] += code * lsb


@functools.partial(
    jax.jit, static_argnames=("n_rows", "levels", "gain", "full_scale",
                              "bm", "bn", "interpret"))
def cim_mvm_grouped_packed(x_codes: jax.Array, w_packed: jax.Array, *,
                           n_rows: int, levels: int, gain: float,
                           full_scale: float, bm: int = 128, bn: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Packed-weight twin of cim_mvm_grouped. w_packed [K/2, N] uint8."""
    m, k = x_codes.shape
    k2, n = w_packed.shape
    assert k == 2 * k2 and k % n_rows == 0 and n_rows % 2 == 0
    groups = k // n_rows
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0

    lsb = full_scale / (gain * (levels - 1))
    kernel = functools.partial(_cim_mvm_packed_kernel, inv_lsb=1.0 / lsb,
                               lsb=lsb, levels=levels)
    grid = (m // bm, n // bn, groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n_rows), lambda i, j, g: (i, g)),
            pl.BlockSpec((n_rows // 2, bn), lambda i, j, g: (g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_codes.astype(jnp.float32), w_packed.astype(jnp.uint8))


@functools.partial(
    jax.jit, static_argnames=("n_rows", "levels", "gain", "full_scale",
                              "bm", "bn", "interpret"))
def cim_mvm_grouped(x_codes: jax.Array, w_codes: jax.Array, *, n_rows: int,
                    levels: int, gain: float, full_scale: float,
                    bm: int = 128, bn: int = 128,
                    interpret: bool = False) -> jax.Array:
    """ŷ[M, N] = Σ_g ADC( x[M, g·144:(g+1)·144] @ w[g·144:(g+1)·144, N] ).

    x_codes [M, K], w_codes [K, N]; K must already be padded to a multiple of
    n_rows (ops.py handles padding — zero codes are exact no-ops).
    """
    m, k = x_codes.shape
    k2, n = w_codes.shape
    assert k == k2 and k % n_rows == 0, (x_codes.shape, w_codes.shape, n_rows)
    groups = k // n_rows
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, "caller pads M/N to block multiples"

    lsb = full_scale / (gain * (levels - 1))
    kernel = functools.partial(_cim_mvm_kernel, inv_lsb=1.0 / lsb, lsb=lsb,
                               levels=levels, n_groups=groups)
    grid = (m // bm, n // bn, groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n_rows), lambda i, j, g: (i, g)),
            pl.BlockSpec((n_rows, bn), lambda i, j, g: (g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, g: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_codes.astype(jnp.float32), w_codes.astype(jnp.float32))
