"""Paged-attention kernel subsystem: block-table flash attention + registry.

PR 4's paged-KV server reads each slot's KV window by gathering its block
table on the host side of the math (`models.common.paged_gather`) and then
attending with an exact one-pass softmax — which materializes the full
`[B, C, KH, G, W]` score tensor. Fine at smoke scale; at a 32k window that
tensor is the whole memory budget. This module is the TPU-scale fix, built
the same way the CIM execution engine was: a small registry of ATTENTION
backends that all consume the paged pool + block tables directly, so the
serving step (`models.transformer.paged_step`) selects its attention path
exactly like layer matmuls select their CIM backend.

  backend   what it does                                         runs on
  --------  ---------------------------------------------------  ---------
  "exact"   the PR-4 reference path: gather each slot's window   any
            through its table, one-pass softmax over the full
            window (models.common.decode_attention /
            paged_prefill_attention — the bit-identity anchors)
  "kernel"  fused Pallas flash kernel: the block gather happens  TPU (or
            INSIDE the kernel (block tables are scalar-          interpret
            prefetched and drive the K/V BlockSpec index maps),  mode on
            and the softmax is accumulated online block-by-      CPU)
            block in VMEM — the [B, C, KH, G, W] score tensor
            never exists; live scores are one [C·G, bs] tile
  "auto"    "kernel", unless REPRO_FORCE_JNP=1 pins "exact"
            (the same escape hatch the CIM engine honors for
            environments without interpret-mode Pallas)

Kernel layout (grid = (B, KH, MB), MB = blocks per slot window):

  * the two leading grid axes are parallel (one program per slot × KV
    head); the block axis is sequential ("arbitrary") and innermost so the
    [C·G, dh] output accumulator plus the online-softmax running max/sum
    stay resident in VMEM across all MB blocks — the same
    revisit-nothing-in-HBM discipline as the fused CIM MVM kernel;
  * the block tables (and per-slot base positions / valid lengths) ride in
    as scalar-prefetch operands: the K/V BlockSpec index maps read
    `tables[b, j]`, so the pool block each grid step DMAs into VMEM IS the
    slot's j-th logical block — a gather the kernel gets for free from the
    pipeline, with no [B, W, KH, dh] windowed copy ever materialized;
  * GQA is folded as rows: q arrives [B, KH, C·G, dh] (C = chunk width, G
    = query heads per KV head), so decode (C=1) and chunked prefill are
    the SAME kernel — the causal mask per row uses that row's chunk
    offset (row // G), mirroring `paged_prefill_attention`'s mask exactly;
  * trash-block lanes (physical block 0 — masked writes, unallocated table
    entries) sit at positions >= the slot's kv_len and are masked at -1e30
    before the online max; their probabilities are forced to exactly 0 and
    their V rows are zeroed before the PV dot, so even NaN poison in the
    trash block cannot reach the output (0·NaN is NaN — masking the weight
    alone would not be enough). The "exact" backend applies the same V
    sanitization outside the softmax, where it is a bit-exact no-op for
    clean pools.

Mesh composition: a bare `pallas_call` cannot be GSPMD-partitioned, so when
a mesh is active the dispatcher wraps the kernel in
`parallel.sharding.shard_map` with KV heads sharded over "model" (when
divisible — the serving head layout; everything else replicated, B is
small). Callers already tracing per-shard (`sharding.in_shard_context()`)
get the plain kernel. The "exact" backend stays plain jnp and lets GSPMD
partition it.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec

from repro.parallel import sharding
from repro.runtime.telemetry import KERNEL_COUNTERS

# jax renamed TPUCompilerParams → CompilerParams across 0.4.x/0.5.x (same
# shim as kernels/cim_mvm.py) — support both toolchains.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


# ---------------------------------------------------------------------------
# backend registry (mirrors core.engine's CIM backend registry)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnBackendSpec:
    """One paged-attention evaluation strategy.

    fn(q, k_pool, v_pool, tables, positions, kv_len) -> o
      q [B, C, H, dh] (C = 1 for decode); pools [NB, bs, KH, dh];
      tables [B, MB] physical block ids; positions [B, C] absolute query
      positions (= lens + chunk offset); kv_len [B] tokens valid in the
      window INCLUDING this step's writes. Returns [B, C, H, dh].
    """

    name: str
    fn: Callable
    pallas: bool = False   # True → wants the shard_map mesh dispatch


_ATTN_REGISTRY: dict[str, AttnBackendSpec] = {}


def register_attn_backend(name: str, *, pallas: bool = False):
    """Register a paged-attention backend under `name` (decorator)."""
    def deco(fn):
        _ATTN_REGISTRY[name] = AttnBackendSpec(name, fn, pallas)
        return fn
    return deco


def get_attn_backend(name: str) -> AttnBackendSpec:
    try:
        return _ATTN_REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown attention backend {name!r}; "
                         f"registered: {sorted(_ATTN_REGISTRY)}") from None


def available_attn_backends() -> tuple[str, ...]:
    return tuple(sorted(_ATTN_REGISTRY))


def _force_jnp() -> bool:
    """REPRO_FORCE_JNP=1 pins auto-selection to the jnp reference — the
    same escape hatch core.engine honors (environments without interpret-
    mode Pallas). Explicit backend names bypass it."""
    return os.environ.get("REPRO_FORCE_JNP", "").strip().lower() \
        in ("1", "true", "yes")


def choose_attn_backend(backend: str) -> str:
    """Resolve "auto" (or an explicit name) to a registered backend."""
    if backend != "auto":
        return get_attn_backend(backend).name
    return "exact" if _force_jnp() else "kernel"


# ---------------------------------------------------------------------------
# "exact" backend: the PR-4 gather + one-pass-softmax reference path
# ---------------------------------------------------------------------------
@register_attn_backend("exact")
def _exact_attention(q, k_pool, v_pool, tables, positions, kv_len):
    """Window gather through the table + the dense-cache attention math.

    Literally the pre-registry serving path (models.common.paged_gather →
    decode_attention / paged_prefill_attention), kept as the bit-identity
    anchor the paged soak tests pin. One hardening addition: V rows at
    positions >= kv_len (trash block / stale block tails) are zeroed
    BEFORE the PV contraction. Their softmax weight is already exactly 0
    (exp(-1e30 - m) underflows), so this is bit-exact for clean pools —
    but 0 · NaN = NaN, so without it NaN poison in never-attended storage
    would still reach the output.
    """
    from repro.models import common  # lazy: kernels must not import models
    k_win = common.paged_gather(k_pool, tables)
    v_win = common.paged_gather(v_pool, tables)
    w = k_win.shape[1]
    valid = (jnp.arange(w)[None, :] < kv_len[:, None])
    # jnp.where, not a mask multiply: 0 · NaN is NaN, so multiplying would
    # let NaN poison through the very rows being sanitized
    v_win = jnp.where(valid[..., None, None], v_win,
                      jnp.zeros((), v_win.dtype))
    if q.shape[1] == 1:
        # same window shape + mask math as the dense slot cache → decode
        # stays bit-identical to the unpaged decode_attention path
        return common.decode_attention(q, k_win, v_win,
                                       kv_len[:, None, None, None])
    return common.paged_prefill_attention(q, k_win, v_win, positions, kv_len)


# ---------------------------------------------------------------------------
# "kernel" backend: fused Pallas flash decode/prefill over block tables
# ---------------------------------------------------------------------------
def _paged_attn_kernel(tables_ref, lens_ref, kvl_ref, q_ref, *refs,
                       scale: float, block_size: int, g: int, kblocks: int,
                       row_tile: int):
    """One (slot b, KV head h, row tile r) program; sequential pass over
    the MB blocks, `kblocks` logical blocks per step.

    q_ref [1, 1, RT, dh] (RT = row tile of the C·G query rows); the step's
    KV arrives as `kblocks` separate [1, bs, 1, dh] refs — the slot's
    logical blocks j·kblocks … j·kblocks+kblocks−1, each fetched by its own
    index map through the scalar-prefetched table, so the pipeline double-
    buffers a [kblocks·bs, dh] span per sequential step. Scratch holds the
    online-softmax state (running max m, sum l, PV accumulator) in VMEM for
    the whole pass; the only score tensor ever live is the
    [RT, kblocks·bs] tile of this step.
    """
    k_refs = refs[:kblocks]
    v_refs = refs[kblocks:2 * kblocks]
    o_ref = refs[2 * kblocks]
    m_ref, l_ref, acc_ref = refs[2 * kblocks + 1:]
    b = pl.program_id(0)
    r = pl.program_id(2)
    j = pl.program_id(3)
    span = kblocks * block_size

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kvl = kvl_ref[b]

    # Steps whose whole span sits at or past the slot's valid length hold
    # nothing attendable (every position masks to weight 0) — skip their
    # MXU work entirely; their table entries point at the trash block
    # anyway (including the pad entries appended to make MB divide).
    @pl.when(j * span < kvl)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)            # [RT, dh]
        k = jnp.concatenate(                           # [span, dh]
            [kr[0, :, 0, :] for kr in k_refs], axis=0).astype(jnp.float32)
        v = jnp.concatenate(
            [vr[0, :, 0, :] for vr in v_refs], axis=0).astype(jnp.float32)
        rt = q.shape[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos_s = j * span \
            + jax.lax.broadcasted_iota(jnp.int32, (rt, span), 1)
        row = r * rt + jax.lax.broadcasted_iota(jnp.int32, (rt, span), 0)
        chunk_off = row // g
        pos_q = lens_ref[b] + chunk_off
        # the paged_prefill_attention mask exactly: causal within the chunk
        # AND inside the slot's valid window (trash/stale lanes land here)
        mask = (pos_s <= pos_q) & (pos_s < kvl)
        s = jnp.where(mask, s, -1e30)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # exp(-1e30 - m) underflows to 0, but force masked weights to an
        # exact 0 so an all-masked tile cannot normalize to uniform
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        # zero invalid V rows pre-dot via where (0-weight · NaN-garbage is
        # still NaN, and so is 0 · NaN from a mask multiply)
        v = jnp.where(pos_s[0:1, :].T < kvl, v, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha \
            + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finish():
        # idle lanes (kv_len = 0) keep l = 0 → emit 0, never NaN; their
        # outputs are discarded by the scheduler anyway
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "g", "interpret",
                                    "kblocks", "row_tile"))
def _paged_attn_call(q3, k_pool, v_pool, tables, lens, kvl, *,
                     block_size: int, g: int, interpret: bool,
                     kblocks: int = 1, row_tile: int | None = None):
    """pallas_call plumbing: q3 [B, KH, CG, dh] f32 → o [B, KH, CG, dh].

    `tables` must already be padded to a multiple of `kblocks` (pad entries
    point at the trash block); CG must divide by `row_tile`.
    """
    b, kh, cg, dh = q3.shape
    mb = tables.shape[1]
    assert mb % kblocks == 0, (mb, kblocks)
    rt = cg if row_tile is None else row_tile
    assert cg % rt == 0, (cg, rt)
    kern = functools.partial(_paged_attn_kernel,
                             scale=1.0 / math.sqrt(dh),
                             block_size=block_size, g=g, kblocks=kblocks,
                             row_tile=rt)

    def _kv_map(i):
        # i-th sub-block of the step's kblocks-wide span; default-arg bind
        # so each spec closes over its own stride offset
        return lambda b, h, r, j, t, ln, kv, i=i: (t[b, j * kblocks + i],
                                                   0, h, 0)

    kv_spec = [pl.BlockSpec((1, block_size, 1, dh), _kv_map(i))
               for i in range(kblocks)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, kh, cg // rt, mb // kblocks),
        in_specs=[
            pl.BlockSpec((1, 1, rt, dh),
                         lambda b, h, r, j, t, ln, kv: (b, h, r, 0)),
            *kv_spec,          # kblocks K blocks …
            *kv_spec,          # … then the matching V blocks
        ],
        out_specs=pl.BlockSpec((1, 1, rt, dh),
                               lambda b, h, r, j, t, ln, kv: (b, h, r, 0)),
        scratch_shapes=[
            pltpu.VMEM((rt, 1), jnp.float32),    # running max m
            pltpu.VMEM((rt, 1), jnp.float32),    # running sum l
            pltpu.VMEM((rt, dh), jnp.float32),   # PV accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, cg, dh), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), lens.astype(jnp.int32),
      kvl.astype(jnp.int32), q3.astype(jnp.float32),
      *([k_pool] * kblocks), *([v_pool] * kblocks))


def _resolve_attn_config(*, window: int, c: int, mb: int, cg: int):
    """(kblocks, row_tile) for this shape, tuning cache first.

    Consults kernels.autotune (env `REPRO_TUNE_CACHE`) under the
    "paged_attn" kernel key and the decode/prefill shape family; a miss —
    or no cache at all — keeps the PR-5 defaults (one block per step, one
    row tile). Values are clamped to the actual geometry so a cache tuned
    on a bigger shape family can never produce an invalid grid.
    """
    from repro.kernels import autotune
    cfg = autotune.lookup("paged_attn",
                          autotune.attn_family(window, c),
                          backend="kernel")
    if autotune.cache_path():
        KERNEL_COUNTERS.tune_lookup("paged_attn", hit=cfg is not None)
    kblocks = 1
    row_tile = None
    if cfg:
        kblocks = max(1, min(int(cfg.get("kblocks", 1) or 1), mb))
        row_tile = cfg.get("row_tile")
        if row_tile:
            row_tile = max(1, min(int(row_tile), cg))
    return kblocks, row_tile


def paged_flash_attention(q, k_pool, v_pool, tables, lens, kv_len, *,
                          interpret: bool | None = None,
                          kblocks: int | None = None,
                          row_tile: int | None = None):
    """Flash-style paged attention: q [B, C, H, dh] × pools [NB, bs, KH, dh]
    through per-slot block tables [B, MB] → [B, C, H, dh].

    lens [B] = tokens already cached per slot BEFORE this step's writes
    (the chunk's base position); kv_len [B] = lens + this step's valid
    writes. GQA rows are folded as C·G so decode (C=1) and chunked prefill
    share one kernel; pools stay in their storage dtype and are upcast
    per-block in VMEM.

    kblocks / row_tile (None → tuning cache, default 1 / single tile)
    control the pipeline shape: each sequential grid step fetches `kblocks`
    logical KV blocks (tables are padded with trash entries to divide), and
    the C·G query rows split into `row_tile`-high parallel tiles (rows are
    padded with dummy queries to divide — their outputs are sliced away).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, c, h, dh = q.shape
    kh = k_pool.shape[2]
    g = h // kh
    bs = k_pool.shape[1]
    mb = tables.shape[1]
    cg = c * g
    if kblocks is None and row_tile is None:
        kblocks, row_tile = _resolve_attn_config(window=mb * bs, c=c,
                                                 mb=mb, cg=cg)
    kblocks = max(1, min(kblocks or 1, mb))
    if row_tile is not None and (row_tile <= 0 or row_tile >= cg):
        row_tile = None
    if mb % kblocks:
        pad = kblocks - mb % kblocks     # pad entries → trash block 0
        tables = jnp.pad(tables, ((0, 0), (0, pad)))
    # [B, C, KH, G, dh] → [B, KH, C·G, dh]: row r = chunk_off·G + g_idx
    q3 = q.reshape(b, c, kh, g, dh).transpose(0, 2, 1, 3, 4) \
          .reshape(b, kh, cg, dh)
    cg_p = cg
    if row_tile is not None and cg % row_tile:
        cg_p = -(-cg // row_tile) * row_tile
        q3 = jnp.pad(q3, ((0, 0), (0, 0), (0, cg_p - cg), (0, 0)))
    out = _paged_attn_call(q3, k_pool, v_pool, tables, lens, kv_len,
                           block_size=bs, g=g, interpret=interpret,
                           kblocks=kblocks, row_tile=row_tile)
    if cg_p != cg:
        out = out[:, :, :cg, :]
    out = out.reshape(b, kh, c, g, dh).transpose(0, 2, 1, 3, 4) \
             .reshape(b, c, h, dh)
    return out.astype(q.dtype)


@register_attn_backend("kernel", pallas=True)
def _kernel_attention(q, k_pool, v_pool, tables, positions, kv_len):
    lens = positions[:, 0].astype(jnp.int32)  # chunk base = first q position
    return paged_flash_attention(q, k_pool, v_pool, tables, lens, kv_len)


# ---------------------------------------------------------------------------
# fused decode write-scatter: paged_write's .at[].set moved into a kernel
# ---------------------------------------------------------------------------
def _fused_write_kernel(wblk_ref, woff_ref, wval_ref, nk_ref, nv_ref,
                        k_ref, v_ref, ko_ref, vo_ref, *, block_size: int):
    """One slot per (sequential) grid step: the slot's target pool block
    arrives via the scalar-prefetched write-block id, the new K/V row is
    blended in at the write offset, and the block is written straight back
    (the pools are input/output aliased, so untouched blocks never move).
    Invalid lanes (write target = the trash block) write their block back
    unmodified — unlike `models.common.paged_write`, the trash block's row
    0 is never clobbered, which only ever differs in never-attended bits.
    """
    b = pl.program_id(0)
    off = woff_ref[b]
    valid = wval_ref[b]
    rows = jax.lax.broadcasted_iota(jnp.int32, (1, block_size, 1, 1), 1)
    sel = (rows == off) & (valid != 0)
    ko_ref[...] = jnp.where(sel, nk_ref[...], k_ref[...])
    vo_ref[...] = jnp.where(sel, nv_ref[...], v_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fused_write_call(k_pool, v_pool, new_k, new_v, wblk, woff, wval, *,
                      interpret: bool):
    nb, bs, kh, dh = k_pool.shape
    b = new_k.shape[0]
    kern = functools.partial(_fused_write_kernel, block_size=bs)
    new_spec = pl.BlockSpec((1, 1, kh, dh),
                            lambda b, t, o, v: (b, 0, 0, 0))
    pool_spec = pl.BlockSpec((1, bs, kh, dh),
                             lambda b, t, o, v: (t[b], 0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b,),
        in_specs=[new_spec, new_spec, pool_spec, pool_spec],
        out_specs=[pool_spec, pool_spec],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)],
        # pools alias their outputs (operand indices count the 3 scalar-
        # prefetch refs): blocks no grid step visits keep their bytes
        input_output_aliases={5: 0, 6: 1},
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(wblk.astype(jnp.int32), woff.astype(jnp.int32),
      wval.astype(jnp.int32), new_k.astype(k_pool.dtype),
      new_v.astype(v_pool.dtype), k_pool, v_pool)


def fused_paged_write(k_pool, v_pool, new_k, new_v, flat_idx, *,
                      interpret: bool | None = None):
    """Kernel-side decode write: scatter each slot's new K/V row (C = 1)
    into its pool block without the host-visible `.at[].set` round trip.

    new_k / new_v [B, 1, KH, dh]; flat_idx [B, 1] flat (block·bs + offset)
    write targets as built by transformer.paged_step — 0 marks an invalid
    lane (paged_write would park it in the trash block; here it is a
    no-op, the only deliberate divergence). Returns the updated pools.

    Prefix-sharing contract (PR 7): flat_idx is derived from the block
    table the HOST passes into the step, and the scheduler copy-on-write
    forks any shared block before stepping (runtime.server._write_plan →
    transformer.cow_copy_block), so by the time this epilogue runs the
    remapped table already points every write at a privately held block —
    the kernel never needs to know about refcounts, and must never be
    handed a table whose write-span blocks are still shared.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bs = k_pool.shape[1]
    fi = flat_idx.reshape(-1).astype(jnp.int32)
    return _fused_write_call(k_pool, v_pool, new_k, new_v,
                             fi // bs, fi % bs, (fi != 0).astype(jnp.int32),
                             interpret=interpret)


# ---------------------------------------------------------------------------
# dispatch (the single entry point models.common calls)
# ---------------------------------------------------------------------------
def _mesh_attn_specs(mesh, kh: int):
    """Head-parallel shard_map specs: KV heads over "model" when divisible
    (the serving head layout), everything else replicated — B is a handful
    of slots and the pool is shared storage. Falls back to fully-replicated
    specs (each shard computes every head redundantly but correctly) when
    the model axis cannot divide KH — the same silent fallback
    sharding.spec_for applies to parameters."""
    heads = None
    if "model" in mesh.axis_names and mesh.shape["model"] > 1 \
            and kh % mesh.shape["model"] == 0:
        heads = "model"
    q_spec = PartitionSpec(None, heads, None, None)
    pool_spec = PartitionSpec(None, None, heads, None)
    return q_spec, pool_spec


def paged_attention(q, k_pool, v_pool, tables, *, positions, kv_len,
                    backend: str = "auto"):
    """Attend q over a paged KV pool through per-slot block tables.

    q [B, C, H, dh]; pools [NB, bs, KH, dh]; tables [B, MB]; positions
    [B, C] absolute query positions (lens + chunk offset, as built by
    transformer.paged_step); kv_len [B]. Returns [B, C, H, dh]. `backend`
    is "auto" | "exact" | "kernel" (see module docstring; models thread
    cfg.attn_backend here). Owns the mesh dispatch: the Pallas backend runs
    per-shard inside sharding.shard_map whenever a mesh is active, heads
    over "model".
    """
    name = choose_attn_backend(backend)
    # trace-time dispatch counter (one count per compiled shape, not per
    # executed step — see telemetry.KernelCounters)
    KERNEL_COUNTERS.count_attn(name)
    spec = get_attn_backend(name)
    mesh = sharding.get_mesh()
    if not (spec.pallas and mesh is not None
            and not sharding.in_shard_context()):
        return spec.fn(q, k_pool, v_pool, tables, positions, kv_len)

    b, c, h, dh = q.shape
    kh = k_pool.shape[2]
    q5 = q.reshape(b, c, kh, h // kh, dh)   # split heads → KH is an axis
    q_spec, pool_spec = _mesh_attn_specs(mesh, kh)
    q5_spec = PartitionSpec(None, None, q_spec[1], None, None)

    def shard_fn(q_l, k_l, v_l, t_l, pos_l, kvl_l):
        q_flat = q_l.reshape(q_l.shape[0], c, -1, dh)
        return spec.fn(q_flat, k_l, v_l, t_l, pos_l, kvl_l).reshape(
            q_l.shape)

    out = sharding.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(q5_spec, pool_spec, pool_spec,
                  PartitionSpec(None, None), PartitionSpec(None, None),
                  PartitionSpec(None)),
        out_specs=q5_spec,
        check_vma=False,
    )(q5, k_pool, v_pool, tables, positions, kv_len)
    return out.reshape(b, c, h, dh)
