"""Pure-jnp oracle for the cim_mvm Pallas kernel.

Mirrors the kernel's deterministic (SimLevel.IDEAL) BP transfer exactly:
grouped MAC → per-group ADC clip/round with VTC gain → digital accumulation.
Kept independent of core/schemes.py so kernel tests exercise a genuinely
separate code path (core uses STE rounding and richer noise models; the
numerics at IDEAL level must agree to float tolerance).
"""
from __future__ import annotations

import jax.numpy as jnp


def cim_mvm_ref(x_codes, w_codes, *, n_rows: int, levels: int, gain: float,
                full_scale: float):
    """x_codes [M, K], w_codes [K, N] (K a multiple of n_rows) → [M, N]."""
    m, k = x_codes.shape
    _, n = w_codes.shape
    groups = k // n_rows
    lsb = full_scale / (gain * (levels - 1))
    xg = x_codes.astype(jnp.float32).reshape(m, groups, n_rows)
    wg = w_codes.astype(jnp.float32).reshape(groups, n_rows, n)
    part = jnp.einsum("mgk,gkn->mgn", xg, wg,
                      preferred_element_type=jnp.float32)
    code = jnp.clip(jnp.round(part / lsb), 0.0, float(levels - 1))
    return jnp.sum(code * lsb, axis=1)
