"""Public jit'd wrapper around the cim_mvm Pallas kernel.

Handles: leading-dim flattening, zero-padding of K to the macro depth and of
M/N to block multiples (zero codes are unselected SRAM rows — bit-exact
no-ops), backend selection (compiled TPU kernel vs interpret mode on CPU),
and block-size tuning knobs used by the §Perf hillclimb.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.macro import MacroConfig, Scheme, SimLevel

from .cim_mvm import (cim_mvm_grouped, cim_mvm_grouped_noisy,
                      cim_mvm_grouped_noisy_packed, cim_mvm_grouped_packed,
                      salt_seed)

__all__ = [
    "cim_mvm_pallas", "cim_mvm_pallas_packed", "cim_mvm_pallas_noisy",
    "cim_mvm_pallas_noisy_packed", "pack_codes", "unpack_codes",
    "packed_col_sums", "salt_seed",
]


def pack_codes(w_codes: jax.Array) -> jax.Array:
    """[..., K, N] 4-bit codes → [..., ceil(K/2), N] uint8 nibble pairs.

    Row 2i lands in the low nibble, row 2i+1 in the high nibble. Odd K is
    zero-padded first (a zero code is an unselected SRAM row — an exact
    no-op in the MVM and in the Eq. 7 correction sums). This is the
    wire/HBM format the packed kernel consumes — 4 bits per stored weight,
    as in the SRAM array. Leading dims (stacked layers, experts) pass
    through untouched.
    """
    k, n = w_codes.shape[-2:]
    if k % 2:
        widths = [(0, 0)] * (w_codes.ndim - 2) + [(0, 1), (0, 0)]
        w_codes = jnp.pad(w_codes, widths)
        k += 1
    wi = w_codes.astype(jnp.int32).reshape(*w_codes.shape[:-2], k // 2, 2, n)
    return (wi[..., 0, :] | (wi[..., 1, :] << 4)).astype(jnp.uint8)


def unpack_codes(w_packed: jax.Array, k: int | None = None) -> jax.Array:
    """Inverse of pack_codes: [..., K2, N] uint8 → [..., K, N] f32 codes.

    `k` trims the pack-padding row when the logical K was odd; defaults to
    the full 2·K2 rows.
    """
    wi = w_packed.astype(jnp.int32)
    lo = (wi & 15).astype(jnp.float32)
    hi = ((wi >> 4) & 15).astype(jnp.float32)
    k2, n = w_packed.shape[-2:]
    full = jnp.stack([lo, hi], axis=-2).reshape(*w_packed.shape[:-2],
                                                2 * k2, n)
    return full if k is None else full[..., :k, :]


def packed_col_sums(w_packed: jax.Array) -> jax.Array:
    """Σ_K W̃ per output column straight from the packed bytes — the Eq. 7
    ΣW̃ correction term without materializing unpacked codes (pack-padding
    rows hold zero codes, so they are exact no-ops in the sum)."""
    wi = w_packed.astype(jnp.int32)
    return jnp.sum((wi & 15) + ((wi >> 4) & 15), axis=-2).astype(jnp.float32)


def _resolve_tiles(x_codes, n: int, n_rows: int,
                   bm: int | None, bn: int | None) -> tuple[int, int]:
    """(bm, bn) for this MVM shape: explicit values win; None consults the
    kernels.autotune cache (env `REPRO_TUNE_CACHE`) under the "cim_mvm"
    kernel key — this is how core.engine.execute_mvm's Pallas backends,
    which call these entry points with no tile kwargs, pick up tuned tiles
    at dispatch. A miss keeps the (128, 128) defaults."""
    if bm is not None and bn is not None:
        return bm, bn
    from repro.kernels import autotune
    k = x_codes.shape[-1]
    m = 1
    for d in x_codes.shape[:-1]:
        m *= d
    tuned = autotune.lookup(
        "cim_mvm", autotune.mvm_family(m, -(-k // n_rows), n),
        backend="pallas")
    if autotune.cache_path():
        from repro.runtime.telemetry import KERNEL_COUNTERS
        KERNEL_COUNTERS.tune_lookup("cim_mvm", hit=tuned is not None)
    tuned = tuned or {}
    if bm is None:
        bm = int(tuned.get("bm", 128) or 128)
    if bn is None:
        bn = int(tuned.get("bn", 128) or 128)
    return max(1, bm), max(1, bn)


def _pad_to(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _prep_dense(x_codes, w_codes, n_rows: int, bm: int, bn: int):
    """Shared operand prep for the dense-weight kernels: flatten leading
    dims, zero-pad K to the macro depth and M/N to block multiples (zero
    codes are unselected SRAM rows — exact no-ops). Returns
    (x2, w2, bm_eff, bn_eff, lead, m, n)."""
    lead = x_codes.shape[:-1]
    k = x_codes.shape[-1]
    x2 = x_codes.reshape(-1, k)
    m, n = x2.shape[0], w_codes.shape[-1]
    x2 = _pad_to(_pad_to(x2, n_rows, 1), min(bm, max(m, 1)), 0)
    w2 = _pad_to(_pad_to(w_codes, n_rows, 0), min(bn, max(n, 1)), 1)
    bm_eff = bm if x2.shape[0] % bm == 0 else x2.shape[0]
    bn_eff = bn if w2.shape[1] % bn == 0 else w2.shape[1]
    return x2, w2, bm_eff, bn_eff, lead, m, n


def _prep_packed(x_codes, w_packed, n_rows: int, bm: int, bn: int):
    """Packed-weight twin of _prep_dense: x pads to the byte rows first,
    w pads in nibble-pair units (zero bytes = two unselected rows)."""
    lead = x_codes.shape[:-1]
    k = x_codes.shape[-1]
    k2 = w_packed.shape[0]
    assert k in (2 * k2, 2 * k2 - 1), (x_codes.shape, w_packed.shape)
    x2 = x_codes.reshape(-1, k)
    m, n = x2.shape[0], w_packed.shape[1]
    x2 = _pad_to(_pad_to(x2, 2, 1), n_rows, 1)
    w2 = _pad_to(w_packed, n_rows // 2, 0)
    x2 = _pad_to(x2, min(bm, max(m, 1)), 0)
    w2 = _pad_to(w2, min(bn, max(n, 1)), 1)
    bm_eff = bm if x2.shape[0] % bm == 0 else x2.shape[0]
    bn_eff = bn if w2.shape[1] % bn == 0 else w2.shape[1]
    return x2, w2, bm_eff, bn_eff, lead, m, n


def cim_mvm_pallas_packed(x_codes: jax.Array, w_packed: jax.Array,
                          cfg: MacroConfig, *, bm: int | None = None,
                          bn: int | None = None,
                          interpret: bool | None = None) -> jax.Array:
    """ŷ ≈ Σ X̃ W̃ with 4-bit-packed weights. x [..., K], w_packed [K2, M]
    with K ≤ 2·K2 (K2 = ceil(K/2) nibble pairs). K, M and the leading dims
    are padded here; zero bytes are pairs of unselected SRAM rows."""
    assert cfg.scheme == Scheme.BP
    assert cfg.n_rows % 2 == 0, "nibble packing needs an even macro depth"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm, bn = _resolve_tiles(x_codes, w_packed.shape[1], cfg.n_rows, bm, bn)
    x2, w2, bm_eff, bn_eff, lead, m, n = _prep_packed(x_codes, w_packed,
                                                      cfg.n_rows, bm, bn)
    out = cim_mvm_grouped_packed(
        x2, w2, n_rows=cfg.n_rows, levels=cfg.effective_adc_levels(),
        gain=cfg.gain, full_scale=cfg.full_scale(), bm=bm_eff, bn=bn_eff,
        interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def cim_mvm_pallas(x_codes: jax.Array, w_codes: jax.Array, cfg: MacroConfig,
                   *, bm: int | None = None, bn: int | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """ŷ ≈ Σ X̃ W̃ through the fused BP kernel.

    x_codes [..., K] unsigned DAC codes, w_codes [K, M] stored codes.
    Only the BP scheme is implemented as a fused kernel — it is the paper's
    deployed scheme; WBS/BS baselines run on the jnp path.
    """
    assert cfg.scheme == Scheme.BP, "fused kernel implements BP only"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm, bn = _resolve_tiles(x_codes, w_codes.shape[-1], cfg.n_rows, bm, bn)
    x2, w2, bm_eff, bn_eff, lead, m, n = _prep_dense(x_codes, w_codes,
                                                     cfg.n_rows, bm, bn)
    out = cim_mvm_grouped(
        x2, w2, n_rows=cfg.n_rows, levels=cfg.effective_adc_levels(),
        gain=cfg.gain, full_scale=cfg.full_scale(), bm=bm_eff, bn=bn_eff,
        interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def cim_mvm_pallas_noisy(x_codes: jax.Array, w_codes: jax.Array,
                         cfg: MacroConfig, *, noise_seed, inl_seed: int = 0,
                         bm: int | None = None, bn: int | None = None,
                         interpret: bool | None = None) -> jax.Array:
    """Stochastic (NOISY/FULL) fused BP MVM: per-conversion thermal noise
    (and, at FULL, the Fig. 15 INL instance for cfg's inl_seed) drawn inside
    the kernel in VMEM. `noise_seed` is a traced int32 scalar — vary it per
    QAT step without recompiling. σ/INL settings come from
    core.adc.stochastic_transfer_params, the same source adc_quantize uses,
    so the fused and jnp pipelines agree in distribution."""
    from repro.core.adc import stochastic_transfer_params
    assert cfg.scheme == Scheme.BP, "fused kernel implements BP only"
    assert cfg.sim_level != SimLevel.IDEAL, \
        "IDEAL transfer runs the deterministic kernel (cim_mvm_pallas)"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    st = stochastic_transfer_params(cfg)
    bm, bn = _resolve_tiles(x_codes, w_codes.shape[-1], cfg.n_rows, bm, bn)
    x2, w2, bm_eff, bn_eff, lead, m, n = _prep_dense(x_codes, w_codes,
                                                     cfg.n_rows, bm, bn)
    out = cim_mvm_grouped_noisy(
        x2, w2, jnp.asarray(noise_seed, jnp.int32), n_rows=cfg.n_rows,
        levels=cfg.effective_adc_levels(), gain=cfg.gain,
        full_scale=cfg.full_scale(), sigma=st["sigma"],
        inl_amp=st["inl_amp"], inl_seed=inl_seed, apply_inl=st["apply_inl"],
        bm=bm_eff, bn=bn_eff, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def cim_mvm_pallas_noisy_packed(x_codes: jax.Array, w_packed: jax.Array,
                                cfg: MacroConfig, *, noise_seed,
                                inl_seed: int = 0, bm: int | None = None,
                                bn: int | None = None,
                                interpret: bool | None = None) -> jax.Array:
    """Stochastic fused BP MVM over nibble-packed weights. Noise draws are a
    pure function of (seed, output coordinate, group) — independent of the
    weight container — so this is bit-identical to cim_mvm_pallas_noisy on
    the unpacked codes under the same seed."""
    from repro.core.adc import stochastic_transfer_params
    assert cfg.scheme == Scheme.BP
    assert cfg.sim_level != SimLevel.IDEAL
    assert cfg.n_rows % 2 == 0, "nibble packing needs an even macro depth"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    st = stochastic_transfer_params(cfg)
    bm, bn = _resolve_tiles(x_codes, w_packed.shape[1], cfg.n_rows, bm, bn)
    x2, w2, bm_eff, bn_eff, lead, m, n = _prep_packed(x_codes, w_packed,
                                                      cfg.n_rows, bm, bn)
    out = cim_mvm_grouped_noisy_packed(
        x2, w2, jnp.asarray(noise_seed, jnp.int32), n_rows=cfg.n_rows,
        levels=cfg.effective_adc_levels(), gain=cfg.gain,
        full_scale=cfg.full_scale(), sigma=st["sigma"],
        inl_amp=st["inl_amp"], inl_seed=inl_seed, apply_inl=st["apply_inl"],
        bm=bm_eff, bn=bn_eff, interpret=interpret)
    return out[:m, :n].reshape(*lead, n)
