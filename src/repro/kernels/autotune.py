"""Kernel tuning cache: tile shapes as tuned, persisted, CI-tracked data.

PR 5's Pallas kernels hard-code their pipeline geometry — one KV block per
sequential paged-attention grid step, fixed (bm, bn) tiles for the CIM MVM.
This module turns those constants into *looked-up* parameters, the software
analogue of how rad_gen/COFFE sizes SRAM transistors by searching a
parameter space against delay models: `benchmarks/kernel_bench.py
--autotune` times every candidate config through the same harness the CI
perf-trajectory uses, and the winners land in a small JSON cache the
dispatchers consult at trace time.

Cache schema (`pico-ram/tune_cache/v1`)::

    {
      "schema":   "pico-ram/tune_cache/v1",
      "platform": "cpu",                       # jax.default_backend()
      "jax":      "0.4.37",                    # provenance only
      "entries": {
        "paged_attn|decode_w4096|kernel|cpu": {
            "block_size": 64, "kblocks": 8, "row_tile": null,
            "us": 1234.5, "default_us": 5678.9},
        "cim_mvm|m32_g4_n128|pallas|cpu": {
            "bm": 32, "bn": 128, "us": 210.0, "default_us": 260.0}
      }
    }

Every entry is keyed `kernel|shape-family|backend|platform`:

* **kernel** — which dispatcher consults it ("paged_attn" for the
  attention registry's Pallas backend, "cim_mvm" for
  `core.engine.execute_mvm`'s Pallas MVM family);
* **shape-family** — a bucketed shape signature, NOT the exact shape, so
  one tuning run covers a neighborhood: paged attention buckets the KV
  window to the next power of two and splits decode (C = 1) from prefill
  (`decode_w4096`); the MVM buckets rows to the next power of two and
  keys the contraction by its group count (`m32_g4_n128`);
* **backend** — the registry backend name the config applies to;
* **platform** — `jax.default_backend()` at tuning time. A cache tuned on
  CPU interpret mode never leaks onto TPU (and vice versa): lookups from
  a different platform miss and fall back to defaults.

`REPRO_TUNE_CACHE` points at the cache file (`serve.py --tune-cache` sets
it). `kblocks` / `row_tile` (and the MVM `bm` / `bn`) are consumed at
dispatch time; `block_size` is a pool-LAYOUT recommendation — the kernel
takes the pool's pagination as given, so only `serve.py` acts on it, when
sizing a paged pool whose block size wasn't pinned on the command line.
No env / missing file / malformed JSON / wrong schema version all
degrade to an empty cache — dispatch falls back to the built-in defaults,
never errors. The file is re-read when its mtime changes, so a freshly
written cache is picked up without restarting the process.
"""
from __future__ import annotations

import json
import os
import warnings

CACHE_SCHEMA = "pico-ram/tune_cache/v1"
CACHE_ENV = "REPRO_TUNE_CACHE"

# mtime-keyed single-slot memo: (path, mtime) -> entries dict
_STATE: dict = {"key": None, "entries": {}}


def _bucket(n: int) -> int:
    """Round up to the next power of two (shape-family coarsening)."""
    return 1 << max(0, int(n - 1).bit_length())


def attn_family(window: int, c: int) -> str:
    """Shape family for the paged-attention kernel: decode (C = 1) vs
    prefill, window bucketed to the next power of two."""
    mode = "decode" if c == 1 else "prefill"
    return f"{mode}_w{_bucket(window)}"


def mvm_family(m: int, groups: int, n: int) -> str:
    """Shape family for the CIM MVM kernels: rows bucketed, contraction
    keyed by its 144-row group count, output width exact."""
    return f"m{_bucket(m)}_g{groups}_n{n}"


def cache_key(kernel: str, family: str, backend: str,
              platform: str | None = None) -> str:
    if platform is None:
        import jax
        platform = jax.default_backend()
    return "|".join((kernel, family, backend, platform))


def cache_path() -> str | None:
    p = os.environ.get(CACHE_ENV, "").strip()
    return p or None


def load_cache(path: str | None = None) -> dict:
    """Entries dict from `path` (default: $REPRO_TUNE_CACHE), {} on any
    problem — a tuning cache is an accelerant, never a dependency."""
    if path is None:
        path = cache_path()
    if not path:
        return {}
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {}
    key = (os.path.abspath(path), mtime)
    if _STATE["key"] == key:
        return _STATE["entries"]
    entries: dict = {}
    try:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != CACHE_SCHEMA:
            raise ValueError(f"unknown tune-cache schema "
                             f"{doc.get('schema')!r} (want {CACHE_SCHEMA})")
        raw = doc.get("entries", {})
        if not isinstance(raw, dict):
            raise ValueError("tune-cache entries must be an object")
        entries = {str(k): v for k, v in raw.items()
                   if isinstance(v, dict)}
    except (OSError, ValueError) as e:
        from repro.runtime.telemetry import KERNEL_COUNTERS
        KERNEL_COUNTERS.count_fallback()
        warnings.warn(f"ignoring tune cache {path!r}: {e}", stacklevel=2)
        entries = {}
    _STATE["key"] = key
    _STATE["entries"] = entries
    return entries


def lookup(kernel: str, family: str, backend: str,
           platform: str | None = None,
           path: str | None = None) -> dict | None:
    """The tuned config dict for (kernel, family, backend, platform), or
    None on a miss — callers keep their built-in defaults then."""
    entries = load_cache(path)
    if not entries:
        return None
    return entries.get(cache_key(kernel, family, backend, platform))


def save_cache(path: str, entries: dict) -> dict:
    """Write `entries` as a schema-v1 cache file; returns the document."""
    import jax
    doc = {
        "schema": CACHE_SCHEMA,
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "entries": entries,
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return doc


# ---------------------------------------------------------------------------
# candidate enumeration (timed by benchmarks/kernel_bench.py --autotune)
# ---------------------------------------------------------------------------
def attn_candidates(mb: int, cg: int,
                    block_size: int | None = None) -> list[dict]:
    """Pipeline-shape candidates for a paged-attention shape: kblocks
    divides into the MB block-table width (fewer, wider sequential steps);
    row_tile splits the C·G query rows into parallel tiles; block_size
    (when the caller states the pool's current pagination) proposes
    coarser pool blocks — fewer, larger fetches per window, the knob
    `serve.py --tune-cache` feeds back into the paged-pool layout (the
    kernel itself takes the pool's pagination as given at dispatch time).
    The default (kblocks=1, single row tile, the stated block_size) is
    always candidate 0 so tuning can only ever tie or win."""
    out = [{"block_size": block_size, "kblocks": 1, "row_tile": None}]
    kb = 2
    while kb <= min(mb, 16):
        out.append({"block_size": block_size, "kblocks": kb,
                    "row_tile": None})
        kb *= 2
    if cg > 8:
        best_kb = min(_bucket(mb), 16) if mb > 1 else 1
        out.append({"block_size": block_size, "kblocks": best_kb,
                    "row_tile": max(8, cg // 2)})
    if block_size is not None:
        for mult in (4, 8):
            if mb % mult == 0:
                out.append({"block_size": block_size * mult, "kblocks": 1,
                            "row_tile": None})
    return out


def mvm_candidates(m: int, n: int) -> list[dict]:
    """(bm, bn) tile candidates for the CIM MVM kernels; the built-in
    (128, 128) default first."""
    out = [{"bm": 128, "bn": 128}]
    for bm in (32, 64, 256):
        if bm < 2 * m:
            out.append({"bm": bm, "bn": 128})
    for bn in (64, 256):
        if bn <= n:
            out.append({"bm": 128, "bn": bn})
    return out
