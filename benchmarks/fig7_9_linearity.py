"""Fig. 7 (shift-and-add linearity, weight sweep) and Fig. 9 (end-to-end
input-sweep linearity). Paper: R² = 0.9999 for both.

Fig. 7 protocol: same input everywhere, sweep the stored 4-bit weight value;
output must be linear in the weight code.
Fig. 9 protocol: all-ones weights, sweep the DAC input code.
"""
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import PROTOTYPE
from repro.core.macro import SimLevel
from repro.core.schemes import bp_mvm

from .common import row


def _r2(x, y):
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    a, b = np.polyfit(x, y, 1)
    resid = y - (a * x + b)
    return 1.0 - resid.var() / y.var()


def run():
    out = []
    t0 = time.perf_counter()
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.FULL)

    # Fig. 7: weight sweep at fixed input
    xs = jnp.full((1, 144), 9.0)
    ys = []
    for wcode in range(16):
        w = jnp.full((144, 1), float(wcode))
        ys.append(float(bp_mvm(xs, w, macro)[0, 0]))
    r2_w = _r2(np.arange(16), ys)
    out.append(row("fig7_shiftadd_weight_sweep",
                   (time.perf_counter() - t0) * 1e6, f"R2={r2_w:.6f}"))

    # Fig. 9: input sweep with all-ones-equivalent weights (max code 15)
    w = jnp.full((144, 1), 15.0)
    codes, outs = [], []
    for xcode in range(16):
        x = jnp.full((1, 144), float(xcode))
        codes.append(xcode)
        outs.append(float(bp_mvm(x, w, macro)[0, 0]))
    r2_x = _r2(codes, outs)
    out.append(row("fig9_end_to_end_input_sweep",
                   (time.perf_counter() - t0) * 1e6, f"R2={r2_x:.6f}"))
    return out


if __name__ == "__main__":
    run()
