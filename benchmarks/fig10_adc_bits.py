"""Fig. 10: task accuracy vs ADC resolution, saturating around 8 bits."""
import dataclasses
import time

from repro.core import PROTOTYPE

from .common import eval_accuracy, make_task, row, train_mlp


def run():
    task = make_task()
    params = train_mlp(task)
    acc_float = eval_accuracy(params, task, None)
    out = []
    t0 = time.perf_counter()
    for bits, levels in ((5, 32), (6, 64), (7, 128), (8, 256), (8.5, 362),
                         (9, 512), (10, 1024)):
        macro = dataclasses.replace(PROTOTYPE, adc_levels=levels)
        acc = eval_accuracy(params, task, macro)
        out.append(row(f"fig10_adc{bits}b",
                       (time.perf_counter() - t0) * 1e6,
                       f"acc={acc:.4f}|float={acc_float:.4f}"))
    return out


if __name__ == "__main__":
    run()
