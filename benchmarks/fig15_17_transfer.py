"""Fig. 15: end-to-end transfer curves at gain 1–4 with DNL/INL;
Fig. 17: transfer-curve slope (gain) vs stored weight code.

Paper: DNL +0.56/−0.41 LSB, INL ±1.10 LSB at gain 1; slope steps consistent
across the 16 weight codes.
"""
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import PROTOTYPE
from repro.core.adc import adc_quantize, inl_curve
from repro.core.macro import SimLevel

from .common import row


def _transfer_codes(macro, n_points=362):
    """Sweep the analog input range; return output codes (no dequant)."""
    v = jnp.linspace(0.0, macro.full_scale() / macro.gain, n_points)
    return adc_quantize(v, macro, dequantize=False)


def run():
    out = []
    t0 = time.perf_counter()
    for gain in (1.0, 2.0, 3.0, 4.0):
        macro = dataclasses.replace(PROTOTYPE, gain=gain,
                                    sim_level=SimLevel.FULL)
        # DNL/INL from the code-edge positions of a fine input sweep
        fine = jnp.linspace(0.0, macro.full_scale() / gain, 1 << 15)
        codes = np.asarray(adc_quantize(fine, macro, dequantize=False))
        edges = np.searchsorted(codes, np.arange(1, macro.adc_levels))
        widths = np.diff(edges).astype(np.float64)
        lsb_samples = widths.mean()
        dnl = widths / lsb_samples - 1.0
        inl = np.cumsum(dnl)
        # raw (absolute-scale) INL of the model curve — the paper's ±1.10
        # bound is on this; the edge-fitted INL removes the endpoint line
        raw = np.asarray(inl_curve(jnp.linspace(0, 1, 1024),
                                   macro.inl_amp_lsb, 0))
        out.append(row(f"fig15_gain{gain:g}",
                       (time.perf_counter() - t0) * 1e6,
                       f"DNL=[{dnl.min():+.2f},{dnl.max():+.2f}]LSB|"
                       f"INLfit=[{inl.min():+.2f},{inl.max():+.2f}]LSB|"
                       f"INLraw=[{raw.min():+.2f},{raw.max():+.2f}]LSB"))

    # Fig. 17: slope of output-vs-input-code per stored weight code
    from repro.core.schemes import bp_mvm
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.FULL)
    slopes = []
    for wcode in range(16):
        w = jnp.full((144, 1), float(wcode))
        ys = [float(bp_mvm(jnp.full((1, 144), float(xc)), w, macro)[0, 0])
              for xc in (2, 6, 10, 14)]
        slopes.append((ys[-1] - ys[0]) / 12.0)
    steps = np.diff(slopes)
    out.append(row("fig17_weight_gain_steps",
                   (time.perf_counter() - t0) * 1e6,
                   f"step_mean={steps.mean():.1f}|step_std={steps.std():.2f}|"
                   f"worst_code={int(np.argmax(np.abs(steps - steps.mean())) + 1)}"))
    return out


if __name__ == "__main__":
    run()
