"""cim_mvm Pallas kernel micro-bench: interpret-mode wall time vs the jnp
reference across tile shapes (structural check — real perf is a TPU matter,
the §Perf roofline reasons from the lowered IR), a packed-vs-unpacked
decode-shape sweep quantifying the nibble-packing HBM win, a stochastic
(NOISY) fused-kernel sweep checking the in-kernel PRNG's distributional
agreement with the einsum reference, a PAGED-ATTENTION sweep (schema v3:
the Pallas flash kernel vs the exact window-softmax reference across
window lengths, with the peak score-tensor byte probe — exact grows as
O(W), the kernel's live scores stay one O(block) tile), and a SERVING
sweep driving the runtime.server engines (paged vs slot cache, plus the
paged engine on the kernel attention backend) over concurrent requests
with mixed prompt lengths — decode tok/s plus the resident KV-cache bytes
at 25 % slot occupancy (the paged-pool memory win).

Schema v4 adds the AUTOTUNE sweep: `--autotune` times every candidate
pipeline config from kernels.autotune (paged-attention kblocks/row_tile,
CIM-MVM (bm, bn) tiles) through this same harness, reports paired
`<name>_default` / `<name>_tuned` rows (the tuned row's derived field
carries `default_us` and `speedup`), and persists the winners as a
tune-cache JSON (`--tune-cache`, default tune_cache.json) that the
dispatchers consult through $REPRO_TUNE_CACHE.

Schema v5 adds the SHARED-PREFIX serving row: requests with a common
prompt prefix drained through the prefix-sharing paged pool vs the same
pool with sharing disabled — sustained decode concurrency (peak lanes
past prefill in one step, the pool-capacity-limited number) and the
prefill tokens the trie absorbed. The bench-smoke CI job gates the
concurrency ratio > 5x.

Schema v6 adds the SPEC-DECODE serving row: the same greedy workload
drained through the paged engine twice — plain decode vs speculative
decode with the ngram drafter (spec_k drafts verified per C=k+1 step) —
after a full warm-up drain per leg so every compiled step shape is
resident before timing. Reports decode tok/s per leg, the speedup (the
number the bench-smoke CI job gates ≥ 1.5x), the accept rate / mean
accepted length, and the accept-length histogram; asserts the two legs
emit bit-identical tokens (the greedy-parity invariant the soak tests
pin).

Schema v7 adds the ENERGY-PARETO row: the mixed-precision autotuner
(analysis.precision_search) searches per-call-site (ADC levels, scheme,
per-channel) overrides on the calibration tree and reports serving
energy/token — uniform 4b×4b BP at native ADC resolution vs the searched
mixed manifest — plus the accuracy-proxy delta (held-out logit KL vs the
float reference, uniform vs mixed). The bench-smoke CI job gates the
mixed-precision energy win ≥ 1.3x at iso-proxy and uploads the manifest
(`--precision-manifest`, consumed by serve.py / ServingConfig) as an
artifact.

Schema v8 adds the SERVE-SLO row: a mixed-prompt workload drained through
the paged engine twice — telemetry on (runtime.telemetry event trace +
step snapshots + histograms) vs telemetry off — with a full warm-up drain
and best-of-N timed repeats per leg. Reports p50/p99 TTFT and ITL from
the telemetry histograms plus decode tok/s per leg and the telemetry
overhead percentage; the bench-smoke CI job gates overhead < 3 %.

CLI (the CI bench-smoke job):
    PYTHONPATH=src python -m benchmarks.kernel_bench --small \\
        --autotune --json-out BENCH_ci.json
writes a machine-readable BENCH_ci.json ({"schema": ..., "rows": [...]})
so per-PR perf-trajectory data accumulates as workflow artifacts."""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.core.macro import MacroConfig, SimLevel
from repro.core.schemes import cim_mvm_codes
from repro.kernels import autotune
from repro.kernels.ops import (cim_mvm_pallas, cim_mvm_pallas_noisy,
                               cim_mvm_pallas_packed, pack_codes)
from repro.kernels.ref import cim_mvm_ref

from .common import row, timeit

BENCH_SCHEMA = "pico-ram/kernel_bench/v8"  # v8: + serve-SLO telemetry row


def run(small: bool = False, precision_manifest: str | None = None):
    out = []
    cfg = MacroConfig()
    key = jax.random.PRNGKey(0)
    # --small: one macro group deep, one tile — the CI smoke configuration
    m, k, n = (64, 288, 64) if small else (256, 1152, 256)
    x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0,
                           16).astype(jnp.float32)

    ref = jax.jit(lambda a, b: cim_mvm_ref(a, b, n_rows=cfg.n_rows,
                                           levels=cfg.adc_levels,
                                           gain=cfg.gain,
                                           full_scale=cfg.full_scale()))
    us_ref = timeit(ref, x, w)
    out.append(row(f"kernel_ref_jnp_{k}x{n}", us_ref, "oracle"))
    tiles = ((64, 64),) if small else ((64, 64), (128, 128), (256, 256))
    for bm, bn in tiles:
        fn = lambda a, b: cim_mvm_pallas(a, b, cfg, bm=bm, bn=bn)
        us = timeit(fn, x, w)
        out.append(row(f"kernel_pallas_bm{bm}_bn{bn}", us,
                       f"interpret_mode|vs_ref={us / max(us_ref, 1e-9):.2f}x"))
    out += run_noisy_sweep(small)
    out += run_packed_sweep(small)
    out += run_paged_attention_sweep(small)
    out += run_serving_sweep(small)
    out += run_shared_prefix_sweep(small)
    out += run_spec_decode_sweep(small)
    out += run_serve_slo_sweep(small)
    out += run_energy_pareto(small, manifest_out=precision_manifest)
    return out


def run_energy_pareto(small: bool = False,
                      manifest_out: str | None = None):
    """Mixed-precision serving energy: uniform vs the searched manifest.

    Runs the full autotuner loop on the LM smoke (calibration tree →
    greedy per-site (ADC levels, scheme, per-channel) descent under the
    SQNR screen + held-out logit-KL budget) and reports the Eq. 4 serving
    energy/token of the uniform native-resolution baseline against the
    mixed config, at iso-accuracy-proxy (both KLs vs the FLOAT reference
    in the derived field — the mixed config may drift at most kl_budget
    beyond uniform). The search is fully deterministic (fixed seed), so
    this row is a stable trend like every other bench row. The winning
    manifest — the deployment artifact ServingConfig(precision_manifest=)
    consumes — is written to `manifest_out`.
    """
    import time

    import numpy as np

    from repro.analysis import precision_search as ps
    from repro.configs.registry import SMOKES
    from repro.core.cim_matmul import CIMConfig
    from repro.models import registry as model_registry

    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32",
                                           cim=CIMConfig(enabled=True))
    params = model_registry.init_params(jax.random.PRNGKey(0), cfg,
                                        max_seq=64)
    cal = np.random.RandomState(7).randint(0, cfg.vocab, size=(2, 16))
    t0 = time.perf_counter()
    man = ps.search(params, cal, cfg, seed=0)
    search_us = (time.perf_counter() - t0) * 1e6
    if manifest_out:
        ps.save_manifest(manifest_out, man)
    m = man["metrics"]
    levels = ";".join(f"{k}:{v['adc_levels']}"
                      for k, v in man["sites"].items())
    return [row(
        "energy_pareto_mixed_precision", search_us,
        f"uniform_pj_tok={m['uniform_pj_per_token']:.1f}|"
        f"mixed_pj_tok={m['mixed_pj_per_token']:.1f}|"
        f"energy_win={m['energy_win']:.3f}x|"
        f"kl_uniform={m['kl_uniform']:.4f}|kl_mixed={m['kl_proxy']:.4f}|"
        f"kl_budget={m['kl_budget']:.3f}|levels={levels}")]


def run_paged_attention_sweep(small: bool = False):
    """Pallas paged-attention kernel vs the exact window-softmax reference.

    Decode-shaped (C=1) attention over a paged block pool through per-slot
    block tables, swept over window lengths. Two numbers per window:

      * wall µs, kernel vs exact (interpret-mode on CPU CI — a structural
        trend like the other kernel rows);
      * the peak score-tensor bytes — the memory probe the kernel exists
        for. The exact path materializes the [B, C, KH, G, W] score tensor
        (grows linearly with the window); the kernel's live scores are one
        [C·G, block_size] VMEM tile per program, CONSTANT in W. Exact
        byte counts, platform-free.
    """
    from repro.kernels.paged_attention import get_attn_backend
    out = []
    b, kh, g, dh, bs = 2, 2, 2, 32, 8
    windows = (64, 256) if small else (256, 1024, 4096)
    key = jax.random.PRNGKey(5)
    for w in windows:
        mb = w // bs
        nb = b * mb + 1              # every slot fully backed + trash block
        q = jax.random.normal(key, (b, 1, kh * g, dh), jnp.float32)
        kp = jax.random.normal(jax.random.fold_in(key, w),
                               (nb, bs, kh, dh), jnp.float32)
        vp = jax.random.normal(jax.random.fold_in(key, w + 1),
                               (nb, bs, kh, dh), jnp.float32)
        tables = (1 + jnp.arange(b * mb, dtype=jnp.int32)).reshape(b, mb)
        lens = jnp.full((b,), w - 1, jnp.int32)     # full-depth decode
        positions = lens[:, None]
        kvl = lens + 1

        def run_backend(name):
            fn = get_attn_backend(name).fn
            return jax.jit(lambda q, k, v: fn(q, k, v, tables, positions,
                                              kvl))

        us_e = timeit(run_backend("exact"), q, kp, vp)
        us_k = timeit(run_backend("kernel"), q, kp, vp)
        bytes_exact = b * 1 * kh * g * w * 4
        bytes_kernel = 1 * g * bs * 4
        out.append(row(
            f"paged_attn_decode_w{w}", us_k,
            f"exact_us={us_e:.1f}|score_bytes exact={bytes_exact} "
            f"kernel={bytes_kernel} "
            f"({bytes_exact / bytes_kernel:.0f}x less)"))
    return out


def run_noisy_sweep(small: bool = False):
    """Stochastic fused kernel vs the einsum NOISY reference: wall time plus
    the distributional-agreement ratio (σ of the ADC-chain error, fused
    in-kernel PRNG vs jax.random.normal) — the number the engine tests pin,
    tracked here per-PR so a PRNG regression shows up in the artifact."""
    out = []
    cfg = dataclasses.replace(MacroConfig(), sim_level=SimLevel.NOISY)
    ideal = dataclasses.replace(cfg, sim_level=SimLevel.IDEAL)
    key = jax.random.PRNGKey(3)
    m, k, n = (32, 288, 64) if small else (64, 1152, 256)
    x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0,
                           16).astype(jnp.float32)
    us_f = timeit(lambda a, b: cim_mvm_pallas_noisy(a, b, cfg, noise_seed=0),
                  x, w)
    us_e = timeit(jax.jit(lambda a, b, kk: cim_mvm_codes(a, b, cfg, key=kk)),
                  x, w, jax.random.fold_in(key, 2))
    y_ideal = cim_mvm_pallas(x, w, ideal)
    s_f = float(jnp.std(cim_mvm_pallas_noisy(x, w, cfg, noise_seed=0)
                        - y_ideal))
    s_e = float(jnp.std(cim_mvm_codes(x, w, cfg,
                                      key=jax.random.fold_in(key, 2))
                        - y_ideal))
    out.append(row(
        f"kernel_pallas_noisy_m{m}_k{k}_n{n}", us_f,
        f"einsum_noisy_us={us_e:.1f}|err_sigma fused={s_f:.3f} "
        f"einsum={s_e:.3f} ratio={s_f / max(s_e, 1e-9):.3f}"))
    return out


def run_packed_sweep(small: bool = False):
    """Packed vs unpacked weights across decode shapes (small M = batch
    slots, big K×N = the weight matrix that dominates decode HBM traffic).

    Decode is memory-bound: the roofline weight-byte term is exact
    (K·N bytes int8 vs ceil(K/2)·N bytes packed = 2.00× less wire traffic,
    4× vs bf16). Wall time here is interpret-mode (structural); the
    bytes ratio is the production-relevant number and is reported per
    shape."""
    out = []
    cfg = MacroConfig()
    key = jax.random.PRNGKey(2)
    shapes = ((8, 576, 128),) if small \
        else ((8, 1152, 512), (8, 2304, 2048), (32, 4320, 1024))
    for m, k, n in shapes:
        x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
        w = jax.random.randint(jax.random.fold_in(key, k + n), (k, n), 0,
                               16).astype(jnp.float32)
        wp = pack_codes(w)
        us_u = timeit(lambda a, b: cim_mvm_pallas(a, b, cfg), x, w)
        us_p = timeit(lambda a, b: cim_mvm_pallas_packed(a, b, cfg), x, wp)
        bytes_u = k * n                    # int8 container codes
        bytes_p = wp.shape[0] * n          # two u4 codes per byte
        out.append(row(
            f"decode_packed_m{m}_k{k}_n{n}", us_p,
            f"unpacked_us={us_u:.1f}|w_bytes {bytes_u}->{bytes_p} "
            f"({bytes_u / bytes_p:.2f}x less HBM)"))
    return out


def run_serving_sweep(small: bool = False):
    """Continuous-batching server sweep: paged vs slot engines end to end.

    Concurrent requests with mixed (seeded) prompt lengths drain through
    both runtime.server engines on the smoke transformer. Reported:

      * decode tok/s per engine (interpret/CPU wall clock — a structural
        trend like the kernel rows, not TPU absolute perf); the paged
        engine is drained twice, once per attention backend, so the
        kernel-vs-exact serving ratio lands in the artifact;
      * resident KV-cache bytes at 25 % slot occupancy: the slot cache
        always holds n_slots × max_len positions, the paged pool only the
        blocks its admitted requests actually cached — the exact byte
        counts are platform-free and are the paged-engine win the trend
        pipeline tracks.
    """
    from repro.configs.registry import SMOKES
    from repro.models import registry as model_registry
    from repro.runtime.server import Request, Server, ServingConfig

    out = []
    import numpy as np
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    n_slots, max_len, block = (4, 64, 8) if small else (8, 128, 16)
    n_req, max_new = (4, 4) if small else (12, 8)
    params = model_registry.init_params(jax.random.PRNGKey(0), cfg,
                                        max_seq=max_len)
    rng = np.random.RandomState(11)
    plens = [int(rng.randint(3, max_len // 4)) for _ in range(n_req)]
    prompts = [rng.randint(0, cfg.vocab, size=p).tolist() for p in plens]

    def drain(paged: bool, attn: str = "exact") -> Server:
        # attention backend pinned explicitly so each row's meaning is
        # stable across PRs (auto re-resolving would silently rebase the
        # paged trend onto the kernel path)
        srv = Server(params, cfg, ServingConfig(
            n_slots=n_slots, max_len=max_len, paged=paged, block_size=block,
            prefill_chunk=max_len // 8, attn=attn))
        for p in prompts:
            srv.submit(Request(prompt=list(p), max_new_tokens=max_new))
        srv.run_until_drained()
        return srv

    slot_bytes = 0
    exact_tok_s = 0.0
    for paged in (False, True):
        srv = drain(paged)
        m = srv.metrics.summary()
        name = "paged" if paged else "slots"
        us_per_tok = m["wall_s"] * 1e6 / max(m["decode_tokens"], 1)
        out.append(row(
            f"serve_decode_{name}_s{n_slots}_r{n_req}", us_per_tok,
            f"decode_tok_s={m['decode_tok_s']:.1f}|"
            f"prefill_tok_s={m['prefill_tok_s']:.1f}|steps={m['steps']}"))
        if not paged:
            slot_bytes = srv.kv_cache_bytes()["total"]
        else:
            exact_tok_s = m["decode_tok_s"]

    # the same paged drain on the Pallas attention kernel: the serving-level
    # kernel-vs-exact decode tok/s the acceptance criteria track
    srv = drain(True, attn="kernel")
    m = srv.metrics.summary()
    us_per_tok = m["wall_s"] * 1e6 / max(m["decode_tokens"], 1)
    out.append(row(
        f"serve_decode_paged_attnkernel_s{n_slots}_r{n_req}", us_per_tok,
        f"decode_tok_s={m['decode_tok_s']:.1f}|"
        f"exact_tok_s={exact_tok_s:.1f}|"
        f"ratio={m['decode_tok_s'] / max(exact_tok_s, 1e-9):.3f}"))

    # KV residency at 25 % slot occupancy: drain ceil(slots/4) requests
    # through the paged engine and report its PEAK block residency (robust
    # to schedule changes, unlike a mid-flight snapshot) vs the slot
    # cache's always-resident n_slots × max_len footprint.
    occ = max(1, n_slots // 4)
    srv = Server(params, cfg, ServingConfig(
        n_slots=n_slots, max_len=max_len, paged=True, block_size=block,
        prefill_chunk=max_len // 8))
    for p in prompts[:occ]:
        srv.submit(Request(prompt=list(p), max_new_tokens=max_new))
    srv.run_until_drained()
    per_block = srv.kv_cache_bytes()["total"] \
        // (srv.alloc.stats.num_blocks + 1)
    paged_bytes = per_block * srv.alloc.stats.peak_in_use
    assert paged_bytes > 0, "occupancy probe allocated no blocks"
    t_probe = srv.metrics.wall_s * 1e6
    out.append(row(
        f"serve_kv_bytes_occ25_s{n_slots}", max(t_probe, 1e-3),
        f"kv_bytes slot={slot_bytes} paged={paged_bytes} "
        f"({slot_bytes / paged_bytes:.2f}x less HBM)"))
    return out


def run_shared_prefix_sweep(small: bool = False):
    """Prefix-sharing paged pool vs the same pool with sharing disabled.

    One warm request populates the prefix trie with a 48-token shared
    prompt prefix (6 blocks at block_size 8), then n_req followers with the
    same prefix + distinct 2-token tails drain together through a pool
    sized so ONE private request fits but two do not (13 usable blocks;
    each request spans 7). Reported, per leg:

      * peak decode lanes — the max lanes simultaneously PAST prefill in a
        single step. Unlike admitted-lane counts (optimistic watermark
        admission transiently over-admits in both legs before preemption
        corrects it), a lane in decode provably holds all its blocks, so
        this is the pool-capacity-limited concurrency. Sharing backs each
        follower with 1 private block + 6 trie blocks → all n_req decode
        together; without sharing two full residents exceed the pool → 1;
      * prefill tokens absorbed by the trie (48 × n_req when sharing);
      * preemptions — 0 when sharing, a storm without.

    The bench-smoke CI job gates shared/nosharing peak decode lanes > 5x:
    the concurrency win the refcounted CoW pool exists for. Deterministic
    (greedy decode, exact counts), so the gate is noise-free.
    """
    from repro.configs.registry import SMOKES
    from repro.models import registry as model_registry
    from repro.runtime.server import Request, Server, ServingConfig

    import numpy as np
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    bs, max_len, n_slots, num_blocks = 8, 64, 8, 13
    n_req, max_new, shared_len = 7, 4, 48
    rng = np.random.RandomState(17)
    prefix = rng.randint(0, cfg.vocab, size=shared_len).tolist()
    tails = [rng.randint(0, cfg.vocab, size=2).tolist()
             for _ in range(n_req + 1)]
    params = model_registry.init_params(jax.random.PRNGKey(0), cfg,
                                        max_seq=max_len)

    def drain(sharing: bool) -> Server:
        srv = Server(params, cfg, ServingConfig(
            n_slots=n_slots, max_len=max_len, paged=True, block_size=bs,
            num_blocks=num_blocks, prefill_chunk=bs, attn="exact",
            prefix_sharing=sharing))
        srv.submit(Request(prompt=prefix + tails[0],
                           max_new_tokens=max_new))
        srv.run_until_drained()          # warm: populates the trie
        srv.metrics = type(srv.metrics)()  # measure followers only
        for t in tails[1:]:
            srv.submit(Request(prompt=prefix + t, max_new_tokens=max_new))
        srv.run_until_drained()
        return srv

    shared = drain(True)
    base = drain(False)
    ms, mb = shared.metrics, base.metrics
    ratio = ms.peak_decode_lanes / max(mb.peak_decode_lanes, 1)
    return [row(
        f"serve_shared_prefix_s{n_slots}_r{n_req}",
        max(ms.wall_s * 1e6, 1e-3),
        f"peak_lanes shared={ms.peak_decode_lanes} "
        f"nosharing={mb.peak_decode_lanes} ({ratio:.1f}x)|"
        f"prefill_tok_saved={ms.prefix_hit_tokens}|"
        f"preempt shared={ms.preemptions} nosharing={mb.preemptions}")]


def run_spec_decode_sweep(small: bool = False):
    """Speculative vs plain greedy decode on the paged engine.

    The same two seeded prompts drain through the paged engine twice:
    plain decode (one token per step) and speculative decode with the
    ngram drafter (spec_k drafts verified in one C=spec_k+1 all-logits
    step, longest agreeing prefix accepted, rollback = truncating the
    lane's kv_len). Long greedy generations on the random-weight smoke
    model reach (near-)periodic attractors, which is exactly the regime
    prompt-lookup drafting exploits — so the accept rate here is a
    stable, deterministic property of the seeds, not noise.

    Methodology: each leg drains the identical workload ONCE un-timed
    (compiles every step shape: prefill chunk, plain C=1, spec C=k+1
    all-logits), resets metrics, then drains again timed — the reported
    tok/s is steady-state serving, not XLA compile time. prefill_chunk
    is pinned to spec_k+1 so both phases share one compiled width.

    Reported: decode tok/s per leg, speedup (bench-smoke CI gates
    ≥ 1.5x), accept rate, mean accepted length, accept-length histogram.
    Asserts both legs emit bit-identical tokens — the greedy-parity
    invariant (exact verification ⇒ spec decode is a pure perf knob).
    """
    from repro.configs.registry import SMOKES
    from repro.models import registry as model_registry
    from repro.runtime.server import Request, Server, ServingConfig

    import numpy as np
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    spec_k = 4
    max_len, max_new = 256, 160
    n_slots, block = 2, 16
    # seeds chosen for their attractor structure: both prompts' greedy
    # continuations go (near-)periodic well inside max_new, the regime
    # the paper-adjacent prompt-lookup literature targets
    prompts = []
    for seed in (7, 23):
        r = np.random.RandomState(seed)
        prompts.append(
            r.randint(0, cfg.vocab, size=int(r.randint(4, 17))).tolist())
    params = model_registry.init_params(jax.random.PRNGKey(0), cfg,
                                        max_seq=max_len)

    def drain(drafter: str) -> tuple[Server, list[list[int]], float]:
        srv = Server(params, cfg, ServingConfig(
            n_slots=n_slots, max_len=max_len, paged=True, block_size=block,
            prefill_chunk=spec_k + 1, attn="exact",
            drafter=drafter, spec_k=spec_k))

        def once() -> list[list[int]]:
            reqs = [Request(prompt=list(p), max_new_tokens=max_new)
                    for p in prompts]
            for r in reqs:
                srv.submit(r)
            srv.run_until_drained()
            return [list(r.output) for r in reqs]

        once()                              # warm: compile every step shape
        srv.metrics = type(srv.metrics)()   # timed leg starts clean
        outs = once()
        return srv, outs, srv.metrics.summary()["decode_tok_s"]

    _, plain_out, plain_tok_s = drain("off")
    srv, spec_out, spec_tok_s = drain("ngram")
    assert plain_out == spec_out, \
        "greedy spec decode diverged from plain decode"
    m = srv.metrics.summary()
    hist = ";".join(f"{k}:{v}" for k, v in m["accept_hist"].items())
    return [row(
        f"serve_spec_decode_k{spec_k}_s{n_slots}",
        m["wall_s"] * 1e6 / max(m["decode_tokens"], 1),
        f"spec_tok_s={spec_tok_s:.1f}|plain_tok_s={plain_tok_s:.1f}|"
        f"speedup={spec_tok_s / max(plain_tok_s, 1e-9):.2f}x|"
        f"accept_rate={m['accept_rate']:.2f}|"
        f"mean_accept_len={m['mean_accept_len']:.2f}|hist={hist}")]


def run_serve_slo_sweep(small: bool = False):
    """Serving SLO percentiles + the telemetry overhead contract.

    One mixed-prompt greedy workload drains through the paged engine in
    two configurations that differ ONLY in ServingConfig.telemetry: the
    on-leg populates the runtime.telemetry event trace / step snapshots /
    TTFT+ITL histograms, the off-leg early-returns at every hook. Each
    leg warms once un-timed (compiles every step shape), then runs
    best-of-N timed drains (metrics reset per repeat, repeats
    interleaved with alternating order so machine-level drift hits both
    legs equally).

    Reported: p50/p99 TTFT and ITL in ms from the on-leg's histograms
    (accumulated across the timed repeats — more samples, stabler tails;
    the warm drain's compile-poisoned samples are reset out), decode
    tok/s per leg (best-of-N drains), and the overhead percentage the
    bench-smoke CI job gates < 3 %.

    How the gated overhead is measured — DIRECT ATTRIBUTION, not the
    on/off throughput difference.  The on-leg's telemetry hooks are
    wrapped with perf_counter pairs and the gate is the median (across
    repeats) of ``time inside hooks / total step() wall``.  Rationale,
    from calibrating on shared CI-class hosts: the differential
    estimate is swamped by noise the hooks don't cause.  Two servers
    built identically WITH TELEMETRY OFF measure 1-2 % apart with
    persistent per-step-index wall differences of +-10 % (each instance
    jits its own step functions, so code/memory placement differs), and
    noisy-neighbor steal adds multi-percent swings that survive
    interleaving, per-step-index min-pairing over dozens of repeats,
    and median-of-phases — while the true hook cost is ~1 % of a step.
    A hard gate on a differential below its own noise floor flakes; the
    attributed fraction is a within-run ratio, so host slowdowns scale
    numerator and denominator together.  It is also conservative where
    it matters: each wrapped call pays the timer overhead inside the
    numerator, and a regression that fattens the hooks (say,
    reintroducing per-lane ring appends on the decode path) lands on it
    directly.  What it cannot see is indirect cost (GC pressure from
    ring allocations, cache pollution), so the on/off tok/s pair stays
    in the derived field as the end-to-end cross-check: tok_s_on within
    noise of tok_s_off is the claim a human should eyeball, and both
    numbers are best-of-N under one-sided noise (a neighbor only ever
    slows a run down).

    The gated fraction is the telemetry HOT phase: Telemetry's hooks
    append raw tuples and defer aggregation (Event/ring/histogram work)
    to a replay pass that runs at read time, outside the step walls —
    see the Telemetry class docstring.  The replay cost is real but
    off-SLO-path by design; the TTFT/ITL percentiles above come from
    the same drains and would show it if it leaked into serving.
    """
    import time

    from repro.configs.registry import SMOKES
    from repro.models import registry as model_registry
    from repro.runtime.server import Request, Server, ServingConfig

    import numpy as np
    cfg = SMOKES["internlm2-1.8b"].replace(dtype="float32")
    n_slots, max_len, block = (4, 64, 8) if small else (8, 128, 16)
    n_req, max_new = (6, 8) if small else (12, 16)
    # drains are tens of ms; lots of interleaved repeats cost little and
    # best-of-N converges on true capability under one-sided timing noise
    # (CI neighbors only ever make a run SLOWER)
    repeats = 9 if small else 5
    rng = np.random.RandomState(29)
    prompts = [rng.randint(0, cfg.vocab,
                           size=int(rng.randint(4, max_len // 4))).tolist()
               for _ in range(n_req)]
    params = model_registry.init_params(jax.random.PRNGKey(0), cfg,
                                        max_seq=max_len)

    def build(telemetry_on: bool) -> Server:
        return Server(params, cfg, ServingConfig(
            n_slots=n_slots, max_len=max_len, paged=True, block_size=block,
            prefill_chunk=max_len // 8, attn="exact",
            telemetry=telemetry_on))

    # every recording entry point the Server calls (event() is the shared
    # internal path of several of these — wrapping it too would double
    # count); telemetry.now() is deliberately unwrapped, both legs pay it
    hooks = ("submit", "admit", "prefill_chunk", "first_token", "emission",
             "decode_step", "spec_verify", "cow_fork", "preempt", "retire",
             "step_snapshot")

    def instrument(tel) -> list:
        """Shadow each hook on the INSTANCE with a self-timing wrapper."""
        acc = [0.0]
        for name in hooks:
            base = getattr(tel, name)

            def timed(*a, _base=base, _acc=acc, **kw):
                t0 = time.perf_counter()
                r = _base(*a, **kw)
                _acc[0] += time.perf_counter() - t0
                return r

            setattr(tel, name, timed)
        return acc

    def once(srv: Server) -> tuple:
        srv.metrics = type(srv.metrics)()       # timed repeats start clean
        for p in prompts:
            srv.submit(Request(prompt=list(p), max_new_tokens=max_new))
        wall = 0.0
        while any(srv.slot_req) or srv.queue:   # run_until_drained, but
            t0 = time.perf_counter()            # timing each step() wall
            srv.step()
            wall += time.perf_counter() - t0
        return srv.metrics.summary()["decode_tok_s"], wall

    srv_on, srv_off = build(True), build(False)
    hook_s = instrument(srv_on.telemetry)
    once(srv_on)                                # warm: compile every shape
    once(srv_off)
    srv_on.telemetry.reset()                    # drop compile-poisoned TTFTs
    tok_s_on = tok_s_off = 0.0
    ratios = []                                 # per-drain hook_s / step wall
    for r in range(repeats):        # interleave, alternate leg order — see
        legs = ("on", "off") if r % 2 == 0 else ("off", "on")   # docstring
        for leg in legs:
            if leg == "on":
                hook_s[0] = 0.0
                tok, wall = once(srv_on)
                tok_s_on = max(tok_s_on, tok)
                ratios.append(hook_s[0] / wall)
            else:
                tok, _ = once(srv_off)
                tok_s_off = max(tok_s_off, tok)
    overhead = sorted(ratios)[len(ratios) // 2] * 100.0
    tel = srv_on.telemetry
    m = srv_on.metrics.summary()
    return [row(
        f"serve_slo_paged_s{n_slots}_r{n_req}",
        m["wall_s"] * 1e6 / max(m["decode_tokens"], 1),
        f"ttft_p50_ms={tel.ttft.percentile(50) * 1e3:.2f}|"
        f"ttft_p99_ms={tel.ttft.percentile(99) * 1e3:.2f}|"
        f"itl_p50_ms={tel.itl.percentile(50) * 1e3:.2f}|"
        f"itl_p99_ms={tel.itl.percentile(99) * 1e3:.2f}|"
        f"tok_s_on={tok_s_on:.1f}|tok_s_off={tok_s_off:.1f}|"
        f"overhead_pct={overhead:+.2f}")]


def run_autotune(small: bool = False):
    """Time every candidate config from kernels.autotune and keep the wins.

    Two shape families, chosen to be the ones the acceptance criteria
    track:

      * paged attention, decode at W = 4096 (`decode_w4096`) — the window
        where the default pagination pays 256 sequential fetch steps. The
        candidate space is (block_size, kblocks, row_tile): kblocks fetches
        several blocks per step (the TPU double-buffering win), block_size
        re-paginates the pool into coarser blocks (fewer, larger fetches —
        the win that also shows in interpret mode, where per-fetch overhead
        dominates). Run even under --small: the family IS the artifact row.
      * the CIM MVM tile family of the --small smoke shape (m64_g2_n64) or
        the full bench shape, over (bm, bn) tile candidates.

    Returns (rows, entries): paired `_default`/`_tuned` bench rows plus the
    tune-cache entries for autotune.save_cache. The default config is
    always candidate 0, so `_tuned` can only tie or beat it.
    """
    from repro.kernels.paged_attention import paged_flash_attention
    rows_out, entries = [], {}

    # ---- paged attention: decode, W = 4096 --------------------------------
    # candidate 0 is the serving default (block_size 16, kblocks 1); the
    # block_size candidates re-paginate the SAME window into coarser pool
    # blocks — fewer, larger fetches per sequential step, the layout knob
    # serve.py --tune-cache feeds back into the paged pool
    b, kh, g, dh, bs = 1, 1, 4, 32, 16
    w = 4096
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (b, 1, kh * g, dh), jnp.float32)
    kv = jax.random.normal(jax.random.fold_in(key, 1),
                           (2, b, w, kh, dh), jnp.float32)
    cands = autotune.attn_candidates(w // bs, kh * g, block_size=bs)
    if small:  # smoke: default, deepest pipeline, and the layout candidates
        cands = [c for c in cands
                 if c["kblocks"] in (1, 16) or c["block_size"] != bs]
    timed = []
    for cand in cands:
        cbs = cand["block_size"]
        mb = w // cbs
        pools = kv.reshape(2, b * mb, cbs, kh, dh)
        kp = jnp.concatenate([jnp.zeros((1, cbs, kh, dh)), pools[0]])
        vp = jnp.concatenate([jnp.zeros((1, cbs, kh, dh)), pools[1]])
        tables = (1 + jnp.arange(b * mb, dtype=jnp.int32)).reshape(b, mb)
        lens = jnp.full((b,), w - 1, jnp.int32)
        kvl = lens + 1
        fn = jax.jit(lambda qq, kk, vv, _t=tables, _l=lens, _kv=kvl,
                     _kb=cand["kblocks"], _rt=cand["row_tile"]:
                     paged_flash_attention(qq, kk, vv, _t, _l, _kv,
                                           kblocks=_kb, row_tile=_rt))
        timed.append((timeit(fn, q, kp, vp), cand))
    default_us = timed[0][0]
    best_us, best = min(timed, key=lambda t: t[0])
    fam = autotune.attn_family(w, 1)
    entries[autotune.cache_key("paged_attn", fam, "kernel")] = {
        **best, "us": best_us, "default_us": default_us}
    rows_out.append(row(f"paged_attn_{fam}_default", default_us,
                        f"block_size={bs}|kblocks=1|row_tile=None"))
    rows_out.append(row(
        f"paged_attn_{fam}_tuned", best_us,
        f"default_us={default_us:.1f}|"
        f"speedup={default_us / max(best_us, 1e-9):.2f}x|"
        f"block_size={best['block_size']}|"
        f"kblocks={best['kblocks']}|row_tile={best['row_tile']}"))

    # ---- CIM MVM tiles ----------------------------------------------------
    cfg = MacroConfig()
    m, k, n = (64, 288, 64) if small else (256, 1152, 256)
    x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
    wmat = jax.random.randint(jax.random.fold_in(key, 3), (k, n), 0,
                              16).astype(jnp.float32)
    timed = []
    for cand in autotune.mvm_candidates(m, n):
        fn = (lambda a, bb, _bm=cand["bm"], _bn=cand["bn"]:
              cim_mvm_pallas(a, bb, cfg, bm=_bm, bn=_bn))
        timed.append((timeit(fn, x, wmat), cand))
    default_us = timed[0][0]
    best_us, best = min(timed, key=lambda t: t[0])
    fam = autotune.mvm_family(m, -(-k // cfg.n_rows), n)
    entries[autotune.cache_key("cim_mvm", fam, "pallas")] = {
        **best, "us": best_us, "default_us": default_us}
    rows_out.append(row(f"cim_mvm_{fam}_default", default_us,
                        "bm=128|bn=128"))
    rows_out.append(row(
        f"cim_mvm_{fam}_tuned", best_us,
        f"default_us={default_us:.1f}|"
        f"speedup={default_us / max(best_us, 1e-9):.2f}x|"
        f"bm={best['bm']}|bn={best['bn']}"))
    return rows_out, entries


def rows_to_json(rows: list[str]) -> dict:
    """CSV rows ("name,us,derived") → the BENCH_ci.json document."""
    parsed = []
    for line in rows:
        name, us, derived = line.split(",", 2)
        parsed.append({"name": name, "us": float(us), "derived": derived})
    return {
        "schema": BENCH_SCHEMA,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "rows": parsed,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI smoke configuration (one group deep, one tile)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the rows as a JSON document "
                         "(the bench-smoke artifact)")
    ap.add_argument("--autotune", action="store_true",
                    help="time the kernels.autotune candidate configs, "
                         "append tuned-vs-default rows, and persist the "
                         "winners to --tune-cache")
    ap.add_argument("--tune-cache", default="tune_cache.json",
                    metavar="PATH",
                    help="where --autotune writes the tuning cache "
                         "(consumed via $REPRO_TUNE_CACHE)")
    ap.add_argument("--precision-manifest", default="precision_manifest.json",
                    metavar="PATH", dest="precision_manifest",
                    help="where the energy-pareto sweep writes the winning "
                         "mixed-precision deployment manifest (consumed by "
                         "serve.py --precision-manifest / "
                         "ServingConfig(precision_manifest=...))")
    args = ap.parse_args(argv)
    rows = run(small=args.small, precision_manifest=args.precision_manifest)
    if args.autotune:
        tuned_rows, entries = run_autotune(small=args.small)
        rows += tuned_rows
        autotune.save_cache(args.tune_cache, entries)
        print(f"wrote {args.tune_cache} ({len(entries)} tuned entries)",
              flush=True)
    if args.json_out:
        doc = rows_to_json(rows)
        with open(args.json_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.json_out} ({len(doc['rows'])} rows)", flush=True)


if __name__ == "__main__":
    main()
