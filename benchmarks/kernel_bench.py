"""cim_mvm Pallas kernel micro-bench: interpret-mode wall time vs the jnp
reference across tile shapes (structural check — real perf is a TPU matter,
the §Perf roofline reasons from the lowered IR), plus a packed-vs-unpacked
decode-shape sweep quantifying the nibble-packing HBM win."""
import time

import jax
import jax.numpy as jnp

from repro.core.macro import MacroConfig
from repro.kernels.ops import cim_mvm_pallas, cim_mvm_pallas_packed, pack_codes
from repro.kernels.ref import cim_mvm_ref

from .common import row, timeit


def run():
    out = []
    cfg = MacroConfig()
    key = jax.random.PRNGKey(0)
    m, k, n = 256, 1152, 256  # 8 macro groups deep
    x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0,
                           16).astype(jnp.float32)

    ref = jax.jit(lambda a, b: cim_mvm_ref(a, b, n_rows=cfg.n_rows,
                                           levels=cfg.adc_levels,
                                           gain=cfg.gain,
                                           full_scale=cfg.full_scale()))
    us_ref = timeit(ref, x, w)
    out.append(row("kernel_ref_jnp_1152x256", us_ref, "oracle"))
    for bm, bn in ((64, 64), (128, 128), (256, 256)):
        fn = lambda a, b: cim_mvm_pallas(a, b, cfg, bm=bm, bn=bn)
        us = timeit(fn, x, w)
        out.append(row(f"kernel_pallas_bm{bm}_bn{bn}", us,
                       f"interpret_mode|vs_ref={us / max(us_ref, 1e-9):.2f}x"))
    out += run_packed_sweep()
    return out


def run_packed_sweep():
    """Packed vs unpacked weights across decode shapes (small M = batch
    slots, big K×N = the weight matrix that dominates decode HBM traffic).

    Decode is memory-bound: the roofline weight-byte term is exact
    (K·N bytes int8 vs ceil(K/2)·N bytes packed = 2.00× less wire traffic,
    4× vs bf16). Wall time here is interpret-mode (structural); the
    bytes ratio is the production-relevant number and is reported per
    shape."""
    out = []
    cfg = MacroConfig()
    key = jax.random.PRNGKey(2)
    for m, k, n in ((8, 1152, 512), (8, 2304, 2048), (32, 4320, 1024)):
        x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
        w = jax.random.randint(jax.random.fold_in(key, k + n), (k, n), 0,
                               16).astype(jnp.float32)
        wp = pack_codes(w)
        us_u = timeit(lambda a, b: cim_mvm_pallas(a, b, cfg), x, w)
        us_p = timeit(lambda a, b: cim_mvm_pallas_packed(a, b, cfg), x, wp)
        bytes_u = k * n                    # int8 container codes
        bytes_p = wp.shape[0] * n          # two u4 codes per byte
        out.append(row(
            f"decode_packed_m{m}_k{k}_n{n}", us_p,
            f"unpacked_us={us_u:.1f}|w_bytes {bytes_u}->{bytes_p} "
            f"({bytes_u / bytes_p:.2f}x less HBM)"))
    return out


if __name__ == "__main__":
    run()
