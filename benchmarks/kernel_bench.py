"""cim_mvm Pallas kernel micro-bench: interpret-mode wall time vs the jnp
reference across tile shapes (structural check — real perf is a TPU matter,
the §Perf roofline reasons from the lowered IR), a packed-vs-unpacked
decode-shape sweep quantifying the nibble-packing HBM win, and a stochastic
(NOISY) fused-kernel sweep checking the in-kernel PRNG's distributional
agreement with the einsum reference.

CLI (the CI bench-smoke job):
    PYTHONPATH=src python -m benchmarks.kernel_bench --small \\
        --json-out BENCH_ci.json
writes a machine-readable BENCH_ci.json ({"schema": ..., "rows": [...]})
so per-PR perf-trajectory data accumulates as workflow artifacts."""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.core.macro import MacroConfig, SimLevel
from repro.core.schemes import cim_mvm_codes
from repro.kernels.ops import (cim_mvm_pallas, cim_mvm_pallas_noisy,
                               cim_mvm_pallas_packed, pack_codes)
from repro.kernels.ref import cim_mvm_ref

from .common import row, timeit

BENCH_SCHEMA = "pico-ram/kernel_bench/v1"


def run(small: bool = False):
    out = []
    cfg = MacroConfig()
    key = jax.random.PRNGKey(0)
    # --small: one macro group deep, one tile — the CI smoke configuration
    m, k, n = (64, 288, 64) if small else (256, 1152, 256)
    x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0,
                           16).astype(jnp.float32)

    ref = jax.jit(lambda a, b: cim_mvm_ref(a, b, n_rows=cfg.n_rows,
                                           levels=cfg.adc_levels,
                                           gain=cfg.gain,
                                           full_scale=cfg.full_scale()))
    us_ref = timeit(ref, x, w)
    out.append(row(f"kernel_ref_jnp_{k}x{n}", us_ref, "oracle"))
    tiles = ((64, 64),) if small else ((64, 64), (128, 128), (256, 256))
    for bm, bn in tiles:
        fn = lambda a, b: cim_mvm_pallas(a, b, cfg, bm=bm, bn=bn)
        us = timeit(fn, x, w)
        out.append(row(f"kernel_pallas_bm{bm}_bn{bn}", us,
                       f"interpret_mode|vs_ref={us / max(us_ref, 1e-9):.2f}x"))
    out += run_noisy_sweep(small)
    out += run_packed_sweep(small)
    return out


def run_noisy_sweep(small: bool = False):
    """Stochastic fused kernel vs the einsum NOISY reference: wall time plus
    the distributional-agreement ratio (σ of the ADC-chain error, fused
    in-kernel PRNG vs jax.random.normal) — the number the engine tests pin,
    tracked here per-PR so a PRNG regression shows up in the artifact."""
    out = []
    cfg = dataclasses.replace(MacroConfig(), sim_level=SimLevel.NOISY)
    ideal = dataclasses.replace(cfg, sim_level=SimLevel.IDEAL)
    key = jax.random.PRNGKey(3)
    m, k, n = (32, 288, 64) if small else (64, 1152, 256)
    x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0,
                           16).astype(jnp.float32)
    us_f = timeit(lambda a, b: cim_mvm_pallas_noisy(a, b, cfg, noise_seed=0),
                  x, w)
    us_e = timeit(jax.jit(lambda a, b, kk: cim_mvm_codes(a, b, cfg, key=kk)),
                  x, w, jax.random.fold_in(key, 2))
    y_ideal = cim_mvm_pallas(x, w, ideal)
    s_f = float(jnp.std(cim_mvm_pallas_noisy(x, w, cfg, noise_seed=0)
                        - y_ideal))
    s_e = float(jnp.std(cim_mvm_codes(x, w, cfg,
                                      key=jax.random.fold_in(key, 2))
                        - y_ideal))
    out.append(row(
        f"kernel_pallas_noisy_m{m}_k{k}_n{n}", us_f,
        f"einsum_noisy_us={us_e:.1f}|err_sigma fused={s_f:.3f} "
        f"einsum={s_e:.3f} ratio={s_f / max(s_e, 1e-9):.3f}"))
    return out


def run_packed_sweep(small: bool = False):
    """Packed vs unpacked weights across decode shapes (small M = batch
    slots, big K×N = the weight matrix that dominates decode HBM traffic).

    Decode is memory-bound: the roofline weight-byte term is exact
    (K·N bytes int8 vs ceil(K/2)·N bytes packed = 2.00× less wire traffic,
    4× vs bf16). Wall time here is interpret-mode (structural); the
    bytes ratio is the production-relevant number and is reported per
    shape."""
    out = []
    cfg = MacroConfig()
    key = jax.random.PRNGKey(2)
    shapes = ((8, 576, 128),) if small \
        else ((8, 1152, 512), (8, 2304, 2048), (32, 4320, 1024))
    for m, k, n in shapes:
        x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
        w = jax.random.randint(jax.random.fold_in(key, k + n), (k, n), 0,
                               16).astype(jnp.float32)
        wp = pack_codes(w)
        us_u = timeit(lambda a, b: cim_mvm_pallas(a, b, cfg), x, w)
        us_p = timeit(lambda a, b: cim_mvm_pallas_packed(a, b, cfg), x, wp)
        bytes_u = k * n                    # int8 container codes
        bytes_p = wp.shape[0] * n          # two u4 codes per byte
        out.append(row(
            f"decode_packed_m{m}_k{k}_n{n}", us_p,
            f"unpacked_us={us_u:.1f}|w_bytes {bytes_u}->{bytes_p} "
            f"({bytes_u / bytes_p:.2f}x less HBM)"))
    return out


def rows_to_json(rows: list[str]) -> dict:
    """CSV rows ("name,us,derived") → the BENCH_ci.json document."""
    parsed = []
    for line in rows:
        name, us, derived = line.split(",", 2)
        parsed.append({"name": name, "us": float(us), "derived": derived})
    return {
        "schema": BENCH_SCHEMA,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "rows": parsed,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI smoke configuration (one group deep, one tile)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the rows as a JSON document "
                         "(the bench-smoke artifact)")
    args = ap.parse_args(argv)
    rows = run(small=args.small)
    if args.json_out:
        doc = rows_to_json(rows)
        with open(args.json_out, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.json_out} ({len(doc['rows'])} rows)", flush=True)


if __name__ == "__main__":
    main()
