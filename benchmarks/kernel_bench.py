"""cim_mvm Pallas kernel micro-bench: interpret-mode wall time vs the jnp
reference across tile shapes (structural check — real perf is a TPU matter,
the §Perf roofline reasons from the lowered IR)."""
import time

import jax
import jax.numpy as jnp

from repro.core.macro import MacroConfig
from repro.kernels.ops import cim_mvm_pallas
from repro.kernels.ref import cim_mvm_ref

from .common import row, timeit


def run():
    out = []
    cfg = MacroConfig()
    key = jax.random.PRNGKey(0)
    m, k, n = 256, 1152, 256  # 8 macro groups deep
    x = jax.random.randint(key, (m, k), 0, 16).astype(jnp.float32)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), 0,
                           16).astype(jnp.float32)

    ref = jax.jit(lambda a, b: cim_mvm_ref(a, b, n_rows=cfg.n_rows,
                                           levels=cfg.adc_levels,
                                           gain=cfg.gain,
                                           full_scale=cfg.full_scale()))
    us_ref = timeit(ref, x, w)
    out.append(row("kernel_ref_jnp_1152x256", us_ref, "oracle"))
    for bm, bn in ((64, 64), (128, 128), (256, 256)):
        fn = lambda a, b: cim_mvm_pallas(a, b, cfg, bm=bm, bn=bn)
        us = timeit(fn, x, w)
        out.append(row(f"kernel_pallas_bm{bm}_bn{bn}", us,
                       f"interpret_mode|vs_ref={us / max(us_ref, 1e-9):.2f}x"))
    return out


if __name__ == "__main__":
    run()
