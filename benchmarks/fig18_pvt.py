"""Fig. 18: σ_E and INL across supply voltage (0.65–1.2 V), temperature
(−40–105 °C), gains (1–4), and process instances (8 groups × 5 chips)."""
import dataclasses
import time

import numpy as np

from repro.core import PROTOTYPE
from repro.core.macro import OperatingPoint

from .common import row


def run():
    out = []
    t0 = time.perf_counter()
    for vdd in (0.65, 0.8, 0.9, 1.0, 1.2):
        m = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=vdd))
        out.append(row(f"fig18_vdd{vdd:g}", (time.perf_counter() - t0) * 1e6,
                       f"sigma_e={m.sigma_e_lsb():.3f}LSB|"
                       f"levels={m.effective_adc_levels()}"))
    for temp in (-40.0, 25.0, 105.0):
        m = dataclasses.replace(PROTOTYPE,
                                op=OperatingPoint(temp_c=temp))
        out.append(row(f"fig18_temp{temp:g}",
                       (time.perf_counter() - t0) * 1e6,
                       f"sigma_e={m.sigma_e_lsb():.3f}LSB"))
    for gain in (1.0, 2.0, 3.0, 4.0):
        m = dataclasses.replace(PROTOTYPE, gain=gain)
        # σ_E in LSB grows sublinearly with gain; in analog units it shrinks
        sigma_analog = m.sigma_e_lsb() * m.adc_lsb()
        out.append(row(f"fig18_gain{gain:g}",
                       (time.perf_counter() - t0) * 1e6,
                       f"sigma_e_lsb={m.sigma_e_lsb():.3f}|"
                       f"sigma_analog={sigma_analog:.1f}"))
    # process variation: INL spread across 8 groups × 5 chips (seeded curves)
    import jax.numpy as jnp
    from repro.core.adc import inl_curve
    spans = []
    for chip in range(5):
        for grp in range(8):
            c = inl_curve(jnp.linspace(0, 1, 256), PROTOTYPE.inl_amp_lsb,
                          seed=chip * 8 + grp)
            spans.append(float(jnp.max(jnp.abs(c))))
    out.append(row("fig18_process_inl_spread",
                   (time.perf_counter() - t0) * 1e6,
                   f"inl_best={min(spans):.2f}|inl_worst={max(spans):.2f}|"
                   f"delta={max(spans) - min(spans):.2f}LSB"))
    return out


if __name__ == "__main__":
    run()
