"""Table I: the "This Work" column reproduced from the behavioural models."""
import dataclasses
import time

from repro.core import GEOMETRY, PROTOTYPE
from repro.core.energy import (compute_density_tops_mm2,
                               macro_throughput_gops, mvm_energy)
from repro.core.macro import OperatingPoint

from .common import row

PAPER = {  # published values for the comparison column
    "memory_density_kb_mm2": 559, "adc_bits": 8.5, "sigma_e_lsb": 0.59,
    "parallelism": 144, "gops_0v65": 3.8, "gops_1v2": 50.3,
    "topsw_0v65": 40.2, "topsw_1v2": 18.6, "tops_mm2_1v2": 0.68,
}


def run():
    t0 = time.perf_counter()
    m065 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=0.65))
    m120 = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=1.2))
    ours = {
        "memory_density_kb_mm2": GEOMETRY.density_kb_mm2,
        "adc_bits": PROTOTYPE.adc_bits,
        "sigma_e_lsb": PROTOTYPE.sigma_e_lsb(),
        "parallelism": PROTOTYPE.n_rows,
        "gops_0v65": macro_throughput_gops(m065),
        "gops_1v2": macro_throughput_gops(m120),
        "topsw_0v65": mvm_energy(m065, 144).tops_per_w,
        "topsw_1v2": mvm_energy(m120, 144).tops_per_w,
        "tops_mm2_1v2": compute_density_tops_mm2(m120),
        "bitwise_topsw_0v65": mvm_energy(m065, 144).bitwise_tops_per_w,
    }
    out = []
    for k, v in ours.items():
        ref = PAPER.get(k)
        derived = f"ours={v:.2f}" + (f"|paper={ref}" if ref is not None
                                     else "")
        out.append(row(f"table1_{k}", (time.perf_counter() - t0) * 1e6,
                       derived))
    return out


if __name__ == "__main__":
    run()
