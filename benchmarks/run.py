"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig2]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (fig1b_schemes, fig2_sqnr, fig7_9_linearity, fig10_adc_bits,
               fig15_17_transfer, fig16_noise, fig18_pvt, fig19_inference,
               fig21_energy, kernel_bench, table1_summary)

MODULES = [
    ("fig1b", fig1b_schemes), ("fig2", fig2_sqnr), ("fig7_9", fig7_9_linearity),
    ("fig10", fig10_adc_bits), ("fig15_17", fig15_17_transfer),
    ("fig16", fig16_noise), ("fig18", fig18_pvt), ("fig19", fig19_inference),
    ("fig21", fig21_energy), ("table1", table1_summary),
    ("kernel", kernel_bench),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on the bench name")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
