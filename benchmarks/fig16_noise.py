"""Fig. 16: (a) RMS σ of output codes under thermal noise (≈0.4 LSB across 8
MVM groups); (b) total computing-error distribution σ_E ≈ 0.59 LSB."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PROTOTYPE
from repro.core.adc import adc_quantize
from repro.core.macro import SimLevel

from .common import row

REPEATS = 50  # paper: each code repeated 50 times


def run():
    out = []
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    v = jnp.linspace(0.0, PROTOTYPE.full_scale(), 256)

    # (a) thermal-only σ per MVM group (different INL seeds = groups)
    sigmas = []
    macro = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.NOISY)
    for grp in range(8):
        codes = jnp.stack([
            adc_quantize(v, macro, key=jax.random.fold_in(key, grp * 100 + r),
                         inl_seed=grp, dequantize=False)
            for r in range(REPEATS)])
        sigmas.append(float(jnp.mean(jnp.std(codes, axis=0))))
    out.append(row("fig16a_thermal_sigma", (time.perf_counter() - t0) * 1e6,
                   f"rms_sigma_lsb={np.mean(sigmas):.3f}|"
                   f"per_group=[{min(sigmas):.3f},{max(sigmas):.3f}]"))

    # (b) total error distribution (noise + INL) vs ideal transfer
    macro_full = dataclasses.replace(PROTOTYPE, sim_level=SimLevel.FULL)
    ideal = adc_quantize(v, PROTOTYPE, dequantize=False)
    errs = []
    for r in range(REPEATS):
        c = adc_quantize(v, macro_full, key=jax.random.fold_in(key, 999 + r),
                         dequantize=False)
        errs.append(np.asarray(c - ideal))
    sigma_e = float(np.std(np.stack(errs)))
    out.append(row("fig16b_total_sigma_e", (time.perf_counter() - t0) * 1e6,
                   f"sigma_e_lsb={sigma_e:.3f}|model={macro_full.sigma_e_lsb():.3f}"))
    return out


if __name__ == "__main__":
    run()
