"""Fig. 21: energy efficiency and clock frequency over 0.65–1.2 V, plus the
DAC's sparsity-dependent energy share (paper: 2.4–14.6 %)."""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import PROTOTYPE
from repro.core.dac import dac_energy_j
from repro.core.energy import macro_throughput_gops, mvm_energy
from repro.core.macro import OperatingPoint

from .common import row


def run():
    out = []
    t0 = time.perf_counter()
    for vdd in (0.65, 0.75, 0.9, 1.05, 1.2):
        m = dataclasses.replace(PROTOTYPE, op=OperatingPoint(vdd=vdd))
        rep = mvm_energy(m, 144)
        out.append(row(f"fig21_vdd{vdd:g}", (time.perf_counter() - t0) * 1e6,
                       f"TOPSW={rep.tops_per_w:.1f}|"
                       f"fclk_MHz={m.clock_hz() / 1e6:.1f}|"
                       f"GOPS={macro_throughput_gops(m):.1f}"))

    # DAC energy share across input sparsity (zero codes charge nothing)
    key = jax.random.PRNGKey(0)
    for sparsity in (0.0, 0.5, 0.9):
        codes = jax.random.randint(key, (4096,), 0, 16).astype(jnp.float32)
        mask = jax.random.uniform(jax.random.fold_in(key, 1),
                                  (4096,)) >= sparsity
        codes = codes * mask
        e_dac = float(dac_energy_j(codes, PROTOTYPE))  # one group conversion
        e_tot = mvm_energy(PROTOTYPE, 144).e_mvm_j
        share = e_dac / (e_tot + e_dac)
        out.append(row(f"fig21_dac_sparsity{sparsity:g}",
                       (time.perf_counter() - t0) * 1e6,
                       f"dac_share={share * 100:.1f}%"))
    return out


if __name__ == "__main__":
    run()
